//! Pure-Rust gradient aggregation fallback.
//!
//! The production path aggregates via the AOT Pallas kernel
//! (`grad_acc.hlo.txt` / `apply_update.hlo.txt`, see `runtime`). This module
//! is (a) the CPU fallback when artifacts are not built, (b) the oracle the
//! integration tests compare the PJRT path against, and (c) a bench subject
//! (chunked and auto-vectorizable vs naive).

/// acc += w * g, elementwise. Chunked for auto-vectorization.
pub fn accumulate(acc: &mut [f32], g: &[f32], w: f32) {
    assert_eq!(acc.len(), g.len());
    const CHUNK: usize = 64;
    let mut ai = acc.chunks_exact_mut(CHUNK);
    let mut gi = g.chunks_exact(CHUNK);
    for (a, gg) in (&mut ai).zip(&mut gi) {
        for k in 0..CHUNK {
            a[k] += w * gg[k];
        }
    }
    for (a, gg) in ai.into_remainder().iter_mut().zip(gi.remainder()) {
        *a += w * gg;
    }
}

/// p -= scale * acc, elementwise (fused SGD apply).
pub fn sgd_apply(params: &mut [f32], acc: &[f32], scale: f32) {
    assert_eq!(params.len(), acc.len());
    const CHUNK: usize = 64;
    let mut pi = params.chunks_exact_mut(CHUNK);
    let mut ai = acc.chunks_exact(CHUNK);
    for (p, a) in (&mut pi).zip(&mut ai) {
        for k in 0..CHUNK {
            p[k] -= scale * a[k];
        }
    }
    for (p, a) in pi.into_remainder().iter_mut().zip(ai.remainder()) {
        *p -= scale * a;
    }
}

/// Mean of `x` gradient slices into `out` (naive bench baseline: extra pass).
pub fn mean_naive(grads: &[&[f32]], out: &mut [f32]) {
    out.fill(0.0);
    for g in grads {
        assert_eq!(g.len(), out.len());
        for (o, v) in out.iter_mut().zip(g.iter()) {
            *o += v;
        }
    }
    let inv = 1.0 / grads.len() as f32;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// x-order update exactly as the coordinator composes it: accumulate x
/// reports then apply with scale = lr/x.
pub fn xorder_update(params: &mut [f32], grads: &[&[f32]], lr: f32, scratch: &mut [f32]) {
    scratch.fill(0.0);
    for g in grads {
        accumulate(scratch, g, 1.0);
    }
    sgd_apply(params, scratch, lr / grads.len() as f32);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_matches_scalar() {
        let mut acc = vec![1.0f32; 131];
        let g: Vec<f32> = (0..131).map(|i| i as f32).collect();
        accumulate(&mut acc, &g, 0.5);
        for (i, &v) in acc.iter().enumerate() {
            assert_eq!(v, 1.0 + 0.5 * i as f32);
        }
    }

    #[test]
    fn sgd_apply_matches_scalar() {
        let mut p = vec![2.0f32; 77];
        let a: Vec<f32> = (0..77).map(|i| (i % 5) as f32).collect();
        sgd_apply(&mut p, &a, 0.1);
        for (i, &v) in p.iter().enumerate() {
            assert!((v - (2.0 - 0.1 * (i % 5) as f32)).abs() < 1e-6);
        }
    }

    #[test]
    fn xorder_equals_mean_sgd() {
        let n = 515;
        let mut p: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let p0 = p.clone();
        let g1: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let g2: Vec<f32> = (0..n).map(|i| 0.5 * i as f32 % 3.0).collect();
        let mut scratch = vec![0.0f32; n];
        xorder_update(&mut p, &[&g1, &g2], 0.2, &mut scratch);
        let mut want = vec![0.0f32; n];
        mean_naive(&[&g1, &g2], &mut want);
        for i in 0..n {
            assert!((p[i] - (p0[i] - 0.2 * want[i])).abs() < 1e-5);
        }
    }
}
