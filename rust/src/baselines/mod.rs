//! The six comparison systems of §V, as driver policies:
//! SSGD, ASGD, Zeno++ [23], LGC [28], Sync-Switch [29], LB-BSP [15]
//! (plus the AR-adapted LGC the paper describes).

use crate::driver::{DriverMode, Policy, PolicyDecision, RoundObs};
use crate::predict::FixedDurationRule;
use crate::sync::SyncMode;
use crate::trace::Arch;

fn base_mode(arch: Arch) -> DriverMode {
    match arch {
        Arch::Ps => DriverMode::Sync(SyncMode::Ssgd),
        Arch::AllReduce => DriverMode::Sync(SyncMode::ArRing { removed: 0, tw_ms: 0.0 }),
    }
}

/// Vanilla bulk-synchronous SGD.
pub struct Ssgd;

impl Policy for Ssgd {
    fn name(&self) -> &'static str {
        "SSGD"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        let mut d = PolicyDecision::simple(base_mode(obs.arch));
        d.lr_rescaled = true; // SSGD runs its tuned LR
        d
    }
}

/// Vanilla asynchronous SGD (PS architecture only in the paper's eval).
/// Runs the *SSGD-tuned* LR — O7's point: the optimal LR shifts and
/// vanilla ASGD doesn't retune.
pub struct Asgd;

impl Policy for Asgd {
    fn name(&self) -> &'static str {
        "ASGD"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        match obs.arch {
            Arch::Ps => PolicyDecision::simple(DriverMode::Sync(SyncMode::Asgd)),
            Arch::AllReduce => {
                let mut d = PolicyDecision::simple(base_mode(obs.arch));
                d.lr_rescaled = true;
                d
            }
        }
    }
}

/// Zeno++ [23]: ASGD with bounded staleness; a validation set filters
/// harmful (stale) gradients before applying, costing extra decision time
/// per update but keeping converged accuracy near-synchronous.
pub struct ZenoPp {
    /// validation overhead per round (scoring candidate gradients)
    pub validate_s: f64,
}

impl Default for ZenoPp {
    fn default() -> Self {
        ZenoPp { validate_s: 0.08 }
    }
}

impl Policy for ZenoPp {
    fn name(&self) -> &'static str {
        "Zeno++"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        let mut d = match obs.arch {
            Arch::Ps => PolicyDecision::simple(DriverMode::Sync(SyncMode::Asgd)),
            Arch::AllReduce => PolicyDecision::simple(base_mode(obs.arch)),
        };
        // bounded staleness + validation filtering: accuracy behaves like
        // high-order sync even though updates are per-report
        d.x_floor = 0.8;
        d.lr_rescaled = true;
        d.overhead_s = self.validate_s;
        // validation consumes the PS's CPU continuously: modeled through
        // the ASGD demand factor already applied by the driver
        d
    }
}

/// Live Gradient Compensation [28]: the K fastest workers' gradients form
/// each update (K = 5 per §V); in AR the N−K slowest workers are removed
/// from the ring and attached to the highest-bandwidth ring worker.
pub struct Lgc {
    pub k: usize,
}

impl Default for Lgc {
    fn default() -> Self {
        Lgc { k: 5 }
    }
}

impl Policy for Lgc {
    fn name(&self) -> &'static str {
        "LGC"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        // K counts gradient sources: only live workers can report, and
        // the driver's AR ring is chained over the live set — so the
        // removal count is live-relative too (dead workers are already
        // outside the ring; counting them here would shrink it twice)
        let live = obs.live_set().count().max(1);
        let k = self.k.min(live);
        let mut d = match obs.arch {
            Arch::Ps => PolicyDecision::simple(DriverMode::FirstK(k)),
            Arch::AllReduce => PolicyDecision::simple(DriverMode::Sync(SyncMode::ArRing {
                removed: live - k.min(live.saturating_sub(1)),
                tw_ms: 0.0,
            })),
        };
        // LGC compensates the K-batch with live-gradient scaling ≈ LR kept
        // proportional; treat as rescaled
        d.lr_rescaled = true;
        d
    }
}

/// Sync-Switch [29]: SSGD normally; a worker straggling continuously for
/// 5 s switches the job to ASGD, reverting when stragglers clear. Does
/// NOT retune the LR after the switch (O7's criticism).
#[derive(Default)]
pub struct SyncSwitch {
    rule: Option<FixedDurationRule>,
}

impl Policy for SyncSwitch {
    fn name(&self) -> &'static str {
        "Sync-Switch"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        let rule = self.rule.get_or_insert_with(|| FixedDurationRule::new(obs.n, 5.0));
        let last: Vec<f64> =
            obs.last_times.iter().map(|&t| if t.is_finite() { t } else { 0.5 }).collect();
        let flags = rule.observe(obs.now, &last);
        let any = flags.iter().any(|&f| f);
        match (obs.arch, any) {
            (Arch::Ps, true) => {
                // switch to ASGD with the SSGD LR (no retuning)
                let mut d = PolicyDecision::simple(DriverMode::Sync(SyncMode::Asgd));
                d.lr_rescaled = false;
                d.overhead_s = 0.02;
                d
            }
            _ => {
                let mut d = PolicyDecision::simple(base_mode(obs.arch));
                d.lr_rescaled = true;
                d.overhead_s = 0.02;
                d
            }
        }
    }
}

/// LB-BSP [15]: stays bulk-synchronous but resizes per-worker batches —
/// if the fastest worker beats the slowest for `window` consecutive
/// rounds, move `delta` samples of batch from slow to fast.
pub struct LbBsp {
    pub window: u64,
    pub delta_frac: f64,
    streak: u64,
    fast: usize,
    slow: usize,
    frac: Vec<f64>,
    /// fractions changed since last shipped to the driver (the driver
    /// keeps its installed vector when `batch_frac` comes back empty, so
    /// unchanged rounds cost no clone)
    dirty: bool,
}

impl Default for LbBsp {
    fn default() -> Self {
        // §V: 8 iterations, 32 samples (of 128 => 0.25)
        LbBsp {
            window: 8,
            delta_frac: 0.25,
            streak: 0,
            fast: 0,
            slow: 0,
            frac: Vec::new(),
            dirty: false,
        }
    }
}

impl Policy for LbBsp {
    fn name(&self) -> &'static str {
        "LB-BSP"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        if self.frac.len() != obs.n {
            self.frac = vec![1.0; obs.n];
            self.dirty = true;
        }
        let last: Vec<f64> =
            obs.last_times.iter().map(|&t| if t.is_finite() { t } else { f64::NAN }).collect();
        // batch resizing only ever shifts load between *live* workers —
        // a dead worker's stale time must not be mistaken for "fast"
        let live_ids: Vec<usize> = obs.live_set().ids();
        if live_ids.len() >= 2 && live_ids.iter().all(|&w| last[w].is_finite()) {
            let fast = *live_ids
                .iter()
                .min_by(|&&a, &&b| last[a].partial_cmp(&last[b]).unwrap())
                .unwrap();
            let slow = *live_ids
                .iter()
                .max_by(|&&a, &&b| last[a].partial_cmp(&last[b]).unwrap())
                .unwrap();
            if fast == self.fast && slow == self.slow && last[slow] > 1.2 * last[fast] {
                self.streak += 1;
            } else {
                self.streak = 0;
                self.fast = fast;
                self.slow = slow;
            }
            if self.streak >= self.window {
                self.streak = 0;
                let d = self.delta_frac.min(self.frac[self.slow] - 0.25);
                if d > 0.0 {
                    self.frac[self.slow] -= d;
                    self.frac[self.fast] += d;
                    self.dirty = true;
                }
            }
        }
        let mut d = PolicyDecision::simple(base_mode(obs.arch));
        d.lr_rescaled = true;
        if self.dirty {
            d.batch_frac = self.frac.clone();
            self.dirty = false;
        }
        d
    }
}

/// Kardam [43]: asynchronous updates where stale gradients are decayed
/// rather than dropped — updates fire per report, and the coordinator's
/// staleness-aware dampening keeps quality above vanilla ASGD. Modeled as
/// ASGD with a quality floor between Zeno++'s filtered path and raw ASGD
/// (decayed stale gradients ≈ partially filtered), plus a small per-round
/// scoring overhead.
pub struct Kardam;

impl Policy for Kardam {
    fn name(&self) -> &'static str {
        "Kardam"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        let mut d = match obs.arch {
            Arch::Ps => PolicyDecision::simple(DriverMode::Sync(SyncMode::Asgd)),
            Arch::AllReduce => PolicyDecision::simple(base_mode(obs.arch)),
        };
        d.x_floor = 0.5; // dampening recovers some, not all, quality
        d.lr_rescaled = true;
        d.overhead_s = 0.03;
        d
    }
}

/// DSSP [18]: stale-synchronous parallel with a dynamically adjusted
/// staleness threshold — here the threshold maps onto the x-order ladder:
/// mild predicted skew widens the allowed staleness (smaller x), uniform
/// times tighten it back to full synchrony.
#[derive(Default)]
pub struct Dssp {
    threshold: usize,
}

impl Policy for Dssp {
    fn name(&self) -> &'static str {
        "DSSP"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        let last: Vec<f64> =
            obs.last_times.iter().map(|&t| if t.is_finite() { t } else { 0.5 }).collect();
        let devs = crate::predict::deviation_ratios(&last);
        let worst = devs.iter().cloned().fold(0.0, f64::max);
        // dynamic threshold: grow while skew persists, shrink when calm
        if worst > 0.4 {
            self.threshold = (self.threshold + 1).min(obs.n.saturating_sub(2));
        } else if worst < 0.2 && self.threshold > 0 {
            self.threshold -= 1;
        }
        let mode = if self.threshold == 0 {
            base_mode(obs.arch)
        } else {
            match obs.arch {
                Arch::Ps => DriverMode::Sync(SyncMode::StaticX(obs.n - self.threshold)),
                Arch::AllReduce => DriverMode::Sync(SyncMode::ArRing {
                    removed: self.threshold,
                    tw_ms: 60.0,
                }),
            }
        };
        let mut d = PolicyDecision::simple(mode);
        d.lr_rescaled = false; // DSSP does not retune the LR (O7)
        d
    }
}

/// All baselines for an architecture, as labeled factories (§V runs SSGD,
/// ASGD, Sync-Switch, LB-BSP, LGC, Zeno++ on PS; SSGD, LB-BSP, LGC on AR).
pub fn baseline_names(arch: Arch) -> Vec<&'static str> {
    match arch {
        Arch::Ps => vec!["SSGD", "ASGD", "Sync-Switch", "LB-BSP", "LGC", "Zeno++"],
        Arch::AllReduce => vec!["SSGD", "LB-BSP", "LGC"],
    }
}

/// Validate a whole system list up-front. Sweep cells run on worker
/// threads where an unknown name is a panic, not an `Err` — callers
/// check the full list here before spawning anything.
pub fn validate_systems<S: AsRef<str>>(systems: &[S]) -> crate::Result<()> {
    for s in systems {
        make_policy(s.as_ref())?;
    }
    Ok(())
}

/// Instantiate a policy (baseline or STAR variant) by its §V name.
/// Unknown names are an *error*, not an abort: experiment subcommands
/// surface it through `exp::dispatch` so a typoed `--system` prints the
/// known set instead of panicking mid-sweep.
pub fn make_policy(name: &str) -> crate::Result<Box<dyn Policy>> {
    use crate::decide::DeciderKind;
    Ok(match name {
        "SSGD" => Box::new(Ssgd),
        "ASGD" => Box::new(Asgd),
        "Zeno++" => Box::new(ZenoPp::default()),
        "LGC" => Box::new(Lgc::default()),
        "Sync-Switch" => Box::new(SyncSwitch::default()),
        "LB-BSP" => Box::new(LbBsp::default()),
        "Kardam" => Box::new(Kardam),
        "DSSP" => Box::new(Dssp::default()),
        "STAR-H" => Box::new(crate::star::Star::new(DeciderKind::Heuristic)),
        "STAR-ML" => Box::new(crate::star::Star::new(DeciderKind::Ml)),
        "STAR-" => Box::new(crate::star::Star::new(DeciderKind::Early)),
        other => {
            // ablations: STAR/SP etc (heuristic kind, per §V-C)
            for (n, abl) in crate::star::ablations() {
                if n == other {
                    return Ok(Box::new(crate::star::Star::with_ablation(
                        DeciderKind::Heuristic,
                        abl,
                        n,
                    )));
                }
            }
            anyhow::bail!(
                "unknown system {other:?} (known: SSGD, ASGD, Zeno++, LGC, Sync-Switch, \
                 LB-BSP, Kardam, DSSP, STAR-H, STAR-ML, STAR-, and the STAR/* ablations)"
            )
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ZOO;

    /// all-live mask large enough for every test's worker count
    const LIVE: [bool; 16] = [true; 16];

    fn obs<'a>(last: &'a [f64], pred: &'a [f64], flags: &'a [bool], arch: Arch) -> RoundObs<'a> {
        RoundObs {
            job: 0,
            n: last.len(),
            arch,
            spec: &ZOO[0],
            step: 100,
            progress: 50.0,
            now: 50.0,
            predicted_times: pred,
            last_times: last,
            value: 40.0,
            predicted_stragglers: flags,
            live: &LIVE[..last.len()],
        }
    }

    #[test]
    fn ssgd_always_sync() {
        let p = vec![0.3, 3.0, 0.3, 0.3];
        let f = vec![false; 4];
        let d = Ssgd.decide(&obs(&p, &p, &f, Arch::Ps));
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Ssgd));
    }

    #[test]
    fn asgd_always_async_on_ps() {
        let p = vec![0.3; 4];
        let f = vec![false; 4];
        let d = Asgd.decide(&obs(&p, &p, &f, Arch::Ps));
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Asgd));
        assert!(!d.lr_rescaled, "vanilla ASGD keeps the SSGD LR (O7)");
    }

    #[test]
    fn zeno_has_floor_and_overhead() {
        let p = vec![0.3; 4];
        let f = vec![false; 4];
        let d = ZenoPp::default().decide(&obs(&p, &p, &f, Arch::Ps));
        assert!(d.x_floor > 0.5);
        assert!(d.overhead_s > 0.0);
    }

    #[test]
    fn lgc_first_k_on_ps_ring_removal_on_ar() {
        let p = vec![0.3; 8];
        let f = vec![false; 8];
        let d = Lgc::default().decide(&obs(&p, &p, &f, Arch::Ps));
        assert_eq!(d.mode, DriverMode::FirstK(5));
        let d2 = Lgc::default().decide(&obs(&p, &p, &f, Arch::AllReduce));
        assert!(matches!(d2.mode, DriverMode::Sync(SyncMode::ArRing { removed: 3, .. })));
    }

    #[test]
    fn sync_switch_needs_persistent_straggler() {
        let mut ss = SyncSwitch::default();
        let slow = vec![0.3, 0.3, 0.3, 1.0];
        let f = vec![false; 4];
        // first sighting at t=50: not yet 5 s of straggling
        let d1 = ss.decide(&obs(&slow, &slow, &f, Arch::Ps));
        assert_eq!(d1.mode, DriverMode::Sync(SyncMode::Ssgd));
        // 6 s later: switch, with unscaled LR
        let mut o = obs(&slow, &slow, &f, Arch::Ps);
        o.now = 56.0;
        let d2 = ss.decide(&o);
        assert_eq!(d2.mode, DriverMode::Sync(SyncMode::Asgd));
        assert!(!d2.lr_rescaled);
        // straggler clears: revert to SSGD
        let ok = vec![0.3; 4];
        let mut o3 = obs(&ok, &ok, &f, Arch::Ps);
        o3.now = 57.0;
        let d3 = ss.decide(&o3);
        assert_eq!(d3.mode, DriverMode::Sync(SyncMode::Ssgd));
    }

    #[test]
    fn lb_bsp_shifts_batches_after_streak() {
        let mut lb = LbBsp::default();
        let times = vec![0.3, 0.3, 0.3, 0.9];
        let f = vec![false; 4];
        let mut d = PolicyDecision::simple(DriverMode::Sync(SyncMode::Ssgd));
        // mirror the driver: an empty batch_frac keeps the installed vector
        let mut installed: Vec<f64> = Vec::new();
        for i in 0..=9 {
            let mut o = obs(&times, &times, &f, Arch::Ps);
            o.now = 50.0 + i as f64;
            d = lb.decide(&o);
            if !d.batch_frac.is_empty() {
                installed = d.batch_frac.clone();
            }
        }
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Ssgd));
        assert!(installed[3] < 1.0, "slow worker sheds batch: {installed:?}");
        assert!(installed[0] > 1.0 || installed.iter().sum::<f64>() > 3.99);
        // total batch conserved
        let total: f64 = installed.iter().sum();
        assert!((total - 4.0).abs() < 1e-9);
    }

    #[test]
    fn factory_builds_all_names() {
        for arch in [Arch::Ps, Arch::AllReduce] {
            for n in baseline_names(arch) {
                let p = make_policy(n).unwrap();
                assert_eq!(p.name(), n);
            }
        }
        for n in ["STAR-H", "STAR-ML", "STAR-", "STAR/SP", "STAR/Tree", "Kardam", "DSSP"] {
            let p = make_policy(n).unwrap();
            assert_eq!(p.name(), n);
        }
    }

    #[test]
    fn factory_errors_on_unknown_instead_of_aborting() {
        let err = make_policy("NotASystem").err().expect("unknown name must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown system"), "{msg}");
        assert!(msg.contains("SSGD"), "error should list known systems: {msg}");
    }

    #[test]
    fn lgc_clamps_k_to_live_workers() {
        let p = vec![0.3; 8];
        let f = vec![false; 8];
        let mut o = obs(&p, &p, &f, Arch::Ps);
        let mut live = vec![true; 8];
        live[0] = false;
        live[1] = false;
        live[2] = false;
        live[3] = false; // 4 live < K=5
        o.live = &live;
        let d = Lgc::default().decide(&o);
        assert_eq!(d.mode, DriverMode::FirstK(4), "K must shrink to the live count");
        // AR: the driver's ring is live-relative, so the removal count is
        // too — with 4 live and K clamped to 4 the ring keeps 3 (the same
        // "always remove one" shape as the fault-free k >= n case), NOT
        // n - k = 4 removed of 4 live
        let mut o2 = obs(&p, &p, &f, Arch::AllReduce);
        o2.live = &live;
        let d2 = Lgc::default().decide(&o2);
        assert!(
            matches!(d2.mode, DriverMode::Sync(SyncMode::ArRing { removed: 1, .. })),
            "{:?}",
            d2.mode
        );
    }

    #[test]
    fn lb_bsp_ignores_dead_workers_when_picking_fast_and_slow() {
        let mut lb = LbBsp::default();
        // worker 3 is slow but DEAD; among the living, 2 is slowest
        let times = vec![0.3, 0.3, 0.6, 0.9];
        let f = vec![false; 4];
        let mut live = vec![true; 4];
        live[3] = false;
        let mut installed: Vec<f64> = Vec::new();
        for i in 0..=9 {
            let mut o = obs(&times, &times, &f, Arch::Ps);
            o.live = &live;
            o.now = 50.0 + i as f64;
            let d = lb.decide(&o);
            if !d.batch_frac.is_empty() {
                installed = d.batch_frac.clone();
            }
        }
        assert!(installed[2] < 1.0, "live slow worker sheds batch: {installed:?}");
        assert!(
            (installed[3] - 1.0).abs() < 1e-12,
            "dead worker's batch untouched: {installed:?}"
        );
    }

    #[test]
    fn kardam_is_dampened_asgd() {
        let p = vec![0.3; 4];
        let f = vec![false; 4];
        let d = Kardam.decide(&obs(&p, &p, &f, Arch::Ps));
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Asgd));
        assert!(d.x_floor > 0.0 && d.x_floor < 0.8, "between ASGD and Zeno++");
    }

    #[test]
    fn dssp_threshold_widens_then_recovers() {
        let mut dssp = Dssp::default();
        let f = vec![false; 8];
        let skewed = vec![0.3, 0.3, 0.3, 0.3, 0.3, 0.3, 0.3, 0.9];
        // persistent skew widens the staleness window (x shrinks)
        let mut d = dssp.decide(&obs(&skewed, &skewed, &f, Arch::Ps));
        d = dssp.decide(&obs(&skewed, &skewed, &f, Arch::Ps));
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::StaticX(6)));
        assert!(!d.lr_rescaled, "DSSP does not retune LR (O7)");
        // calm times tighten back toward synchrony
        let calm = vec![0.3; 8];
        let d2 = dssp.decide(&obs(&calm, &calm, &f, Arch::Ps));
        assert_eq!(d2.mode, DriverMode::Sync(SyncMode::StaticX(7)));
        let d3 = dssp.decide(&obs(&calm, &calm, &f, Arch::Ps));
        assert_eq!(d3.mode, DriverMode::Sync(SyncMode::Ssgd));
    }
}
