//! Discrete-event simulation engine: a monotonic clock + a stable
//! binary-heap event queue (ties broken by insertion sequence so runs are
//! bit-reproducible). The trace driver schedules job arrivals, iteration
//! completions, and evaluation ticks through this.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

/// An event due at `at`; `seq` makes ordering total and FIFO among ties.
struct Entry<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue + clock.
pub struct Engine<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
    /// high-water mark of `heap.len()` — the queue-depth figure the
    /// driver's throughput benchmarks report (`BENCH_driver.json`)
    peak: usize,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0, peak: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Highest number of events ever simultaneously pending.
    pub fn peak_pending(&self) -> usize {
        self.peak
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at.is_finite(), "non-finite event time");
        let at = if at < self.now { self.now } else { at };
        self.heap.push(Entry { at, seq: self.seq, event });
        self.seq += 1;
        if self.heap.len() > self.peak {
            self.peak = self.heap.len();
        }
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now, "time went backwards");
        self.now = e.at;
        self.processed += 1;
        Some((e.at, e.event))
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut e = Engine::new();
        e.schedule_at(5.0, "c");
        e.schedule_at(1.0, "a");
        e.schedule_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| e.next().map(|(_, x)| x)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(e.now(), 5.0);
        assert_eq!(e.events_processed(), 3);
    }

    #[test]
    fn fifo_among_ties() {
        let mut e = Engine::new();
        for i in 0..10 {
            e.schedule_at(2.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| e.next().map(|(_, x)| x)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_monotonic_even_with_past_schedules() {
        let mut e = Engine::new();
        e.schedule_at(10.0, "x");
        e.next();
        e.schedule_at(3.0, "past"); // clamped to now=10
        let (t, _) = e.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut e = Engine::new();
        e.schedule_at(4.0, "first");
        e.next();
        e.schedule_in(2.5, "second");
        let (t, _) = e.next().unwrap();
        assert!((t - 6.5).abs() < 1e-12);
    }

    #[test]
    fn peak_pending_tracks_high_water_mark() {
        let mut e = Engine::new();
        assert_eq!(e.peak_pending(), 0);
        for i in 0..5 {
            e.schedule_at(i as f64, i);
        }
        assert_eq!(e.peak_pending(), 5);
        e.next();
        e.next();
        // draining does not lower the high-water mark
        assert_eq!(e.peak_pending(), 5);
        e.schedule_at(9.0, 99);
        assert_eq!(e.peak_pending(), 5, "4 pending < peak of 5");
        for i in 0..3 {
            e.schedule_at(10.0 + i as f64, i);
        }
        assert_eq!(e.peak_pending(), 7);
    }

    #[test]
    fn heap_scales() {
        let mut e = Engine::new();
        let mut rng = crate::simrng::Rng::seeded(1);
        for i in 0..10_000 {
            e.schedule_at(rng.range(0.0, 1e6), i);
        }
        let mut last = 0.0;
        while let Some((t, _)) = e.next() {
            assert!(t >= last);
            last = t;
        }
    }
}
