//! Discrete-event simulation engine: a monotonic clock + a stable
//! binary-heap event queue (ties broken by insertion sequence so runs are
//! bit-reproducible). The trace driver schedules job arrivals, iteration
//! completions, and evaluation ticks through this.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

/// An event due at `at`; `seq` makes ordering total and FIFO among ties.
struct Entry<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue + clock.
pub struct Engine<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
    /// high-water mark of `heap.len()` — the queue-depth figure the
    /// driver's throughput benchmarks report (`BENCH_driver.json`)
    peak: usize,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0, peak: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Highest number of events ever simultaneously pending.
    pub fn peak_pending(&self) -> usize {
        self.peak
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at.is_finite(), "non-finite event time");
        let at = if at < self.now { self.now } else { at };
        self.heap.push(Entry { at, seq: self.seq, event });
        self.seq += 1;
        if self.heap.len() > self.peak {
            self.peak = self.heap.len();
        }
    }

    /// Schedule `event` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now, "time went backwards");
        self.now = e.at;
        self.processed += 1;
        Some((e.at, e.event))
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }
}

/// A partitioned event queue: `n` independent sub-heaps sharing one
/// clock and one global insertion sequence.
///
/// Pop order is **provably byte-identical** to a single [`Engine`]
/// regardless of how events are assigned to shards: `seq` is unique
/// across shards, so `(at, seq)` is a strict total order; each shard's
/// head is its minimum, hence the minimum over the ≤`n` heads is the
/// global minimum — the same entry a global heap would pop. What
/// sharding buys is locality: each push/pop sifts a heap `n×` smaller
/// (the hot cache-resident window at 10⁶ pending events), and the
/// linear head scan is negligible for the shard counts used here
/// (≤ [`MAX_SHARDS`]).
pub struct ShardedEngine<E> {
    shards: Vec<BinaryHeap<Entry<E>>>,
    now: SimTime,
    seq: u64,
    processed: u64,
    /// total entries across shards (kept so `pending()` stays O(1))
    pending: usize,
    /// high-water mark of total pending across all shards — identical
    /// to the global heap's figure by the equivalence argument above
    peak: usize,
}

/// Upper bound on shard count: keeps the `next()` head scan trivially
/// cheap while still cutting a 10⁶-entry heap into ≲16k-entry shards.
pub const MAX_SHARDS: usize = 64;

impl<E> ShardedEngine<E> {
    /// `nshards` is clamped to `1..=MAX_SHARDS`.
    pub fn new(nshards: usize) -> Self {
        let n = nshards.clamp(1, MAX_SHARDS);
        ShardedEngine {
            shards: (0..n).map(|_| BinaryHeap::new()).collect(),
            now: 0.0,
            seq: 0,
            processed: 0,
            pending: 0,
            peak: 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Highest number of events ever simultaneously pending (summed
    /// across shards).
    pub fn peak_pending(&self) -> usize {
        self.peak
    }

    /// Schedule `event` on `shard` at absolute time `at` (clamped to
    /// now). Shard assignment never affects pop order — see the type
    /// docs — so callers may pick any stable key.
    pub fn schedule_at(&mut self, shard: usize, at: SimTime, event: E) {
        debug_assert!(at.is_finite(), "non-finite event time");
        let at = if at < self.now { self.now } else { at };
        let shard = shard % self.shards.len();
        self.shards[shard].push(Entry { at, seq: self.seq, event });
        self.seq += 1;
        self.pending += 1;
        if self.pending > self.peak {
            self.peak = self.pending;
        }
    }

    /// Schedule `event` on `shard` after a relative delay.
    pub fn schedule_in(&mut self, shard: usize, delay: SimTime, event: E) {
        self.schedule_at(shard, self.now + delay.max(0.0), event);
    }

    /// Index of the shard holding the globally next entry by
    /// `(at, seq)`, or None when empty.
    fn next_shard(&self) -> Option<usize> {
        let mut best: Option<(usize, SimTime, u64)> = None;
        for (i, h) in self.shards.iter().enumerate() {
            if let Some(e) = h.peek() {
                let better = match best {
                    None => true,
                    // `at` is finite (asserted at schedule time), so the
                    // plain comparisons agree with Entry's total order
                    Some((_, bat, bseq)) => e.at < bat || (e.at == bat && e.seq < bseq),
                };
                if better {
                    best = Some((i, e.at, e.seq));
                }
            }
        }
        best.map(|(i, _, _)| i)
    }

    /// Pop the globally next event, advancing the clock to its time.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        let i = self.next_shard()?;
        let e = self.shards[i].pop().expect("next_shard points at a non-empty shard");
        debug_assert!(e.at >= self.now, "time went backwards");
        self.now = e.at;
        self.processed += 1;
        self.pending -= 1;
        Some((e.at, e.event))
    }

    /// Peek the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.next_shard().and_then(|i| self.shards[i].peek()).map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut e = Engine::new();
        e.schedule_at(5.0, "c");
        e.schedule_at(1.0, "a");
        e.schedule_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| e.next().map(|(_, x)| x)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(e.now(), 5.0);
        assert_eq!(e.events_processed(), 3);
    }

    #[test]
    fn fifo_among_ties() {
        let mut e = Engine::new();
        for i in 0..10 {
            e.schedule_at(2.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| e.next().map(|(_, x)| x)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_monotonic_even_with_past_schedules() {
        let mut e = Engine::new();
        e.schedule_at(10.0, "x");
        e.next();
        e.schedule_at(3.0, "past"); // clamped to now=10
        let (t, _) = e.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut e = Engine::new();
        e.schedule_at(4.0, "first");
        e.next();
        e.schedule_in(2.5, "second");
        let (t, _) = e.next().unwrap();
        assert!((t - 6.5).abs() < 1e-12);
    }

    #[test]
    fn peak_pending_tracks_high_water_mark() {
        let mut e = Engine::new();
        assert_eq!(e.peak_pending(), 0);
        for i in 0..5 {
            e.schedule_at(i as f64, i);
        }
        assert_eq!(e.peak_pending(), 5);
        e.next();
        e.next();
        // draining does not lower the high-water mark
        assert_eq!(e.peak_pending(), 5);
        e.schedule_at(9.0, 99);
        assert_eq!(e.peak_pending(), 5, "4 pending < peak of 5");
        for i in 0..3 {
            e.schedule_at(10.0 + i as f64, i);
        }
        assert_eq!(e.peak_pending(), 7);
    }

    /// Property test (hand-rolled, seeded — no external proptest dep):
    /// random interleavings of schedules and pops drain in identical
    /// order from a global heap and from sharded engines at 1, 2, and 8
    /// partitions, for arbitrary shard assignments.
    #[test]
    fn sharded_pop_order_identical_across_partitions() {
        for case in 0..50u64 {
            let mut rng = crate::simrng::Rng::seeded(0x5AA3D + case);
            // script: Some((shard_key, at)) = schedule, None = pop
            let mut script: Vec<Option<(usize, f64)>> = Vec::new();
            for _ in 0..rng.usize(10, 400) {
                if rng.chance(0.6) {
                    script.push(Some((rng.usize(0, 63), rng.range(0.0, 1e4))));
                } else {
                    script.push(None);
                }
            }
            let run_global = |script: &[Option<(usize, f64)>]| {
                let mut e = Engine::new();
                let mut popped = Vec::new();
                for (id, step) in script.iter().enumerate() {
                    match step {
                        Some((_, at)) => e.schedule_at(*at, id),
                        None => popped.push(e.next().map(|(t, id)| (t.to_bits(), id))),
                    }
                }
                while let Some((t, id)) = e.next() {
                    popped.push(Some((t.to_bits(), id)));
                }
                (popped, e.events_processed(), e.peak_pending())
            };
            let run_sharded = |script: &[Option<(usize, f64)>], n: usize| {
                let mut e = ShardedEngine::new(n);
                let mut popped = Vec::new();
                for (id, step) in script.iter().enumerate() {
                    match step {
                        Some((shard, at)) => e.schedule_at(*shard, *at, id),
                        None => popped.push(e.next().map(|(t, id)| (t.to_bits(), id))),
                    }
                }
                while let Some((t, id)) = e.next() {
                    popped.push(Some((t.to_bits(), id)));
                }
                (popped, e.events_processed(), e.peak_pending())
            };
            let want = run_global(&script);
            for n in [1, 2, 8] {
                let got = run_sharded(&script, n);
                assert_eq!(got, want, "case {case}: {n}-shard drain diverged from global heap");
            }
        }
    }

    #[test]
    fn sharded_clamps_past_schedules_and_counts_peak_in_total() {
        let mut e = ShardedEngine::new(4);
        e.schedule_at(0, 10.0, "x");
        e.next();
        e.schedule_at(3, 3.0, "past"); // clamped to now=10 like Engine
        assert_eq!(e.peek_time(), Some(10.0));
        let (t, _) = e.next().unwrap();
        assert_eq!(t, 10.0);
        // peak is total across shards, not per-shard
        let mut e = ShardedEngine::new(2);
        for i in 0..6 {
            e.schedule_at(i % 2, i as f64, i);
        }
        assert_eq!(e.peak_pending(), 6);
        assert_eq!(e.pending(), 6);
        e.next();
        assert_eq!(e.pending(), 5);
        assert_eq!(e.peak_pending(), 6);
    }

    #[test]
    fn sharded_shard_count_is_clamped() {
        assert_eq!(ShardedEngine::<()>::new(0).num_shards(), 1);
        assert_eq!(ShardedEngine::<()>::new(7).num_shards(), 7);
        assert_eq!(ShardedEngine::<()>::new(10_000).num_shards(), MAX_SHARDS);
    }

    #[test]
    fn heap_scales() {
        let mut e = Engine::new();
        let mut rng = crate::simrng::Rng::seeded(1);
        for i in 0..10_000 {
            e.schedule_at(rng.range(0.0, 1e6), i);
        }
        let mut last = 0.0;
        while let Some((t, _)) = e.next() {
            assert!(t >= last);
            last = t;
        }
    }
}
