//! Statistics substrate: percentiles, CDF/PDF summaries, correlation,
//! histograms, online accumulators. Every figure in the paper is a CDF,
//! PDF, or percentile band — this module regenerates those summaries.

/// Online mean/variance (Welford) + min/max.
#[derive(Clone, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a copy of the data (nearest-rank with linear interp).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile assuming `xs` is already sorted ascending.
pub fn percentile_sorted(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    if xs.len() == 1 {
        return xs[0];
    }
    let rank = p / 100.0 * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    xs[lo] * (1.0 - frac) + xs[hi] * frac
}

/// Incrementally sorted sample stream: `push` keeps the backing vec
/// ordered with a binary-search insert, so a percentile read is a plain
/// [`percentile_sorted`] lookup instead of [`percentile`]'s
/// clone-and-sort. The dispatch fabric reads a p99 straggler threshold
/// after every completed cell, which made the batch form O(n log n)
/// *per completion*. Both paths funnel into [`percentile_sorted`] over
/// identically sorted data, so they agree exactly.
#[derive(Clone, Debug, Default)]
pub struct SortedStream {
    sorted: Vec<f64>,
}

impl SortedStream {
    pub fn push(&mut self, x: f64) {
        let at = self.sorted.partition_point(|&y| y <= x);
        self.sorted.insert(at, x);
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        percentile_sorted(&self.sorted, p)
    }

    pub fn as_sorted(&self) -> &[f64] {
        &self.sorted
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// A CDF summary sampled at the given x grid: returns P(X <= x) per point.
pub fn cdf_at(xs: &[f64], grid: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    grid.iter()
        .map(|&g| {
            let cnt = v.partition_point(|&x| x <= g);
            cnt as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Evenly-spaced grid over [lo, hi] with n points.
pub fn grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n).map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64).collect()
}

/// Histogram with `bins` equal-width bins over [lo, hi]; returns counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || !x.is_finite() {
            continue;
        }
        let mut b = ((x - lo) / w) as usize;
        if b >= bins {
            b = bins - 1; // clamp x == hi (and overshoot) into last bin
        }
        h[b] += 1;
    }
    h
}

/// Number of distinct occupied bins when [0, max] is split into `bins`
/// equal bins — the paper's Fig 6 statistic for worker iteration times.
pub fn occupied_bins(xs: &[f64], bins: usize) -> usize {
    if xs.is_empty() {
        return 0;
    }
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if hi <= 0.0 {
        return 1;
    }
    histogram(xs, 0.0, hi, bins).iter().filter(|&&c| c > 0).count()
}

/// Summary band used all over §V: mean with 1st and 99th percentiles.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Band {
    pub mean: f64,
    pub p1: f64,
    pub p99: f64,
}

pub fn band(xs: &[f64]) -> Band {
    Band { mean: mean(xs), p1: percentile(xs, 1.0), p99: percentile(xs, 99.0) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut o = Online::new();
        for &x in &xs {
            o.add(x);
        }
        assert!((o.mean() - 4.0).abs() < 1e-12);
        assert!((o.min - 1.0).abs() < 1e-12);
        assert!((o.max - 10.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((o.var() - var).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        let mut rng = crate::simrng::Rng::seeded(1);
        let x: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        assert!(pearson(&x, &y).abs() < 0.05);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [1.0, 2.0, 2.0, 5.0];
        let g = grid(0.0, 6.0, 7);
        let c = cdf_at(&xs, &g);
        for w in c.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(*c.last().unwrap(), 1.0);
    }

    #[test]
    fn histogram_clamps_max() {
        // 0.5 sits on the boundary and goes to the upper bin; 1.0 == hi is
        // clamped into the last bin
        let h = histogram(&[0.0, 0.5, 1.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![1, 2]);
        let h2 = histogram(&[0.0, 0.49, 1.0], 0.0, 1.0, 2);
        assert_eq!(h2, vec![2, 1]);
    }

    #[test]
    fn occupied_bins_spread() {
        // all equal -> last bin only
        assert_eq!(occupied_bins(&[3.0, 3.0, 3.0], 8), 1);
        // spread evenly over 8 bins (bin centers)
        let xs: Vec<f64> = (0..8).map(|i| i as f64 + 0.5).collect();
        assert_eq!(occupied_bins(&xs, 8), 8);
    }

    #[test]
    fn sorted_stream_matches_batch_percentile_on_random_sequences() {
        let mut rng = crate::simrng::Rng::seeded(7);
        let mut stream = SortedStream::default();
        let mut batch: Vec<f64> = Vec::new();
        for _ in 0..500 {
            let x = rng.normal() * 3.0 + rng.f64() * 10.0;
            stream.push(x);
            batch.push(x);
            for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
                // bit-exact, not just close: both sides interpolate over
                // the same sorted data
                assert_eq!(stream.percentile(p), percentile(&batch, p), "p{p} after {} samples", batch.len());
            }
        }
        assert_eq!(stream.len(), batch.len());
        assert!(stream.as_sorted().windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn band_orders() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let b = band(&xs);
        assert!(b.p1 < b.mean && b.mean < b.p99);
    }
}
