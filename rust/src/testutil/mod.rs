//! Property-testing substrate (proptest is unavailable offline): seeded
//! random-case generation with failing-seed reporting and a lightweight
//! shrink pass for integer-vector inputs.

use crate::simrng::Rng;

/// Run `cases` random property checks. `gen` builds an input from the RNG,
/// `prop` returns Err(msg) on violation. Panics with the seed and input
/// debug form on the first failure so the case is replayable.
pub fn forall<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base_seed = match std::env::var("STAR_PROP_SEED") {
        Ok(v) => v.parse().unwrap_or(0xBADC0DE),
        Err(_) => 0xBADC0DE,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  \
                 violation: {msg}\n  input: {input:#?}\n  \
                 replay with STAR_PROP_SEED={base_seed}"
            );
        }
    }
}

/// Shrinking helper for Vec<T> inputs: tries removing chunks while the
/// property still fails, returning a (locally) minimal failing input.
pub fn shrink_vec<T: Clone, P>(mut input: Vec<T>, mut fails: P) -> Vec<T>
where
    P: FnMut(&[T]) -> bool,
{
    debug_assert!(fails(&input));
    let mut chunk = input.len() / 2;
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= input.len() {
            let mut candidate = input.clone();
            candidate.drain(i..i + chunk);
            if !candidate.is_empty() && fails(&candidate) {
                input = candidate;
            } else {
                i += chunk;
            }
        }
        chunk /= 2;
    }
    input
}

/// Assert two floats are close (absolute + relative tolerance).
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a as f64, $b as f64, $tol as f64);
        let scale = a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() <= tol * scale,
            "assert_close failed: {} vs {} (tol {})",
            a,
            b,
            tol
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs-nonneg", 200, |r| r.normal(), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failure() {
        forall("always-fails", 5, |r| r.int(0, 10), |_| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_small_case() {
        // fails iff the vector contains a 7
        let input = vec![1, 2, 7, 3, 4, 5, 7, 9];
        let min = shrink_vec(input, |v| v.contains(&7));
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn close_macro() {
        assert_close!(1.0, 1.0 + 1e-9, 1e-6);
    }
}
