//! Synchronization modes (§IV-B): SSGD, ASGD, static-x-order,
//! dynamic-x-order, and the AR-ring family (x removed stragglers attached
//! to waiting parents). This module defines the modes and their *round
//! semantics*: given per-worker iteration durations, when does each
//! parameter update fire, from how many gradient reports, and at what
//! staleness — consumed by both the simulator driver and the real PJRT
//! training loop in `examples/e2e_train.rs`.
//!
//! Conservation contract (pinned by `tests/proptest_coordinator.rs`):
//! every gradient report is applied in exactly one update — except the
//! AR ring, where a removed straggler that misses the parent wait is
//! *explicitly* dropped, and the driver-level first-K rule
//! ([`crate::driver::first_k_split`]), which drops everything after the
//! K-th arrival. Under fault injection the driver evaluates these round
//! rules over the *live* membership through the shared
//! [`crate::driver::membership`] layer (DESIGN.md §7/§8); the planner
//! here stays membership-agnostic — callers pass the durations of
//! whichever workers are actually in the round.

use crate::simrng::Rng;

/// A synchronization mode. `Copy` on purpose: modes are read on the
/// driver's per-event dispatch path, and a copyable mode is what keeps
/// that path free of `.clone()` calls (DESIGN.md §3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyncMode {
    /// bulk-synchronous: one update from all N workers
    Ssgd,
    /// fully asynchronous: one update per gradient report
    Asgd,
    /// update per x gradient reports, arrival order (1 < x < N)
    StaticX(usize),
    /// update per predicted-iteration-time cluster (§IV-B)
    DynamicX,
    /// ring all-reduce with `removed` stragglers re-attached to parents
    /// that wait `tw_ms` after their own computation (AR architecture)
    ArRing { removed: usize, tw_ms: f64 },
}

impl SyncMode {
    pub fn name(&self) -> String {
        match self {
            SyncMode::Ssgd => "SSGD".into(),
            SyncMode::Asgd => "ASGD".into(),
            SyncMode::StaticX(x) => format!("{x}-order"),
            SyncMode::DynamicX => "dynamic-x".into(),
            SyncMode::ArRing { removed, tw_ms } => format!("ring(-{removed},{tw_ms}ms)"),
        }
    }

    /// Allocation-free label (drops the parameters of [`SyncMode::name`])
    /// for hot logging/stats paths.
    pub fn static_name(&self) -> &'static str {
        match self {
            SyncMode::Ssgd => "SSGD",
            SyncMode::Asgd => "ASGD",
            SyncMode::StaticX(_) => "static-x",
            SyncMode::DynamicX => "dynamic-x",
            SyncMode::ArRing { .. } => "ring",
        }
    }

    /// Is this one of the async-family modes that changes the effective
    /// batch (and thus needs LR rescaling per §IV-C / O7)?
    pub fn shrinks_batch(&self, n: usize) -> bool {
        match self {
            SyncMode::Ssgd => false,
            SyncMode::Asgd => n > 1,
            SyncMode::StaticX(x) => *x < n,
            SyncMode::DynamicX => true,
            SyncMode::ArRing { removed, .. } => *removed > 0,
        }
    }
}

/// LR scaling on mode switch (§IV-C): r_new = (M_new / M) * r_ssgd where
/// M_new = y·M/N and y = reports per update.
pub fn scaled_lr(base_lr: f64, reports: usize, n: usize) -> f64 {
    base_lr * reports as f64 / n as f64
}

/// One parameter update within a round.
#[derive(Clone, Debug, PartialEq)]
pub struct Update {
    /// offset from round start, seconds
    pub at: f64,
    /// worker ranks whose gradients form this update
    pub members: Vec<usize>,
    /// updates applied earlier in the round (gradient staleness proxy)
    pub staleness: f64,
}

/// The schedule of one training round under a mode.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    pub updates: Vec<Update>,
    /// when each worker becomes free to start its next iteration
    /// (offset from round start)
    pub worker_end: Vec<f64>,
    /// wall span of the round
    pub span: f64,
    /// gradient reports that made it into some update this round
    pub reports_used: usize,
}

/// Build the round schedule for `mode` given actual per-worker durations
/// `times` (seconds) and `predicted` durations (used only by DynamicX for
/// grouping, mirroring §IV-B where clusters form on *predicted* times).
pub fn plan_round(mode: &SyncMode, times: &[f64], predicted: &[f64]) -> RoundPlan {
    let n = times.len();
    assert!(n >= 1);
    assert_eq!(predicted.len(), n);
    match mode {
        SyncMode::Ssgd => {
            let t_max = max_of(times);
            RoundPlan {
                updates: vec![Update { at: t_max, members: (0..n).collect(), staleness: 0.0 }],
                worker_end: vec![t_max; n],
                span: t_max,
                reports_used: n,
            }
        }
        SyncMode::Asgd => {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap());
            let updates = order
                .iter()
                .enumerate()
                .map(|(k, &w)| Update { at: times[w], members: vec![w], staleness: k as f64 })
                .collect();
            RoundPlan {
                updates,
                worker_end: times.to_vec(),
                span: max_of(times),
                reports_used: n,
            }
        }
        SyncMode::StaticX(x) => {
            let x = (*x).clamp(1, n);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap());
            let mut updates = Vec::new();
            let mut worker_end = vec![0.0; n];
            for (g, chunk) in order.chunks(x).enumerate() {
                let at = chunk.iter().map(|&w| times[w]).fold(0.0, f64::max);
                for &w in chunk {
                    worker_end[w] = at;
                }
                updates.push(Update { at, members: chunk.to_vec(), staleness: g as f64 });
            }
            let span = max_of(times);
            let used = updates.iter().map(|u| u.members.len()).sum();
            RoundPlan { updates, worker_end, span, reports_used: used }
        }
        SyncMode::DynamicX => {
            let clusters = cluster_times(predicted, 0.15, 0.02);
            let mut updates: Vec<Update> = clusters
                .into_iter()
                .map(|members| {
                    let at = members.iter().map(|&w| times[w]).fold(0.0, f64::max);
                    Update { at, members, staleness: 0.0 }
                })
                .collect();
            updates.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
            let mut worker_end = vec![0.0; n];
            for (g, u) in updates.iter_mut().enumerate() {
                u.staleness = g as f64;
                for &w in &u.members {
                    worker_end[w] = u.at;
                }
            }
            let span = max_of(times);
            let used = updates.iter().map(|u| u.members.len()).sum();
            RoundPlan { updates, worker_end, span, reports_used: used }
        }
        SyncMode::ArRing { removed, tw_ms } => {
            let tw = tw_ms / 1e3;
            let removed = (*removed).min(n.saturating_sub(1));
            // slowest `removed` workers leave the ring
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap());
            let ring: Vec<usize> = order[..n - removed].to_vec();
            let out: Vec<usize> = order[n - removed..].to_vec();
            let t_ring = ring.iter().map(|&w| times[w]).fold(0.0, f64::max);
            let deadline = t_ring + tw;
            // q removed stragglers finish within the parent wait window
            let mut members = ring.clone();
            members.extend(out.iter().copied().filter(|&w| times[w] <= deadline));
            members.sort_unstable();
            let reports = members.len();
            let span = deadline;
            RoundPlan {
                updates: vec![Update { at: deadline, members, staleness: 0.0 }],
                // everyone (incl. removed stragglers) resumes on broadcast
                worker_end: times.iter().map(|&t| t.max(deadline)).collect(),
                span,
                reports_used: reports,
            }
        }
    }
}

/// Agglomerative (single-linkage on the sorted line) clustering of
/// predicted iteration times: a new cluster starts where the gap to the
/// previous time exceeds `rel` (relative) or `abs_s` (absolute floor).
/// This is the 1-D specialization of hierarchical clustering with a
/// distance threshold (§IV-B cites sklearn's AgglomerativeClustering).
pub fn cluster_times(times: &[f64], rel: f64, abs_s: f64) -> Vec<Vec<usize>> {
    let n = times.len();
    if n == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap());
    let mut clusters: Vec<Vec<usize>> = vec![vec![order[0]]];
    for win in order.windows(2) {
        let (prev, cur) = (win[0], win[1]);
        let gap = times[cur] - times[prev];
        let thresh = (rel * times[prev]).max(abs_s);
        if gap > thresh {
            clusters.push(Vec::new());
        }
        clusters.last_mut().unwrap().push(cur);
    }
    clusters
}

/// All candidate modes STAR-H/STAR-ML enumerate for an N-worker PS job
/// (§IV-C1): SSGD, ASGD, static x for x=2..N-1, dynamic-x.
pub fn candidate_modes_ps(n: usize) -> Vec<SyncMode> {
    let mut v = vec![SyncMode::Ssgd, SyncMode::Asgd];
    for x in 2..n {
        v.push(SyncMode::StaticX(x));
    }
    v.push(SyncMode::DynamicX);
    v
}

/// Candidate AR modes: x removed in 1..=stragglers, t_w over a grid (§V:
/// 30–210 ms), plus the full ring (x = 0).
pub fn candidate_modes_ar(stragglers: usize, tw_grid_ms: &[f64]) -> Vec<SyncMode> {
    let mut v = vec![SyncMode::ArRing { removed: 0, tw_ms: 0.0 }];
    for x in 1..=stragglers {
        for &tw in tw_grid_ms {
            v.push(SyncMode::ArRing { removed: x, tw_ms: tw });
        }
    }
    v
}

/// Simulated per-report communication jitter helper used by tests and the
/// e2e example to derive plausible durations.
pub fn jittered_times(base_s: f64, n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n).map(|_| base_s * rng.range(0.9, 1.15)).collect()
}

fn max_of(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    const T4: [f64; 4] = [1.0, 1.1, 1.2, 5.0];

    #[test]
    fn ssgd_single_update_at_max() {
        let p = plan_round(&SyncMode::Ssgd, &T4, &T4);
        assert_eq!(p.updates.len(), 1);
        assert_eq!(p.updates[0].at, 5.0);
        assert_eq!(p.updates[0].members.len(), 4);
        assert!(p.worker_end.iter().all(|&e| e == 5.0));
        assert_eq!(p.reports_used, 4);
    }

    #[test]
    fn asgd_one_update_per_worker_no_waiting() {
        let p = plan_round(&SyncMode::Asgd, &T4, &T4);
        assert_eq!(p.updates.len(), 4);
        assert_eq!(p.worker_end, T4.to_vec());
        // fastest has no staleness, slowest the most
        assert_eq!(p.updates[0].staleness, 0.0);
        assert_eq!(p.updates[3].staleness, 3.0);
        assert_eq!(p.updates[3].members, vec![3]);
    }

    #[test]
    fn static_2_groups_by_arrival() {
        let p = plan_round(&SyncMode::StaticX(2), &T4, &T4);
        assert_eq!(p.updates.len(), 2);
        assert_eq!(p.updates[0].members, vec![0, 1]);
        assert_eq!(p.updates[0].at, 1.1);
        assert_eq!(p.updates[1].members, vec![2, 3]);
        assert_eq!(p.updates[1].at, 5.0);
        // fast pair freed at 1.1, not at 5.0: straggler no longer blocks them
        assert_eq!(p.worker_end[0], 1.1);
        assert_eq!(p.worker_end[3], 5.0);
    }

    #[test]
    fn static_x_remainder_group() {
        let t = [1.0, 2.0, 3.0, 4.0, 5.0];
        let p = plan_round(&SyncMode::StaticX(2), &t, &t);
        assert_eq!(p.updates.len(), 3);
        assert_eq!(p.updates[2].members, vec![4]);
        assert_eq!(p.reports_used, 5);
    }

    #[test]
    fn dynamic_clusters_similar_predictions() {
        // predictions: {1.0,1.05,1.1} and {5.0}; actuals slightly different
        let pred = [1.0, 1.05, 1.1, 5.0];
        let act = [1.02, 1.0, 1.2, 4.8];
        let p = plan_round(&SyncMode::DynamicX, &act, &pred);
        assert_eq!(p.updates.len(), 2);
        assert_eq!(p.updates[0].members.len(), 3);
        assert_eq!(p.updates[0].at, 1.2); // max actual within cluster
        assert_eq!(p.updates[1].members, vec![3]);
    }

    #[test]
    fn ar_ring_full_is_ssgd_like() {
        let p = plan_round(&SyncMode::ArRing { removed: 0, tw_ms: 0.0 }, &T4, &T4);
        assert_eq!(p.updates.len(), 1);
        assert_eq!(p.reports_used, 4);
        assert_eq!(p.span, 5.0);
    }

    #[test]
    fn ar_ring_removal_shrinks_span_and_counts_q() {
        // remove the 5.0 straggler; ring max becomes 1.2; wait 100 ms
        let p = plan_round(&SyncMode::ArRing { removed: 1, tw_ms: 100.0 }, &T4, &T4);
        assert!((p.span - 1.3).abs() < 1e-9);
        // straggler (5.0) missed the 1.3 deadline: q = 0, reports = 3
        assert_eq!(p.reports_used, 3);
        // wait long enough and its report makes it: q = 1
        let p2 = plan_round(&SyncMode::ArRing { removed: 1, tw_ms: 4000.0 }, &T4, &T4);
        assert_eq!(p2.reports_used, 4);
    }

    #[test]
    fn cluster_times_splits_on_gap() {
        let t = [0.10, 0.11, 0.12, 0.50, 0.52, 2.0];
        let c = cluster_times(&t, 0.15, 0.02);
        assert_eq!(c.len(), 3);
        assert_eq!(c[0], vec![0, 1, 2]);
        assert_eq!(c[1], vec![3, 4]);
        assert_eq!(c[2], vec![5]);
    }

    #[test]
    fn cluster_times_single_cluster_when_tight() {
        let t = [1.0, 1.01, 1.02, 1.03];
        assert_eq!(cluster_times(&t, 0.15, 0.02).len(), 1);
    }

    #[test]
    fn cluster_covers_all_workers_exactly_once() {
        let mut rng = Rng::seeded(4);
        for _ in 0..100 {
            let n = rng.usize(1, 12);
            let t: Vec<f64> = (0..n).map(|_| rng.range(0.1, 3.0)).collect();
            let c = cluster_times(&t, 0.15, 0.02);
            let mut seen: Vec<usize> = c.into_iter().flatten().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn scaled_lr_proportional() {
        assert!((scaled_lr(0.1, 2, 8) - 0.025).abs() < 1e-12);
        assert!((scaled_lr(0.1, 8, 8) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn candidate_sets() {
        let ps = candidate_modes_ps(8);
        assert!(ps.contains(&SyncMode::Ssgd));
        assert!(ps.contains(&SyncMode::Asgd));
        assert!(ps.contains(&SyncMode::StaticX(2)));
        assert!(ps.contains(&SyncMode::StaticX(7)));
        assert!(!ps.contains(&SyncMode::StaticX(8)));
        assert!(ps.contains(&SyncMode::DynamicX));
        let ar = candidate_modes_ar(2, &[30.0, 90.0]);
        assert_eq!(ar.len(), 1 + 2 * 2);
    }

    #[test]
    fn modes_that_shrink_batch() {
        assert!(!SyncMode::Ssgd.shrinks_batch(8));
        assert!(SyncMode::Asgd.shrinks_batch(8));
        assert!(SyncMode::StaticX(4).shrinks_batch(8));
        assert!(!SyncMode::StaticX(8).shrinks_batch(8));
        assert!(SyncMode::ArRing { removed: 1, tw_ms: 50.0 }.shrinks_batch(8));
    }

    #[test]
    fn updates_are_time_ordered_and_partition_members() {
        let mut rng = Rng::seeded(77);
        for _ in 0..200 {
            let n = rng.usize(2, 12);
            let t: Vec<f64> = (0..n).map(|_| rng.range(0.05, 4.0)).collect();
            for mode in [
                SyncMode::Ssgd,
                SyncMode::Asgd,
                SyncMode::StaticX(rng.usize(2, n.max(3) - 1)),
                SyncMode::DynamicX,
            ] {
                let p = plan_round(&mode, &t, &t);
                let mut last = 0.0;
                let mut seen = vec![false; n];
                for u in &p.updates {
                    assert!(u.at >= last - 1e-12, "{mode:?}");
                    last = u.at;
                    for &m in &u.members {
                        assert!(!seen[m], "duplicate member in {mode:?}");
                        seen[m] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "{mode:?} must use all workers");
                assert!(p.span <= t.iter().cloned().fold(0.0, f64::max) + 1e-12);
            }
        }
    }
}
