//! Micro-benchmark substrate (criterion is unavailable offline): warmup,
//! calibrated iteration counts, mean/p50/p99, throughput reporting, and a
//! machine-readable JSON artifact (`BENCH_*.json`) so perf trajectories
//! can be tracked across PRs. `cargo bench` targets in `rust/benches/`
//! are built on this.

use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::jsonio::{self, Json};

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    /// optional derived throughput: (unit, items per second)
    pub throughput: Option<(String, f64)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>12}  p99 {:>12}  min {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", jsonio::s(&self.name)),
            ("iters", jsonio::num(self.iters as f64)),
            ("ns_per_iter", jsonio::num(self.mean_ns)),
            ("p50_ns", jsonio::num(self.p50_ns)),
            ("p99_ns", jsonio::num(self.p99_ns)),
            ("min_ns", jsonio::num(self.min_ns)),
        ];
        if let Some((unit, per_sec)) = &self.throughput {
            pairs.push((
                "throughput",
                jsonio::obj(vec![
                    ("unit", jsonio::s(unit)),
                    ("per_sec", jsonio::num(*per_sec)),
                ]),
            ));
        }
        jsonio::obj(pairs)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner. Measures wall-time per call of `f`, auto-scaling the
/// sample count so total time stays near `budget`.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            budget: Duration::from_millis(1200),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(30),
            budget: Duration::from_millis(250),
            results: Vec::new(),
        }
    }

    /// Run one benchmark. `f` should return something to keep the work
    /// observable; it is black_box'ed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + initial rate estimate.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 3 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_call = w0.elapsed().as_nanos() as f64 / warm_iters as f64;
        // Choose sample layout: up to 100 samples, batched if calls are fast.
        let target_samples = 60u64;
        let budget_ns = self.budget.as_nanos() as f64;
        let calls_total = (budget_ns / per_call.max(1.0)).max(3.0) as u64;
        let batch = (calls_total / target_samples).max(1);
        let samples = (calls_total / batch).clamp(3, 300);

        let mut times = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            times.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let res = BenchResult {
            name: name.to_string(),
            iters: samples * batch,
            mean_ns: times.iter().sum::<f64>() / times.len() as f64,
            p50_ns: crate::stats::percentile_sorted(&times, 50.0),
            p99_ns: crate::stats::percentile_sorted(&times, 99.0),
            min_ns: times[0],
            throughput: None,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Attach a throughput figure (items of `unit` per iteration) to the
    /// last result and print the derived rate.
    pub fn throughput(&mut self, unit: &str, per_iter: f64) {
        if let Some(r) = self.results.last_mut() {
            let per_sec = per_iter / (r.mean_ns / 1e9);
            r.throughput = Some((unit.to_string(), per_sec));
            println!("{:<44} {:>14.0} {unit}/s", format!("  ↳ {}", r.name), per_sec);
        }
    }

    /// Serialize every result as a `star-bench-v1` JSON document.
    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("schema", jsonio::s("star-bench-v1")),
            ("generated_by", jsonio::s("star::benchkit")),
            (
                "results",
                jsonio::arr(self.results.iter().map(|r| r.to_json())),
            ),
        ])
    }

    /// Write the JSON artifact (e.g. `BENCH_sim.json`); CI commits/uploads
    /// these so the perf trajectory is visible across PRs.
    pub fn write_json(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        println!("bench results written to {}", path.display());
        Ok(())
    }

    /// Bench-binary epilogue: write the artifact to `$STAR_BENCH_JSON` if
    /// set (single-target runs only — the override is shared, so a full
    /// `cargo bench` would make every target clobber it), else to
    /// `default_name`. Failures warn instead of panicking so a read-only
    /// working directory never kills a bench run.
    pub fn write_json_env(&self, default_name: &str) {
        let out = std::env::var("STAR_BENCH_JSON").unwrap_or_else(|_| default_name.into());
        if let Err(e) = self.write_json(Path::new(&out)) {
            eprintln!("warning: could not write {out}: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::quick();
        let r = b.bench("sum", || (0..1000u64).sum::<u64>());
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns * 1.01);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }

    #[test]
    fn throughput_attaches_to_last_result() {
        let mut b = Bencher::quick();
        b.bench("sum", || (0..100u64).sum::<u64>());
        b.throughput("adds", 100.0);
        let r = b.results.last().unwrap();
        let (unit, per_sec) = r.throughput.as_ref().unwrap();
        assert_eq!(unit, "adds");
        assert!(*per_sec > 0.0);
    }

    #[test]
    fn json_artifact_roundtrips() {
        let mut b = Bencher::quick();
        b.bench("sum", || (0..100u64).sum::<u64>());
        b.throughput("adds", 100.0);
        let path = std::env::temp_dir().join("star_benchkit_test.json");
        b.write_json(&path).unwrap();
        let parsed = Json::parse_file(&path).unwrap();
        assert_eq!(parsed.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        let results = parsed.get("results").unwrap().arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().str().unwrap(), "sum");
        assert!(results[0].get("ns_per_iter").unwrap().num().unwrap() > 0.0);
        assert!(
            results[0]
                .get("throughput")
                .unwrap()
                .get("per_sec")
                .unwrap()
                .num()
                .unwrap()
                > 0.0
        );
        let _ = std::fs::remove_file(&path);
    }
}
