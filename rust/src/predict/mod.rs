//! Straggler prediction (§IV-A).
//!
//! Pipeline per worker, every iteration:
//!  1. record observed (available CPU, available bandwidth) into a ring
//!     history;
//!  2. predict the next iteration's resources — production path runs the
//!     AOT LSTM artifact through PJRT ([`runtime::Predictor`]), with a
//!     pure-Rust AR(1) fallback of the same interface;
//!  3. map predicted resources to a predicted iteration time with an
//!     online ridge regression over physical features (the paper's
//!     "regression model" with model type / batch size as inputs);
//!  4. flag workers whose predicted deviation ratio d_i > 20% (§II).
//!
//! The baseline predictors of §III-B / Fig 17 (fixed-duration rule,
//! deviation-ratio LSTM) live here too so the comparison is apples-to-
//! apples.

use std::collections::VecDeque;

/// History window length (matches the python-side LSTM WINDOW).
pub const WINDOW: usize = 32;

/// Straggler threshold from §II.
pub const STRAGGLER_DEV: f64 = 0.20;

/// Ring buffer of recent per-iteration observations for one worker.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub cpu: VecDeque<f64>,
    pub bw: VecDeque<f64>,
    pub iter_s: VecDeque<f64>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, cpu: f64, bw: f64, iter_s: f64) {
        push_cap(&mut self.cpu, cpu);
        push_cap(&mut self.bw, bw);
        push_cap(&mut self.iter_s, iter_s);
    }

    pub fn len(&self) -> usize {
        self.cpu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cpu.is_empty()
    }

    /// history rows as [cpu, bw] pairs oldest-first, padded by repeating
    /// the oldest value when shorter than WINDOW (artifact needs W rows)
    pub fn padded_rows(&self) -> Vec<[f32; 2]> {
        let mut rows = Vec::with_capacity(WINDOW);
        let first = [
            *self.cpu.front().unwrap_or(&0.5) as f32,
            *self.bw.front().unwrap_or(&0.5) as f32,
        ];
        for _ in self.len()..WINDOW {
            rows.push(first);
        }
        for i in 0..self.len() {
            rows.push([self.cpu[i] as f32, self.bw[i] as f32]);
        }
        rows
    }
}

fn push_cap(q: &mut VecDeque<f64>, v: f64) {
    if q.len() == WINDOW {
        q.pop_front();
    }
    q.push_back(v);
}

/// Resource forecast interface: next-iteration (cpu, bw).
pub trait ResourcePredictor {
    fn predict(&mut self, h: &History) -> (f64, f64);
}

/// AR(1) fallback: x' = mean + rho (last − mean), rho from the window's
/// lag-1 autocorrelation. Zero-dependency, always available.
#[derive(Clone, Debug, Default)]
pub struct ArPredictor;

impl ArPredictor {
    fn ar1(xs: &VecDeque<f64>) -> f64 {
        let n = xs.len();
        if n == 0 {
            return 0.5;
        }
        if n < 4 {
            return xs[n - 1];
        }
        let v: Vec<f64> = xs.iter().copied().collect();
        let mean = v.iter().sum::<f64>() / n as f64;
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..n - 1 {
            num += (v[i] - mean) * (v[i + 1] - mean);
        }
        for x in &v {
            den += (x - mean) * (x - mean);
        }
        let rho = if den > 1e-12 { (num / den).clamp(-1.0, 1.0) } else { 0.0 };
        mean + rho * (v[n - 1] - mean)
    }
}

impl ResourcePredictor for ArPredictor {
    fn predict(&mut self, h: &History) -> (f64, f64) {
        (Self::ar1(&h.cpu).clamp(0.0, 1.0), Self::ar1(&h.bw).clamp(0.0, 1.0))
    }
}

/// Online ridge regression y ≈ w·x over `D` features with forgetting:
/// maintains XᵀX and Xᵀy, refits on demand (tiny D, Gaussian elimination).
#[derive(Clone, Debug)]
pub struct Ridge<const D: usize> {
    pub xtx: [[f64; D]; D],
    pub xty: [f64; D],
    pub w: [f64; D],
    pub n: u64,
    pub lambda: f64,
    /// exponential forgetting factor per observation (1.0 = none)
    pub forget: f64,
    dirty: bool,
}

impl<const D: usize> Ridge<D> {
    pub fn new(lambda: f64, forget: f64) -> Self {
        Ridge {
            xtx: [[0.0; D]; D],
            xty: [0.0; D],
            w: [0.0; D],
            n: 0,
            lambda,
            forget,
            dirty: false,
        }
    }

    pub fn observe(&mut self, x: &[f64; D], y: f64) {
        for i in 0..D {
            for j in 0..D {
                self.xtx[i][j] = self.forget * self.xtx[i][j] + x[i] * x[j];
            }
            self.xty[i] = self.forget * self.xty[i] + x[i] * y;
        }
        self.n += 1;
        self.dirty = true;
    }

    pub fn fit(&mut self) {
        // (XᵀX + λI) w = Xᵀy, Gaussian elimination with partial pivoting
        let mut a = self.xtx;
        let mut b = self.xty;
        for i in 0..D {
            a[i][i] += self.lambda;
        }
        for col in 0..D {
            let mut piv = col;
            for r in col + 1..D {
                if a[r][col].abs() > a[piv][col].abs() {
                    piv = r;
                }
            }
            a.swap(col, piv);
            b.swap(col, piv);
            let d = a[col][col];
            if d.abs() < 1e-12 {
                continue;
            }
            for r in 0..D {
                if r == col {
                    continue;
                }
                let f = a[r][col] / d;
                for c in col..D {
                    a[r][c] -= f * a[col][c];
                }
                b[r] -= f * b[col];
            }
        }
        for i in 0..D {
            self.w[i] = if a[i][i].abs() < 1e-12 { 0.0 } else { b[i] / a[i][i] };
        }
        self.dirty = false;
    }

    pub fn predict(&mut self, x: &[f64; D]) -> f64 {
        if self.dirty {
            self.fit();
        }
        let mut y = 0.0;
        for i in 0..D {
            y += self.w[i] * x[i];
        }
        y
    }
}

/// Iteration-time regressor features (§IV-A: predicted resources + model
/// type + batch size, expressed physically so one regressor generalizes):
/// [1, pre_work/cpu, bytes/bw, gpu_ms, pre_work, bytes]
pub const ITER_FEATURES: usize = 6;

/// Online iteration-time model: predicted (cpu_share, bw_share) → seconds.
#[derive(Clone, Debug)]
pub struct IterTimeModel {
    pub ridge: Ridge<ITER_FEATURES>,
}

impl Default for IterTimeModel {
    fn default() -> Self {
        Self::new()
    }
}

impl IterTimeModel {
    pub fn new() -> Self {
        IterTimeModel { ridge: Ridge::new(1e-4, 0.999) }
    }

    pub fn features(
        pre_cpu_ms: f64,
        gpu_ms: f64,
        grad_mb: f64,
        cpu_share: f64,
        bw_share_gbps: f64,
    ) -> [f64; ITER_FEATURES] {
        let cpu = cpu_share.max(1e-3);
        let bw = bw_share_gbps.max(1e-3);
        let bytes_gbit = grad_mb * 8.0 / 1000.0;
        [
            1.0,
            pre_cpu_ms / 1000.0 / cpu,
            2.0 * bytes_gbit / bw,
            gpu_ms / 1000.0,
            pre_cpu_ms / 1000.0,
            bytes_gbit,
        ]
    }

    pub fn observe(&mut self, x: &[f64; ITER_FEATURES], seconds: f64) {
        self.ridge.observe(x, seconds);
    }

    pub fn predict(&mut self, x: &[f64; ITER_FEATURES]) -> f64 {
        self.ridge.predict(x).max(1e-3)
    }

    pub fn trained(&self) -> bool {
        self.ridge.n >= 8
    }
}

/// Deviation ratios d_i = (T_i − min T)/min T (§II).
pub fn deviation_ratios(times: &[f64]) -> Vec<f64> {
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-9);
    times.iter().map(|&t| (t - min) / min).collect()
}

/// Straggler flags at the §II threshold.
pub fn straggler_flags(times: &[f64]) -> Vec<bool> {
    deviation_ratios(times).into_iter().map(|d| d > STRAGGLER_DEV).collect()
}

/// Confusion counts for predictor evaluation (Fig 17).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    pub fn add(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, false) => self.tn += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    /// FP rate among predicted-or-actual positives, as the paper reports.
    pub fn fp_rate(&self) -> f64 {
        let denom = (self.fp + self.tn) as f64;
        if denom == 0.0 { 0.0 } else { self.fp as f64 / denom }
    }

    pub fn fn_rate(&self) -> f64 {
        let denom = (self.tp + self.fn_) as f64;
        if denom == 0.0 { 0.0 } else { self.fn_ as f64 / denom }
    }
}

/// Fixed-duration baseline (§III-B / Sync-Switch): flags a worker as a
/// straggler only after it has straggled for `persist_s` continuous
/// seconds. State machine per worker.
#[derive(Clone, Debug)]
pub struct FixedDurationRule {
    pub persist_s: f64,
    since: Vec<Option<f64>>,
}

impl FixedDurationRule {
    pub fn new(n: usize, persist_s: f64) -> Self {
        FixedDurationRule { persist_s, since: vec![None; n] }
    }

    /// Observe iteration at time `t`; returns per-worker predicted flags
    /// for the *next* iteration.
    pub fn observe(&mut self, t: f64, times: &[f64]) -> Vec<bool> {
        let flags = straggler_flags(times);
        flags
            .iter()
            .enumerate()
            .map(|(i, &f)| {
                if f {
                    let s = *self.since[i].get_or_insert(t);
                    t - s >= self.persist_s
                } else {
                    self.since[i] = None;
                    false
                }
            })
            .collect()
    }
}

/// Deviation-ratio time-series baseline (§III-B "LSTM on past ratios"):
/// same AR machinery applied directly to d_i instead of resources.
#[derive(Clone, Debug)]
pub struct RatioSeriesRule {
    histories: Vec<VecDeque<f64>>,
}

impl RatioSeriesRule {
    pub fn new(n: usize) -> Self {
        RatioSeriesRule { histories: vec![VecDeque::new(); n] }
    }

    pub fn observe_and_predict(&mut self, times: &[f64]) -> Vec<bool> {
        let ratios = deviation_ratios(times);
        ratios
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                push_cap(&mut self.histories[i], d);
                ArPredictor::ar1(&self.histories[i]) > STRAGGLER_DEV
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_ring_caps_at_window() {
        let mut h = History::new();
        for i in 0..(WINDOW + 10) {
            h.push(i as f64, 0.5, 0.1);
        }
        assert_eq!(h.len(), WINDOW);
        assert_eq!(h.cpu[0], 10.0);
        assert_eq!(h.padded_rows().len(), WINDOW);
    }

    #[test]
    fn padded_rows_repeat_oldest() {
        let mut h = History::new();
        h.push(0.3, 0.6, 0.1);
        h.push(0.4, 0.7, 0.1);
        let rows = h.padded_rows();
        assert_eq!(rows.len(), WINDOW);
        assert_eq!(rows[0], [0.3f32, 0.6f32]);
        assert_eq!(rows[WINDOW - 1], [0.4f32, 0.7f32]);
    }

    #[test]
    fn ar_predictor_tracks_constant() {
        let mut h = History::new();
        for _ in 0..WINDOW {
            h.push(0.7, 0.4, 0.1);
        }
        let (c, b) = ArPredictor.predict(&h);
        assert!((c - 0.7).abs() < 1e-9);
        assert!((b - 0.4).abs() < 1e-9);
    }

    #[test]
    fn ar_predictor_mean_reverts_on_noise() {
        let mut rng = crate::simrng::Rng::seeded(1);
        let mut h = History::new();
        for _ in 0..WINDOW {
            h.push(0.5 + 0.05 * rng.normal(), 0.5, 0.1);
        }
        let (c, _) = ArPredictor.predict(&h);
        assert!((c - 0.5).abs() < 0.1);
    }

    #[test]
    fn ridge_recovers_linear_function() {
        let mut r: Ridge<3> = Ridge::new(1e-6, 1.0);
        let mut rng = crate::simrng::Rng::seeded(2);
        for _ in 0..500 {
            let x = [1.0, rng.range(0.0, 2.0), rng.range(-1.0, 1.0)];
            let y = 0.5 + 2.0 * x[1] - 1.5 * x[2];
            r.observe(&x, y);
        }
        r.fit();
        assert!((r.w[0] - 0.5).abs() < 1e-6);
        assert!((r.w[1] - 2.0).abs() < 1e-6);
        assert!((r.w[2] + 1.5).abs() < 1e-6);
    }

    #[test]
    fn iter_time_model_learns_physical_law() {
        // ground truth: T = pre/cpu + gpu + 2*bytes/bw (the feature map is
        // exact, so ridge should nail it)
        let mut m = IterTimeModel::new();
        let mut rng = crate::simrng::Rng::seeded(3);
        for _ in 0..400 {
            let cpu = rng.range(0.5, 8.0);
            let bw = rng.range(0.5, 10.0);
            let x = IterTimeModel::features(250.0, 60.0, 30.0, cpu, bw);
            let y = 0.25 / cpu + 0.06 + 2.0 * 0.24 / bw;
            m.observe(&x, y);
        }
        assert!(m.trained());
        let x = IterTimeModel::features(250.0, 60.0, 30.0, 2.0, 2.0);
        let want = 0.25 / 2.0 + 0.06 + 2.0 * 0.24 / 2.0;
        let got = m.predict(&x);
        assert!((got - want).abs() / want < 0.05, "got {got} want {want}");
    }

    #[test]
    fn deviation_and_flags() {
        let d = deviation_ratios(&[1.0, 1.1, 1.5]);
        assert!((d[0] - 0.0).abs() < 1e-12);
        assert!((d[2] - 0.5).abs() < 1e-12);
        assert_eq!(straggler_flags(&[1.0, 1.1, 1.5]), vec![false, false, true]);
        // boundary: exactly 20% is NOT a straggler (strict >)
        assert_eq!(straggler_flags(&[1.0, 1.2]), vec![false, false]);
    }

    #[test]
    fn confusion_rates() {
        let mut c = Confusion::default();
        c.add(true, true);
        c.add(true, false);
        c.add(false, true);
        c.add(false, false);
        assert!((c.fp_rate() - 0.5).abs() < 1e-12);
        assert!((c.fn_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fixed_duration_rule_needs_persistence() {
        let mut r = FixedDurationRule::new(2, 5.0);
        // straggling starts at t=0; not flagged until 5 s have elapsed
        assert_eq!(r.observe(0.0, &[1.0, 2.0]), vec![false, false]);
        assert_eq!(r.observe(3.0, &[1.0, 2.0]), vec![false, false]);
        assert_eq!(r.observe(6.0, &[1.0, 2.0]), vec![false, true]);
        // recovery resets the clock
        assert_eq!(r.observe(7.0, &[1.0, 1.0]), vec![false, false]);
        assert_eq!(r.observe(8.0, &[1.0, 2.0]), vec![false, false]);
    }

    #[test]
    fn ratio_series_rule_predicts_persistent_straggler() {
        let mut r = RatioSeriesRule::new(2);
        let mut out = Vec::new();
        for _ in 0..10 {
            out = r.observe_and_predict(&[1.0, 1.6]);
        }
        assert_eq!(out, vec![false, true]);
    }
}
