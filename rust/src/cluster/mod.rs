//! Cluster substrate: servers, tasks, and resource contention.
//!
//! Reproduces the testbed of §III — 5 GPU instances (8 GPUs, 96 vCPUs
//! each) + 3 CPU instances (64 vCPUs) — as a contention model in which the
//! paper's straggler phenomena *emerge* rather than being injected:
//!
//! * every task (worker or PS) carries steady CPU/bandwidth demands from
//!   the model zoo (PSs demand more than workers, O4; ASGD more than
//!   SSGD, O5);
//! * each server grants **max–min fair (water-filling) shares** of its
//!   time-varying available capacity among co-located tasks;
//! * available capacity = nameplate − smooth background load (AR-like
//!   hash noise, paper [31]) − transient contention spikes with
//!   heavy-tailed durations (0.1–500 s, Fig 7);
//! * `cpulimit`/`tc`-style throttling (§V) is a per-task cap.
//!
//! Iteration times are then computed from these shares by the driver;
//! deviation ratios above 20% are stragglers (§II).

use crate::simrng::Rng;

/// Resource kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Res {
    Cpu,
    Bw,
}

/// Server class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerKind {
    /// p4d.24xlarge-like: 8 GPUs, 96 vCPUs
    Gpu,
    /// m4.16xlarge-like: 0 GPUs, 64 vCPUs
    Cpu,
}

/// Task role within a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Worker { rank: usize },
    Ps { idx: usize },
}

impl Role {
    pub fn is_ps(&self) -> bool {
        matches!(self, Role::Ps { .. })
    }
}

/// A transient contention spike (external co-tenant interference).
#[derive(Clone, Copy, Debug)]
pub struct Spike {
    pub start: f64,
    pub end: f64,
    pub cpu_frac: f64,
    pub bw_frac: f64,
}

/// One server.
#[derive(Clone, Debug)]
pub struct Server {
    pub kind: ServerKind,
    pub cpus: f64,
    pub bw_gbps: f64,
    pub gpus: usize,
    pub gpus_used: usize,
    /// lazily extended contention spikes, ordered by start
    spikes: Vec<Spike>,
    spike_horizon: f64,
    spike_rng: Rng,
}

/// A registered task.
#[derive(Clone, Debug)]
pub struct Task {
    pub job: usize,
    pub role: Role,
    pub server: usize,
    pub cpu_demand: f64,
    pub bw_demand: f64,
    /// dynamic caps (prevention / equalization), fraction of demand (0,1]
    pub cpu_cap: f64,
    pub bw_cap: f64,
    /// static throttles (the paper's cpulimit / tc), composed with caps
    pub cpu_throttle: f64,
    pub bw_throttle: f64,
    pub active: bool,
}

impl Task {
    pub fn capped_cpu(&self) -> f64 {
        self.cpu_demand * self.cpu_cap * self.cpu_throttle
    }

    pub fn capped_bw(&self) -> f64 {
        self.bw_demand * self.bw_cap * self.bw_throttle
    }
}

pub type TaskId = usize;

/// Cluster configuration (defaults = the paper's testbed).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub gpu_servers: usize,
    pub cpu_servers: usize,
    pub gpus_per_server: usize,
    pub gpu_server_cpus: f64,
    pub cpu_server_cpus: f64,
    /// effective per-server network budget for training traffic, Gbps.
    /// Calibrated (not nameplate 400G) so that PS fan-in contention can
    /// saturate links as in Fig 9 — see DESIGN.md §2.
    pub gpu_server_bw: f64,
    pub cpu_server_bw: f64,
    /// mean seconds between contention spikes per server
    pub spike_interval_s: f64,
    /// lognormal duration parameters (median ≈ 4 s, tail to ~500 s, Fig 7)
    pub spike_dur_mu: f64,
    pub spike_dur_sigma: f64,
    /// background load fraction bounds
    pub bg_base: f64,
    pub bg_amp: f64,
    /// mean seconds between per-task straggler events (0 = off)
    pub task_event_interval_s: f64,
    /// per-task event magnitude range (fraction of the task's share lost)
    pub task_event_mag: (f64, f64),
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            gpu_servers: 5,
            cpu_servers: 3,
            gpus_per_server: 8,
            gpu_server_cpus: 96.0,
            cpu_server_cpus: 64.0,
            gpu_server_bw: 50.0,
            cpu_server_bw: 25.0,
            spike_interval_s: 240.0,
            spike_dur_mu: 1.4,    // e^1.4 ≈ 4 s median
            spike_dur_sigma: 1.6, // p99.9 ≈ 500 s
            bg_base: 0.08,
            bg_amp: 0.14,
            task_event_interval_s: 75.0,
            task_event_mag: (0.4, 0.85),
            seed: 0,
        }
    }
}

/// The cluster: servers + task registry + contention model.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub servers: Vec<Server>,
    pub tasks: Vec<Task>,
    /// per-server list of active task ids (hot-path index; shares() is
    /// called on every simulated iteration)
    by_server: Vec<Vec<TaskId>>,
    /// lazily-created per-task straggler-event streams (heavy-tailed
    /// slowdowns hitting one task: pinned-core co-tenants, NIC queue
    /// imbalance, GC pauses — the paper's 0.1–500 s events, Fig 7)
    task_events: Vec<SpikeStream>,
    noise_seed: u64,
}

/// A lazily-extended stream of heavy-tailed events.
#[derive(Clone, Debug)]
pub struct SpikeStream {
    spikes: Vec<Spike>,
    horizon: f64,
    rng: Rng,
}

impl SpikeStream {
    fn new(rng: Rng) -> Self {
        SpikeStream { spikes: Vec::new(), horizon: 0.0, rng }
    }

    /// Extend to time `t` and return the active magnitude for `res`.
    fn frac_at(&mut self, t: f64, interval: f64, mag: (f64, f64), dur_mu: f64, dur_sigma: f64, res: Res) -> f64 {
        while self.horizon <= t {
            let gap = self.rng.exponential(1.0 / interval);
            let start = self.horizon + gap;
            let dur = self.rng.lognormal(dur_mu, dur_sigma).clamp(0.1, 500.0);
            let both = self.rng.chance(0.35);
            let on_cpu = both || self.rng.chance(0.5);
            let m = self.rng.range(mag.0, mag.1);
            self.spikes.push(Spike {
                start,
                end: start + dur,
                cpu_frac: if on_cpu { m } else { 0.0 },
                bw_frac: if !on_cpu || both { m } else { 0.0 },
            });
            self.horizon = start;
        }
        let mut frac: f64 = 0.0;
        for sp in self.spikes.iter().rev() {
            if sp.start > t {
                continue;
            }
            if sp.end > t {
                frac += match res {
                    Res::Cpu => sp.cpu_frac,
                    Res::Bw => sp.bw_frac,
                };
            }
            if sp.start + 500.0 < t {
                break;
            }
        }
        frac.min(0.9)
    }
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0x5eed);
        let mut servers = Vec::new();
        for _ in 0..cfg.gpu_servers {
            servers.push(Server {
                kind: ServerKind::Gpu,
                cpus: cfg.gpu_server_cpus,
                bw_gbps: cfg.gpu_server_bw,
                gpus: cfg.gpus_per_server,
                gpus_used: 0,
                spikes: Vec::new(),
                spike_horizon: 0.0,
                spike_rng: rng.fork(servers_tag(servers_len(&servers))),
            });
        }
        for _ in 0..cfg.cpu_servers {
            servers.push(Server {
                kind: ServerKind::Cpu,
                cpus: cfg.cpu_server_cpus,
                bw_gbps: cfg.cpu_server_bw,
                gpus: 0,
                gpus_used: 0,
                spikes: Vec::new(),
                spike_horizon: 0.0,
                spike_rng: rng.fork(servers_tag(servers_len(&servers))),
            });
        }
        let noise_seed = rng.next_u64();
        let by_server = vec![Vec::new(); servers.len()];
        Cluster { cfg, servers, tasks: Vec::new(), by_server, task_events: Vec::new(), noise_seed }
    }

    pub fn gpu_server_ids(&self) -> Vec<usize> {
        (0..self.servers.len()).filter(|&s| self.servers[s].kind == ServerKind::Gpu).collect()
    }

    pub fn cpu_server_ids(&self) -> Vec<usize> {
        (0..self.servers.len()).filter(|&s| self.servers[s].kind == ServerKind::Cpu).collect()
    }

    // -- task registry -------------------------------------------------------

    /// Register a task; workers consume a GPU slot on their server.
    pub fn add_task(&mut self, task: Task) -> TaskId {
        if matches!(task.role, Role::Worker { .. }) {
            self.servers[task.server].gpus_used += 1;
            debug_assert!(
                self.servers[task.server].gpus_used <= self.servers[task.server].gpus,
                "GPU oversubscription on server {}",
                task.server
            );
        }
        let server = task.server;
        self.tasks.push(task);
        let id = self.tasks.len() - 1;
        self.by_server[server].push(id);
        self.task_events.push(SpikeStream::new(Rng::new(
            self.noise_seed ^ (id as u64).wrapping_mul(0xA24B_AED4_963E_E407),
            0x7a51,
        )));
        id
    }

    /// Deactivate a task (job finished) and release its GPU slot.
    pub fn remove_task(&mut self, id: TaskId) {
        if self.tasks[id].active {
            self.tasks[id].active = false;
            let server = self.tasks[id].server;
            self.by_server[server].retain(|&x| x != id);
            if matches!(self.tasks[id].role, Role::Worker { .. }) {
                self.servers[server].gpus_used -= 1;
            }
        }
    }

    pub fn free_gpus(&self, server: usize) -> usize {
        self.servers[server].gpus - self.servers[server].gpus_used
    }

    /// Number of active PSs hosted on `server`.
    pub fn ps_count(&self, server: usize) -> usize {
        self.by_server[server].iter().filter(|&&i| self.tasks[i].role.is_ps()).count()
    }

    // -- contention model ----------------------------------------------------

    /// Smooth background load fraction in [bg_base, bg_base+bg_amp]:
    /// cosine-interpolated hash noise at two time scales (seconds +
    /// minutes), deterministic in (seed, server, resource, t).
    pub fn background_frac(&self, server: usize, res: Res, t: f64) -> f64 {
        let tag = (server as u64) << 8 | res_tag(res);
        let fast = smooth_noise(self.noise_seed ^ tag, t);
        let slow = smooth_noise(self.noise_seed ^ tag ^ 0xABCD, t / 60.0);
        (self.cfg.bg_base + self.cfg.bg_amp * (0.6 * slow + 0.4 * fast)).clamp(0.0, 0.95)
    }

    /// Extend + query contention spikes overlapping time `t`.
    fn spike_frac(&mut self, server: usize, res: Res, t: f64) -> f64 {
        let cfg_interval = self.cfg.spike_interval_s;
        let (mu, sigma) = (self.cfg.spike_dur_mu, self.cfg.spike_dur_sigma);
        let srv = &mut self.servers[server];
        while srv.spike_horizon <= t {
            let gap = srv.spike_rng.exponential(1.0 / cfg_interval);
            let start = srv.spike_horizon + gap;
            let dur = srv.spike_rng.lognormal(mu, sigma).clamp(0.1, 500.0);
            let both = srv.spike_rng.chance(0.3);
            let on_cpu = both || srv.spike_rng.chance(0.5);
            let mag = srv.spike_rng.range(0.2, 0.7);
            srv.spikes.push(Spike {
                start,
                end: start + dur,
                cpu_frac: if on_cpu { mag } else { 0.0 },
                bw_frac: if !on_cpu || both { mag } else { 0.0 },
            });
            srv.spike_horizon = start;
        }
        // sum overlapping (rare to have >1); scan tail (spikes sorted by start)
        let mut frac: f64 = 0.0;
        for s in srv.spikes.iter().rev() {
            if s.start > t {
                continue;
            }
            if s.end > t {
                frac += match res {
                    Res::Cpu => s.cpu_frac,
                    Res::Bw => s.bw_frac,
                };
            }
            // spikes are start-ordered; once start+500 < t nothing earlier overlaps
            if s.start + 500.0 < t {
                break;
            }
        }
        frac.min(0.9)
    }

    /// Available capacity of `res` on `server` at time `t`.
    pub fn available(&mut self, server: usize, res: Res, t: f64) -> f64 {
        let cap = match res {
            Res::Cpu => self.servers[server].cpus,
            Res::Bw => self.servers[server].bw_gbps,
        };
        let bg = self.background_frac(server, res, t);
        (cap * (1.0 - bg)).max(0.05 * cap)
    }

    /// Max–min fair share of `res` for every active task on `server` at
    /// time `t`. Returns (task_id, share) pairs.
    pub fn shares(&mut self, server: usize, res: Res, t: f64) -> Vec<(TaskId, f64)> {
        let avail = self.available(server, res, t);
        let ids: Vec<TaskId> = self.by_server[server].clone();
        let demands: Vec<f64> = ids
            .iter()
            .map(|&i| match res {
                Res::Cpu => self.tasks[i].capped_cpu(),
                Res::Bw => self.tasks[i].capped_bw(),
            })
            .collect();
        let mut alloc = water_fill(&demands, avail);
        // per-task interference: co-tenant contention hits individual
        // tasks unevenly (pinned cores, NIC queues), which is where the
        // paper's *within-server* stragglers come from (Fig 3/4). Scaled
        // by how loaded the server is.
        let load = (demands.iter().sum::<f64>() / avail.max(1e-9)).min(1.5);
        for (k, &id) in ids.iter().enumerate() {
            let inter = self.task_interference(server, id, res, t, load);
            alloc[k] *= 1.0 - inter;
        }
        ids.into_iter().zip(alloc).collect()
    }

    /// Interference fraction in [0, 0.85] on one task: smooth per-task
    /// noise (amplified under load) + heavy-tailed contention spikes that
    /// hit a hashed subset of the server's tasks.
    fn task_interference(&mut self, server: usize, id: TaskId, res: Res, t: f64, load: f64) -> f64 {
        // smooth component: per-task two-scale noise, cubed for a skewed
        // (mostly-small, occasionally-large) distribution
        let tag = 0x7a5c_u64 ^ ((id as u64) << 16) ^ res_tag(res);
        let fast = smooth_noise(self.noise_seed ^ tag, t / 3.0);
        let slow = smooth_noise(self.noise_seed ^ tag ^ 0x99, t / 45.0);
        let u = 0.5 * fast + 0.5 * slow;
        // superlinear in load: relieving a loaded server (balanced PS
        // placement, §IV-D1 equalization caps) pays off disproportionately
        let smooth = 1.1 * u * u * load.clamp(0.0, 1.2).powf(1.5);
        // spike component: victim-hashed server spikes
        let spike = self.spike_frac(server, res, t);
        let victim = {
            let h = (self.noise_seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_mul(0xBF58_476D_1CE4_E5B9);
            (h >> 32) & 1 == 0
        };
        let hit = if victim { spike } else { 0.0 };
        // per-task heavy-tailed straggler events (the dominant mechanism)
        let own = if self.cfg.task_event_interval_s > 0.0 {
            let (mu, sigma) = (self.cfg.spike_dur_mu, self.cfg.spike_dur_sigma);
            self.task_events[id].frac_at(
                t,
                self.cfg.task_event_interval_s,
                self.cfg.task_event_mag,
                mu,
                sigma,
                res,
            )
        } else {
            0.0
        };
        (smooth + hit + own).clamp(0.0, 0.9)
    }

    /// Share granted to one task (water-filled against its co-located set).
    pub fn share_of(&mut self, id: TaskId, res: Res, t: f64) -> f64 {
        let server = self.tasks[id].server;
        self.shares(server, res, t)
            .into_iter()
            .find(|&(i, _)| i == id)
            .map(|(_, s)| s)
            .unwrap_or(0.0)
    }

    /// Fraction of nameplate capacity in use on `server` (for Fig 9).
    pub fn utilization(&mut self, server: usize, res: Res, t: f64) -> f64 {
        let cap = match res {
            Res::Cpu => self.servers[server].cpus,
            Res::Bw => self.servers[server].bw_gbps,
        };
        let granted: f64 = self.shares(server, res, t).iter().map(|&(_, s)| s).sum();
        let external = cap - self.available(server, res, t);
        ((granted + external) / cap).clamp(0.0, 1.0)
    }
}

/// Max–min fair (water-filling) allocation of `capacity` among `demands`;
/// no task receives more than its demand, and unmet demand shares the
/// remainder equally.
pub fn water_fill(demands: &[f64], capacity: f64) -> Vec<f64> {
    let n = demands.len();
    if n == 0 {
        return Vec::new();
    }
    let total: f64 = demands.iter().sum();
    if total <= capacity {
        return demands.to_vec();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| demands[a].partial_cmp(&demands[b]).unwrap());
    let mut alloc = vec![0.0; n];
    let mut remaining = capacity;
    let mut left = n;
    for (k, &i) in order.iter().enumerate() {
        let fair = remaining / left as f64;
        if demands[i] <= fair {
            alloc[i] = demands[i];
            remaining -= demands[i];
        } else {
            // everyone from here on gets the equal split
            for &j in &order[k..] {
                alloc[j] = remaining / left as f64;
            }
            return alloc;
        }
        left -= 1;
    }
    alloc
}

fn res_tag(res: Res) -> u64 {
    match res {
        Res::Cpu => 1,
        Res::Bw => 2,
    }
}

fn servers_len(v: &[Server]) -> usize {
    v.len()
}

fn servers_tag(i: usize) -> u64 {
    0x5e4e_0000 + i as u64
}

/// Deterministic smooth noise in [0, 1]: cosine interpolation between
/// per-integer-cell hash values.
fn smooth_noise(seed: u64, t: f64) -> f64 {
    let cell = t.floor();
    let frac = t - cell;
    let h = |c: f64| {
        let mut x = seed ^ (c as i64 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        (x >> 11) as f64 / (1u64 << 53) as f64
    };
    let w = 0.5 - 0.5 * (std::f64::consts::PI * frac).cos();
    h(cell) * (1.0 - w) + h(cell + 1.0) * w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(job: usize, server: usize, cpu: f64, bw: f64) -> Task {
        Task {
            job,
            role: Role::Worker { rank: 0 },
            server,
            cpu_demand: cpu,
            bw_demand: bw,
            cpu_cap: 1.0,
            bw_cap: 1.0,
            cpu_throttle: 1.0,
            bw_throttle: 1.0,
            active: true,
        }
    }

    #[test]
    fn water_fill_under_capacity_grants_demand() {
        let a = water_fill(&[1.0, 2.0, 3.0], 10.0);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn water_fill_over_capacity_is_max_min_fair() {
        let a = water_fill(&[1.0, 4.0, 4.0], 6.0);
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert!((a[1] - 2.5).abs() < 1e-12);
        assert!((a[2] - 2.5).abs() < 1e-12);
        let sum: f64 = a.iter().sum();
        assert!((sum - 6.0).abs() < 1e-9);
    }

    #[test]
    fn water_fill_never_exceeds_demand_or_capacity() {
        let mut rng = Rng::seeded(5);
        for _ in 0..200 {
            let n = rng.usize(1, 12);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.1, 10.0)).collect();
            let cap = rng.range(0.5, 30.0);
            let a = water_fill(&demands, cap);
            let sum: f64 = a.iter().sum();
            assert!(sum <= cap + 1e-9 || sum <= demands.iter().sum::<f64>() + 1e-9);
            for (x, d) in a.iter().zip(&demands) {
                assert!(*x <= d + 1e-9);
                assert!(*x >= 0.0);
            }
        }
    }

    #[test]
    fn default_testbed_shape() {
        let c = Cluster::new(ClusterConfig::default());
        assert_eq!(c.servers.len(), 8);
        assert_eq!(c.gpu_server_ids().len(), 5);
        assert_eq!(c.cpu_server_ids().len(), 3);
        assert_eq!(c.servers[0].gpus, 8);
        assert_eq!(c.servers[5].gpus, 0);
    }

    #[test]
    fn gpu_slots_tracked() {
        let mut c = Cluster::new(ClusterConfig::default());
        assert_eq!(c.free_gpus(0), 8);
        let id = c.add_task(worker(0, 0, 2.0, 1.0));
        assert_eq!(c.free_gpus(0), 7);
        c.remove_task(id);
        assert_eq!(c.free_gpus(0), 8);
        c.remove_task(id); // idempotent
        assert_eq!(c.free_gpus(0), 8);
    }

    #[test]
    fn shares_respect_contention() {
        let mut c = Cluster::new(ClusterConfig::default());
        // saturate CPU on server 0 with ten 12-vCPU tasks (120 > 96)
        for j in 0..10 {
            let mut t = worker(j, 0, 12.0, 0.5);
            t.role = Role::Ps { idx: 0 }; // avoid GPU slots
            c.add_task(t);
        }
        let sh = c.shares(0, Res::Cpu, 10.0);
        let total: f64 = sh.iter().map(|&(_, s)| s).sum();
        assert!(total <= 96.0 + 1e-6);
        for &(_, s) in &sh {
            assert!(s < 12.0); // contended: nobody gets full demand
        }
    }

    #[test]
    fn throttle_caps_share() {
        let mut c = Cluster::new(ClusterConfig::default());
        let id = c.add_task(worker(0, 0, 8.0, 1.0));
        c.tasks[id].cpu_cap = 0.1; // cpulimit to 10%
        let s = c.share_of(id, Res::Cpu, 5.0);
        assert!(s <= 0.8 + 1e-9, "{s}");
    }

    #[test]
    fn background_noise_is_smooth_and_bounded() {
        let c = Cluster::new(ClusterConfig::default());
        let mut prev = c.background_frac(0, Res::Cpu, 0.0);
        for i in 1..200 {
            let t = i as f64 * 0.1;
            let v = c.background_frac(0, Res::Cpu, t);
            assert!((0.0..=0.95).contains(&v));
            assert!((v - prev).abs() < 0.15, "jump at {t}: {prev} -> {v}");
            prev = v;
        }
    }

    #[test]
    fn background_deterministic() {
        let a = Cluster::new(ClusterConfig::default());
        let b = Cluster::new(ClusterConfig::default());
        for i in 0..50 {
            let t = i as f64 * 3.7;
            assert_eq!(a.background_frac(1, Res::Bw, t), b.background_frac(1, Res::Bw, t));
        }
    }

    #[test]
    fn spikes_heavy_tailed_and_reproducible() {
        let mut c = Cluster::new(ClusterConfig::default());
        // force spike generation out to t=50_000 (spikes are applied
        // per-task, so a task must be present)
        c.add_task(worker(0, 0, 2.0, 1.0));
        let _ = c.shares(0, Res::Cpu, 50_000.0);
        let durs: Vec<f64> = c.servers[0].spikes.iter().map(|s| s.end - s.start).collect();
        assert!(durs.len() > 50, "want many spikes, got {}", durs.len());
        for d in &durs {
            // tolerance: end = start + dur loses ~1e-11 at start ~ 5e4
            assert!((0.0999..=500.001).contains(d), "{d}");
        }
        let max = durs.iter().cloned().fold(0.0, f64::max);
        let med = crate::stats::median(&durs);
        assert!(max > 20.0 * med, "heavy tail expected: max={max} med={med}");
    }

    #[test]
    fn available_positive_and_below_capacity() {
        let mut c = Cluster::new(ClusterConfig::default());
        for i in 0..100 {
            let t = i as f64 * 13.3;
            let a = c.available(2, Res::Bw, t);
            assert!(a > 0.0 && a <= c.cfg.gpu_server_bw);
        }
    }

    #[test]
    fn ps_count_counts_only_active_ps() {
        let mut c = Cluster::new(ClusterConfig::default());
        let mut ps = worker(0, 3, 4.0, 2.0);
        ps.role = Role::Ps { idx: 0 };
        let a = c.add_task(ps.clone());
        c.add_task(worker(0, 3, 2.0, 1.0));
        assert_eq!(c.ps_count(3), 1);
        c.remove_task(a);
        assert_eq!(c.ps_count(3), 0);
    }

    #[test]
    fn utilization_rises_with_load() {
        let mut c = Cluster::new(ClusterConfig::default());
        let before = c.utilization(4, Res::Cpu, 100.0);
        for j in 0..12 {
            let mut t = worker(j, 4, 10.0, 0.2);
            t.role = Role::Ps { idx: 0 };
            c.add_task(t);
        }
        let after = c.utilization(4, Res::Cpu, 100.0);
        assert!(after > before);
        assert!(after <= 1.0);
    }
}
