//! Cluster substrate: servers, tasks, and resource contention.
//!
//! Reproduces the testbed of §III — 5 GPU instances (8 GPUs, 96 vCPUs
//! each) + 3 CPU instances (64 vCPUs) — as a contention model in which the
//! paper's straggler phenomena *emerge* rather than being injected:
//!
//! * every task (worker or PS) carries steady CPU/bandwidth demands from
//!   the model zoo (PSs demand more than workers, O4; ASGD more than
//!   SSGD, O5);
//! * each server grants **max–min fair (water-filling) shares** of its
//!   time-varying available capacity among co-located tasks;
//! * available capacity = nameplate − smooth background load (AR-like
//!   hash noise, paper [31]) − transient contention spikes with
//!   heavy-tailed durations (0.1–500 s, Fig 7);
//! * `cpulimit`/`tc`-style throttling (§V) is a per-task cap.
//!
//! Iteration times are then computed from these shares by the driver;
//! deviation ratios above 20% are stragglers (§II).
//!
//! ## Share cache (DESIGN.md §2.3)
//!
//! Share queries are the simulator's hottest path: every simulated
//! iteration start asks for the worker's CPU+bandwidth shares and every
//! PS's bandwidth share, and SSGD fires a whole round of iteration starts
//! at the *same* simulated instant. Shares are therefore computed **once
//! per (server, resource, time) epoch** into a reusable buffer (in-place
//! water-fill, no per-query allocation) and invalidated by *partitioned*
//! generation counters: each server carries its own monotonically
//! increasing generation that bumps whenever anything share-relevant
//! changes **on that server** (task registration/deactivation, caps,
//! throttles, demands), so a mutation on one server leaves every other
//! server's cached epochs valid (DESIGN.md §12). A global generation still
//! advances in lock-step for observability ([`Cluster::generation`]).
//! All mutation goes through [`Cluster::set_caps`]/[`Cluster::set_demands`]/
//! [`Cluster::set_throttles`] so invalidation cannot be missed; the cache
//! can be disabled ([`Cluster::set_share_cache_enabled`]) to force direct
//! recomputation, and the two paths are bit-identical (verified by the
//! `share_cache_equivalence` integration test).
//!
//! Within one server generation the co-located set and its capped demands
//! are constant — only availability and interference vary with `t` — so
//! each epoch also keeps its gathered demand vector and the water-fill's
//! sorted permutation keyed on the generation: a fill at a new time skips
//! the gather and the sort entirely and runs one O(n) allocation pass
//! (DESIGN.md §13). A fill itself is a pure function of per-server state
//! ([`fill_epoch`]'s signature proves it), which is what lets
//! [`Cluster::prefill_epochs`] fill the distinct epochs an upcoming round
//! will touch across scoped threads, byte-identically to serial fills.
//!
//! Contention-spike and per-task event lists are pruned as simulated time
//! advances (event durations are capped at 500 s, and the discrete-event
//! driver queries at non-decreasing times), so arbitrarily long traces run
//! in bounded memory.

use crate::simrng::Rng;

/// Resource kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Res {
    Cpu,
    Bw,
}

/// Server class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerKind {
    /// p4d.24xlarge-like: 8 GPUs, 96 vCPUs
    Gpu,
    /// m4.16xlarge-like: 0 GPUs, 64 vCPUs
    Cpu,
}

/// Task role within a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Worker { rank: usize },
    Ps { idx: usize },
}

impl Role {
    pub fn is_ps(&self) -> bool {
        matches!(self, Role::Ps { .. })
    }
}

/// A transient contention spike (external co-tenant interference).
#[derive(Clone, Copy, Debug)]
pub struct Spike {
    pub start: f64,
    pub end: f64,
    pub cpu_frac: f64,
    pub bw_frac: f64,
}

/// Spike durations are clamped to this (Fig 7's 0.1–500 s tail); it bounds
/// both the reverse scan and how far behind the clock pruning must keep
/// entries alive.
const SPIKE_MAX_DUR_S: f64 = 500.0;

/// Expired spikes are dropped in batches of this size (amortizes the
/// front-drain to O(1) per query).
const SPIKE_PRUNE_BATCH: usize = 64;

/// Below this many pending fills, `prefill_epochs` runs serially: a fill
/// is a few microseconds, so spawning scoped threads for a handful of
/// fills costs more than it saves. (Results are identical either way —
/// this is purely a dispatch heuristic.)
const PREFILL_MIN_PAR_FILLS: usize = 8;

/// One server.
#[derive(Clone, Debug)]
pub struct Server {
    pub kind: ServerKind,
    pub cpus: f64,
    pub bw_gbps: f64,
    pub gpus: usize,
    pub gpus_used: usize,
    /// lazily extended contention spikes, ordered by start; pruned as the
    /// query clock advances
    spikes: Vec<Spike>,
    spike_horizon: f64,
    /// highest query time pruning has run for — earlier queries would see
    /// wrong (missing) contention, so they are rejected in debug builds
    spike_pruned_to: f64,
    spike_rng: Rng,
}

/// A registered task.
///
/// Demands, caps, and throttles feed the share cache; the cluster's task
/// registry is private, so all mutation flows through the invalidating
/// `Cluster::set_*` methods (reads via [`Cluster::task`]).
#[derive(Clone, Debug)]
pub struct Task {
    pub job: usize,
    pub role: Role,
    pub server: usize,
    pub cpu_demand: f64,
    pub bw_demand: f64,
    /// dynamic caps (prevention / equalization), fraction of demand (0,1]
    pub cpu_cap: f64,
    pub bw_cap: f64,
    /// static throttles (the paper's cpulimit / tc), composed with caps
    pub cpu_throttle: f64,
    pub bw_throttle: f64,
    pub active: bool,
}

impl Task {
    pub fn capped_cpu(&self) -> f64 {
        self.cpu_demand * self.cpu_cap * self.cpu_throttle
    }

    pub fn capped_bw(&self) -> f64 {
        self.bw_demand * self.bw_cap * self.bw_throttle
    }
}

pub type TaskId = usize;

/// Cluster configuration (defaults = the paper's testbed).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub gpu_servers: usize,
    pub cpu_servers: usize,
    pub gpus_per_server: usize,
    pub gpu_server_cpus: f64,
    pub cpu_server_cpus: f64,
    /// effective per-server network budget for training traffic, Gbps.
    /// Calibrated (not nameplate 400G) so that PS fan-in contention can
    /// saturate links as in Fig 9 — see DESIGN.md §2.
    pub gpu_server_bw: f64,
    pub cpu_server_bw: f64,
    /// mean seconds between contention spikes per server
    pub spike_interval_s: f64,
    /// lognormal duration parameters (median ≈ 4 s, tail to ~500 s, Fig 7)
    pub spike_dur_mu: f64,
    pub spike_dur_sigma: f64,
    /// background load fraction bounds
    pub bg_base: f64,
    pub bg_amp: f64,
    /// mean seconds between per-task straggler events (0 = off)
    pub task_event_interval_s: f64,
    /// per-task event magnitude range (fraction of the task's share lost)
    pub task_event_mag: (f64, f64),
    pub seed: u64,
}

impl ClusterConfig {
    /// Total server count (GPU + CPU) this config builds.
    pub fn total_servers(&self) -> usize {
        self.gpu_servers + self.cpu_servers
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            gpu_servers: 5,
            cpu_servers: 3,
            gpus_per_server: 8,
            gpu_server_cpus: 96.0,
            cpu_server_cpus: 64.0,
            gpu_server_bw: 50.0,
            cpu_server_bw: 25.0,
            spike_interval_s: 240.0,
            spike_dur_mu: 1.4,    // e^1.4 ≈ 4 s median
            spike_dur_sigma: 1.6, // p99.9 ≈ 500 s
            bg_base: 0.08,
            bg_amp: 0.14,
            task_event_interval_s: 75.0,
            task_event_mag: (0.4, 0.85),
            seed: 0,
        }
    }
}

/// One cached share epoch for a (server, resource) pair: the water-filled,
/// interference-scaled share of every active task on the server at `time`,
/// valid while the cluster generation is unchanged. Buffers are reused
/// across epochs, so steady-state queries allocate nothing.
#[derive(Clone, Debug, Default)]
struct ShareEpoch {
    time: f64,
    generation: u64,
    valid: bool,
    /// task ids in `by_server` order at fill time
    ids: Vec<TaskId>,
    shares: Vec<f64>,
    /// Generation-keyed fill inputs (DESIGN.md §13): membership and
    /// capped demands change only on a `server_gen` bump, so fills at
    /// new times within one generation reuse the gathered vector (and
    /// its sum) instead of re-reading the task registry.
    inputs_gen: u64,
    inputs_valid: bool,
    demands: Vec<f64>,
    demand_total: f64,
    /// demand-sorted permutation for the over-capacity water-fill,
    /// built at most once per generation. `order_built` is separate
    /// from `inputs_valid` because under-capacity fills never need it —
    /// a later contended fill in the same generation builds it then.
    order: Vec<usize>,
    order_built: bool,
}

/// Per-task interference constants hoisted out of the fill inner loop:
/// the `smooth_noise` seeds for both resources at both time scales plus
/// the victim-hash bit — all pure functions of `(noise_seed, task id)`,
/// precomputed once at registration instead of re-hashed on every fill.
#[derive(Clone, Copy, Debug)]
struct TaskNoise {
    /// `res_idx`-indexed seed of the fast (t/3) noise component
    fast: [u64; 2],
    /// `res_idx`-indexed seed of the slow (t/45) noise component
    slow: [u64; 2],
    /// whether server spikes hit this task (hashed victim subset)
    victim: bool,
}

impl TaskNoise {
    fn compute(noise_seed: u64, id: TaskId) -> Self {
        let mut fast = [0u64; 2];
        let mut slow = [0u64; 2];
        for res in [Res::Cpu, Res::Bw] {
            let tag = 0x7a5c_u64 ^ ((id as u64) << 16) ^ res_tag(res);
            fast[res_idx(res)] = noise_seed ^ tag;
            slow[res_idx(res)] = noise_seed ^ tag ^ 0x99;
        }
        let h = (noise_seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_mul(0xBF58_476D_1CE4_E5B9);
        TaskNoise { fast, slow, victim: (h >> 32) & 1 == 0 }
    }
}

/// The cluster: servers + task registry + contention model.
///
/// Everything that feeds a share computation — tasks, server capacities,
/// config — is private, so a mutation that bypasses the cache's
/// generation bump cannot compile; read through [`Cluster::task`],
/// [`Cluster::server`], and [`Cluster::config`].
#[derive(Clone, Debug)]
pub struct Cluster {
    cfg: ClusterConfig,
    servers: Vec<Server>,
    tasks: Vec<Task>,
    /// suspension flags parallel to `tasks` (fault injection): a
    /// suspended task keeps its registration (and GPU slot — it restarts
    /// in place) but leaves `by_server`, so it draws no shares
    suspended: Vec<bool>,
    /// per-server capacity-degradation windows from the fault plan
    /// (NIC flaps / co-located bursts), registered up-front and queried
    /// statelessly by `available`
    degradations: Vec<Vec<Spike>>,
    /// per-server list of active task ids (hot-path index; share queries
    /// happen on every simulated iteration)
    by_server: Vec<Vec<TaskId>>,
    /// lazily-created per-task straggler-event streams (heavy-tailed
    /// slowdowns hitting one task: pinned-core co-tenants, NIC queue
    /// imbalance, GC pauses — the paper's 0.1–500 s events, Fig 7).
    /// Owned **per server** (outer index) so a share-epoch fill touches
    /// only its own server's streams — the state partitioning that makes
    /// [`Cluster::prefill_epochs`] data-race-free; `event_slot` maps a
    /// global task id to its slot. Stream RNGs stay keyed on the global
    /// id, so the streams are bit-identical to the old flat layout.
    task_events: Vec<Vec<SpikeStream>>,
    /// task id -> index into `task_events[task.server]`
    event_slot: Vec<usize>,
    /// task id -> precomputed interference constants
    task_noise: Vec<TaskNoise>,
    noise_seed: u64,
    /// bumped on any share-relevant mutation — the cluster-wide change
    /// counter exposed through [`Cluster::generation`]
    generation: u64,
    /// per-server generation (DESIGN.md §12): bumped alongside
    /// `generation` but only for the mutated task's server, and it —
    /// not the global counter — keys the share-epoch cache. A task
    /// event on one server therefore invalidates only that server's
    /// two epochs; every other partition's cached shares stay hot.
    /// Bit-identical to global keying: the generation is purely an
    /// invalidation key, and a fill at (server, res, t) is a
    /// deterministic function of that server's state
    server_gen: Vec<u64>,
    /// server ids by kind, precomputed at construction (the server set is
    /// immutable after `new`, so these never invalidate); placement asks
    /// for them on every job admission
    gpu_ids: Vec<usize>,
    cpu_ids: Vec<usize>,
    /// `servers.len() * 2` epochs, indexed `server * 2 + res_idx(res)`
    cache: Vec<ShareEpoch>,
    cache_enabled: bool,
    /// number of epoch recomputations (cache misses); the partition
    /// tests assert that cross-server mutations leave this untouched
    epoch_fills: u64,
    /// when set, every fill's wall time accrues into `fill_wall_s`
    /// (off by default: `Instant::now` twice per fill is measurable on
    /// the million-fill traces)
    fill_timing: bool,
    /// cumulative wall-clock seconds spent inside epoch fills (only
    /// accrued while `fill_timing` is on); for parallel prefill this is
    /// the *sum over workers* — cross-thread fill cost, not elapsed time
    fill_wall_s: f64,
    /// prefill scratch: per-server bitmask of requested resources
    /// (`1 << res_idx`) and the list of servers holding a nonzero mask —
    /// reused across rounds so prefill allocates nothing in steady state
    prefill_mask: Vec<u8>,
    prefill_servers: Vec<usize>,
}

/// A lazily-extended stream of heavy-tailed events.
#[derive(Clone, Debug)]
pub struct SpikeStream {
    spikes: Vec<Spike>,
    horizon: f64,
    /// see `Server::spike_pruned_to`
    pruned_to: f64,
    rng: Rng,
}

impl SpikeStream {
    fn new(rng: Rng) -> Self {
        SpikeStream { spikes: Vec::new(), horizon: 0.0, pruned_to: 0.0, rng }
    }

    /// Extend to time `t` and return the active magnitude for `res`.
    fn frac_at(&mut self, t: f64, interval: f64, mag: (f64, f64), dur_mu: f64, dur_sigma: f64, res: Res) -> f64 {
        debug_assert!(
            t >= self.pruned_to,
            "cluster query times must be non-decreasing once pruning has run \
             (query at {t}, events pruned for {})",
            self.pruned_to
        );
        while self.horizon <= t {
            let gap = self.rng.exponential(1.0 / interval);
            let start = self.horizon + gap;
            let dur = self.rng.lognormal(dur_mu, dur_sigma).clamp(0.1, SPIKE_MAX_DUR_S);
            let both = self.rng.chance(0.35);
            let on_cpu = both || self.rng.chance(0.5);
            let m = self.rng.range(mag.0, mag.1);
            self.spikes.push(Spike {
                start,
                end: start + dur,
                cpu_frac: if on_cpu { m } else { 0.0 },
                bw_frac: if !on_cpu || both { m } else { 0.0 },
            });
            self.horizon = start;
        }
        prune_spikes(&mut self.spikes, t, &mut self.pruned_to);
        let mut frac: f64 = 0.0;
        for sp in self.spikes.iter().rev() {
            if sp.start > t {
                continue;
            }
            if sp.end > t {
                frac += match res {
                    Res::Cpu => sp.cpu_frac,
                    Res::Bw => sp.bw_frac,
                };
            }
            if sp.start + SPIKE_MAX_DUR_S < t {
                break;
            }
        }
        frac.min(0.9)
    }
}

/// Drop spikes that can no longer overlap any query at time >= `t`:
/// entries are start-ordered with duration <= [`SPIKE_MAX_DUR_S`], so
/// everything with `start + 500 < t` is dead (the driver's query times are
/// non-decreasing). Drained in batches to stay O(1) amortized.
/// `pruned_to` records the watermark so debug builds can reject the
/// out-of-order queries that pruning would silently answer wrong.
fn prune_spikes(spikes: &mut Vec<Spike>, t: f64, pruned_to: &mut f64) {
    let cut = spikes.partition_point(|s| s.start + SPIKE_MAX_DUR_S < t);
    if cut >= SPIKE_PRUNE_BATCH {
        spikes.drain(..cut);
        *pruned_to = t;
    }
}

fn res_idx(res: Res) -> usize {
    match res {
        Res::Cpu => 0,
        Res::Bw => 1,
    }
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0x5eed);
        let n_servers = cfg.gpu_servers + cfg.cpu_servers;
        let mut servers = Vec::with_capacity(n_servers);
        for i in 0..n_servers {
            let gpu = i < cfg.gpu_servers;
            servers.push(Server {
                kind: if gpu { ServerKind::Gpu } else { ServerKind::Cpu },
                cpus: if gpu { cfg.gpu_server_cpus } else { cfg.cpu_server_cpus },
                bw_gbps: if gpu { cfg.gpu_server_bw } else { cfg.cpu_server_bw },
                gpus: if gpu { cfg.gpus_per_server } else { 0 },
                gpus_used: 0,
                spikes: Vec::new(),
                spike_horizon: 0.0,
                spike_pruned_to: 0.0,
                // 0x5e4e_0000 + index keeps the seed lineage of the
                // original per-server fork tags (bit-compatible streams)
                spike_rng: rng.fork(0x5e4e_0000 + i as u64),
            });
        }
        let noise_seed = rng.next_u64();
        let by_server = vec![Vec::new(); servers.len()];
        let cache = vec![ShareEpoch::default(); servers.len() * 2];
        let degradations = vec![Vec::new(); servers.len()];
        let gpu_ids = (0..servers.len()).filter(|&s| servers[s].kind == ServerKind::Gpu).collect();
        let cpu_ids = (0..servers.len()).filter(|&s| servers[s].kind == ServerKind::Cpu).collect();
        Cluster {
            cfg,
            servers,
            tasks: Vec::new(),
            suspended: Vec::new(),
            degradations,
            by_server,
            task_events: vec![Vec::new(); n_servers],
            event_slot: Vec::new(),
            task_noise: Vec::new(),
            noise_seed,
            generation: 0,
            server_gen: vec![0; n_servers],
            gpu_ids,
            cpu_ids,
            cache,
            cache_enabled: true,
            epoch_fills: 0,
            fill_timing: false,
            fill_wall_s: 0.0,
            prefill_mask: vec![0; n_servers],
            prefill_servers: Vec::new(),
        }
    }

    /// GPU-server ids, ascending — precomputed at construction (the
    /// server set never changes after `new`), so callers get a slice
    /// instead of a freshly collected `Vec` per placement.
    pub fn gpu_server_ids(&self) -> &[usize] {
        &self.gpu_ids
    }

    /// CPU-server ids, ascending (see [`Cluster::gpu_server_ids`]).
    pub fn cpu_server_ids(&self) -> &[usize] {
        &self.cpu_ids
    }

    // -- task registry -------------------------------------------------------

    /// Record a share-relevant mutation on `server`: the global change
    /// counter and the server's partition generation move together, so
    /// only that server's cached epochs go stale (DESIGN.md §12).
    fn bump(&mut self, server: usize) {
        self.generation += 1;
        self.server_gen[server] += 1;
    }

    /// Register a task; workers consume a GPU slot on their server.
    pub fn add_task(&mut self, task: Task) -> TaskId {
        if matches!(task.role, Role::Worker { .. }) {
            self.servers[task.server].gpus_used += 1;
            debug_assert!(
                self.servers[task.server].gpus_used <= self.servers[task.server].gpus,
                "GPU oversubscription on server {}",
                task.server
            );
        }
        let server = task.server;
        self.tasks.push(task);
        self.suspended.push(false);
        let id = self.tasks.len() - 1;
        self.by_server[server].push(id);
        // the stream RNG stays keyed on the *global* id even though the
        // stream lives in its server's partition — bit-compatible with
        // the pre-partitioned flat layout
        self.event_slot.push(self.task_events[server].len());
        self.task_events[server].push(SpikeStream::new(Rng::new(
            self.noise_seed ^ (id as u64).wrapping_mul(0xA24B_AED4_963E_E407),
            0x7a51,
        )));
        self.task_noise.push(TaskNoise::compute(self.noise_seed, id));
        self.bump(server);
        id
    }

    /// Deactivate a task (job finished) and release its GPU slot. Works
    /// on suspended tasks too (a job can finish while a member is down).
    pub fn remove_task(&mut self, id: TaskId) {
        if self.tasks[id].active {
            self.tasks[id].active = false;
            self.suspended[id] = false;
            let server = self.tasks[id].server;
            self.by_server[server].retain(|&x| x != id);
            if matches!(self.tasks[id].role, Role::Worker { .. }) {
                self.servers[server].gpus_used -= 1;
            }
            self.bump(server);
        }
    }

    /// Suspend a task (fault injection: crash / server outage). The task
    /// keeps its registration and GPU slot (it restarts in place) but
    /// stops drawing shares; the share-epoch cache is invalidated via the
    /// generation bump (DESIGN.md §2.3).
    pub fn suspend_task(&mut self, id: TaskId) {
        if self.tasks[id].active && !self.suspended[id] {
            self.suspended[id] = true;
            let server = self.tasks[id].server;
            self.by_server[server].retain(|&x| x != id);
            self.bump(server);
        }
    }

    /// Resume a previously suspended task (restart complete).
    pub fn resume_task(&mut self, id: TaskId) {
        if self.tasks[id].active && self.suspended[id] {
            self.suspended[id] = false;
            let server = self.tasks[id].server;
            self.by_server[server].push(id);
            self.bump(server);
        }
    }

    /// Is this task currently suspended?
    pub fn is_suspended(&self, id: TaskId) -> bool {
        self.suspended[id]
    }

    /// Register a capacity-degradation window [start, end) on `server`
    /// (fault plan: NIC flap / co-located burst). Windows are expected to
    /// be registered before the simulation queries their span; the
    /// generation bump drops any epoch cached in the meantime.
    pub fn add_degradation(
        &mut self,
        server: usize,
        start: f64,
        end: f64,
        cpu_frac: f64,
        bw_frac: f64,
    ) {
        self.degradations[server].push(Spike {
            start,
            end,
            cpu_frac: cpu_frac.clamp(0.0, 0.9),
            bw_frac: bw_frac.clamp(0.0, 0.9),
        });
        self.degradations[server]
            .sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        self.bump(server);
    }

    /// Degraded capacity fraction on `server` at `t` (0 when no window
    /// from the fault plan overlaps). Windows are start-ordered, so the
    /// scan stops at the first window opening after `t` — this sits on
    /// the `available` hot path (every share-epoch fill).
    pub fn degradation_frac(&self, server: usize, res: Res, t: f64) -> f64 {
        degradation_frac_in(&self.degradations[server], res, t)
    }

    /// Set a task's dynamic caps (§IV-D1 prevention / equalization),
    /// invalidating cached shares when the values actually change.
    pub fn set_caps(&mut self, id: TaskId, cpu_cap: f64, bw_cap: f64) {
        let t = &mut self.tasks[id];
        if t.cpu_cap != cpu_cap || t.bw_cap != bw_cap {
            t.cpu_cap = cpu_cap;
            t.bw_cap = bw_cap;
            let server = t.server;
            self.bump(server);
        }
    }

    /// Set a task's static throttles (the paper's cpulimit / tc).
    pub fn set_throttles(&mut self, id: TaskId, cpu_throttle: f64, bw_throttle: f64) {
        let t = &mut self.tasks[id];
        if t.cpu_throttle != cpu_throttle || t.bw_throttle != bw_throttle {
            t.cpu_throttle = cpu_throttle;
            t.bw_throttle = bw_throttle;
            let server = t.server;
            self.bump(server);
        }
    }

    /// Set a task's steady demands (mode-dependent, O5).
    pub fn set_demands(&mut self, id: TaskId, cpu_demand: f64, bw_demand: f64) {
        let t = &mut self.tasks[id];
        if t.cpu_demand != cpu_demand || t.bw_demand != bw_demand {
            t.cpu_demand = cpu_demand;
            t.bw_demand = bw_demand;
            let server = t.server;
            self.bump(server);
        }
    }

    /// Read-only view of one registered task.
    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }

    /// Number of tasks ever registered (deactivated ones keep their slot).
    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Read-only view of one server.
    pub fn server(&self, s: usize) -> &Server {
        &self.servers[s]
    }

    pub fn server_count(&self) -> usize {
        self.servers.len()
    }

    /// Read-only view of the cluster configuration.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Current invalidation generation (bumps on any share-relevant change).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Number of share-epoch recomputations so far (cache misses). The
    /// partitioned-invalidation tests assert that mutations on one
    /// server leave other servers' epochs hot (no new fills).
    pub fn epoch_fills(&self) -> u64 {
        self.epoch_fills
    }

    /// Cumulative wall-clock seconds spent inside epoch fills. Zero
    /// unless [`Cluster::set_fill_timing`] enabled timing; for parallel
    /// prefills this sums the per-worker fill time (total compute, not
    /// elapsed), so fills/second stays comparable at any thread count.
    pub fn fill_wall_s(&self) -> f64 {
        self.fill_wall_s
    }

    /// Enable per-fill wall-time accrual (off by default: two `Instant`
    /// reads per fill are measurable on million-fill traces).
    pub fn set_fill_timing(&mut self, on: bool) {
        self.fill_timing = on;
    }

    /// Disable (or re-enable) the share cache. With the cache off every
    /// query recomputes from scratch — the reference path the equivalence
    /// tests compare against; results are bit-identical either way.
    pub fn set_share_cache_enabled(&mut self, enabled: bool) {
        self.cache_enabled = enabled;
    }

    pub fn free_gpus(&self, server: usize) -> usize {
        self.servers[server].gpus - self.servers[server].gpus_used
    }

    /// Number of active PSs hosted on `server`.
    pub fn ps_count(&self, server: usize) -> usize {
        self.by_server[server].iter().filter(|&&i| self.tasks[i].role.is_ps()).count()
    }

    // -- contention model ----------------------------------------------------

    /// Smooth background load fraction in [bg_base, bg_base+bg_amp]:
    /// cosine-interpolated hash noise at two time scales (seconds +
    /// minutes), deterministic in (seed, server, resource, t).
    pub fn background_frac(&self, server: usize, res: Res, t: f64) -> f64 {
        background_frac_in(&self.cfg, self.noise_seed, server, res, t)
    }

    /// Available capacity of `res` on `server` at time `t`: nameplate
    /// minus smooth background load minus any fault-plan degradation
    /// window overlapping `t`.
    pub fn available(&self, server: usize, res: Res, t: f64) -> f64 {
        available_in(
            &self.servers[server],
            &self.degradations[server],
            &self.cfg,
            self.noise_seed,
            server,
            res,
            t,
        )
    }

    /// Fill the (server, res) share epoch for time `t` unless it is
    /// already current. The fill itself is [`fill_epoch`] — a free
    /// function of one server's state — so this method is only the cache
    /// check plus accounting; `prefill_epochs` runs the same function on
    /// disjoint servers across threads.
    fn ensure_epoch(&mut self, server: usize, res: Res, t: f64) {
        let slot = server * 2 + res_idx(res);
        if self.cache_enabled {
            let e = &self.cache[slot];
            if e.valid && e.generation == self.server_gen[server] && e.time == t {
                return;
            }
        }
        self.epoch_fills += 1;
        let t0 = if self.fill_timing { Some(std::time::Instant::now()) } else { None };
        let ctx = FillCtx {
            cfg: &self.cfg,
            tasks: &self.tasks,
            noise: &self.task_noise,
            event_slot: &self.event_slot,
            by_server: &self.by_server,
            degradations: &self.degradations,
            noise_seed: self.noise_seed,
            reuse_inputs: self.cache_enabled,
        };
        fill_epoch(
            &ctx,
            &mut self.servers[server],
            &mut self.task_events[server],
            &mut self.cache[slot],
            self.server_gen[server],
            server,
            res,
            t,
        );
        if let Some(t0) = t0 {
            self.fill_wall_s += t0.elapsed().as_secs_f64();
        }
    }

    /// Fill the distinct `(server, res)` epochs in `keys` for time `t`
    /// across up to `threads` scoped workers, returning how many fills
    /// actually ran (already-current epochs are skipped, duplicates
    /// deduped). Byte-identical to filling them one by one on the query
    /// path: a fill is a pure function of its own server's state
    /// ([`fill_epoch`]), distinct servers share no mutable state, and the
    /// lazy spike/event streams extend deterministically to whatever time
    /// first queries them — so *who* fills an epoch can never change
    /// *what* it holds. With the cache disabled this is a no-op (there is
    /// nothing to pre-fill; every query recomputes anyway).
    pub fn prefill_epochs(&mut self, keys: &[(usize, Res)], t: f64, threads: usize) -> usize {
        if keys.is_empty() || !self.cache_enabled {
            return 0;
        }
        // dedupe into per-server resource masks, skipping epochs that are
        // already current (same check as ensure_epoch)
        let mut pending = 0usize;
        for &(server, res) in keys {
            let e = &self.cache[server * 2 + res_idx(res)];
            if e.valid && e.generation == self.server_gen[server] && e.time == t {
                continue;
            }
            let bit = 1u8 << res_idx(res);
            if self.prefill_mask[server] & bit == 0 {
                if self.prefill_mask[server] == 0 {
                    self.prefill_servers.push(server);
                }
                self.prefill_mask[server] |= bit;
                pending += 1;
            }
        }
        if pending == 0 {
            return 0;
        }
        let workers = threads.min(self.prefill_servers.len());
        if workers <= 1 || pending < PREFILL_MIN_PAR_FILLS {
            // not worth spawning: run the fills serially in key order
            let servers = std::mem::take(&mut self.prefill_servers);
            for &server in &servers {
                let mask = std::mem::replace(&mut self.prefill_mask[server], 0);
                for res in [Res::Cpu, Res::Bw] {
                    if mask & (1 << res_idx(res)) != 0 {
                        self.ensure_epoch(server, res, t);
                    }
                }
            }
            self.prefill_servers = servers;
            self.prefill_servers.clear();
            return pending;
        }
        // deterministic partition: ascending server order, contiguous chunks
        self.prefill_servers.sort_unstable();
        let ctx = FillCtx {
            cfg: &self.cfg,
            tasks: &self.tasks,
            noise: &self.task_noise,
            event_slot: &self.event_slot,
            by_server: &self.by_server,
            degradations: &self.degradations,
            noise_seed: self.noise_seed,
            reuse_inputs: true,
        };
        let timing = self.fill_timing;
        let mask = &self.prefill_mask;
        let gens = &self.server_gen;
        let wall: f64 = {
            // zip the per-server mutable state into disjoint work items:
            // each item owns one server's Server, event streams, and two
            // cache slots, so scoped threads mutate without overlap
            let mut work = Vec::with_capacity(self.prefill_servers.len());
            {
                let mut want = self.prefill_servers.iter().copied().peekable();
                for (((s, srv), events), slots) in self
                    .servers
                    .iter_mut()
                    .enumerate()
                    .zip(self.task_events.iter_mut())
                    .zip(self.cache.chunks_exact_mut(2))
                {
                    if want.peek() == Some(&s) {
                        want.next();
                        work.push((s, srv, events, slots));
                    }
                }
            }
            let chunk = work.len().div_ceil(workers);
            let walls: Vec<f64> = std::thread::scope(|scope| {
                let handles: Vec<_> = work
                    .chunks_mut(chunk)
                    .map(|part| {
                        let ctx = &ctx;
                        scope.spawn(move || {
                            let mut w = 0.0f64;
                            for (s, srv, events, slots) in part.iter_mut() {
                                let t0 = if timing { Some(std::time::Instant::now()) } else { None };
                                let m = mask[*s];
                                let gen = gens[*s];
                                let (cpu_slot, bw_slot) = slots.split_at_mut(1);
                                if m & (1 << res_idx(Res::Cpu)) != 0 {
                                    fill_epoch(ctx, srv, events, &mut cpu_slot[0], gen, *s, Res::Cpu, t);
                                }
                                if m & (1 << res_idx(Res::Bw)) != 0 {
                                    fill_epoch(ctx, srv, events, &mut bw_slot[0], gen, *s, Res::Bw, t);
                                }
                                if let Some(t0) = t0 {
                                    w += t0.elapsed().as_secs_f64();
                                }
                            }
                            w
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("prefill worker panicked")).collect()
            });
            walls.into_iter().sum()
        };
        self.epoch_fills += pending as u64;
        self.fill_wall_s += wall;
        for &server in &self.prefill_servers {
            self.prefill_mask[server] = 0;
        }
        self.prefill_servers.clear();
        pending
    }

    /// Max–min fair share of `res` for every active task on `server` at
    /// time `t`. Returns (task_id, share) pairs.
    pub fn shares(&mut self, server: usize, res: Res, t: f64) -> Vec<(TaskId, f64)> {
        let mut out = Vec::new();
        self.shares_into(server, res, t, &mut out);
        out
    }

    /// Allocation-free [`Cluster::shares`]: fills `out` (cleared first)
    /// with the same (task_id, share) pairs in the same order — with a
    /// reused buffer, repeat queries allocate nothing. Bit-identical to
    /// `shares` (pinned by a proptest).
    pub fn shares_into(&mut self, server: usize, res: Res, t: f64, out: &mut Vec<(TaskId, f64)>) {
        self.ensure_epoch(server, res, t);
        let e = &self.cache[server * 2 + res_idx(res)];
        out.clear();
        out.extend(e.ids.iter().copied().zip(e.shares.iter().copied()));
    }

    /// Zero-copy view of the (server, res, t) share epoch: parallel
    /// `(task_ids, shares)` slices straight out of the cache. Valid until
    /// the next `&mut self` call; for callers that only scan, this is the
    /// cheapest form — no pair-building at all.
    pub fn shares_view(&mut self, server: usize, res: Res, t: f64) -> (&[TaskId], &[f64]) {
        self.ensure_epoch(server, res, t);
        let e = &self.cache[server * 2 + res_idx(res)];
        (&e.ids, &e.shares)
    }

    /// Share granted to one task (water-filled against its co-located set).
    pub fn share_of(&mut self, id: TaskId, res: Res, t: f64) -> f64 {
        let server = self.tasks[id].server;
        self.ensure_epoch(server, res, t);
        let e = &self.cache[server * 2 + res_idx(res)];
        e.ids.iter().position(|&i| i == id).map(|k| e.shares[k]).unwrap_or(0.0)
    }

    /// Batched hot-path query: one task's (CPU, bandwidth) share pair at
    /// `t`. Fills at most two epochs; repeat queries at the same instant
    /// (e.g. a whole SSGD round starting together) are pure lookups.
    pub fn worker_shares(&mut self, id: TaskId, t: f64) -> (f64, f64) {
        (self.share_of(id, Res::Cpu, t), self.share_of(id, Res::Bw, t))
    }

    /// Batched hot-path query: sum of bandwidth shares over `ids` (the
    /// PS-side aggregate fan-in) at `t`, one epoch fill per server.
    pub fn bw_share_sum(&mut self, ids: &[TaskId], t: f64) -> f64 {
        let mut sum = 0.0;
        for &id in ids {
            sum += self.share_of(id, Res::Bw, t);
        }
        sum
    }

    /// Fraction of nameplate capacity in use on `server` (for Fig 9).
    pub fn utilization(&mut self, server: usize, res: Res, t: f64) -> f64 {
        let cap = match res {
            Res::Cpu => self.servers[server].cpus,
            Res::Bw => self.servers[server].bw_gbps,
        };
        self.ensure_epoch(server, res, t);
        let granted: f64 = self.cache[server * 2 + res_idx(res)].shares.iter().sum();
        let external = cap - self.available(server, res, t);
        ((granted + external) / cap).clamp(0.0, 1.0)
    }
}

/// The immutable cluster state a share-epoch fill reads — everything
/// except the one server being filled. Building it (all shared `&`
/// borrows plus two copies) is free, and because it is `Sync`, one
/// context serves every prefill worker at once; the per-server *mutable*
/// state travels separately as `&mut` arguments, which is exactly the
/// disjointness that makes parallel prefill sound (DESIGN.md §13).
struct FillCtx<'a> {
    cfg: &'a ClusterConfig,
    tasks: &'a [Task],
    noise: &'a [TaskNoise],
    event_slot: &'a [usize],
    by_server: &'a [Vec<TaskId>],
    degradations: &'a [Vec<Spike>],
    noise_seed: u64,
    /// whether generation-keyed fill inputs may be reused. False when the
    /// share cache is disabled, so the reference path re-gathers and
    /// re-sorts from the registry on every query — a true from-scratch
    /// recompute for the equivalence tests to compare against.
    reuse_inputs: bool,
}

/// See [`Cluster::background_frac`].
fn background_frac_in(cfg: &ClusterConfig, noise_seed: u64, server: usize, res: Res, t: f64) -> f64 {
    let tag = (server as u64) << 8 | res_tag(res);
    let fast = smooth_noise(noise_seed ^ tag, t);
    let slow = smooth_noise(noise_seed ^ tag ^ 0xABCD, t / 60.0);
    (cfg.bg_base + cfg.bg_amp * (0.6 * slow + 0.4 * fast)).clamp(0.0, 0.95)
}

/// See [`Cluster::degradation_frac`]; `windows` is one server's
/// start-ordered degradation list.
fn degradation_frac_in(windows: &[Spike], res: Res, t: f64) -> f64 {
    let mut frac: f64 = 0.0;
    for w in windows {
        if w.start > t {
            break;
        }
        if t < w.end {
            frac += match res {
                Res::Cpu => w.cpu_frac,
                Res::Bw => w.bw_frac,
            };
        }
    }
    frac.min(0.9)
}

/// See [`Cluster::available`].
fn available_in(
    srv: &Server,
    windows: &[Spike],
    cfg: &ClusterConfig,
    noise_seed: u64,
    server: usize,
    res: Res,
    t: f64,
) -> f64 {
    let cap = match res {
        Res::Cpu => srv.cpus,
        Res::Bw => srv.bw_gbps,
    };
    let bg = background_frac_in(cfg, noise_seed, server, res, t);
    let deg = degradation_frac_in(windows, res, t);
    (cap * (1.0 - (bg + deg).min(0.95))).max(0.05 * cap)
}

/// Extend + query one server's contention spikes overlapping time `t`.
fn spike_frac_in(cfg: &ClusterConfig, srv: &mut Server, res: Res, t: f64) -> f64 {
    debug_assert!(
        t >= srv.spike_pruned_to,
        "cluster query times must be non-decreasing once pruning has run \
         (query at {t}, server spikes pruned for {})",
        srv.spike_pruned_to
    );
    while srv.spike_horizon <= t {
        let gap = srv.spike_rng.exponential(1.0 / cfg.spike_interval_s);
        let start = srv.spike_horizon + gap;
        let dur = srv.spike_rng.lognormal(cfg.spike_dur_mu, cfg.spike_dur_sigma).clamp(0.1, SPIKE_MAX_DUR_S);
        let both = srv.spike_rng.chance(0.3);
        let on_cpu = both || srv.spike_rng.chance(0.5);
        let mag = srv.spike_rng.range(0.2, 0.7);
        srv.spikes.push(Spike {
            start,
            end: start + dur,
            cpu_frac: if on_cpu { mag } else { 0.0 },
            bw_frac: if !on_cpu || both { mag } else { 0.0 },
        });
        srv.spike_horizon = start;
    }
    prune_spikes(&mut srv.spikes, t, &mut srv.spike_pruned_to);
    // sum overlapping (rare to have >1); scan tail (spikes sorted by start)
    let mut frac: f64 = 0.0;
    for s in srv.spikes.iter().rev() {
        if s.start > t {
            continue;
        }
        if s.end > t {
            frac += match res {
                Res::Cpu => s.cpu_frac,
                Res::Bw => s.bw_frac,
            };
        }
        // spikes are start-ordered; once start+500 < t nothing earlier overlaps
        if s.start + SPIKE_MAX_DUR_S < t {
            break;
        }
    }
    frac.min(0.9)
}

/// Interference fraction in [0, 0.9] on one task: smooth per-task noise
/// (amplified under load) + heavy-tailed contention spikes that hit a
/// hashed subset of the server's tasks. `events` is the task's server's
/// stream partition. Seeds and the victim bit come precomputed from
/// [`TaskNoise`]; values are bit-identical to hashing them inline.
fn task_interference_in(
    ctx: &FillCtx<'_>,
    srv: &mut Server,
    events: &mut [SpikeStream],
    id: TaskId,
    res: Res,
    t: f64,
    load: f64,
) -> f64 {
    // smooth component: per-task two-scale noise, squared for a skewed
    // (mostly-small, occasionally-large) distribution
    let tn = &ctx.noise[id];
    let fast = smooth_noise(tn.fast[res_idx(res)], t / 3.0);
    let slow = smooth_noise(tn.slow[res_idx(res)], t / 45.0);
    let u = 0.5 * fast + 0.5 * slow;
    // superlinear in load: relieving a loaded server (balanced PS
    // placement, §IV-D1 equalization caps) pays off disproportionately
    let smooth = 1.1 * u * u * load.clamp(0.0, 1.2).powf(1.5);
    // spike component: victim-hashed server spikes
    let spike = spike_frac_in(ctx.cfg, srv, res, t);
    let hit = if tn.victim { spike } else { 0.0 };
    // per-task heavy-tailed straggler events (the dominant mechanism)
    let own = if ctx.cfg.task_event_interval_s > 0.0 {
        events[ctx.event_slot[id]].frac_at(
            t,
            ctx.cfg.task_event_interval_s,
            ctx.cfg.task_event_mag,
            ctx.cfg.spike_dur_mu,
            ctx.cfg.spike_dur_sigma,
            res,
        )
    } else {
        0.0
    };
    (smooth + hit + own).clamp(0.0, 0.9)
}

/// Compute the (server, res, t) share epoch into `e`: gather the
/// co-located demands (or reuse the generation-keyed cached vector), one
/// in-place water-fill (sort skipped when the permutation is already
/// built for this generation), then per-task interference scaling. This
/// is the only place shares are computed.
///
/// A pure function of its arguments — it touches exactly one server's
/// mutable state (`srv`, that server's `events` partition, its epoch
/// `e`) plus the shared read-only [`FillCtx`] — which is the whole
/// soundness argument for [`Cluster::prefill_epochs`] running fills for
/// distinct servers concurrently.
#[allow(clippy::too_many_arguments)]
fn fill_epoch(
    ctx: &FillCtx<'_>,
    srv: &mut Server,
    events: &mut Vec<SpikeStream>,
    e: &mut ShareEpoch,
    gen: u64,
    server: usize,
    res: Res,
    t: f64,
) {
    let avail = available_in(srv, &ctx.degradations[server], ctx.cfg, ctx.noise_seed, server, res, t);
    if !(ctx.reuse_inputs && e.inputs_valid && e.inputs_gen == gen) {
        // membership or demands changed (or reuse is disabled): re-gather
        // from the registry and drop the stale permutation
        e.ids.clear();
        e.ids.extend_from_slice(&ctx.by_server[server]);
        e.demands.clear();
        for &i in &e.ids {
            e.demands.push(match res {
                Res::Cpu => ctx.tasks[i].capped_cpu(),
                Res::Bw => ctx.tasks[i].capped_bw(),
            });
        }
        e.demand_total = e.demands.iter().sum();
        e.inputs_gen = gen;
        e.inputs_valid = true;
        e.order_built = false;
    }
    // water-fill over the cached inputs (same arithmetic as
    // `water_fill_into`, with the gather and sort amortized across the
    // generation)
    let n = e.ids.len();
    e.shares.clear();
    e.shares.resize(n, 0.0);
    if n > 0 {
        if e.demand_total <= avail {
            e.shares.copy_from_slice(&e.demands);
        } else {
            if !e.order_built {
                let (demands, order) = (&e.demands, &mut e.order);
                order.clear();
                order.extend(0..n);
                order.sort_by(|&a, &b| demands[a].partial_cmp(&demands[b]).unwrap());
                e.order_built = true;
            }
            fill_sorted_over(&e.demands, avail, &e.order, &mut e.shares);
        }
    }
    // per-task interference: co-tenant contention hits individual tasks
    // unevenly (pinned cores, NIC queues), which is where the paper's
    // *within-server* stragglers come from (Fig 3/4). Scaled by how
    // loaded the server is.
    let load = (e.demand_total / avail.max(1e-9)).min(1.5);
    for k in 0..n {
        let id = e.ids[k];
        e.shares[k] *= 1.0 - task_interference_in(ctx, srv, events, id, res, t, load);
    }
    e.time = t;
    e.generation = gen;
    e.valid = true;
}

/// Max–min fair (water-filling) allocation of `capacity` among `demands`;
/// no task receives more than its demand, and unmet demand shares the
/// remainder equally.
pub fn water_fill(demands: &[f64], capacity: f64) -> Vec<f64> {
    let mut order = Vec::new();
    let mut alloc = Vec::new();
    water_fill_into(demands, capacity, &mut order, &mut alloc);
    alloc
}

/// In-place [`water_fill`]: writes the allocation into `alloc` using
/// `order` as sort scratch, allocating nothing once the buffers have grown
/// to the working-set size. Identical results to `water_fill` (same stable
/// sort, same tie-breaking).
pub fn water_fill_into(
    demands: &[f64],
    capacity: f64,
    order: &mut Vec<usize>,
    alloc: &mut Vec<f64>,
) {
    let n = demands.len();
    alloc.clear();
    alloc.resize(n, 0.0);
    if n == 0 {
        return;
    }
    let total: f64 = demands.iter().sum();
    if total <= capacity {
        alloc.copy_from_slice(demands);
        return;
    }
    order.clear();
    order.extend(0..n);
    order.sort_by(|&a, &b| demands[a].partial_cmp(&demands[b]).unwrap());
    fill_sorted_over(demands, capacity, order, alloc);
}

/// [`water_fill_into`] with a caller-supplied demand-sorted permutation:
/// skips the gather and the sort, running only the O(n) allocation pass.
/// `order` must be a permutation of `0..demands.len()` that is
/// non-decreasing in demand; *any* such permutation yields bit-identical
/// allocations (ties subtract equal bit-values in either order, and the
/// fair-split boundary can never fall between tied demands), which is
/// what lets the share cache reuse one stably-sorted permutation for a
/// whole server generation (DESIGN.md §13; pinned by a proptest).
pub fn water_fill_sorted(
    demands: &[f64],
    capacity: f64,
    order: &[usize],
    alloc: &mut Vec<f64>,
) {
    let n = demands.len();
    debug_assert_eq!(order.len(), n, "order must be a permutation of 0..n");
    debug_assert!(
        order.windows(2).all(|w| demands[w[0]] <= demands[w[1]]),
        "order must be non-decreasing in demand"
    );
    alloc.clear();
    alloc.resize(n, 0.0);
    if n == 0 {
        return;
    }
    let total: f64 = demands.iter().sum();
    if total <= capacity {
        alloc.copy_from_slice(demands);
        return;
    }
    fill_sorted_over(demands, capacity, order, alloc);
}

/// The over-capacity water-fill allocation pass (shared verbatim by the
/// sorting and sorted-reuse entry points, so the two are bit-identical
/// by construction): walk tasks in demand order, granting full demand
/// while it fits under the current fair share, then split the remainder
/// equally among everyone still unserved.
fn fill_sorted_over(demands: &[f64], capacity: f64, order: &[usize], alloc: &mut [f64]) {
    let mut remaining = capacity;
    let mut left = order.len();
    for (k, &i) in order.iter().enumerate() {
        let fair = remaining / left as f64;
        if demands[i] <= fair {
            alloc[i] = demands[i];
            remaining -= demands[i];
        } else {
            // everyone from here on gets the equal split
            for &j in &order[k..] {
                alloc[j] = remaining / left as f64;
            }
            return;
        }
        left -= 1;
    }
}

fn res_tag(res: Res) -> u64 {
    match res {
        Res::Cpu => 1,
        Res::Bw => 2,
    }
}

/// Deterministic smooth noise in [0, 1]: cosine interpolation between
/// per-integer-cell hash values.
fn smooth_noise(seed: u64, t: f64) -> f64 {
    let cell = t.floor();
    let frac = t - cell;
    let h = |c: f64| {
        let mut x = seed ^ (c as i64 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        (x >> 11) as f64 / (1u64 << 53) as f64
    };
    let w = 0.5 - 0.5 * (std::f64::consts::PI * frac).cos();
    h(cell) * (1.0 - w) + h(cell + 1.0) * w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(job: usize, server: usize, cpu: f64, bw: f64) -> Task {
        Task {
            job,
            role: Role::Worker { rank: 0 },
            server,
            cpu_demand: cpu,
            bw_demand: bw,
            cpu_cap: 1.0,
            bw_cap: 1.0,
            cpu_throttle: 1.0,
            bw_throttle: 1.0,
            active: true,
        }
    }

    #[test]
    fn water_fill_under_capacity_grants_demand() {
        let a = water_fill(&[1.0, 2.0, 3.0], 10.0);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn water_fill_over_capacity_is_max_min_fair() {
        let a = water_fill(&[1.0, 4.0, 4.0], 6.0);
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert!((a[1] - 2.5).abs() < 1e-12);
        assert!((a[2] - 2.5).abs() < 1e-12);
        let sum: f64 = a.iter().sum();
        assert!((sum - 6.0).abs() < 1e-9);
    }

    #[test]
    fn water_fill_never_exceeds_demand_or_capacity() {
        let mut rng = Rng::seeded(5);
        for _ in 0..200 {
            let n = rng.usize(1, 12);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.1, 10.0)).collect();
            let cap = rng.range(0.5, 30.0);
            let a = water_fill(&demands, cap);
            let sum: f64 = a.iter().sum();
            assert!(sum <= cap + 1e-9 || sum <= demands.iter().sum::<f64>() + 1e-9);
            for (x, d) in a.iter().zip(&demands) {
                assert!(*x <= d + 1e-9);
                assert!(*x >= 0.0);
            }
        }
    }

    #[test]
    fn water_fill_zero_capacity_grants_nothing() {
        // capacity 0 with demand: everyone shares the 0 remainder
        let a = water_fill(&[1.0, 4.0], 0.0);
        assert_eq!(a, vec![0.0, 0.0]);
        let mut order = vec![9]; // dirty scratch
        let mut alloc = vec![7.0];
        water_fill_into(&[1.0, 4.0], 0.0, &mut order, &mut alloc);
        assert_eq!(alloc, vec![0.0, 0.0]);
        // zero capacity, zero demands: under-capacity branch, all zero
        assert_eq!(water_fill(&[0.0, 0.0], 0.0), vec![0.0, 0.0]);
        // no tasks at all
        assert_eq!(water_fill(&[], 0.0), Vec::<f64>::new());
        water_fill_into(&[], 5.0, &mut order, &mut alloc);
        assert!(alloc.is_empty());
    }

    #[test]
    fn water_fill_all_zero_demands_grant_zero() {
        let a = water_fill(&[0.0, 0.0, 0.0], 10.0);
        assert_eq!(a, vec![0.0, 0.0, 0.0]);
        let mut order = Vec::new();
        let mut alloc = Vec::new();
        water_fill_into(&[0.0, 0.0, 0.0], 10.0, &mut order, &mut alloc);
        assert_eq!(alloc, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn water_fill_single_task_at_exact_capacity() {
        // total == capacity takes the under-capacity fast path exactly
        let a = water_fill(&[6.0], 6.0);
        assert_eq!(a, vec![6.0]);
        let mut order = Vec::new();
        let mut alloc = Vec::new();
        water_fill_into(&[6.0], 6.0, &mut order, &mut alloc);
        assert_eq!(alloc, vec![6.0]);
        // one epsilon over: the fair-split branch, still exactly capacity
        let a = water_fill(&[6.0 + 1e-12], 6.0);
        assert_eq!(a.len(), 1);
        assert!((a[0] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn shares_into_and_view_match_shares() {
        let mut c = Cluster::new(ClusterConfig::default());
        for j in 0..10 {
            let mut t = worker(j, j % 3, 12.0, 0.5);
            t.role = Role::Ps { idx: 0 };
            c.add_task(t);
        }
        let mut buf = vec![(99usize, 9.9)]; // dirty scratch
        for step in 0..5 {
            let t = 10.0 + step as f64 * 3.3;
            for server in 0..8 {
                for res in [Res::Cpu, Res::Bw] {
                    let want = c.shares(server, res, t);
                    c.shares_into(server, res, t, &mut buf);
                    assert_eq!(want, buf, "server {server} {res:?} t {t}");
                    let (ids, shares) = c.shares_view(server, res, t);
                    assert_eq!(ids.len(), shares.len());
                    let pairs: Vec<(TaskId, f64)> =
                        ids.iter().copied().zip(shares.iter().copied()).collect();
                    assert_eq!(want, pairs);
                }
            }
        }
    }

    #[test]
    fn water_fill_into_matches_and_reuses_buffers() {
        let mut rng = Rng::seeded(11);
        let mut order = Vec::new();
        let mut alloc = Vec::new();
        for _ in 0..200 {
            let n = rng.usize(0, 14);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.0, 10.0)).collect();
            let cap = rng.range(0.0, 30.0);
            let want = water_fill(&demands, cap);
            // buffers deliberately carry state from the previous case
            water_fill_into(&demands, cap, &mut order, &mut alloc);
            assert_eq!(want, alloc, "demands {demands:?} cap {cap}");
        }
    }

    #[test]
    fn default_testbed_shape() {
        let c = Cluster::new(ClusterConfig::default());
        assert_eq!(c.servers.len(), 8);
        assert_eq!(c.gpu_server_ids().len(), 5);
        assert_eq!(c.cpu_server_ids().len(), 3);
        assert_eq!(c.servers[0].gpus, 8);
        assert_eq!(c.servers[5].gpus, 0);
    }

    #[test]
    fn gpu_slots_tracked() {
        let mut c = Cluster::new(ClusterConfig::default());
        assert_eq!(c.free_gpus(0), 8);
        let id = c.add_task(worker(0, 0, 2.0, 1.0));
        assert_eq!(c.free_gpus(0), 7);
        c.remove_task(id);
        assert_eq!(c.free_gpus(0), 8);
        c.remove_task(id); // idempotent
        assert_eq!(c.free_gpus(0), 8);
    }

    #[test]
    fn shares_respect_contention() {
        let mut c = Cluster::new(ClusterConfig::default());
        // saturate CPU on server 0 with ten 12-vCPU tasks (120 > 96)
        for j in 0..10 {
            let mut t = worker(j, 0, 12.0, 0.5);
            t.role = Role::Ps { idx: 0 }; // avoid GPU slots
            c.add_task(t);
        }
        let sh = c.shares(0, Res::Cpu, 10.0);
        let total: f64 = sh.iter().map(|&(_, s)| s).sum();
        assert!(total <= 96.0 + 1e-6);
        for &(_, s) in &sh {
            assert!(s < 12.0); // contended: nobody gets full demand
        }
    }

    #[test]
    fn throttle_caps_share() {
        let mut c = Cluster::new(ClusterConfig::default());
        let id = c.add_task(worker(0, 0, 8.0, 1.0));
        c.set_caps(id, 0.1, 1.0); // cpulimit to 10%
        let s = c.share_of(id, Res::Cpu, 5.0);
        assert!(s <= 0.8 + 1e-9, "{s}");
    }

    #[test]
    fn cap_changes_invalidate_cached_shares() {
        let mut c = Cluster::new(ClusterConfig::default());
        let mut first = 0;
        for j in 0..10 {
            let mut t = worker(j, 0, 12.0, 0.5);
            t.role = Role::Ps { idx: 0 };
            let id = c.add_task(t);
            if j == 0 {
                first = id;
            }
        }
        let t = 10.0;
        let before = c.share_of(first, Res::Cpu, t);
        // same (generation, time): a pure cache hit must repeat exactly
        assert_eq!(before, c.share_of(first, Res::Cpu, t));
        c.set_caps(first, 0.1, 1.0);
        let after = c.share_of(first, Res::Cpu, t);
        assert!(after < before, "cap must shrink the cached share: {after} vs {before}");
        // writing identical values must not churn the generation
        let g = c.generation();
        c.set_caps(first, 0.1, 1.0);
        c.set_demands(first, 12.0, 0.5);
        c.set_throttles(first, 1.0, 1.0);
        assert_eq!(g, c.generation());
        c.set_throttles(first, 0.5, 1.0);
        assert!(c.generation() > g);
    }

    #[test]
    fn mutations_invalidate_only_their_servers_partition() {
        let mut c = Cluster::new(ClusterConfig::default());
        let a = c.add_task(worker(0, 0, 8.0, 1.0));
        let b = c.add_task(worker(1, 1, 8.0, 1.0));
        let t = 5.0;
        let share_a = c.share_of(a, Res::Cpu, t);
        let fills = c.epoch_fills();
        // a repeat query is a pure hit
        assert_eq!(share_a, c.share_of(a, Res::Cpu, t));
        assert_eq!(fills, c.epoch_fills());
        // mutating server 1 must leave server 0's epoch hot...
        c.set_caps(b, 0.5, 0.5);
        assert_eq!(share_a, c.share_of(a, Res::Cpu, t));
        assert_eq!(fills, c.epoch_fills(), "cross-server mutation refilled a hot epoch");
        // ...while mutating server 0 forces a refill there
        c.set_demands(a, 6.0, 1.0);
        let _ = c.share_of(a, Res::Cpu, t);
        assert_eq!(fills + 1, c.epoch_fills());
    }

    #[test]
    fn cached_shares_match_direct_recompute() {
        let mut cached = Cluster::new(ClusterConfig::default());
        let mut direct = Cluster::new(ClusterConfig::default());
        direct.set_share_cache_enabled(false);
        let mut ids = Vec::new();
        for j in 0..12 {
            let mut t = worker(j, j % 5, 4.0 + j as f64, 1.0 + 0.3 * j as f64);
            if j % 3 == 0 {
                t.role = Role::Ps { idx: 0 };
            }
            ids.push(cached.add_task(t.clone()));
            direct.add_task(t);
        }
        let mut t = 0.0;
        for step in 0..120 {
            t += 3.7;
            for server in 0..8 {
                for res in [Res::Cpu, Res::Bw] {
                    assert_eq!(
                        cached.shares(server, res, t),
                        direct.shares(server, res, t),
                        "server {server} {res:?} t {t}"
                    );
                }
            }
            for &id in &ids {
                assert_eq!(
                    cached.worker_shares(id, t),
                    (direct.share_of(id, Res::Cpu, t), direct.share_of(id, Res::Bw, t))
                );
            }
            assert_eq!(cached.bw_share_sum(&ids, t), direct.bw_share_sum(&ids, t));
            for server in 0..8 {
                assert_eq!(
                    cached.utilization(server, Res::Cpu, t),
                    direct.utilization(server, Res::Cpu, t)
                );
            }
            // interleave share-relevant mutations on both clusters
            match step % 3 {
                0 => {
                    let id = ids[step % ids.len()];
                    cached.set_caps(id, 0.5, 0.7);
                    direct.set_caps(id, 0.5, 0.7);
                }
                1 => {
                    let id = ids[(step * 7) % ids.len()];
                    cached.set_demands(id, 6.0, 2.0);
                    direct.set_demands(id, 6.0, 2.0);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn suspend_excludes_from_shares_and_resume_restores() {
        let mut c = Cluster::new(ClusterConfig::default());
        let mut ids = Vec::new();
        for j in 0..10 {
            let mut t = worker(j, 0, 12.0, 0.5);
            t.role = Role::Ps { idx: 0 };
            ids.push(c.add_task(t));
        }
        let t = 10.0;
        let before = c.share_of(ids[0], Res::Cpu, t);
        assert!(before > 0.0);
        let others_before = c.share_of(ids[1], Res::Cpu, t);

        let g = c.generation();
        c.suspend_task(ids[0]);
        assert!(c.is_suspended(ids[0]));
        assert!(c.generation() > g, "suspension must invalidate the share cache");
        assert_eq!(c.share_of(ids[0], Res::Cpu, t), 0.0, "suspended task draws nothing");
        // survivors split the freed capacity
        assert!(c.share_of(ids[1], Res::Cpu, t) > others_before);
        // double-suspend is a no-op (no generation churn)
        let g2 = c.generation();
        c.suspend_task(ids[0]);
        assert_eq!(g2, c.generation());

        c.resume_task(ids[0]);
        assert!(!c.is_suspended(ids[0]));
        assert!(c.share_of(ids[0], Res::Cpu, t) > 0.0);
    }

    #[test]
    fn suspended_worker_keeps_gpu_slot_until_removed() {
        let mut c = Cluster::new(ClusterConfig::default());
        let id = c.add_task(worker(0, 0, 2.0, 1.0));
        assert_eq!(c.free_gpus(0), 7);
        c.suspend_task(id);
        assert_eq!(c.free_gpus(0), 7, "restart-in-place holds the slot");
        c.remove_task(id);
        assert_eq!(c.free_gpus(0), 8);
        assert!(!c.is_suspended(id), "removal clears suspension");
    }

    #[test]
    fn suspended_ps_not_counted() {
        let mut c = Cluster::new(ClusterConfig::default());
        let mut ps = worker(0, 3, 4.0, 2.0);
        ps.role = Role::Ps { idx: 0 };
        let id = c.add_task(ps);
        assert_eq!(c.ps_count(3), 1);
        c.suspend_task(id);
        assert_eq!(c.ps_count(3), 0);
        c.resume_task(id);
        assert_eq!(c.ps_count(3), 1);
    }

    #[test]
    fn degradation_window_cuts_available_capacity() {
        let base = Cluster::new(ClusterConfig::default());
        let mut degraded = Cluster::new(ClusterConfig::default());
        degraded.add_degradation(0, 100.0, 200.0, 0.5, 0.5);
        for &t in &[50.0, 150.0, 250.0] {
            for res in [Res::Cpu, Res::Bw] {
                let a = base.available(0, res, t);
                let b = degraded.available(0, res, t);
                if (100.0..200.0).contains(&t) {
                    assert!(b < a, "window must cut capacity at t={t}");
                } else {
                    assert_eq!(a, b, "no effect outside the window at t={t}");
                }
            }
        }
        // other servers untouched
        assert_eq!(base.available(1, Res::Cpu, 150.0), degraded.available(1, Res::Cpu, 150.0));
    }

    #[test]
    fn degradation_shrinks_shares_under_contention() {
        let mk = || {
            let mut c = Cluster::new(ClusterConfig::default());
            let mut ids = Vec::new();
            for j in 0..10 {
                let mut t = worker(j, 0, 12.0, 0.5);
                t.role = Role::Ps { idx: 0 };
                ids.push(c.add_task(t));
            }
            (c, ids)
        };
        let (mut base, ids) = mk();
        let (mut deg, _) = mk();
        deg.add_degradation(0, 0.0, 1000.0, 0.6, 0.0);
        let a = base.share_of(ids[0], Res::Cpu, 10.0);
        let b = deg.share_of(ids[0], Res::Cpu, 10.0);
        assert!(b < a, "degraded CPU must shrink the contended share: {b} vs {a}");
    }

    #[test]
    fn background_noise_is_smooth_and_bounded() {
        let c = Cluster::new(ClusterConfig::default());
        let mut prev = c.background_frac(0, Res::Cpu, 0.0);
        for i in 1..200 {
            let t = i as f64 * 0.1;
            let v = c.background_frac(0, Res::Cpu, t);
            assert!((0.0..=0.95).contains(&v));
            assert!((v - prev).abs() < 0.15, "jump at {t}: {prev} -> {v}");
            prev = v;
        }
    }

    #[test]
    fn background_deterministic() {
        let a = Cluster::new(ClusterConfig::default());
        let b = Cluster::new(ClusterConfig::default());
        for i in 0..50 {
            let t = i as f64 * 3.7;
            assert_eq!(a.background_frac(1, Res::Bw, t), b.background_frac(1, Res::Bw, t));
        }
    }

    #[test]
    fn spikes_heavy_tailed_and_reproducible() {
        let mut c = Cluster::new(ClusterConfig::default());
        // spikes are applied per-task, so a task must be present
        c.add_task(worker(0, 0, 2.0, 1.0));
        // walk the clock forward monotonically (as the driver does),
        // harvesting spike durations before pruning retires the entries
        let mut durs: Vec<f64> = Vec::new();
        let mut last_start = f64::NEG_INFINITY;
        let mut t = 0.0;
        while t <= 50_000.0 {
            let _ = c.shares(0, Res::Cpu, t);
            for s in &c.servers[0].spikes {
                if s.start > last_start {
                    durs.push(s.end - s.start);
                }
            }
            if let Some(s) = c.servers[0].spikes.last() {
                last_start = s.start;
            }
            t += 100.0;
        }
        assert!(durs.len() > 50, "want many spikes, got {}", durs.len());
        for d in &durs {
            // tolerance: end = start + dur loses ~1e-11 at start ~ 5e4
            assert!((0.0999..=500.001).contains(d), "{d}");
        }
        let max = durs.iter().cloned().fold(0.0, f64::max);
        let med = crate::stats::median(&durs);
        assert!(max > 20.0 * med, "heavy tail expected: max={max} med={med}");
    }

    #[test]
    fn spike_lists_stay_bounded_on_long_traces() {
        let mut c = Cluster::new(ClusterConfig::default());
        let id = c.add_task(worker(0, 0, 2.0, 1.0));
        let mut t = 0.0;
        while t <= 500_000.0 {
            let _ = c.share_of(id, Res::Cpu, t);
            t += 50.0;
        }
        // ~2083 spikes were generated (mean gap 240 s); pruning must keep
        // only the ~500 s live window plus at most one unpruned batch
        let live = c.servers[0].spikes.len();
        assert!(live < 2 * SPIKE_PRUNE_BATCH + 16, "server spikes not pruned: {live}");
        let ev = c.task_events[0][c.event_slot[id]].spikes.len();
        assert!(ev < 2 * SPIKE_PRUNE_BATCH + 16, "task events not pruned: {ev}");
    }

    #[test]
    fn available_positive_and_below_capacity() {
        let c = Cluster::new(ClusterConfig::default());
        for i in 0..100 {
            let t = i as f64 * 13.3;
            let a = c.available(2, Res::Bw, t);
            assert!(a > 0.0 && a <= c.cfg.gpu_server_bw);
        }
    }

    #[test]
    fn ps_count_counts_only_active_ps() {
        let mut c = Cluster::new(ClusterConfig::default());
        let mut ps = worker(0, 3, 4.0, 2.0);
        ps.role = Role::Ps { idx: 0 };
        let a = c.add_task(ps.clone());
        c.add_task(worker(0, 3, 2.0, 1.0));
        assert_eq!(c.ps_count(3), 1);
        c.remove_task(a);
        assert_eq!(c.ps_count(3), 0);
    }

    #[test]
    fn utilization_rises_with_load() {
        let mut c = Cluster::new(ClusterConfig::default());
        let before = c.utilization(4, Res::Cpu, 100.0);
        for j in 0..12 {
            let mut t = worker(j, 4, 10.0, 0.2);
            t.role = Role::Ps { idx: 0 };
            c.add_task(t);
        }
        let after = c.utilization(4, Res::Cpu, 100.0);
        assert!(after > before);
        assert!(after <= 1.0);
    }

    /// Proptest: the sorted-reuse water-fill is bit-identical to the
    /// allocating form for *any* valid demand-sorted permutation —
    /// including tie-heavy, zero-demand, and exact-capacity vectors
    /// (the claim that lets one cached permutation serve a whole server
    /// generation, DESIGN.md §13).
    #[test]
    fn water_fill_sorted_matches_allocating_form() {
        crate::testutil::forall(
            "water-fill-sorted-equiv",
            400,
            |r| {
                let n = r.usize(0, 14);
                // a small palette forces heavy ties; occasional continuous
                // draws cover the general case
                let palette = [0.0, 0.0, 0.5, 1.0, 1.0, 1.0, 2.5, 4.0];
                let demands: Vec<f64> = (0..n)
                    .map(|_| {
                        if r.chance(0.7) {
                            palette[r.usize(0, palette.len() - 1)]
                        } else {
                            r.range(0.0, 10.0)
                        }
                    })
                    .collect();
                let total: f64 = demands.iter().sum();
                // mix exact-capacity, zero, under- and over-capacity
                let cap = match r.usize(0, 3) {
                    0 => total,
                    1 => 0.0,
                    2 => r.range(0.0, total.max(0.1)),
                    _ => r.range(0.0, 30.0),
                };
                let tie_swaps = r.usize(0, 6);
                (demands, cap, tie_swaps)
            },
            |(demands, cap, tie_swaps)| {
                let want = water_fill(demands, *cap);
                // the stably-sorted permutation (what the cache stores)
                let mut order: Vec<usize> = (0..demands.len()).collect();
                order.sort_by(|&a, &b| demands[a].partial_cmp(&demands[b]).unwrap());
                let mut alloc = vec![42.0]; // dirty scratch
                water_fill_sorted(demands, *cap, &order, &mut alloc);
                if want != alloc {
                    return Err(format!("stable order: want {want:?} got {alloc:?}"));
                }
                // any other demand-sorted permutation (adjacent tied
                // entries swapped) must produce the same bits
                for s in 0..*tie_swaps {
                    let k = s % order.len().max(1);
                    if k + 1 < order.len() && demands[order[k]] == demands[order[k + 1]] {
                        order.swap(k, k + 1);
                    }
                }
                water_fill_sorted(demands, *cap, &order, &mut alloc);
                if want != alloc {
                    return Err(format!("tie-swapped order: want {want:?} got {alloc:?}"));
                }
                Ok(())
            },
        );
    }

    /// A generation bump must drop the cached permutation: mutate demands
    /// so the sort order reverses, and require the post-bump shares to
    /// match a cluster that never cached anything. A stale permutation
    /// reused here would mis-allocate (the proof is the `direct` cluster,
    /// whose fills re-sort every time).
    #[test]
    fn generation_bump_rebuilds_demand_permutation() {
        let mk = || {
            let mut c = Cluster::new(ClusterConfig::default());
            let mut ids = Vec::new();
            for j in 0..10 {
                // ascending demands 8..17 saturate server 0 (sum 125 > 96)
                let mut t = worker(j, 0, 8.0 + j as f64, 0.5);
                t.role = Role::Ps { idx: 0 };
                ids.push(c.add_task(t));
            }
            (c, ids)
        };
        let (mut cached, ids) = mk();
        let (mut direct, _) = mk();
        direct.set_share_cache_enabled(false);
        // build the permutation inside the first generation
        for step in 0..3 {
            let t = 5.0 + step as f64;
            for res in [Res::Cpu, Res::Bw] {
                assert_eq!(cached.shares(0, res, t), direct.shares(0, res, t));
            }
        }
        // reverse the demand ordering: task j goes from 8+j to 20-j
        for (j, &id) in ids.iter().enumerate() {
            cached.set_demands(id, 20.0 - j as f64, 0.5);
            direct.set_demands(id, 20.0 - j as f64, 0.5);
        }
        for step in 0..3 {
            let t = 9.0 + step as f64;
            for res in [Res::Cpu, Res::Bw] {
                assert_eq!(
                    cached.shares(0, res, t),
                    direct.shares(0, res, t),
                    "stale permutation reused after generation bump ({res:?}, t={t})"
                );
            }
        }
    }

    /// Prefilled epochs make the round's queries pure cache hits: the
    /// fill count after prefill+queries equals the count after prefill
    /// alone, and a second prefill at the same instant fills nothing.
    #[test]
    fn prefill_makes_round_queries_pure_hits() {
        let mut c = Cluster::new(ClusterConfig::default());
        for j in 0..16 {
            let mut t = worker(j, j % 8, 6.0, 0.8);
            t.role = Role::Ps { idx: 0 };
            c.add_task(t);
        }
        let keys: Vec<(usize, Res)> =
            (0..8).flat_map(|s| [(s, Res::Cpu), (s, Res::Bw)]).collect();
        let t = 12.5;
        let filled = c.prefill_epochs(&keys, t, 4);
        assert_eq!(filled, 16, "all 16 epochs were cold");
        let fills = c.epoch_fills();
        for &(s, res) in &keys {
            let _ = c.shares(s, res, t);
        }
        assert_eq!(fills, c.epoch_fills(), "queries after prefill must be pure hits");
        assert_eq!(c.prefill_epochs(&keys, t, 4), 0, "everything is already current");
        // duplicate keys dedupe to one fill each
        let dup: Vec<(usize, Res)> = vec![(0, Res::Cpu); 5];
        let _ = c.shares(0, Res::Cpu, t + 1.0); // only (0, Cpu) goes stale... and refills
        assert_eq!(c.prefill_epochs(&dup, t + 1.0, 4), 0);
        assert_eq!(c.prefill_epochs(&dup, t + 2.0, 4), 1);
    }

    /// Thread-count invariance: prefilling with 1 thread, with 8
    /// threads, or not at all (lazy query-path fills) produces
    /// bit-identical shares and identical fill counts, across
    /// generation-bumping mutations.
    #[test]
    fn prefill_thread_count_never_changes_shares() {
        let mk = || {
            let mut c = Cluster::new(ClusterConfig::default());
            let mut ids = Vec::new();
            for j in 0..20 {
                let mut t = worker(j, j % 8, 9.0 + (j % 4) as f64, 0.9);
                t.role = Role::Ps { idx: 0 };
                ids.push(c.add_task(t));
            }
            (c, ids)
        };
        let (mut lazy, ids) = mk();
        let (mut serial, _) = mk();
        let (mut parallel, _) = mk();
        let keys: Vec<(usize, Res)> =
            (0..8).flat_map(|s| [(s, Res::Cpu), (s, Res::Bw)]).collect();
        for step in 0..6 {
            let t = 3.0 + step as f64 * 4.1;
            serial.prefill_epochs(&keys, t, 1);
            parallel.prefill_epochs(&keys, t, 8);
            for &(s, res) in &keys {
                let want = lazy.shares(s, res, t);
                assert_eq!(want, serial.shares(s, res, t), "serial prefill diverged");
                assert_eq!(want, parallel.shares(s, res, t), "parallel prefill diverged");
            }
            assert_eq!(lazy.epoch_fills(), serial.epoch_fills());
            assert_eq!(lazy.epoch_fills(), parallel.epoch_fills());
            // churn a server so the next round re-fills under a new generation
            let id = ids[step % ids.len()];
            lazy.set_caps(id, 0.6, 0.8);
            serial.set_caps(id, 0.6, 0.8);
            parallel.set_caps(id, 0.6, 0.8);
        }
    }

    /// With the cache disabled there is nothing to pre-fill: prefill is a
    /// no-op and the direct-recompute path stays a true from-scratch
    /// recompute (regather + re-sort every query).
    #[test]
    fn prefill_is_noop_with_cache_disabled() {
        let mut c = Cluster::new(ClusterConfig::default());
        for j in 0..8 {
            let mut t = worker(j, j % 8, 6.0, 0.8);
            t.role = Role::Ps { idx: 0 };
            c.add_task(t);
        }
        c.set_share_cache_enabled(false);
        let keys: Vec<(usize, Res)> =
            (0..8).flat_map(|s| [(s, Res::Cpu), (s, Res::Bw)]).collect();
        assert_eq!(c.prefill_epochs(&keys, 5.0, 4), 0);
        assert_eq!(c.epoch_fills(), 0, "prefill must not fill with the cache off");
    }

    /// Fill timing accrues only when enabled, and only on actual fills.
    #[test]
    fn fill_timing_accrues_only_when_enabled() {
        let mut c = Cluster::new(ClusterConfig::default());
        let id = c.add_task(worker(0, 0, 2.0, 1.0));
        let _ = c.share_of(id, Res::Cpu, 1.0);
        assert_eq!(c.fill_wall_s(), 0.0, "timing off by default");
        c.set_fill_timing(true);
        let _ = c.share_of(id, Res::Cpu, 2.0);
        assert!(c.fill_wall_s() > 0.0, "a timed fill must accrue wall time");
        let w = c.fill_wall_s();
        let _ = c.share_of(id, Res::Cpu, 2.0); // pure hit
        assert_eq!(w, c.fill_wall_s(), "cache hits accrue nothing");
    }
}
