//! Minimal JSON substrate (serde is unavailable offline): a recursive-
//! descent parser and an emitter, enough for `artifacts/manifest.json`
//! and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn int(&self) -> Result<i64> {
        Ok(self.num()? as i64)
    }

    /// Non-negative integer accessor (seeds, counts). Unlike [`Json::int`]
    /// it rejects negative and fractional numbers instead of truncating —
    /// a scenario spec with `"seed": -3` must error, not wrap.
    pub fn u64(&self) -> Result<u64> {
        let n = self.num()?;
        if !(n >= 0.0) || n.fract() != 0.0 || n > 9e15 {
            bail!("not a non-negative integer: {self:?}");
        }
        Ok(n as u64)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // -- emission ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, 0, false);
        out
    }

    fn emit(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (k, item) in v.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.emit(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, val)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    emit_string(out, key);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.emit(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for emitting results.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn b(v: bool) -> Json {
    Json::Bool(v)
}

pub fn nums(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or_else(|| anyhow!("bad \\u"))?,
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("unknown escape \\{}", e as char),
                    }
                }
                _ => {
                    // collect the full utf-8 sequence
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i += len - 1;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected , or ] at offset {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected , or }} at offset {}", self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e1}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().int().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().num().unwrap(), -25.0);
        let again = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
        let again2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.str().unwrap(), "café ☕");
        let rt = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Json::parse_file(&p).unwrap();
            assert_eq!(m.get("interchange").unwrap().str().unwrap(), "hlo-text");
        }
    }

    #[test]
    fn builders() {
        let v = obj(vec![("xs", nums(&[1.0, 2.0])), ("name", s("t")), ("on", b(true))]);
        let parsed = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn u64_rejects_negative_and_fractional() {
        assert_eq!(Json::parse("7").unwrap().u64().unwrap(), 7);
        assert_eq!(Json::parse("0").unwrap().u64().unwrap(), 0);
        assert!(Json::parse("-3").unwrap().u64().is_err());
        assert!(Json::parse("2.5").unwrap().u64().is_err());
        assert!(Json::parse("\"7\"").unwrap().u64().is_err());
    }
}
