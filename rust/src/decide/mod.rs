//! Synchronization-mode determination (§IV-C): the PGNS heuristic
//! (STAR-H, Eq. (1)–(3)) and the online ML regressor (STAR-ML), plus the
//! early-decision variant (STAR-) that trades prediction freshness for
//! zero training pause.

use crate::models::ModelSpec;
use crate::predict::Ridge;
use crate::sync::{candidate_modes_ar, candidate_modes_ps, cluster_times, SyncMode};

/// Which decision engine a STAR instance runs (§V calls these STAR-H,
/// STAR-ML and STAR-).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeciderKind {
    /// PGNS heuristic; decision pauses training (§V: ~970 ms in the
    /// paper's python; we also *measure* our rust latency in Fig 28)
    Heuristic,
    /// online regressor bootstrapped from heuristic decisions; inference
    /// overlaps training (no pause)
    Ml,
    /// heuristic executed one iteration early on stale predictions
    Early,
}

/// Expected time to reach unit training progress for a PS-architecture
/// mode (Eq. (1) generalized with the harmonic group-rate aggregation
/// that Eq. (2) uses; SSGD = static-N, ASGD = static-1).
///
/// Steady state of an x-order round: each gradient group cycles at its
/// own completion time t_g, producing one update of batch x·M/N per
/// cycle; the g-th group's gradients land after g earlier updates, so its
/// contribution carries the staleness discount γ^g (the same discount the
/// training-progress model applies). Progress rate
/// = Σ_g γ^(G−1) / (n_u(x·M/N) · t_g) — every group's gradients are G−1
/// versions stale in steady state (G−1−g updates land after its read in
/// the same round, then g more before its next apply); expected time to a
/// unit of progress is the reciprocal.
pub fn time_to_progress_ps(
    spec: &ModelSpec,
    progress: f64,
    n: usize,
    mode: &SyncMode,
    predicted: &[f64],
) -> f64 {
    debug_assert_eq!(predicted.len(), n);
    let m_total = (n * crate::models::WORKER_BATCH) as f64;
    let per_worker = m_total / n as f64;
    let groups: Vec<Vec<usize>> = match mode {
        SyncMode::Ssgd => vec![(0..n).collect()],
        SyncMode::Asgd => (0..n).map(|w| vec![w]).collect(),
        SyncMode::StaticX(x) => {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| predicted[a].partial_cmp(&predicted[b]).unwrap());
            order.chunks(*x).map(|c| c.to_vec()).collect()
        }
        SyncMode::DynamicX => cluster_times(predicted, 0.15, 0.02),
        SyncMode::ArRing { .. } => {
            unreachable!("AR modes go through time_to_progress_ar")
        }
    };
    // order groups by completion time: earlier groups are fresher
    let mut with_t: Vec<(f64, f64)> = groups
        .iter()
        .map(|g| {
            let t_g = g.iter().map(|&w| predicted[w]).fold(0.0, f64::max).max(1e-6);
            (t_g, g.len() as f64 * per_worker)
        })
        .collect();
    with_t.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let gamma = crate::progress::STALE_GAMMA;
    let disc = gamma.powi(with_t.len() as i32 - 1);
    let mut rate = 0.0;
    for (t_g, batch) in &with_t {
        rate += disc / (spec.n_u(progress, *batch) * t_g);
    }
    if rate <= 0.0 {
        f64::INFINITY
    } else {
        1.0 / rate
    }
}

/// Eq. (3): AR-architecture time to unit progress for removing `removed`
/// stragglers with parent wait `tw_ms`. q = removed stragglers whose
/// predicted time fits within t_ring + t_w.
pub fn time_to_progress_ar(
    spec: &ModelSpec,
    progress: f64,
    n: usize,
    removed: usize,
    tw_ms: f64,
    predicted: &[f64],
) -> f64 {
    debug_assert_eq!(predicted.len(), n);
    let removed = removed.min(n.saturating_sub(1));
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| predicted[a].partial_cmp(&predicted[b]).unwrap());
    let ring = &order[..n - removed];
    let out = &order[n - removed..];
    let t_ring = ring.iter().map(|&w| predicted[w]).fold(0.0, f64::max).max(1e-6);
    let tw = tw_ms / 1e3;
    let q = out.iter().filter(|&&w| predicted[w] <= t_ring + tw).count();
    let m_total = (n * crate::models::WORKER_BATCH) as f64;
    let batch = (n - removed + q) as f64 * m_total / n as f64;
    spec.n_u(progress, batch) * (t_ring + tw)
}

/// One decision: the mode plus the LR it must run at (§IV-C LR scaling).
#[derive(Clone, Debug)]
pub struct Decision {
    pub mode: SyncMode,
    pub lr: f64,
    /// estimated time to unit progress used for the pick (diagnostics)
    pub est: f64,
    /// next-best estimates, for prevention fallback ordering (§IV-D1)
    pub ranked: Vec<(SyncMode, f64)>,
}

/// STAR-H: enumerate Eq. (1)/(2) over the PS candidates (§IV-C1).
pub fn choose_ps_heuristic(
    spec: &ModelSpec,
    progress: f64,
    n: usize,
    predicted: &[f64],
) -> Decision {
    let mut ranked: Vec<(SyncMode, f64)> = candidate_modes_ps(n)
        .into_iter()
        .map(|m| {
            let est = time_to_progress_ps(spec, progress, n, &m, predicted);
            (m, est)
        })
        .collect();
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    decision_from(spec, n, predicted, ranked)
}

/// STAR-H for AR: enumerate x ∈ 1..=#stragglers and a t_w grid (§IV-C1).
pub fn choose_ar_heuristic(
    spec: &ModelSpec,
    progress: f64,
    n: usize,
    stragglers: usize,
    tw_grid_ms: &[f64],
    predicted: &[f64],
) -> Decision {
    let mut ranked: Vec<(SyncMode, f64)> = candidate_modes_ar(stragglers, tw_grid_ms)
        .into_iter()
        .map(|m| {
            let est = match &m {
                SyncMode::ArRing { removed, tw_ms } => {
                    time_to_progress_ar(spec, progress, n, *removed, *tw_ms, predicted)
                }
                _ => unreachable!(),
            };
            (m, est)
        })
        .collect();
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    decision_from(spec, n, predicted, ranked)
}

fn decision_from(
    spec: &ModelSpec,
    n: usize,
    predicted: &[f64],
    ranked: Vec<(SyncMode, f64)>,
) -> Decision {
    let (mode, est) = ranked[0];
    let lr = lr_for_mode(spec, n, &mode, predicted);
    Decision { mode, lr, est, ranked }
}

/// LR scaling per §IV-C: r_new = (M_new/M)·r_ssgd with y = expected
/// reports per update under the mode.
pub fn lr_for_mode(spec: &ModelSpec, n: usize, mode: &SyncMode, predicted: &[f64]) -> f64 {
    let y = expected_reports(n, mode, predicted);
    crate::sync::scaled_lr(spec.base_lr, y.max(1) as usize, n)
}

/// Expected gradient reports per update under a mode.
pub fn expected_reports(n: usize, mode: &SyncMode, predicted: &[f64]) -> u64 {
    match mode {
        SyncMode::Ssgd => n as u64,
        SyncMode::Asgd => 1,
        SyncMode::StaticX(x) => *x as u64,
        SyncMode::DynamicX => {
            let clusters = cluster_times(predicted, 0.15, 0.02);
            if clusters.is_empty() {
                n as u64
            } else {
                (predicted.len() as f64 / clusters.len() as f64).round().max(1.0) as u64
            }
        }
        SyncMode::ArRing { removed, .. } => (n - removed.min(&(n - 1))) as u64,
    }
}

// ---------------------------------------------------------------------------
// STAR-ML: online regressor
// ---------------------------------------------------------------------------

/// Feature dimension for the mode-latency regressor (§IV-C2 inputs:
/// per-worker predicted times summary, deviation ratio, model type,
/// learning rate, training stage, and the mode descriptor).
pub const ML_FEATURES: usize = 10;

/// The STAR-ML regressor: predicts log(time to unit progress) for a
/// (job-state, mode) pair. Bootstrapped online from STAR-H outcomes and
/// then refined with observed outcomes.
#[derive(Clone, Debug)]
pub struct MlDecider {
    pub ridge: Ridge<ML_FEATURES>,
    pub samples: u64,
    /// minimum observations before the regressor takes over from the
    /// heuristic (§IV-C2: "switches once the ML model is trained")
    pub min_samples: u64,
}

impl Default for MlDecider {
    fn default() -> Self {
        Self::new()
    }
}

impl MlDecider {
    pub fn new() -> Self {
        MlDecider { ridge: Ridge::new(1e-3, 0.9995), samples: 0, min_samples: 200 }
    }

    pub fn trained(&self) -> bool {
        self.samples >= self.min_samples
    }

    pub fn features(
        spec: &ModelSpec,
        progress: f64,
        n: usize,
        predicted: &[f64],
        mode: &SyncMode,
    ) -> [f64; ML_FEATURES] {
        let min = predicted.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-6);
        let max = predicted.iter().cloned().fold(0.0, f64::max);
        let mean = predicted.iter().sum::<f64>() / n as f64;
        let dev = (max - min) / min;
        let y = expected_reports(n, mode, predicted) as f64;
        let (is_dyn, tw) = match mode {
            SyncMode::DynamicX => (1.0, 0.0),
            SyncMode::ArRing { tw_ms, .. } => (0.0, *tw_ms / 1000.0),
            _ => (0.0, 0.0),
        };
        [
            1.0,
            mean.ln().max(-8.0),
            dev.min(10.0),
            (progress + 1.0).ln(),
            spec.grad_mb / 100.0,
            spec.base_lr * 10.0,
            y / n as f64,
            is_dyn,
            tw,
            max.ln().max(-8.0),
        ]
    }

    /// Record an observed outcome: the realized time per unit progress for
    /// the state/mode the job just ran.
    pub fn observe(&mut self, x: &[f64; ML_FEATURES], time_per_progress: f64) {
        self.ridge.observe(x, time_per_progress.max(1e-6).ln());
        self.samples += 1;
    }

    /// Choose the mode with minimum predicted latency among candidates.
    pub fn choose(
        &mut self,
        spec: &ModelSpec,
        progress: f64,
        n: usize,
        predicted: &[f64],
        candidates: Vec<SyncMode>,
    ) -> Decision {
        let mut ranked: Vec<(SyncMode, f64)> = candidates
            .into_iter()
            .map(|m| {
                let x = Self::features(spec, progress, n, predicted, &m);
                let est = self.ridge.predict(&x).exp();
                (m, est)
            })
            .collect();
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        decision_from(spec, n, predicted, ranked)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ZOO;

    fn uniform(n: usize, t: f64) -> Vec<f64> {
        vec![t; n]
    }

    #[test]
    fn no_straggler_ssgd_beats_asgd() {
        // O6: with no stragglers SSGD has lower TTA than ASGD, and the
        // heuristic never picks ASGD in that state
        let spec = &ZOO[0];
        let pred = uniform(8, 0.3);
        let d = choose_ps_heuristic(spec, 100.0, 8, &pred);
        let t_ssgd = time_to_progress_ps(spec, 100.0, 8, &SyncMode::Ssgd, &pred);
        let t_asgd = time_to_progress_ps(spec, 100.0, 8, &SyncMode::Asgd, &pred);
        assert!(t_ssgd < t_asgd, "ssgd {t_ssgd} vs asgd {t_asgd}");
        assert_ne!(d.mode, SyncMode::Asgd);
        // the pick is within a whisker of full-sync (uniform times):
        assert!(d.est <= t_ssgd * 1.001);
    }

    #[test]
    fn severe_straggler_prefers_partial_modes() {
        let spec = &ZOO[0];
        let mut pred = uniform(8, 0.3);
        pred[7] = 30.0; // pathological straggler
        let d = choose_ps_heuristic(spec, 100.0, 8, &pred);
        assert_ne!(d.mode, SyncMode::Ssgd, "must not wait 30 s per update");
        let t_best = d.est;
        let t_ssgd = time_to_progress_ps(spec, 100.0, 8, &SyncMode::Ssgd, &pred);
        assert!(t_best < t_ssgd / 3.0);
    }

    #[test]
    fn late_stage_penalizes_small_batches_more() {
        // PGNS grows with step => async modes lose appeal later (O6)
        let spec = &ZOO[3];
        let mut pred = uniform(8, 0.3);
        pred[7] = 0.55;
        let early_gap = time_to_progress_ps(spec, 10.0, 8, &SyncMode::Asgd, &pred)
            / time_to_progress_ps(spec, 10.0, 8, &SyncMode::Ssgd, &pred);
        let late_gap = time_to_progress_ps(spec, 500.0, 8, &SyncMode::Asgd, &pred)
            / time_to_progress_ps(spec, 500.0, 8, &SyncMode::Ssgd, &pred);
        assert!(late_gap > early_gap);
    }

    #[test]
    fn dynamic_beats_static_on_clustered_times() {
        // two clear clusters: dynamic groups them exactly; any static x
        // that splits a cluster wastes waiting time
        let spec = &ZOO[1];
        let pred = vec![0.30, 0.31, 0.32, 0.33, 1.50, 1.52, 1.54, 1.56];
        let t_dyn = time_to_progress_ps(spec, 100.0, 8, &SyncMode::DynamicX, &pred);
        let t_3 = time_to_progress_ps(spec, 100.0, 8, &SyncMode::StaticX(3), &pred);
        assert!(t_dyn < t_3, "dyn {t_dyn} vs static-3 {t_3}");
    }

    #[test]
    fn ar_removal_helps_with_straggler() {
        let spec = &ZOO[2];
        let mut pred = uniform(8, 0.3);
        pred[0] = 3.0;
        let keep = time_to_progress_ar(spec, 100.0, 8, 0, 0.0, &pred);
        let drop1 = time_to_progress_ar(spec, 100.0, 8, 1, 60.0, &pred);
        assert!(drop1 < keep);
        let d = choose_ar_heuristic(spec, 100.0, 8, 1, &[30.0, 60.0, 120.0], &pred);
        assert!(matches!(d.mode, SyncMode::ArRing { removed: 1, .. }));
    }

    #[test]
    fn ar_q_counts_fast_removed_workers() {
        let spec = &ZOO[2];
        let mut pred = uniform(8, 0.3);
        pred[0] = 0.35; // mild "straggler": fits in a 100ms wait window
        let with_wait = time_to_progress_ar(spec, 0.0, 8, 1, 100.0, &pred);
        let no_wait = time_to_progress_ar(spec, 0.0, 8, 1, 0.0, &pred);
        // waiting 100 ms recovers the report (bigger batch) — for a mild
        // straggler the extra wait should pay for itself via n_u
        let _ = (with_wait, no_wait); // both finite
        assert!(with_wait.is_finite() && no_wait.is_finite());
        // q effect: with the wait, batch is 8/8 instead of 7/8
        // => n_u smaller
        let nu_with = spec.n_u(0.0, 8.0 * 128.0);
        let nu_without = spec.n_u(0.0, 7.0 * 128.0);
        assert!(nu_with < nu_without);
    }

    #[test]
    fn lr_scaling_follows_batch() {
        let spec = &ZOO[0]; // base_lr = 0.1
        let pred = uniform(8, 0.3);
        assert!((lr_for_mode(spec, 8, &SyncMode::Ssgd, &pred) - 0.1).abs() < 1e-12);
        assert!((lr_for_mode(spec, 8, &SyncMode::Asgd, &pred) - 0.0125).abs() < 1e-12);
        assert!((lr_for_mode(spec, 8, &SyncMode::StaticX(4), &pred) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn ranked_modes_sorted_ascending() {
        let spec = &ZOO[5];
        let mut pred = uniform(6, 0.4);
        pred[3] = 1.1;
        let d = choose_ps_heuristic(spec, 500.0, 6, &pred);
        for w in d.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(d.ranked[0].1, d.est);
    }

    #[test]
    fn ml_learns_to_match_heuristic_ordering() {
        let spec = &ZOO[0];
        let mut ml = MlDecider::new();
        let mut rng = crate::simrng::Rng::seeded(8);
        // train on heuristic estimates across random states
        for _ in 0..600 {
            let n = 8;
            let mut pred: Vec<f64> = (0..n).map(|_| rng.range(0.2, 0.5)).collect();
            if rng.chance(0.5) {
                pred[0] = rng.range(1.0, 4.0);
            }
            let prog = rng.range(0.0, 600.0);
            for m in candidate_modes_ps(n) {
                let est = time_to_progress_ps(spec, prog, n, &m, &pred);
                let x = MlDecider::features(spec, prog, n, &pred, &m);
                ml.observe(&x, est);
            }
        }
        assert!(ml.trained());
        // on a fresh heavy-straggler state the ML choice should avoid SSGD
        let mut pred = uniform(8, 0.3);
        pred[7] = 20.0;
        let d = ml.choose(spec, 300.0, 8, &pred, candidate_modes_ps(8));
        assert_ne!(d.mode, SyncMode::Ssgd);
    }
}
