//! The ten-model zoo from §III: eight CIFAR-10 image models + two
//! WikiText-2 NLP models, with the calibrated constants the simulator
//! needs (sizes, per-iteration costs, resource demands, PGNS schedule,
//! accuracy-curve anchors). Absolute values are calibrated so the
//! *measured phenomena* of the paper hold: communication dominates
//! iteration time (Fig 2), PSs out-consume workers (O4), ASGD out-consumes
//! SSGD (O5), and x-order converged accuracy matches Fig 16's spread.

/// Task category (drives accuracy vs perplexity reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    Image,
    Nlp,
}

/// One trainable model's calibrated constants.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub kind: Kind,
    /// parameters, millions
    pub params_m: f64,
    /// gradient/parameter payload per transfer, MB (f32)
    pub grad_mb: f64,
    /// GPU fwd+bwd time per iteration (batch 128) on the homogeneous GPU, ms
    pub gpu_ms: f64,
    /// CPU preprocessing work per iteration, vCPU-milliseconds
    pub pre_cpu_ms: f64,
    /// steady CPU demand of one worker, vCPUs (preprocess + busy-poll)
    pub worker_cpu: f64,
    /// steady bandwidth demand of one worker, Gbps
    pub worker_bw: f64,
    /// PS demand multipliers over a worker (O4: +5–87% CPU, +101–296% bw)
    pub ps_cpu_factor: f64,
    pub ps_bw_factor: f64,
    /// ASGD demand multipliers over SSGD (O5: +44–351% CPU, +38–427% bw)
    pub asgd_cpu_factor: f64,
    pub asgd_bw_factor: f64,
    /// optimal (SSGD) learning rate from §III
    pub base_lr: f64,
    /// accuracy curve: start / converged (SSGD) accuracy, % (image) —
    /// for NLP these hold perplexity start / converged instead
    pub acc0: f64,
    pub acc_max: f64,
    /// progress constant: progress units to 1/e of the gap
    pub tau: f64,
    /// staleness penalty anchor, accuracy points lost at x=1 vs x=N for
    /// N=8 (Fig 16); NLP: perplexity points gained
    pub kappa_pts: f64,
    /// LR-mismatch penalty when running async-family modes with the
    /// unscaled SSGD LR (O7), accuracy pts (NLP: perplexity pts)
    pub lr_mismatch_pts: f64,
    /// PGNS schedule φ(p) = phi0 * (1 + p / phi_scale), where p is the
    /// accumulated statistical progress — PGNS grows as the model improves
    /// ([45], [46]), independent of how many (small) updates were taken
    pub phi0: f64,
    pub phi_scale: f64,
    /// TTA sensitivity to CPU / bandwidth deprivation (§IV-D1), unitless
    pub cpu_sens: f64,
    pub bw_sens: f64,
}

/// Index into [`ZOO`].
pub type ModelId = usize;

/// Staleness-penalty shape exponent (fit to Fig 16, see DESIGN.md §5).
pub const STALENESS_EXP: f64 = 1.6;

/// Exponent of the realized-staleness quality penalty (concave: even mild
/// staleness costs some converged quality, anchored to Fig 16's x=1/x=N
/// endpoints).
pub const STALE_QUALITY_EXP: f64 = 0.9;

pub static ZOO: &[ModelSpec] = &[
    ModelSpec {
        name: "ResNet20", kind: Kind::Image, params_m: 0.27, grad_mb: 1.1,
        gpu_ms: 22.0, pre_cpu_ms: 220.0, worker_cpu: 2.2, worker_bw: 0.35,
        ps_cpu_factor: 1.18, ps_bw_factor: 2.1, asgd_cpu_factor: 1.55,
        asgd_bw_factor: 1.45, base_lr: 0.1, acc0: 10.0, acc_max: 91.5,
        tau: 170.0, kappa_pts: 7.5, lr_mismatch_pts: 1.8, phi0: 1200.0,
        phi_scale: 85.0, cpu_sens: 0.62, bw_sens: 0.31,
    },
    ModelSpec {
        name: "ResNet56", kind: Kind::Image, params_m: 0.85, grad_mb: 3.4,
        gpu_ms: 45.0, pre_cpu_ms: 230.0, worker_cpu: 2.3, worker_bw: 0.45,
        ps_cpu_factor: 1.22, ps_bw_factor: 2.3, asgd_cpu_factor: 1.62,
        asgd_bw_factor: 1.55, base_lr: 0.1, acc0: 10.0, acc_max: 93.0,
        tau: 200.0, kappa_pts: 8.0, lr_mismatch_pts: 2.0, phi0: 1400.0,
        phi_scale: 100.0, cpu_sens: 0.58, bw_sens: 0.36,
    },
    ModelSpec {
        name: "VGG13", kind: Kind::Image, params_m: 9.4, grad_mb: 37.6,
        gpu_ms: 52.0, pre_cpu_ms: 240.0, worker_cpu: 2.6, worker_bw: 1.6,
        ps_cpu_factor: 1.45, ps_bw_factor: 3.1, asgd_cpu_factor: 2.1,
        asgd_bw_factor: 2.6, base_lr: 0.01, acc0: 10.0, acc_max: 93.4,
        tau: 215.0, kappa_pts: 9.2, lr_mismatch_pts: 2.1, phi0: 1800.0,
        phi_scale: 107.0, cpu_sens: 0.44, bw_sens: 0.66,
    },
    ModelSpec {
        name: "VGG16", kind: Kind::Image, params_m: 14.7, grad_mb: 58.8,
        gpu_ms: 60.0, pre_cpu_ms: 245.0, worker_cpu: 2.7, worker_bw: 2.2,
        ps_cpu_factor: 1.52, ps_bw_factor: 3.4, asgd_cpu_factor: 2.4,
        asgd_bw_factor: 3.2, base_lr: 0.01, acc0: 10.0, acc_max: 93.6,
        tau: 230.0, kappa_pts: 9.8, lr_mismatch_pts: 2.3, phi0: 2000.0,
        phi_scale: 115.0, cpu_sens: 0.41, bw_sens: 0.72,
    },
    ModelSpec {
        name: "DenseNet121", kind: Kind::Image, params_m: 7.0, grad_mb: 28.0,
        gpu_ms: 92.0, pre_cpu_ms: 260.0, worker_cpu: 2.8, worker_bw: 1.3,
        ps_cpu_factor: 1.48, ps_bw_factor: 3.0, asgd_cpu_factor: 2.2,
        asgd_bw_factor: 2.4, base_lr: 0.01, acc0: 10.0, acc_max: 94.0,
        tau: 245.0, kappa_pts: 9.0, lr_mismatch_pts: 2.2, phi0: 1900.0,
        phi_scale: 122.0, cpu_sens: 0.52, bw_sens: 0.58,
    },
    ModelSpec {
        name: "AlexNet", kind: Kind::Image, params_m: 2.5, grad_mb: 10.0,
        gpu_ms: 15.0, pre_cpu_ms: 210.0, worker_cpu: 2.1, worker_bw: 0.9,
        ps_cpu_factor: 1.30, ps_bw_factor: 2.6, asgd_cpu_factor: 1.8,
        asgd_bw_factor: 1.9, base_lr: 0.01, acc0: 10.0, acc_max: 86.0,
        tau: 150.0, kappa_pts: 7.0, lr_mismatch_pts: 1.7, phi0: 1300.0,
        phi_scale: 75.0, cpu_sens: 0.49, bw_sens: 0.47,
    },
    ModelSpec {
        name: "GoogleNet", kind: Kind::Image, params_m: 6.2, grad_mb: 24.8,
        gpu_ms: 70.0, pre_cpu_ms: 250.0, worker_cpu: 2.6, worker_bw: 1.2,
        ps_cpu_factor: 1.40, ps_bw_factor: 2.9, asgd_cpu_factor: 2.0,
        asgd_bw_factor: 2.3, base_lr: 0.01, acc0: 10.0, acc_max: 93.0,
        tau: 220.0, kappa_pts: 8.8, lr_mismatch_pts: 2.0, phi0: 1700.0,
        phi_scale: 110.0, cpu_sens: 0.50, bw_sens: 0.55,
    },
    ModelSpec {
        name: "MobileNet", kind: Kind::Image, params_m: 3.2, grad_mb: 12.8,
        gpu_ms: 30.0, pre_cpu_ms: 235.0, worker_cpu: 2.4, worker_bw: 1.0,
        ps_cpu_factor: 1.34, ps_bw_factor: 2.7, asgd_cpu_factor: 1.9,
        asgd_bw_factor: 2.0, base_lr: 0.01, acc0: 10.0, acc_max: 90.2,
        tau: 185.0, kappa_pts: 8.2, lr_mismatch_pts: 1.9, phi0: 1500.0,
        phi_scale: 92.0, cpu_sens: 0.55, bw_sens: 0.50,
    },
    ModelSpec {
        name: "LSTM", kind: Kind::Nlp, params_m: 13.0, grad_mb: 52.0,
        gpu_ms: 120.0, pre_cpu_ms: 300.0, worker_cpu: 3.0, worker_bw: 2.0,
        ps_cpu_factor: 1.60, ps_bw_factor: 3.5, asgd_cpu_factor: 2.6,
        asgd_bw_factor: 3.5, base_lr: 0.01, acc0: 600.0, acc_max: 101.0,
        tau: 260.0, kappa_pts: 38.0, lr_mismatch_pts: 22.0, phi0: 2200.0,
        phi_scale: 130.0, cpu_sens: 0.47, bw_sens: 0.68,
    },
    ModelSpec {
        name: "Transformer", kind: Kind::Nlp, params_m: 19.0, grad_mb: 76.0,
        gpu_ms: 100.0, pre_cpu_ms: 290.0, worker_cpu: 3.1, worker_bw: 2.6,
        ps_cpu_factor: 1.87, ps_bw_factor: 3.9, asgd_cpu_factor: 3.1,
        asgd_bw_factor: 4.2, base_lr: 0.01, acc0: 420.0, acc_max: 62.0,
        tau: 275.0, kappa_pts: 30.0, lr_mismatch_pts: 18.0, phi0: 2600.0,
        phi_scale: 137.0, cpu_sens: 0.45, bw_sens: 0.73,
    },
];

/// Per-worker mini-batch size (§III).
pub const WORKER_BATCH: usize = 128;

impl ModelSpec {
    pub fn by_name(name: &str) -> Option<(ModelId, &'static ModelSpec)> {
        ZOO.iter().enumerate().find(|(_, m)| m.name == name)
    }

    /// PGNS φ at accumulated progress p (pre-computed schedule; §IV-C1
    /// approximation of [45]'s per-epoch pre-calculated values).
    pub fn phi(&self, progress: f64) -> f64 {
        self.phi0 * (1.0 + progress.max(0.0) / self.phi_scale)
    }

    /// Parameter updates needed per unit progress for an update built from
    /// batch `b` at progress `p`: n_u = 1 + φ_k / b   ([46], Eq. (1)).
    pub fn n_u(&self, progress: f64, batch: f64) -> f64 {
        1.0 + self.phi(progress) / batch.max(1.0)
    }

    /// Converged accuracy (image) or perplexity (NLP) for a mode whose
    /// average update uses x of N workers' gradients, with/without LR
    /// rescaling (Fig 16 + O7 model, DESIGN.md §5). For NLP the penalty is
    /// *added* (higher perplexity = worse).
    pub fn converged_value(&self, x_over_n: f64, lr_rescaled: bool) -> f64 {
        let frac = (1.0 - x_over_n.clamp(0.0, 1.0)).powf(STALENESS_EXP);
        let mut penalty = self.kappa_pts * frac;
        if !lr_rescaled && x_over_n < 0.999 {
            penalty += self.lr_mismatch_pts;
        }
        match self.kind {
            Kind::Image => self.acc_max - penalty,
            Kind::Nlp => self.acc_max + penalty,
        }
    }

    /// Converged quality as a function of *realized* mean gradient
    /// staleness (fraction of a full round, 0 = fully synchronous): the
    /// asymptote the progress model approaches. All gradients are used in
    /// x-order modes, so quality is governed by how stale they are when
    /// applied, plus the O7 LR-mismatch penalty.
    pub fn converged_value_stale(&self, stale_frac: f64, lr_rescaled: bool) -> f64 {
        let mut penalty = self.kappa_pts * stale_frac.clamp(0.0, 1.0).powf(STALE_QUALITY_EXP);
        if !lr_rescaled && stale_frac > 1e-3 {
            penalty += self.lr_mismatch_pts;
        }
        match self.kind {
            Kind::Image => self.acc_max - penalty,
            Kind::Nlp => self.acc_max + penalty,
        }
    }

    /// Whether a candidate value has reached `target` ("accuracy >= target"
    /// for image, "perplexity <= target" for NLP).
    pub fn reached(&self, value: f64, target: f64) -> bool {
        match self.kind {
            Kind::Image => value >= target - 1e-9,
            Kind::Nlp => value <= target + 1e-9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_ten_models_eight_image_two_nlp() {
        assert_eq!(ZOO.len(), 10);
        assert_eq!(ZOO.iter().filter(|m| m.kind == Kind::Image).count(), 8);
        assert_eq!(ZOO.iter().filter(|m| m.kind == Kind::Nlp).count(), 2);
    }

    #[test]
    fn resnet_lr_is_point_one_others_point_oh_one() {
        for m in ZOO {
            if m.name.starts_with("ResNet") {
                assert_eq!(m.base_lr, 0.1);
            } else {
                assert_eq!(m.base_lr, 0.01);
            }
        }
    }

    #[test]
    fn ps_factors_within_o4_ranges() {
        for m in ZOO {
            assert!((1.05..=1.87).contains(&m.ps_cpu_factor), "{}", m.name);
            assert!((2.0..=4.0).contains(&m.ps_bw_factor), "{}", m.name);
        }
    }

    #[test]
    fn asgd_factors_within_o5_ranges() {
        for m in ZOO {
            assert!((1.44..=4.51).contains(&m.asgd_cpu_factor), "{}", m.name);
            assert!((1.38..=5.27).contains(&m.asgd_bw_factor), "{}", m.name);
        }
    }

    #[test]
    fn phi_grows_with_progress() {
        let m = &ZOO[0];
        assert!(m.phi(300.0) > m.phi(0.0));
        assert!(m.n_u(300.0, 512.0) > m.n_u(0.0, 512.0));
        // bigger batch => fewer updates needed
        assert!(m.n_u(100.0, 1024.0) < m.n_u(100.0, 128.0));
    }

    #[test]
    fn converged_value_matches_fig16_shape() {
        // Fig 16 anchors (8-worker job): 1-order 80.3, 2-order 82.7,
        // 4-order 86.4, 8-order 88.9 => spread ≈ 8.6 pts, convex in x.
        let m = ModelSpec {
            kappa_pts: 9.8, acc_max: 88.9, ..ZOO[3].clone()
        };
        let a1 = m.converged_value(1.0 / 8.0, true);
        let a2 = m.converged_value(2.0 / 8.0, true);
        let a4 = m.converged_value(4.0 / 8.0, true);
        let a8 = m.converged_value(1.0, true);
        assert!((a8 - 88.9).abs() < 1e-9);
        assert!(a1 < a2 && a2 < a4 && a4 < a8);
        assert!((a1 - 80.3).abs() < 1.0, "a1={a1}");
        assert!((a2 - 82.7).abs() < 1.0, "a2={a2}");
        // convexity: marginal gain shrinks as x grows
        assert!((a2 - a1) > (a8 - a4) / 4.0);
    }

    #[test]
    fn lr_mismatch_penalizes_unrescaled_async() {
        let m = &ZOO[4];
        assert!(m.converged_value(0.25, false) < m.converged_value(0.25, true));
        // full-sync SSGD never pays the penalty
        assert_eq!(m.converged_value(1.0, false), m.converged_value(1.0, true));
    }

    #[test]
    fn nlp_penalty_raises_perplexity() {
        let (_, lstm) = ModelSpec::by_name("LSTM").unwrap();
        assert!(lstm.converged_value(0.125, true) > lstm.acc_max);
        assert!(lstm.reached(lstm.acc_max, lstm.acc_max));
        assert!(!lstm.reached(lstm.acc_max + 5.0, lstm.acc_max));
    }

    #[test]
    fn by_name_roundtrip() {
        for (i, m) in ZOO.iter().enumerate() {
            let (j, found) = ModelSpec::by_name(m.name).unwrap();
            assert_eq!(i, j);
            assert_eq!(found.name, m.name);
        }
        assert!(ModelSpec::by_name("nope").is_none());
    }
}
