//! Trace substrate: Philly-style job trace generation (§III) + a parser
//! for real Philly CSV extracts + the placement policy of the paper.
//!
//! The paper samples 350 jobs from the Microsoft Philly trace
//! (Oct 9–13 2017) and assigns: workers U[4,12] (same GPU instance when
//! possible), PS count U[1, N], PSs either co-located on the job's GPU
//! servers or on separate CPU servers (random, "industry practice"), and
//! one of ten models per job. The generator reproduces exactly that
//! sampling, seeded; the parser accepts a real trace CSV when available.

use crate::cluster::{Cluster, Role, Task};
use crate::models::{ModelSpec, ZOO};
use crate::simrng::Rng;

/// Architecture under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Ps,
    AllReduce,
}

/// One job drawn from the trace. `Copy`: six machine words, read per
/// placement on the driver's dispatch path — copying beats cloning.
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub id: usize,
    /// arrival offset from trace start, seconds
    pub arrival_s: f64,
    pub model: usize, // index into models::ZOO
    pub workers: usize,
    pub ps_count: usize,
    /// PSs on the job's GPU servers (true) or separate CPU servers (false)
    pub ps_on_gpu_servers: bool,
}

impl JobSpec {
    pub fn spec(&self) -> &'static ModelSpec {
        &ZOO[self.model]
    }
}

/// Trace generation parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub jobs: usize,
    pub seed: u64,
    /// trace span the arrivals cover, seconds (paper: ~4 days)
    pub span_s: f64,
    pub min_workers: usize,
    pub max_workers: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            jobs: 350,
            seed: 0,
            span_s: 4.0 * 24.0 * 3600.0,
            min_workers: 4,
            max_workers: 12,
        }
    }
}

impl TraceConfig {
    /// The CLI/scenario pacing rule: `jobs` arrivals over `jobs · 280 s`,
    /// keeping the cluster equally busy at any job count. Every entry
    /// point that down-scales the 350-job trace (`star simulate`,
    /// `ExpCtx`, the scenario layer's classic Philly family) builds its
    /// config through this one constructor so the traces agree.
    pub fn paced(jobs: usize, seed: u64) -> TraceConfig {
        TraceConfig { jobs, seed, span_s: jobs as f64 * 280.0, ..Default::default() }
    }

    /// [`TraceConfig::paced`] for a cluster `factor`× the paper testbed:
    /// the span shrinks by the factor so arrivals keep the scaled
    /// cluster as busy as `paced` keeps the 8-server one. Factor 1 is
    /// byte-identical to `paced`. This is the scale benchmark's 10⁶-job
    /// synthetic-trace path — generation is O(jobs) with no per-job
    /// state besides the output vec, so a 1000× / 1M-job trace builds
    /// in one pass.
    pub fn paced_scaled(jobs: usize, seed: u64, factor: usize) -> TraceConfig {
        TraceConfig {
            jobs,
            seed,
            span_s: jobs as f64 * 280.0 / factor.max(1) as f64,
            ..Default::default()
        }
    }
}

/// Generate a Philly-like trace: bursty day/night arrivals (two-level
/// Poisson mix), worker/PS counts and model mix per §III.
///
/// This generator is also the *classic backend* of the scenario layer's
/// workload builder ([`crate::scenario::workload`]): a scenario whose
/// workload matches the Philly family defaults delegates here unchanged
/// (byte-identical traces), while customized mixes/arrivals run the
/// scenario generator's own seeded streams.
pub fn generate(cfg: &TraceConfig) -> Vec<JobSpec> {
    let mut rng = Rng::new(cfg.seed, 0x7ace);
    let mut jobs = Vec::with_capacity(cfg.jobs);
    // bursty arrivals: rate doubles during "day" half of each 24h period
    let mut t: f64 = 0.0;
    let base_gap = cfg.span_s / cfg.jobs as f64;
    for id in 0..cfg.jobs {
        let day_phase = (t / 86_400.0).fract();
        let rate_mult = if day_phase < 0.5 { 1.6 } else { 0.6 };
        t += rng.exponential(rate_mult / base_gap);
        let workers = rng.usize(cfg.min_workers, cfg.max_workers);
        jobs.push(JobSpec {
            id,
            arrival_s: t.min(cfg.span_s),
            model: rng.usize(0, ZOO.len() - 1),
            workers,
            ps_count: rng.usize(1, workers),
            ps_on_gpu_servers: rng.chance(0.5),
        });
    }
    jobs
}

/// Parse a Philly-style CSV: `jobid,submit_s,num_gpus[,model]` per line
/// (header optional). Missing model -> hashed onto the zoo.
pub fn parse_philly_csv(text: &str, cfg: &TraceConfig) -> crate::Result<Vec<JobSpec>> {
    let mut rng = Rng::new(cfg.seed, 0xCC);
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
        if lineno == 0 && cols.len() >= 2 && cols[1].parse::<f64>().is_err() {
            continue; // header row (submit column is non-numeric)
        }
        if cols.len() < 3 {
            anyhow::bail!("line {}: want jobid,submit_s,num_gpus[,model]", lineno + 1);
        }
        let submit: f64 = cols[1].parse()?;
        let gpus: usize = cols[2].parse()?;
        let workers = gpus.clamp(cfg.min_workers, cfg.max_workers);
        let model = match cols.get(3) {
            Some(name) if !name.is_empty() => {
                ModelSpec::by_name(name)
                    .map(|(i, _)| i)
                    .ok_or_else(|| anyhow::anyhow!("line {}: unknown model {name}", lineno + 1))?
            }
            _ => (cols[0].bytes().map(|b| b as usize).sum::<usize>()) % ZOO.len(),
        };
        jobs.push(JobSpec {
            id: jobs.len(),
            arrival_s: submit,
            model,
            workers,
            ps_count: rng.usize(1, workers),
            ps_on_gpu_servers: rng.chance(0.5),
        });
    }
    jobs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    Ok(jobs)
}

/// A job's placed tasks.
#[derive(Clone, Debug)]
pub struct Placement {
    pub worker_tasks: Vec<crate::cluster::TaskId>,
    pub ps_tasks: Vec<crate::cluster::TaskId>,
}

/// Placement error: not enough free GPUs right now (job must queue).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NoCapacity;

/// Place a job per §III: workers fill one GPU server if possible, else
/// spill to others; PSs go to the job's GPU servers or to CPU servers,
/// choosing — when STAR's high-load balancing is on — the server hosting
/// the fewest PSs (§IV-D2a), else the first that fits.
pub fn place_job(
    cluster: &mut Cluster,
    job: &JobSpec,
    balance_ps: bool,
) -> Result<Placement, NoCapacity> {
    let spec = job.spec();
    // -- workers: prefer a single server with enough free GPUs
    let gpu_ids = cluster.gpu_server_ids();
    let total_free: usize = gpu_ids.iter().map(|&s| cluster.free_gpus(s)).sum();
    if total_free < job.workers {
        return Err(NoCapacity);
    }
    let mut assignment: Vec<usize> = Vec::with_capacity(job.workers);
    if let Some(&s) = gpu_ids.iter().find(|&&s| cluster.free_gpus(s) >= job.workers) {
        assignment.extend(std::iter::repeat(s).take(job.workers));
    } else {
        // spill: greedy most-free-first
        let mut by_free: Vec<usize> = gpu_ids.to_vec();
        by_free.sort_by_key(|&s| std::cmp::Reverse(cluster.free_gpus(s)));
        let mut need = job.workers;
        for &s in &by_free {
            let take = cluster.free_gpus(s).min(need);
            assignment.extend(std::iter::repeat(s).take(take));
            need -= take;
            if need == 0 {
                break;
            }
        }
    }
    let worker_tasks: Vec<_> = assignment
        .iter()
        .enumerate()
        .map(|(rank, &server)| {
            cluster.add_task(Task {
                job: job.id,
                role: Role::Worker { rank },
                server,
                cpu_demand: spec.worker_cpu,
                bw_demand: spec.worker_bw,
                cpu_cap: 1.0,
                bw_cap: 1.0,
                cpu_throttle: 1.0,
                bw_throttle: 1.0,
                active: true,
            })
        })
        .collect();

    // -- PSs (copied out of the cluster's cached id lists: the selection
    // loop below mutates the cluster via `add_task`)
    let candidates: Vec<usize> = if job.ps_on_gpu_servers {
        cluster.gpu_server_ids().to_vec()
    } else {
        cluster.cpu_server_ids().to_vec()
    };
    let mut ps_tasks = Vec::with_capacity(job.ps_count);
    for idx in 0..job.ps_count {
        let server = if balance_ps {
            // STAR §IV-D2a: fewest hosted PSs first (ties: lower id)
            *candidates
                .iter()
                .min_by_key(|&&s| (cluster.ps_count(s), s))
                .expect("candidate set nonempty")
        } else {
            // baseline industry practice: round-robin by index
            candidates[idx % candidates.len()]
        };
        ps_tasks.push(cluster.add_task(Task {
            job: job.id,
            role: Role::Ps { idx },
            server,
            cpu_demand: spec.worker_cpu * spec.ps_cpu_factor,
            bw_demand: spec.worker_bw * spec.ps_bw_factor,
            cpu_cap: 1.0,
            bw_cap: 1.0,
            cpu_throttle: 1.0,
            bw_throttle: 1.0,
            active: true,
        }));
    }
    Ok(Placement { worker_tasks, ps_tasks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn generate_matches_paper_sampling() {
        let jobs = generate(&TraceConfig::default());
        assert_eq!(jobs.len(), 350);
        for j in &jobs {
            assert!((4..=12).contains(&j.workers));
            assert!(j.ps_count >= 1 && j.ps_count <= j.workers);
            assert!(j.model < ZOO.len());
            assert!(j.arrival_s >= 0.0 && j.arrival_s <= TraceConfig::default().span_s);
        }
        // arrivals sorted
        for w in jobs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        // all ten models appear
        let mut seen = vec![false; ZOO.len()];
        for j in &jobs {
            seen[j.model] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn paced_scaled_matches_paced_at_factor_one() {
        let a = TraceConfig::paced(40, 7);
        let b = TraceConfig::paced_scaled(40, 7, 1);
        assert_eq!(a.span_s, b.span_s);
        let (ta, tb) = (generate(&a), generate(&b));
        assert_eq!(ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.workers, y.workers);
        }
        // a 10x cluster compresses the span 10x (and factor 0 is clamped)
        assert_eq!(TraceConfig::paced_scaled(40, 7, 10).span_s * 10.0, a.span_s);
        assert_eq!(TraceConfig::paced_scaled(40, 7, 0).span_s, a.span_s);
    }

    #[test]
    fn generate_deterministic() {
        let a = generate(&TraceConfig::default());
        let b = generate(&TraceConfig::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.workers, y.workers);
            assert_eq!(x.model, y.model);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn csv_parser_roundtrip() {
        let text = "jobid,submit,gpus,model\nj1,100,8,VGG16\nj2,50,4,\n# comment\nj3,900,32,LSTM\n";
        let jobs = parse_philly_csv(text, &TraceConfig::default()).unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].arrival_s, 50.0);
        assert_eq!(jobs[1].spec().name, "VGG16");
        // 32 gpus clamped to 12 workers
        assert_eq!(jobs[2].workers, 12);
    }

    #[test]
    fn csv_parser_rejects_bad_rows() {
        assert!(parse_philly_csv("1,2", &TraceConfig::default()).is_err());
        assert!(parse_philly_csv("j,5,4,NotAModel", &TraceConfig::default()).is_err());
    }

    #[test]
    fn placement_prefers_single_server() {
        let mut c = Cluster::new(ClusterConfig::default());
        let job = JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: 0,
            workers: 8,
            ps_count: 2,
            ps_on_gpu_servers: false,
        };
        let p = place_job(&mut c, &job, false).unwrap();
        assert_eq!(p.worker_tasks.len(), 8);
        let servers: std::collections::BTreeSet<usize> =
            p.worker_tasks.iter().map(|&t| c.task(t).server).collect();
        assert_eq!(servers.len(), 1, "8 workers fit one empty 8-GPU server");
        // PSs on CPU servers
        for &t in &p.ps_tasks {
            assert!(c.cpu_server_ids().contains(&c.task(t).server));
        }
    }

    #[test]
    fn placement_spills_when_fragmented() {
        let mut c = Cluster::new(ClusterConfig::default());
        // consume 5 GPUs on every GPU server
        let gpu_ids: Vec<usize> = c.gpu_server_ids().to_vec();
        for (j, s) in gpu_ids.into_iter().enumerate() {
            for r in 0..5 {
                c.add_task(Task {
                    job: 1000 + j,
                    role: Role::Worker { rank: r },
                    server: s,
                    cpu_demand: 1.0,
                    bw_demand: 0.1,
                    cpu_cap: 1.0,
                    bw_cap: 1.0,
                    cpu_throttle: 1.0,
                    bw_throttle: 1.0,
                    active: true,
                });
            }
        }
        let job = JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: 0,
            workers: 7,
            ps_count: 1,
            ps_on_gpu_servers: true,
        };
        let p = place_job(&mut c, &job, false).unwrap();
        let servers: std::collections::BTreeSet<usize> =
            p.worker_tasks.iter().map(|&t| c.task(t).server).collect();
        assert!(servers.len() >= 2, "must spill across servers");
    }

    #[test]
    fn placement_fails_without_capacity() {
        let mut c = Cluster::new(ClusterConfig::default());
        let big = JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: 0,
            workers: 12,
            ps_count: 1,
            ps_on_gpu_servers: false,
        };
        // fill the cluster: 40 gpus / 12 -> 3 jobs place, 4th fails
        assert!(place_job(&mut c, &big, false).is_ok());
        assert!(place_job(&mut c, &big, false).is_ok());
        assert!(place_job(&mut c, &big, false).is_ok());
        assert!(matches!(place_job(&mut c, &big, false), Err(NoCapacity)));
    }

    #[test]
    fn balanced_ps_placement_spreads() {
        let mut c = Cluster::new(ClusterConfig::default());
        let job = JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: 3,
            workers: 4,
            ps_count: 3,
            ps_on_gpu_servers: false,
        };
        let p = place_job(&mut c, &job, true).unwrap();
        let counts: Vec<usize> = c.cpu_server_ids().iter().map(|&s| c.ps_count(s)).collect();
        assert_eq!(counts.iter().sum::<usize>(), 3);
        assert!(*counts.iter().max().unwrap() <= 1, "balanced: {counts:?}");
        drop(p);
    }
}
