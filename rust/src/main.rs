//! `star` — leader CLI for the STAR training coordinator.
//!
//! Subcommands:
//! * `train`  — run the real PJRT training path: N in-process workers on
//!   the AOT transformer artifacts, coordinated under a STAR-selected (or
//!   forced) synchronization mode (see also `examples/e2e_train.rs`).
//! * `simulate` — run one system over a generated Philly-style trace and
//!   print the per-job summary.
//! * `replay` — like `simulate` but from a Philly CSV file.
//! * `scenario` — the declarative what-if layer (DESIGN.md §9):
//!   `scenario list` prints the built-ins, `scenario run <file.json|name>`
//!   executes a spec file or built-in.
//! * `artifacts` — inspect the AOT artifact manifest.
//!
//! Every experiment figure/table lives in the separate `experiments`
//! binary (DESIGN.md §4); each family is also runnable as a delegated
//! built-in scenario.

use anyhow::Context;

use star::baselines::make_policy;
use star::cli::Args;
use star::driver::{Driver, DriverConfig};
use star::runtime::{Manifest, Runtime, TrainSession};
use star::stats;
use star::table::{self, Table};
use star::trace::{generate, Arch, TraceConfig};

fn main() {
    let args = Args::parse_env();
    let code = match args.subcommand() {
        Some("train") => cmd(train(&args)),
        Some("simulate") => cmd(simulate(&args)),
        Some("replay") => cmd(replay(&args)),
        Some("scenario") => cmd(scenario(&args)),
        Some("worker") => cmd(worker(&args)),
        Some("dispatch") => cmd(dispatch_cmd(&args)),
        Some("artifacts") => cmd(artifacts(&args)),
        _ => {
            eprintln!(
                "usage: star <train|simulate|replay|scenario|worker|dispatch|artifacts> [options]\n\
                 \n\
                 train      --config tiny|small|base --workers N --steps K [--mode ssgd|asgd|static-x|dynamic|star] [--seed S]\n\
                 simulate   --system SSGD[,ASGD,…,STAR-ML] --jobs N [--arch ps|ar] [--seed S] [--fault-rate R] [--fault-seed S] [--threads N] [--prefill-threads N] [--profile] [--streaming-stats]\n\
                 replay     --trace FILE.csv --system NAME [--arch ps|ar] [--fault-rate R] [--fault-seed S]\n\
                 scenario   list | run <file.json|builtin> [--quick] [--jobs N] [--out DIR] [--threads N]\n\
                 \x20          | sample <space.json|builtin> [--count N] [--out-dir DIR] [--index K]\n\
                 \x20          | search <space.json|builtin> [--count N] [--points P] [--quick] [--jobs N]\n\
                 \x20            [--out DIR] [--threads N | --dispatch + dispatch options]\n\
                 worker     [--listen HOST:PORT]   (serve sweep cells over stdio, or TCP with --listen)\n\
                 dispatch   <file.json|builtin|space> [--quick] [--jobs N] [--count N] [--points P]\n\
                 \x20          [--out DIR] [--workers N] [--connect H:P,…] [--window K]\n\
                 \x20          [--deadline-s X] [--retries N] [--backoff-ms B] [--straggler-factor F]\n\
                 \x20          [--journal PATH] [--fresh] [--commit-batch N] [--commit-interval-ms M]\n\
                 \x20          [--chaos] [--chaos-seed S] [--chaos-kill-prob P] [--chaos-stall-prob P]\n\
                 \x20          [--chaos-stall-ms M] [--chaos-slow-worker I] [--chaos-slow-ms M]\n\
                 \x20          [--worker-bin PATH]\n\
                 artifacts  [--dir artifacts]"
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd(r: star::Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn train(args: &Args) -> star::Result<()> {
    args.check_known(&["config", "workers", "steps", "mode", "seed", "lr"])?;
    let config = args.str_or("config", "tiny");
    let workers = args.usize_or("workers", 4)?;
    let steps = args.u64_or("steps", 50)?;
    let mode = args.str_or("mode", "star");
    let seed = args.u64_or("seed", 0)?;
    let lr = args.f64_or("lr", 0.5)? as f32;

    let man = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    let mut session = TrainSession::new(&rt, &man, &config)?;
    session.init_params(seed as i32)?;
    println!(
        "star train: config={config} params={} workers={workers} steps={steps} mode={mode}",
        session.info.param_count
    );
    let mut rng = star::simrng::Rng::seeded(seed);
    let info = session.info.clone();
    let tokens = |rng: &mut star::simrng::Rng| -> Vec<i32> {
        star::runtime::synth_corpus_batch(&info, rng)
    };
    for step in 0..steps {
        let mut grads = Vec::new();
        let mut loss_sum = 0.0;
        for _ in 0..workers {
            let batch = tokens(&mut rng);
            let (loss, g) = session.train_step(&batch)?;
            loss_sum += loss;
            grads.push(g);
        }
        // x per mode: ssgd = all, asgd = 1, static-x = x
        let x = match mode.as_str() {
            "asgd" => 1,
            m if m.starts_with("static-") => m[7..].parse().unwrap_or(workers),
            _ => workers,
        };
        let used: Vec<Vec<f32>> = grads.into_iter().take(x).collect();
        let eff_lr = lr * used.len() as f32 / workers as f32;
        session.xorder_update(&used, eff_lr)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}  mean worker loss {:.4}", loss_sum / workers as f32);
        }
    }
    Ok(())
}

fn simulate(args: &Args) -> star::Result<()> {
    args.check_known(&[
        "system",
        "jobs",
        "arch",
        "seed",
        "fault-rate",
        "fault-seed",
        "threads",
        "prefill-threads",
        "profile",
        "streaming-stats",
    ])?;
    // `--system` accepts a comma-separated list; each system is an
    // independent run cell over the same trace, swept `--threads`-wide
    // (reports print in command-line order regardless of finish order)
    let systems_arg = args.str_or("system", "STAR-ML");
    let systems: Vec<String> = systems_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if systems.is_empty() {
        anyhow::bail!("--system expects at least one system name");
    }
    let jobs = args.usize_or("jobs", 60)?;
    let seed = args.u64_or("seed", 0)?;
    let arch = parse_arch(&args.str_or("arch", "ps"))?;
    let fault_rate = args.f64_or("fault-rate", 0.0)?;
    let fault_seed = args.u64_or("fault-seed", 0)?;
    let threads = star::exp::sweep::resolve_threads(args.usize_or("threads", 0)?);
    // --prefill-threads: intra-run parallel share-epoch prefill
    // (DESIGN.md §13). 1 = serial lazy fills (byte-exact legacy path);
    // 0 = all cores. Artifacts are byte-identical at any value.
    let prefill_threads = match args.usize_or("prefill-threads", 1)? {
        0 => star::exp::sweep::resolve_threads(0),
        n => n,
    };
    // --profile: per-phase timing counters (event dispatch / share fills
    // / policy decide / stats) from the instrumented run, printed as a
    // table per system — where the wall time goes, without a profiler
    let profile = args.flag("profile");
    // --streaming-stats: fold finished jobs into running aggregates
    // (quantile sketch + sums) instead of a Vec<JobStats> — bounded
    // memory on very long traces; the report comes from the aggregates
    let streaming = args.flag("streaming-stats");
    // validate every name before spawning sweep workers
    star::baselines::validate_systems(&systems)?;
    let trace = generate(&TraceConfig::paced(jobs, seed));
    let all = star::exp::sweep::run_indexed(&systems, threads, |_, sys| {
        run_stats(
            sys,
            arch,
            seed,
            trace.clone(),
            fault_rate,
            fault_seed,
            profile,
            streaming,
            prefill_threads,
        )
    })?;
    for (sys, (stats, metrics, agg)) in systems.iter().zip(&all) {
        match agg {
            Some(agg) => report_streaming(sys, arch, agg),
            None => report(sys, arch, stats),
        }
        if profile {
            print_profile(sys, metrics);
        }
    }
    Ok(())
}

/// `star scenario list | run | sample | search` — the declarative
/// what-if layer. `list` (or `--list`) prints the built-in scenarios
/// and spaces; `run` executes one spec, `sample` expands a space into
/// concrete specs (DESIGN.md §11), `search` runs the counterfactual
/// sensitivity + regret sweep over a space.
fn scenario(args: &Args) -> star::Result<()> {
    let action = args.pos(1);
    if args.flag("list") || action == Some("list") {
        args.check_known(&["list"])?;
        let mut t = Table::new(
            "Built-in scenarios (star scenario run <name>; spec files: examples/scenarios/)",
            &["name", "flavor", "description"],
        );
        for sc in star::scenario::builtins() {
            t.rowf(&[
                table::s(sc.name.as_str()),
                table::s(if sc.experiments.is_empty() { "generic" } else { "delegated" }),
                table::s(sc.description.as_str()),
            ]);
        }
        t.print();
        let mut t = Table::new(
            "Built-in scenario spaces (star scenario sample|search <name>)",
            &["name", "free dims", "description"],
        );
        for sp in star::scenario::builtin_spaces() {
            t.rowf(&[
                table::s(sp.name.as_str()),
                table::s(sp.free_dims().join(",")),
                table::s(sp.description.as_str()),
            ]);
        }
        t.print();
        return Ok(());
    }
    match action {
        Some("run") => {
            args.check_known(&["quick", "jobs", "out", "threads"])?;
            let target = args.pos(2).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: star scenario run <file.json|builtin> \
                     [--quick] [--jobs N] [--out DIR] [--threads N]"
                )
            })?;
            let sc = star::scenario::load(target)?;
            let opts = star::scenario::RunOpts {
                quick: args.flag("quick"),
                out_dir: args.str_or("out", "results").into(),
                threads: star::exp::sweep::resolve_threads(args.usize_or("threads", 0)?),
                jobs_override: jobs_override(args)?,
            };
            star::scenario::run(&sc, &opts)
        }
        Some("sample") => scenario_sample(args),
        Some("search") => scenario_search(args),
        other => anyhow::bail!(
            "unknown scenario action {:?} (expected: list | run <file.json|builtin> | \
             sample <space.json|builtin> | search <space.json|builtin>)",
            other.unwrap_or("<missing>")
        ),
    }
}

/// `star scenario sample <space.json|builtin> --count N [--out-dir D]
/// [--index K]` — expand a space into concrete validated scenario
/// specs. `--index K` prints sample K's canonical JSON to stdout
/// instead; sampling is pure per index (same space+seed+index ⇒
/// byte-identical spec), so a sampled set is reproducible piecewise.
fn scenario_sample(args: &Args) -> star::Result<()> {
    args.check_known(&["count", "out-dir", "index"])?;
    let target = args.pos(2).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: star scenario sample <space.json|builtin> [--count N] [--out-dir DIR] \
             [--index K]"
        )
    })?;
    let space = star::scenario::space::load(target)?;
    space.validate().with_context(|| format!("space {:?}", space.name))?;
    if args.get("index").is_some() {
        let k = args.usize_or("index", 0)?;
        println!("{}", space.sample_at(k).to_json().to_string_pretty());
        return Ok(());
    }
    let count = args.usize_or("count", 16)?;
    let out_dir = std::path::PathBuf::from(args.str_or("out-dir", "results/samples"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    for k in 0..count {
        let sc = space.sample_at(k);
        let path = out_dir.join(format!("{}.json", sc.name));
        // trailing newline so the file matches `--index K` stdout exactly
        std::fs::write(&path, format!("{}\n", sc.to_json().to_string_pretty()))
            .with_context(|| format!("writing {}", path.display()))?;
    }
    println!(
        "sampled {count} scenarios from space {:?} into {}",
        space.name,
        out_dir.display()
    );
    Ok(())
}

/// `star scenario search <space.json|builtin>` — the counterfactual
/// driver: center-sweep sensitivity probes + sampled regret cells,
/// in-process via the sweep harness, or scattered over the fabric with
/// `--dispatch` (byte-identical artifacts either way).
fn scenario_search(args: &Args) -> star::Result<()> {
    args.check_known(&[
        "count", "points", "quick", "jobs", "threads", "out", "dispatch", "workers", "connect",
        "deadline-s", "retries", "backoff-ms", "straggler-factor", "journal", "fresh", "chaos",
        "chaos-seed", "chaos-kill-prob", "chaos-stall-prob", "chaos-stall-ms",
        "chaos-slow-worker", "chaos-slow-ms", "worker-bin", "window", "commit-batch",
        "commit-interval-ms",
    ])?;
    let target = args.pos(2).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: star scenario search <space.json|builtin> [--count N] [--points P] \
             [--quick] [--jobs N] [--out DIR] [--threads N | --dispatch + dispatch options]"
        )
    })?;
    let space = star::scenario::space::load(target)?;
    let count = args.usize_or("count", 16)?;
    let points = args.usize_or("points", 5)?;
    let jobs = jobs_override(args)?;
    let quick = args.flag("quick");
    if args.flag("dispatch") {
        let sweep = star::fabric::SweepSpec::from_space(&space, count, points, jobs, quick)?;
        return star::fabric::dispatch::dispatch(&sweep, &dispatch_opts(args)?).map(|_| ());
    }
    let opts = star::scenario::search::SearchOpts {
        count,
        points,
        quick,
        jobs_override: jobs,
        threads: star::exp::sweep::resolve_threads(args.usize_or("threads", 0)?),
        out_dir: args.str_or("out", "results").into(),
    };
    star::scenario::search::run(&space, &opts)
}

/// `--jobs N` is an override: absent means "the spec's own job count".
fn jobs_override(args: &Args) -> star::Result<Option<usize>> {
    Ok(match args.get("jobs") {
        None => None,
        Some(_) => Some(args.usize_or("jobs", 0)?),
    })
}

/// `star worker` — serve sweep cells over the `star-cell-v1` line
/// protocol: stdio by default (the dispatcher's subprocess mode), or a
/// TCP accept loop with `--listen HOST:PORT` (fleet mode; port 0 picks a
/// free port and prints the bound address).
fn worker(args: &Args) -> star::Result<()> {
    args.check_known(&["listen"])?;
    match args.get("listen") {
        Some(addr) => star::fabric::worker::serve_tcp(addr),
        None => star::fabric::worker::serve_stdio(),
    }
}

/// `star dispatch` — scatter a scenario's sweep grid across workers
/// (pipelined `--window` deep per worker, EWMA-load-balanced) with
/// deadlines, retry, straggler re-issue, and a resumable group-committed
/// checkpoint journal; merge results index-ordered into artifacts
/// byte-identical to a serial `--threads 1` run.
fn dispatch_cmd(args: &Args) -> star::Result<()> {
    args.check_known(&[
        "quick", "jobs", "count", "points", "out", "workers", "connect", "deadline-s",
        "retries", "backoff-ms", "straggler-factor", "journal", "fresh", "chaos", "chaos-seed",
        "chaos-kill-prob", "chaos-stall-prob", "chaos-stall-ms", "chaos-slow-worker",
        "chaos-slow-ms", "worker-bin", "window", "commit-batch", "commit-interval-ms",
    ])?;
    let target = args.pos(1).ok_or_else(|| {
        anyhow::anyhow!("usage: star dispatch <file.json|builtin> [options] (see `star` usage)")
    })?;
    let jobs = jobs_override(args)?;
    let quick = args.flag("quick");
    // a dispatch target is a scenario or a scenario space; scenarios win
    // ties (address a space explicitly via `scenario search --dispatch`)
    let sweep = match star::scenario::load(target) {
        Ok(sc) => star::fabric::SweepSpec::from_scenario(&sc, jobs, quick)?,
        Err(scenario_err) => match star::scenario::space::load(target) {
            Ok(space) => star::fabric::SweepSpec::from_space(
                &space,
                args.usize_or("count", 16)?,
                args.usize_or("points", 5)?,
                jobs,
                quick,
            )?,
            Err(_) => return Err(scenario_err),
        },
    };
    star::fabric::dispatch::dispatch(&sweep, &dispatch_opts(args)?).map(|_| ())
}

/// The fabric flags shared by `star dispatch` and
/// `star scenario search --dispatch`.
fn dispatch_opts(args: &Args) -> star::Result<star::fabric::dispatch::DispatchOpts> {
    let chaos = if args.flag("chaos") {
        let defaults = star::fabric::chaos::ChaosConfig::default();
        Some(star::fabric::chaos::ChaosConfig {
            seed: args.u64_or("chaos-seed", defaults.seed)?,
            kill_prob: args.f64_or("chaos-kill-prob", defaults.kill_prob)?,
            stall_prob: args.f64_or("chaos-stall-prob", defaults.stall_prob)?,
            stall_ms: args.u64_or("chaos-stall-ms", defaults.stall_ms)?,
            die_after_ms: defaults.die_after_ms,
            slow_worker: match args.get("chaos-slow-worker") {
                Some(_) => Some(args.usize_or("chaos-slow-worker", 0)?),
                None => None,
            },
            slow_ms: args.u64_or("chaos-slow-ms", defaults.slow_ms)?,
        })
    } else {
        None
    };
    let defaults = star::fabric::dispatch::DispatchOpts::default();
    Ok(star::fabric::dispatch::DispatchOpts {
        workers: args.usize_or("workers", 4)?,
        connect: match args.get("connect") {
            Some(list) => list.split(',').map(|a| a.trim().to_string()).collect(),
            None => Vec::new(),
        },
        out_dir: args.str_or("out", "results").into(),
        journal: args.get("journal").map(std::path::PathBuf::from),
        fresh: args.flag("fresh"),
        deadline_s: args.f64_or("deadline-s", 600.0)?,
        retries: args.usize_or("retries", 5)?,
        backoff_ms: args.u64_or("backoff-ms", 100)?,
        straggler_factor: args.f64_or("straggler-factor", 3.0)?,
        chaos,
        worker_bin: args.get("worker-bin").map(std::path::PathBuf::from),
        window: args.usize_or("window", defaults.window)?,
        commit_batch: args.usize_or("commit-batch", defaults.commit_batch)?,
        commit_interval_ms: args.u64_or("commit-interval-ms", defaults.commit_interval_ms)?,
    })
}

fn replay(args: &Args) -> star::Result<()> {
    args.check_known(&["trace", "system", "arch", "seed", "fault-rate", "fault-seed"])?;
    let path = args.require("trace")?;
    let system = args.str_or("system", "STAR-ML");
    let seed = args.u64_or("seed", 0)?;
    let arch = parse_arch(&args.str_or("arch", "ps"))?;
    let fault_rate = args.f64_or("fault-rate", 0.0)?;
    let fault_seed = args.u64_or("fault-seed", 0)?;
    let text = std::fs::read_to_string(path)?;
    let trace = star::trace::parse_philly_csv(&text, &TraceConfig::default())?;
    run_and_report(&system, arch, seed, trace, fault_rate, fault_seed)
}

fn run_and_report(
    system: &str,
    arch: Arch,
    seed: u64,
    trace: Vec<star::trace::JobSpec>,
    fault_rate: f64,
    fault_seed: u64,
) -> star::Result<()> {
    // validate the system name before the simulation starts
    make_policy(system)?;
    let (stats_v, _, _) =
        run_stats(system, arch, seed, trace, fault_rate, fault_seed, false, false, 1);
    report(system, arch, &stats_v);
    Ok(())
}

/// One run cell: a fresh driver over `trace` under `system`. Callers
/// must have validated the system name (the per-job factory runs
/// mid-simulation, where failing is no longer an option). With
/// `streaming` on, per-job stats fold into the returned `StreamAgg`
/// and the stats vec comes back empty.
#[allow(clippy::too_many_arguments)]
fn run_stats(
    system: &str,
    arch: Arch,
    seed: u64,
    trace: Vec<star::trace::JobSpec>,
    fault_rate: f64,
    fault_seed: u64,
    profile: bool,
    streaming: bool,
    prefill_threads: usize,
) -> (Vec<star::driver::JobStats>, star::driver::RunMetrics, Option<star::driver::StreamAgg>) {
    let base_cfg = DriverConfig::default();
    // the scenario layer's rate regime — the shared --fault-rate recipe
    let faults = star::scenario::FaultRegime::Rate { rate: fault_rate, seed: fault_seed }.plan(
        &trace,
        star::faults::span_for(&trace, base_cfg.max_job_duration_s),
        base_cfg.cluster.total_servers(),
    );
    let cfg = DriverConfig {
        arch,
        seed,
        record_series: false,
        faults,
        profile,
        streaming_stats: streaming,
        prefill_threads,
        ..Default::default()
    };
    let name = system.to_string();
    let driver = Driver::new(
        cfg,
        trace,
        Box::new(move |_| make_policy(&name).expect("validated by caller")),
    );
    if streaming {
        let (agg, _, metrics) = driver.run_streaming();
        (Vec::new(), metrics, Some(agg))
    } else {
        let (stats, _, metrics) = driver.run_instrumented();
        (stats, metrics, None)
    }
}

/// The `--streaming-stats` report: same metric rows as [`report`], read
/// off the running aggregates instead of a retained per-job vec.
fn report_streaming(system: &str, arch: Arch, agg: &star::driver::StreamAgg) {
    let mut t = Table::new(
        &format!("{system} over {} jobs ({arch:?}, streamed aggregates)", agg.jobs),
        &["metric", "mean", "p1", "p99"],
    );
    let rows: [(&str, &star::driver::StatStream); 6] = [
        ("jct_s", &agg.jct_s),
        ("tta_s", &agg.tta_s),
        ("queue_s", &agg.queue_s),
        ("updates", &agg.updates),
        ("iters", &agg.iters),
        ("downtime_s", &agg.downtime_s),
    ];
    for (name, s) in rows {
        t.rowf(&[
            table::s(name),
            table::f(s.mean(), 2),
            table::f(s.quantile(0.01), 2),
            table::f(s.quantile(0.99), 2),
        ]);
    }
    t.print();
}

/// The `--profile` table: per-phase wall seconds from the driver's
/// lightweight counters. Sub-phases nest inside the dispatch total;
/// "other" is grouping/queue/fault-transition residue.
fn print_profile(system: &str, m: &star::driver::RunMetrics) {
    let p = &m.profile;
    let other = (p.dispatch_s - (p.itertime_s + p.decide_s + p.stats_s)).max(0.0);
    let mut t = Table::new(
        &format!(
            "{system} — per-phase timing ({} events, {:.0} events/s, peak queue {})",
            m.events,
            m.events_per_sec(),
            m.peak_queue_depth
        ),
        &["phase", "wall_s", "share_pct", "calls"],
    );
    let total = p.dispatch_s.max(1e-12);
    let rows: [(&str, f64, u64); 6] = [
        ("event dispatch (total)", p.dispatch_s, m.events),
        ("- share fills / iter time", p.itertime_s, p.itertime_calls),
        ("- share-epoch fills", m.fill_wall_s, m.epoch_fills),
        ("- policy decide", p.decide_s, p.decide_calls),
        ("- stats accounting", p.stats_s, p.stats_calls),
        ("- other (grouping, queue, faults)", other, 0),
    ];
    for (name, secs, calls) in rows {
        t.rowf(&[
            table::s(name),
            table::f(secs, 3),
            table::f(secs / total * 100.0, 1),
            table::i(calls as i64),
        ]);
    }
    t.print();
}

fn report(system: &str, arch: Arch, stats_v: &[star::driver::JobStats]) {
    let mut t = Table::new(
        &format!("{system} over {} jobs ({arch:?})", stats_v.len()),
        &["metric", "mean", "p1", "p99"],
    );
    let tta: Vec<f64> = stats_v.iter().filter_map(|s| s.tta_s).collect();
    let jct: Vec<f64> = stats_v.iter().map(|s| s.jct_s).collect();
    let acc: Vec<f64> =
        stats_v.iter().filter(|s| !s.is_nlp).map(|s| s.converged_value).collect();
    let strag: Vec<f64> = stats_v.iter().map(|s| s.straggler_episodes as f64).collect();
    for (name, v, d) in [
        ("TTA (s)", &tta, 0),
        ("JCT (s)", &jct, 0),
        ("accuracy (%)", &acc, 2),
        ("straggler episodes", &strag, 0),
    ] {
        let b = stats::band(v);
        t.rowf(&[
            table::s(name),
            table::f(b.mean, d),
            table::f(b.p1, d),
            table::f(b.p99, d),
        ]);
    }
    t.print();
}

/// `--arch` parsing, shared with the scenario spec's `archs` field.
fn parse_arch(s: &str) -> star::Result<Arch> {
    star::scenario::parse_arch(s)
}

fn artifacts(args: &Args) -> star::Result<()> {
    args.check_known(&["dir"])?;
    let man = match args.get("dir") {
        Some(d) => Manifest::load(std::path::Path::new(d))?,
        None => Manifest::discover()?,
    };
    let mut t = Table::new("AOT artifacts", &["config", "params", "padded", "vocab", "seq", "batch", "pallas"]);
    for name in man.config_names() {
        let c = man.config(&name)?;
        t.rowf(&[
            table::s(c.name),
            table::i(c.param_count as i64),
            table::i(c.padded_param_count as i64),
            table::i(c.vocab as i64),
            table::i(c.seq_len as i64),
            table::i(c.batch as i64),
            table::s(if c.use_pallas_matmul { "yes" } else { "no" }),
        ]);
    }
    t.print();
    Ok(())
}
