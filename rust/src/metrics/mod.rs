//! Metrics substrate: named counters and wall-time timers, used by the
//! coordinator hot path and by Fig 28 (decision-time overhead).

use std::collections::BTreeMap;
use std::time::Instant;

/// A registry of counters and duration accumulators.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, TimerStat>,
}

#[derive(Clone, Debug, Default)]
pub struct TimerStat {
    pub calls: u64,
    pub total_ns: u128,
    pub max_ns: u128,
}

impl TimerStat {
    pub fn mean_ms(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.calls as f64 / 1e6
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_ns(name, t0.elapsed().as_nanos());
        out
    }

    pub fn record_ns(&mut self, name: &str, ns: u128) {
        let t = self.timers.entry(name.to_string()).or_default();
        t.calls += 1;
        t.total_ns += ns;
        t.max_ns = t.max_ns.max(ns);
    }

    pub fn timer(&self, name: &str) -> Option<&TimerStat> {
        self.timers.get(name)
    }

    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, t) in &other.timers {
            let e = self.timers.entry(k.clone()).or_default();
            e.calls += t.calls;
            e.total_ns += t.total_ns;
            e.max_ns = e.max_ns.max(t.max_ns);
        }
    }

    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(out, "counter {k:<40} {v}");
        }
        for (k, t) in &self.timers {
            let _ = writeln!(
                out,
                "timer   {k:<40} calls={} mean={:.3}ms max={:.3}ms",
                t.calls,
                t.mean_ms(),
                t.max_ns as f64 / 1e6
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("a");
        m.add("a", 4);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let mut m = Metrics::new();
        let v = m.time("t", || 42);
        assert_eq!(v, 42);
        let t = m.timer("t").unwrap();
        assert_eq!(t.calls, 1);
        assert!(t.total_ns > 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        a.inc("x");
        a.record_ns("t", 100);
        let mut b = Metrics::new();
        b.add("x", 2);
        b.record_ns("t", 300);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        let t = a.timer("t").unwrap();
        assert_eq!(t.calls, 2);
        assert_eq!(t.max_ns, 300);
    }
}
