//! [`ScenarioSpace`]: a declarative *space* of scenarios (DESIGN.md
//! §11) — ranges/choices over cluster shape, oversubscription factors,
//! arrival process & load, job mix, PS placement, worker bounds, and
//! fault rate, plus a fixed policy × arch grid shared by every point.
//!
//! The sampler is a pure function of `(space, index)`: a fresh PCG fork
//! per index means the same space + seed + index always yields a
//! byte-identical [`Scenario`], so sampled sets are resumable and
//! dispatchable as `(spec, index)` cells over the sweep fabric without
//! shipping the expanded scenarios anywhere. [`super::search`] runs the
//! sampled set and one-factor center sweeps built from
//! [`ScenarioSpace::dim_points`].

use std::path::Path;

use anyhow::{bail, Context};

use crate::jsonio::{self, Json};
use crate::simrng::Rng;
use crate::trace::Arch;

use super::spec::{
    arch_tag, check_keys, get_str_list, get_u64, parse_arch, Arrival, ClusterShape, DriverKnobs,
    FaultRegime, ModelMix, PsSpec, Scenario, WorkloadSpec,
};

/// Stream tag for the space sampler's root generator: forks of this
/// root never collide with the workload builder (`0x5CE0`) or fault
/// plan streams.
const SPACE_STREAM: u64 = 0x5ACE;

/// A continuous dimension: fixed, uniform over `[lo, hi]`, log-uniform
/// over `[lo, hi]` (for scale-free knobs like oversubscription
/// factors), or a finite choice set.
#[derive(Clone, Debug, PartialEq)]
pub enum NumDim {
    Fixed(f64),
    Range { lo: f64, hi: f64 },
    LogRange { lo: f64, hi: f64 },
    Choice(Vec<f64>),
}

impl NumDim {
    fn from_json(j: &Json, path: &str) -> crate::Result<NumDim> {
        if let Json::Num(v) = j {
            return Ok(NumDim::Fixed(*v));
        }
        check_keys(j, path, &["fixed", "range", "logrange", "choice"])?;
        let keys = j.obj().with_context(|| format!("{path}: expected a number or an object"))?;
        if keys.len() != 1 {
            bail!("{path}: give exactly one of fixed, range, logrange, choice");
        }
        if let Some(v) = j.opt("fixed") {
            return Ok(NumDim::Fixed(v.num().with_context(|| format!("{path}.fixed"))?));
        }
        if let Some(v) = j.opt("range") {
            let (lo, hi) = pair(v, &format!("{path}.range"))?;
            return Ok(NumDim::Range { lo, hi });
        }
        if let Some(v) = j.opt("logrange") {
            let (lo, hi) = pair(v, &format!("{path}.logrange"))?;
            return Ok(NumDim::LogRange { lo, hi });
        }
        let v = j.opt("choice").expect("len-1 object with allowed keys");
        let mut vals = Vec::new();
        for (i, item) in v.arr().with_context(|| format!("{path}.choice"))?.iter().enumerate() {
            vals.push(item.num().with_context(|| format!("{path}.choice[{i}]"))?);
        }
        Ok(NumDim::Choice(vals))
    }

    fn to_json(&self) -> Json {
        match self {
            NumDim::Fixed(v) => jsonio::obj(vec![("fixed", jsonio::num(*v))]),
            NumDim::Range { lo, hi } => jsonio::obj(vec![("range", jsonio::nums(&[*lo, *hi]))]),
            NumDim::LogRange { lo, hi } => {
                jsonio::obj(vec![("logrange", jsonio::nums(&[*lo, *hi]))])
            }
            NumDim::Choice(vs) => jsonio::obj(vec![(
                "choice",
                Json::Arr(vs.iter().map(|&v| jsonio::num(v)).collect()),
            )]),
        }
    }

    /// True when this dimension actually varies (a sensitivity axis).
    pub fn is_free(&self) -> bool {
        match self {
            NumDim::Fixed(_) => false,
            NumDim::Range { lo, hi } | NumDim::LogRange { lo, hi } => lo < hi,
            NumDim::Choice(vs) => vs.len() > 1,
        }
    }

    /// The center of the dimension: midpoint, geometric mean, or the
    /// first choice — the "all else held here" anchor of one-factor
    /// sensitivity sweeps.
    pub fn center(&self) -> f64 {
        match self {
            NumDim::Fixed(v) => *v,
            NumDim::Range { lo, hi } => (lo + hi) / 2.0,
            NumDim::LogRange { lo, hi } => ((lo.ln() + hi.ln()) / 2.0).exp(),
            NumDim::Choice(vs) => vs[0],
        }
    }

    /// `k` evenly spaced probe values across the dimension (log-spaced
    /// for [`NumDim::LogRange`]; every value for a choice set).
    pub fn points(&self, k: usize) -> Vec<f64> {
        match self {
            NumDim::Fixed(v) => vec![*v],
            NumDim::Range { lo, hi } => {
                if k < 2 || lo >= hi {
                    return vec![self.center()];
                }
                (0..k).map(|i| lo + (hi - lo) * i as f64 / (k - 1) as f64).collect()
            }
            NumDim::LogRange { lo, hi } => {
                if k < 2 || lo >= hi {
                    return vec![self.center()];
                }
                let (a, b) = (lo.ln(), hi.ln());
                (0..k).map(|i| (a + (b - a) * i as f64 / (k - 1) as f64).exp()).collect()
            }
            NumDim::Choice(vs) => vs.clone(),
        }
    }

    fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            NumDim::Fixed(v) => *v,
            NumDim::Range { lo, hi } => rng.range(*lo, *hi),
            NumDim::LogRange { lo, hi } => rng.range(lo.ln(), hi.ln()).exp(),
            NumDim::Choice(vs) => *rng.choose(vs),
        }
    }

    fn validate_in(&self, path: &str, min: f64, max: f64) -> crate::Result<()> {
        let check = |v: f64| -> crate::Result<()> {
            if !v.is_finite() || v < min || v > max {
                bail!("{path}: values must be finite in [{min}, {max}], got {v}");
            }
            Ok(())
        };
        match self {
            NumDim::Fixed(v) => check(*v),
            NumDim::Range { lo, hi } | NumDim::LogRange { lo, hi } => {
                check(*lo)?;
                check(*hi)?;
                if lo > hi {
                    bail!("{path}: lo ({lo}) must be ≤ hi ({hi})");
                }
                if matches!(self, NumDim::LogRange { .. }) && *lo <= 0.0 {
                    bail!("{path}: logrange needs lo > 0, got {lo}");
                }
                Ok(())
            }
            NumDim::Choice(vs) => {
                if vs.is_empty() {
                    bail!("{path}: choice set must be non-empty");
                }
                vs.iter().try_for_each(|&v| check(v))
            }
        }
    }
}

/// An integer dimension: fixed, inclusive range, or a choice set.
#[derive(Clone, Debug, PartialEq)]
pub enum IntDim {
    Fixed(u64),
    Range { lo: u64, hi: u64 },
    Choice(Vec<u64>),
}

impl IntDim {
    fn from_json(j: &Json, path: &str) -> crate::Result<IntDim> {
        if matches!(j, Json::Num(_)) {
            return Ok(IntDim::Fixed(j.u64().with_context(|| path.to_string())?));
        }
        check_keys(j, path, &["fixed", "range", "choice"])?;
        let keys = j.obj().with_context(|| format!("{path}: expected an integer or an object"))?;
        if keys.len() != 1 {
            bail!("{path}: give exactly one of fixed, range, choice");
        }
        if let Some(v) = j.opt("fixed") {
            return Ok(IntDim::Fixed(v.u64().with_context(|| format!("{path}.fixed"))?));
        }
        if let Some(v) = j.opt("range") {
            let a = v.arr().with_context(|| format!("{path}.range"))?;
            if a.len() != 2 {
                bail!("{path}.range: expected [lo, hi]");
            }
            return Ok(IntDim::Range {
                lo: a[0].u64().with_context(|| format!("{path}.range"))?,
                hi: a[1].u64().with_context(|| format!("{path}.range"))?,
            });
        }
        let v = j.opt("choice").expect("len-1 object with allowed keys");
        let mut vals = Vec::new();
        for (i, item) in v.arr().with_context(|| format!("{path}.choice"))?.iter().enumerate() {
            vals.push(item.u64().with_context(|| format!("{path}.choice[{i}]"))?);
        }
        Ok(IntDim::Choice(vals))
    }

    fn to_json(&self) -> Json {
        match self {
            IntDim::Fixed(v) => jsonio::obj(vec![("fixed", jsonio::num(*v as f64))]),
            IntDim::Range { lo, hi } => {
                jsonio::obj(vec![("range", jsonio::nums(&[*lo as f64, *hi as f64]))])
            }
            IntDim::Choice(vs) => jsonio::obj(vec![(
                "choice",
                Json::Arr(vs.iter().map(|&v| jsonio::num(v as f64)).collect()),
            )]),
        }
    }

    pub fn is_free(&self) -> bool {
        match self {
            IntDim::Fixed(_) => false,
            IntDim::Range { lo, hi } => lo < hi,
            IntDim::Choice(vs) => vs.len() > 1,
        }
    }

    pub fn center(&self) -> u64 {
        match self {
            IntDim::Fixed(v) => *v,
            IntDim::Range { lo, hi } => (lo + hi) / 2,
            IntDim::Choice(vs) => vs[0],
        }
    }

    /// Up to `k` evenly spaced integers (deduplicated after rounding).
    pub fn points(&self, k: usize) -> Vec<u64> {
        match self {
            IntDim::Fixed(v) => vec![*v],
            IntDim::Range { lo, hi } => {
                if k < 2 || lo >= hi {
                    return vec![self.center()];
                }
                let mut out: Vec<u64> = Vec::new();
                for i in 0..k {
                    let v = (*lo as f64 + (hi - lo) as f64 * i as f64 / (k - 1) as f64).round()
                        as u64;
                    if out.last() != Some(&v) {
                        out.push(v);
                    }
                }
                out
            }
            IntDim::Choice(vs) => vs.clone(),
        }
    }

    fn sample(&self, rng: &mut Rng) -> u64 {
        match self {
            IntDim::Fixed(v) => *v,
            IntDim::Range { lo, hi } => rng.int(*lo as i64, *hi as i64) as u64,
            IntDim::Choice(vs) => *rng.choose(vs),
        }
    }

    fn validate_in(&self, path: &str, min: u64, max: u64) -> crate::Result<()> {
        let check = |v: u64| -> crate::Result<()> {
            if v < min || v > max {
                bail!("{path}: values must be in [{min}, {max}], got {v}");
            }
            Ok(())
        };
        match self {
            IntDim::Fixed(v) => check(*v),
            IntDim::Range { lo, hi } => {
                check(*lo)?;
                check(*hi)?;
                if lo > hi {
                    bail!("{path}: lo ({lo}) must be ≤ hi ({hi})");
                }
                Ok(())
            }
            IntDim::Choice(vs) => {
                if vs.is_empty() {
                    bail!("{path}: choice set must be non-empty");
                }
                vs.iter().try_for_each(|&v| check(v))
            }
        }
    }
}

/// The fixed dimension roster, in the documented draw order of the
/// sampler and the report order of the sensitivity sweep. `arrival` and
/// `models` are choice dimensions over the space's `arrival`/`models`
/// lists; everything else is a [`NumDim`]/[`IntDim`].
pub const DIM_NAMES: [&str; 13] = [
    "jobs",
    "gpu_servers",
    "cpu_servers",
    "gpus_per_server",
    "cpu_factor",
    "bw_factor",
    "arrival",
    "arrival_load",
    "models",
    "ps_on_gpu_prob",
    "min_workers",
    "max_workers",
    "fault_rate",
];

/// One concrete assignment of every dimension — the sampler's output
/// and the unit the materializer turns into a validated [`Scenario`].
#[derive(Clone, Debug, PartialEq)]
pub struct DimValues {
    pub jobs: u64,
    pub gpu_servers: u64,
    pub cpu_servers: u64,
    pub gpus_per_server: u64,
    pub cpu_factor: f64,
    pub bw_factor: f64,
    /// index into [`ScenarioSpace::arrival`]
    pub arrival: usize,
    pub arrival_load: f64,
    /// index into [`ScenarioSpace::models`]
    pub models: usize,
    pub ps_on_gpu_prob: f64,
    pub min_workers: u64,
    pub max_workers: u64,
    pub fault_rate: f64,
}

/// A parameter space over [`Scenario`]. Every point shares the policy ×
/// arch grid, driver knobs, and the arrival/mix *shapes*; the dims vary
/// cluster size, oversubscription, load, placement, worker bounds, and
/// fault rate.
#[derive(Clone, Debug)]
pub struct ScenarioSpace {
    pub name: String,
    pub description: String,
    /// sampler seed: `(seed, index)` fully determines sample `index`
    pub seed: u64,
    /// fault-plan seed of center/sensitivity scenarios (samples draw
    /// their own per-index fault seeds)
    pub fault_seed: u64,
    pub policies: Vec<String>,
    pub archs: Vec<Arch>,
    /// arrival-process choice set (the `arrival` dimension)
    pub arrival: Vec<Arrival>,
    /// model-mix choice set (the `models` dimension)
    pub models: Vec<ModelMix>,
    pub jobs: IntDim,
    pub gpu_servers: IntDim,
    pub cpu_servers: IntDim,
    pub gpus_per_server: IntDim,
    pub cpu_factor: NumDim,
    pub bw_factor: NumDim,
    /// load multiplier: the arrival span is divided by this, so 2.0
    /// packs the same jobs into half the time (twice the pressure)
    pub arrival_load: NumDim,
    pub ps_on_gpu_prob: NumDim,
    pub min_workers: IntDim,
    pub max_workers: IntDim,
    /// fault-regime dimension: `FaultRegime::Rate` at this rate
    pub fault_rate: NumDim,
    pub driver: DriverKnobs,
}

impl Default for ScenarioSpace {
    fn default() -> Self {
        let w = WorkloadSpec::default();
        ScenarioSpace {
            name: String::new(),
            description: String::new(),
            seed: 0,
            fault_seed: 0,
            policies: Vec::new(),
            archs: vec![Arch::Ps],
            arrival: vec![w.arrival.clone()],
            models: vec![w.models.clone()],
            jobs: IntDim::Fixed(w.jobs as u64),
            gpu_servers: IntDim::Fixed(ClusterShape::default().gpu_servers as u64),
            cpu_servers: IntDim::Fixed(ClusterShape::default().cpu_servers as u64),
            gpus_per_server: IntDim::Fixed(ClusterShape::default().gpus_per_server as u64),
            cpu_factor: NumDim::Fixed(1.0),
            bw_factor: NumDim::Fixed(1.0),
            arrival_load: NumDim::Fixed(1.0),
            ps_on_gpu_prob: NumDim::Fixed(w.ps.on_gpu_prob),
            min_workers: IntDim::Fixed(w.min_workers as u64),
            max_workers: IntDim::Fixed(w.max_workers as u64),
            fault_rate: NumDim::Fixed(0.0),
            driver: DriverKnobs::default(),
        }
    }
}

impl ScenarioSpace {
    pub fn from_file(path: &Path) -> crate::Result<ScenarioSpace> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j).with_context(|| format!("space {}", path.display()))
    }

    pub fn from_json(j: &Json) -> crate::Result<ScenarioSpace> {
        check_keys(
            j,
            "space",
            &[
                "name",
                "description",
                "seed",
                "fault_seed",
                "policies",
                "archs",
                "arrival",
                "models",
                "dims",
                "driver",
            ],
        )?;
        let d = ScenarioSpace::default();
        let dims = j.opt("dims");
        if let Some(v) = dims {
            check_keys(
                v,
                "space.dims",
                &[
                    "jobs",
                    "gpu_servers",
                    "cpu_servers",
                    "gpus_per_server",
                    "cpu_factor",
                    "bw_factor",
                    "arrival_load",
                    "ps_on_gpu_prob",
                    "min_workers",
                    "max_workers",
                    "fault_rate",
                ],
            )?;
        }
        let num = |key: &str, default: &NumDim| -> crate::Result<NumDim> {
            match dims.and_then(|v| v.opt(key)) {
                None => Ok(default.clone()),
                Some(v) => NumDim::from_json(v, &format!("space.dims.{key}")),
            }
        };
        let int = |key: &str, default: &IntDim| -> crate::Result<IntDim> {
            match dims.and_then(|v| v.opt(key)) {
                None => Ok(default.clone()),
                Some(v) => IntDim::from_json(v, &format!("space.dims.{key}")),
            }
        };
        let sp = ScenarioSpace {
            name: j.get("name").and_then(|v| v.str()).context("space.name")?.to_string(),
            description: match j.opt("description") {
                None => String::new(),
                Some(v) => v.str().context("space.description")?.to_string(),
            },
            seed: get_u64(j, "space", "seed", d.seed)?,
            fault_seed: get_u64(j, "space", "fault_seed", d.fault_seed)?,
            policies: get_str_list(j, "policies")?,
            archs: match j.opt("archs") {
                None => d.archs,
                Some(v) => {
                    let mut archs = Vec::new();
                    for (i, a) in v.arr().context("space.archs")?.iter().enumerate() {
                        let tag = a.str().with_context(|| format!("space.archs[{i}]"))?;
                        archs.push(
                            parse_arch(tag).with_context(|| format!("space.archs[{i}]"))?,
                        );
                    }
                    archs
                }
            },
            arrival: match j.opt("arrival") {
                None => d.arrival,
                Some(v) => {
                    let mut out = Vec::new();
                    for (i, a) in v.arr().context("space.arrival")?.iter().enumerate() {
                        out.push(
                            Arrival::from_json(a)
                                .with_context(|| format!("space.arrival[{i}]"))?,
                        );
                    }
                    out
                }
            },
            models: match j.opt("models") {
                None => d.models,
                Some(v) => {
                    let mut out = Vec::new();
                    for (i, m) in v.arr().context("space.models")?.iter().enumerate() {
                        out.push(
                            ModelMix::from_json(m)
                                .with_context(|| format!("space.models[{i}]"))?,
                        );
                    }
                    out
                }
            },
            jobs: int("jobs", &d.jobs)?,
            gpu_servers: int("gpu_servers", &d.gpu_servers)?,
            cpu_servers: int("cpu_servers", &d.cpu_servers)?,
            gpus_per_server: int("gpus_per_server", &d.gpus_per_server)?,
            cpu_factor: num("cpu_factor", &d.cpu_factor)?,
            bw_factor: num("bw_factor", &d.bw_factor)?,
            arrival_load: num("arrival_load", &d.arrival_load)?,
            ps_on_gpu_prob: num("ps_on_gpu_prob", &d.ps_on_gpu_prob)?,
            min_workers: int("min_workers", &d.min_workers)?,
            max_workers: int("max_workers", &d.max_workers)?,
            fault_rate: num("fault_rate", &d.fault_rate)?,
            driver: match j.opt("driver") {
                None => d.driver,
                Some(v) => DriverKnobs::from_json(v)?,
            },
        };
        sp.validate()?;
        Ok(sp)
    }

    /// Canonical fully-expanded emission: parse → emit → parse is the
    /// identity (pinned by the round-trip tests).
    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("name", jsonio::s(&self.name)),
            ("description", jsonio::s(&self.description)),
            ("seed", jsonio::num(self.seed as f64)),
            ("fault_seed", jsonio::num(self.fault_seed as f64)),
            ("policies", Json::Arr(self.policies.iter().map(|p| jsonio::s(p)).collect())),
            (
                "archs",
                Json::Arr(self.archs.iter().map(|&a| jsonio::s(arch_tag(a))).collect()),
            ),
            ("arrival", Json::Arr(self.arrival.iter().map(|a| a.to_json()).collect())),
            ("models", Json::Arr(self.models.iter().map(|m| m.to_json()).collect())),
            (
                "dims",
                jsonio::obj(vec![
                    ("jobs", self.jobs.to_json()),
                    ("gpu_servers", self.gpu_servers.to_json()),
                    ("cpu_servers", self.cpu_servers.to_json()),
                    ("gpus_per_server", self.gpus_per_server.to_json()),
                    ("cpu_factor", self.cpu_factor.to_json()),
                    ("bw_factor", self.bw_factor.to_json()),
                    ("arrival_load", self.arrival_load.to_json()),
                    ("ps_on_gpu_prob", self.ps_on_gpu_prob.to_json()),
                    ("min_workers", self.min_workers.to_json()),
                    ("max_workers", self.max_workers.to_json()),
                    ("fault_rate", self.fault_rate.to_json()),
                ]),
            ),
            ("driver", self.driver.to_json()),
        ])
    }

    /// Every rule names the offending field. Beyond per-dim bounds, the
    /// clincher is materializing the center of every arrival × models
    /// choice pair and running full [`Scenario::validate`] on it: with
    /// the clamped materializer this proves *every* sampled scenario is
    /// valid, not just the ones a test happened to draw.
    pub fn validate(&self) -> crate::Result<()> {
        if self.name.is_empty()
            || !self
                .name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        {
            bail!(
                "space.name: must be non-empty and use only [A-Za-z0-9._-] \
                 (it keys result artifacts), got {:?}",
                self.name
            );
        }
        if self.policies.is_empty() {
            bail!("space.policies: need at least one policy");
        }
        for (i, p) in self.policies.iter().enumerate() {
            crate::baselines::make_policy(p).with_context(|| format!("space.policies[{i}]"))?;
        }
        if self.archs.is_empty() {
            bail!("space.archs: must name at least one architecture (ps, ar)");
        }
        if self.arrival.is_empty() {
            bail!("space.arrival: need at least one arrival-process choice");
        }
        if self.models.is_empty() {
            bail!("space.models: need at least one model-mix choice");
        }
        self.jobs.validate_in("space.dims.jobs", 1, 1_000_000)?;
        self.gpu_servers.validate_in("space.dims.gpu_servers", 1, 10_000)?;
        self.cpu_servers.validate_in("space.dims.cpu_servers", 0, 10_000)?;
        self.gpus_per_server.validate_in("space.dims.gpus_per_server", 1, 1024)?;
        self.cpu_factor.validate_in("space.dims.cpu_factor", 1e-3, 1e3)?;
        self.bw_factor.validate_in("space.dims.bw_factor", 1e-3, 1e3)?;
        self.arrival_load.validate_in("space.dims.arrival_load", 1e-3, 1e3)?;
        self.ps_on_gpu_prob.validate_in("space.dims.ps_on_gpu_prob", 0.0, 1.0)?;
        self.min_workers.validate_in("space.dims.min_workers", 1, 10_000)?;
        self.max_workers.validate_in("space.dims.max_workers", 1, 10_000)?;
        self.fault_rate.validate_in("space.dims.fault_rate", 0.0, 1e3)?;
        let center = self.center();
        for ai in 0..self.arrival.len() {
            for mi in 0..self.models.len() {
                let v = DimValues { arrival: ai, models: mi, ..center.clone() };
                self.center_scenario("validate-probe", &v).validate().with_context(|| {
                    format!(
                        "space: center scenario with arrival[{ai}] × models[{mi}] is invalid"
                    )
                })?;
            }
        }
        Ok(())
    }

    /// The all-dims-at-center assignment (choice dims at index 0).
    pub fn center(&self) -> DimValues {
        DimValues {
            jobs: self.jobs.center(),
            gpu_servers: self.gpu_servers.center(),
            cpu_servers: self.cpu_servers.center(),
            gpus_per_server: self.gpus_per_server.center(),
            cpu_factor: self.cpu_factor.center(),
            bw_factor: self.bw_factor.center(),
            arrival: 0,
            arrival_load: self.arrival_load.center(),
            models: 0,
            ps_on_gpu_prob: self.ps_on_gpu_prob.center(),
            min_workers: self.min_workers.center(),
            max_workers: self.max_workers.center(),
            fault_rate: self.fault_rate.center(),
        }
    }

    /// Names of the dimensions that actually vary, in [`DIM_NAMES`]
    /// order — the sensitivity sweep's axes.
    pub fn free_dims(&self) -> Vec<&'static str> {
        DIM_NAMES.iter().copied().filter(|d| self.dim_is_free(d)).collect()
    }

    fn dim_is_free(&self, dim: &str) -> bool {
        match dim {
            "jobs" => self.jobs.is_free(),
            "gpu_servers" => self.gpu_servers.is_free(),
            "cpu_servers" => self.cpu_servers.is_free(),
            "gpus_per_server" => self.gpus_per_server.is_free(),
            "cpu_factor" => self.cpu_factor.is_free(),
            "bw_factor" => self.bw_factor.is_free(),
            "arrival" => self.arrival.len() > 1,
            "arrival_load" => self.arrival_load.is_free(),
            "models" => self.models.len() > 1,
            "ps_on_gpu_prob" => self.ps_on_gpu_prob.is_free(),
            "min_workers" => self.min_workers.is_free(),
            "max_workers" => self.max_workers.is_free(),
            "fault_rate" => self.fault_rate.is_free(),
            _ => false,
        }
    }

    /// One-factor probes: all dims at center, `dim` swept across up to
    /// `k` points. Returns `(value label, assignment)` per point.
    pub fn dim_points(&self, dim: &str, k: usize) -> Vec<(String, DimValues)> {
        let center = self.center();
        let num = |vals: Vec<f64>, set: fn(&mut DimValues, f64)| -> Vec<(String, DimValues)> {
            vals.into_iter()
                .map(|v| {
                    let mut dv = center.clone();
                    set(&mut dv, v);
                    (fmt_f64(v), dv)
                })
                .collect()
        };
        let int = |vals: Vec<u64>, set: fn(&mut DimValues, u64)| -> Vec<(String, DimValues)> {
            vals.into_iter()
                .map(|v| {
                    let mut dv = center.clone();
                    set(&mut dv, v);
                    (v.to_string(), dv)
                })
                .collect()
        };
        match dim {
            "jobs" => int(self.jobs.points(k), |d, v| d.jobs = v),
            "gpu_servers" => int(self.gpu_servers.points(k), |d, v| d.gpu_servers = v),
            "cpu_servers" => int(self.cpu_servers.points(k), |d, v| d.cpu_servers = v),
            "gpus_per_server" => {
                int(self.gpus_per_server.points(k), |d, v| d.gpus_per_server = v)
            }
            "cpu_factor" => num(self.cpu_factor.points(k), |d, v| d.cpu_factor = v),
            "bw_factor" => num(self.bw_factor.points(k), |d, v| d.bw_factor = v),
            "arrival" => (0..self.arrival.len())
                .map(|i| {
                    let mut dv = center.clone();
                    dv.arrival = i;
                    (arrival_tag(&self.arrival[i]).to_string(), dv)
                })
                .collect(),
            "arrival_load" => num(self.arrival_load.points(k), |d, v| d.arrival_load = v),
            "models" => (0..self.models.len())
                .map(|i| {
                    let mut dv = center.clone();
                    dv.models = i;
                    (mix_tag(&self.models[i]).to_string(), dv)
                })
                .collect(),
            "ps_on_gpu_prob" => num(self.ps_on_gpu_prob.points(k), |d, v| d.ps_on_gpu_prob = v),
            "min_workers" => int(self.min_workers.points(k), |d, v| d.min_workers = v),
            "max_workers" => int(self.max_workers.points(k), |d, v| d.max_workers = v),
            "fault_rate" => num(self.fault_rate.points(k), |d, v| d.fault_rate = v),
            _ => Vec::new(),
        }
    }

    /// Draw sample `index`'s assignment + per-sample workload/fault
    /// seeds. Pure in `(self.seed, index)`: a fresh root is forked per
    /// index, so any cell can be recomputed alone, in any order, on any
    /// machine — the fabric's byte-identity contract.
    pub fn sample_values_at(&self, index: usize) -> (DimValues, u64, u64) {
        let mut root = Rng::new(self.seed, SPACE_STREAM);
        let mut rng = root.fork(index as u64);
        // draw order is DIM_NAMES order, then the two seeds — documented
        // in DESIGN.md §11; changing it re-keys every sampled set
        let v = DimValues {
            jobs: self.jobs.sample(&mut rng),
            gpu_servers: self.gpu_servers.sample(&mut rng),
            cpu_servers: self.cpu_servers.sample(&mut rng),
            gpus_per_server: self.gpus_per_server.sample(&mut rng),
            cpu_factor: self.cpu_factor.sample(&mut rng),
            bw_factor: self.bw_factor.sample(&mut rng),
            arrival: rng.usize(0, self.arrival.len() - 1),
            arrival_load: self.arrival_load.sample(&mut rng),
            models: rng.usize(0, self.models.len() - 1),
            ps_on_gpu_prob: self.ps_on_gpu_prob.sample(&mut rng),
            min_workers: self.min_workers.sample(&mut rng),
            max_workers: self.max_workers.sample(&mut rng),
            fault_rate: self.fault_rate.sample(&mut rng),
        };
        // 52-bit seeds survive the f64 JSON round-trip bit-exactly and
        // stay inside jsonio's 9e15 integer bound
        let workload_seed = rng.next_u64() >> 12;
        let fault_seed = rng.next_u64() >> 12;
        (v, workload_seed, fault_seed)
    }

    /// Sample `index` as a validated scenario named `{space}-s{index}`.
    pub fn sample_at(&self, index: usize) -> Scenario {
        let (v, workload_seed, fault_seed) = self.sample_values_at(index);
        self.materialize(format!("{}-s{index:03}", self.name), &v, workload_seed, fault_seed)
    }

    /// A center-anchored scenario (sensitivity probes): seeds are the
    /// space's own, so the swept dimension is the *only* thing varying.
    pub fn center_scenario(&self, name: &str, v: &DimValues) -> Scenario {
        self.materialize(name.to_string(), v, self.seed, self.fault_seed)
    }

    /// Turn an assignment into a scenario. Cross-dim constraints are
    /// resolved by clamping (worker bounds to the cluster's GPU count,
    /// PS placement to GPU servers when there are no CPU servers), so
    /// every in-bounds assignment materializes to a valid scenario.
    fn materialize(
        &self,
        name: String,
        v: &DimValues,
        workload_seed: u64,
        fault_seed: u64,
    ) -> Scenario {
        let gpu_servers = v.gpu_servers.max(1) as usize;
        let gpus_per_server = v.gpus_per_server.max(1) as usize;
        let total_gpus = gpu_servers * gpus_per_server;
        let jobs = v.jobs.max(1) as usize;
        let min_workers = (v.min_workers.max(1) as usize).min(total_gpus);
        let max_workers = (v.max_workers as usize).clamp(min_workers, total_gpus);
        let cpu_servers = v.cpu_servers as usize;
        let on_gpu_prob =
            if cpu_servers == 0 { 1.0 } else { v.ps_on_gpu_prob.clamp(0.0, 1.0) };
        let arrival = &self.arrival[v.arrival];
        let base_span = match explicit_span(arrival) {
            s if s > 0.0 => s,
            _ => jobs as f64 * 280.0,
        };
        let span_s = base_span / v.arrival_load;
        Scenario {
            name,
            description: String::new(),
            experiments: Vec::new(),
            cluster: ClusterShape {
                gpu_servers,
                cpu_servers,
                gpus_per_server,
                cpu_factor: v.cpu_factor,
                bw_factor: v.bw_factor,
            },
            workload: WorkloadSpec {
                jobs,
                seed: workload_seed,
                arrival: with_span(arrival, span_s),
                min_workers,
                max_workers,
                models: self.models[v.models].clone(),
                ps: PsSpec { on_gpu_prob, ..PsSpec::default() },
            },
            faults: FaultRegime::Rate { rate: v.fault_rate.max(0.0), seed: fault_seed },
            policies: self.policies.clone(),
            archs: self.archs.clone(),
            driver: self.driver.clone(),
        }
    }

    /// The assignment as a flat JSON object (choice dims as their kind
    /// tags) — the `knobs` block of every search result row.
    pub fn knobs_json(&self, v: &DimValues) -> Json {
        jsonio::obj(vec![
            ("jobs", jsonio::num(v.jobs as f64)),
            ("gpu_servers", jsonio::num(v.gpu_servers as f64)),
            ("cpu_servers", jsonio::num(v.cpu_servers as f64)),
            ("gpus_per_server", jsonio::num(v.gpus_per_server as f64)),
            ("cpu_factor", jsonio::num(v.cpu_factor)),
            ("bw_factor", jsonio::num(v.bw_factor)),
            ("arrival", jsonio::s(arrival_tag(&self.arrival[v.arrival]))),
            ("arrival_load", jsonio::num(v.arrival_load)),
            ("models", jsonio::s(mix_tag(&self.models[v.models]))),
            ("ps_on_gpu_prob", jsonio::num(v.ps_on_gpu_prob)),
            ("min_workers", jsonio::num(v.min_workers as f64)),
            ("max_workers", jsonio::num(v.max_workers as f64)),
            ("fault_rate", jsonio::num(v.fault_rate)),
        ])
    }
}

/// The short kind tag of an arrival process (labels, knob reports).
pub fn arrival_tag(a: &Arrival) -> &'static str {
    match a {
        Arrival::Philly { .. } => "philly",
        Arrival::Poisson { .. } => "poisson",
        Arrival::Bursty { .. } => "bursty",
        Arrival::Diurnal { .. } => "diurnal",
    }
}

/// The short kind tag of a model mix (labels, knob reports).
pub fn mix_tag(m: &ModelMix) -> &'static str {
    match m {
        ModelMix::Uniform => "uniform",
        ModelMix::Vision => "vision",
        ModelMix::Nlp => "nlp",
        ModelMix::Weighted(_) => "weighted",
    }
}

fn explicit_span(a: &Arrival) -> f64 {
    match a {
        Arrival::Philly { span_s }
        | Arrival::Poisson { span_s }
        | Arrival::Bursty { span_s, .. }
        | Arrival::Diurnal { span_s, .. } => *span_s,
    }
}

fn with_span(a: &Arrival, span_s: f64) -> Arrival {
    let mut out = a.clone();
    match &mut out {
        Arrival::Philly { span_s: s }
        | Arrival::Poisson { span_s: s }
        | Arrival::Bursty { span_s: s, .. }
        | Arrival::Diurnal { span_s: s, .. } => *s = span_s,
    }
    out
}

/// Minimal-digits value label, charset-safe for scenario names and
/// report columns (`0.5000` → `0.5`, `1000.0000` → `1000`).
fn fmt_f64(v: f64) -> String {
    let s = format!("{v:.4}");
    s.trim_end_matches('0').trim_end_matches('.').to_string()
}

fn pair(v: &Json, path: &str) -> crate::Result<(f64, f64)> {
    let a = v.arr().with_context(|| path.to_string())?;
    if a.len() != 2 {
        bail!("{path}: expected [lo, hi], got {} elements", a.len());
    }
    Ok((
        a[0].num().with_context(|| path.to_string())?,
        a[1].num().with_context(|| path.to_string())?,
    ))
}

// -- builtin spaces ----------------------------------------------------------

/// The named spaces behind `star scenario sample|search <name>`.
///
/// * `frontier` — the broad counterfactual frontier: cluster size, CPU
///   and network oversubscription, arrival family and load, and fault
///   rate all free; the headline "which knob most moves TTA/p99 JCT?"
///   space.
/// * `mode_choice` — the paper's §5 sensitivity question distilled:
///   only fault rate and CPU oversubscription vary, policies span
///   sync/semi-sync/STAR, answering "at what fault rate does STAR's
///   advantage collapse?".
pub fn builtin_spaces() -> Vec<ScenarioSpace> {
    vec![
        ScenarioSpace {
            name: "frontier".into(),
            description: "broad counterfactual frontier: cluster shape, oversubscription, \
                          arrival family and load, and fault rate all free"
                .into(),
            seed: 7,
            fault_seed: 7,
            policies: vec!["SSGD".into(), "LGC".into(), "STAR-H".into()],
            archs: vec![Arch::Ps],
            arrival: vec![
                Arrival::Philly { span_s: 0.0 },
                Arrival::Poisson { span_s: 0.0 },
                Arrival::Bursty {
                    span_s: 0.0,
                    burst_every_s: 3600.0,
                    burst_len_s: 600.0,
                    mult: 6.0,
                },
            ],
            models: vec![ModelMix::Uniform],
            jobs: IntDim::Range { lo: 20, hi: 60 },
            gpu_servers: IntDim::Range { lo: 4, hi: 8 },
            cpu_factor: NumDim::LogRange { lo: 0.35, hi: 1.0 },
            bw_factor: NumDim::LogRange { lo: 0.5, hi: 1.0 },
            arrival_load: NumDim::Range { lo: 0.5, hi: 2.0 },
            fault_rate: NumDim::Range { lo: 0.0, hi: 4.0 },
            ..Default::default()
        },
        ScenarioSpace {
            name: "mode_choice".into(),
            description: "the §5 mode-choice sensitivity: fault rate × CPU oversubscription \
                          against sync, semi-sync, and STAR policies"
                .into(),
            seed: 11,
            fault_seed: 11,
            policies: vec!["SSGD".into(), "LB-BSP".into(), "STAR-H".into()],
            archs: vec![Arch::Ps],
            jobs: IntDim::Fixed(24),
            cpu_factor: NumDim::Range { lo: 0.35, hi: 1.0 },
            fault_rate: NumDim::Range { lo: 0.0, hi: 8.0 },
            ..Default::default()
        },
    ]
}

pub fn space_names() -> Vec<String> {
    builtin_spaces().iter().map(|s| s.name.clone()).collect()
}

pub fn find_space(name: &str) -> Option<ScenarioSpace> {
    builtin_spaces().into_iter().find(|s| s.name == name)
}

/// Resolve a `star scenario sample|search` target: bare names hit the
/// builtin-space table, anything path-like reads a space spec file —
/// the same discipline as [`super::load`].
pub fn load(target: &str) -> crate::Result<ScenarioSpace> {
    let looks_like_path = target.ends_with(".json") || target.contains('/');
    if !looks_like_path {
        return find_space(target).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario space {target:?} (built-ins: {}; or pass a .json space file)",
                space_names().join(", ")
            )
        });
    }
    let path = Path::new(target);
    if path.is_file() {
        return ScenarioSpace::from_file(path);
    }
    Err(anyhow::anyhow!("scenario space file {target:?} not found"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> crate::Result<ScenarioSpace> {
        ScenarioSpace::from_json(&Json::parse(text).unwrap())
    }

    fn err_of(text: &str) -> String {
        format!("{:#}", parse(text).err().expect("space must be rejected"))
    }

    const FULL: &str = r#"{
        "name": "kitchen-sink",
        "description": "every dim form",
        "seed": 9, "fault_seed": 3,
        "policies": ["SSGD", "STAR-H"],
        "archs": ["ps", "ar"],
        "arrival": [
            {"kind": "philly", "span_s": 0},
            {"kind": "diurnal", "span_s": 0, "period_s": 3600, "peak_mult": 3}
        ],
        "models": ["uniform", "vision"],
        "dims": {
            "jobs": {"range": [10, 40]},
            "gpu_servers": {"choice": [4, 6, 8]},
            "cpu_factor": {"logrange": [0.25, 1.0]},
            "bw_factor": 0.8,
            "arrival_load": {"range": [0.5, 2.0]},
            "ps_on_gpu_prob": {"fixed": 0.5},
            "fault_rate": {"choice": [0, 1, 4]}
        }
    }"#;

    #[test]
    fn parse_emit_parse_is_identity() {
        let s1 = parse(FULL).unwrap();
        let j = s1.to_json();
        let s2 = ScenarioSpace::from_json(&j).unwrap();
        assert_eq!(j, s2.to_json());
        assert_eq!(j.to_string_pretty(), s2.to_json().to_string_pretty());
    }

    #[test]
    fn builtin_spaces_are_unique_valid_and_round_trip() {
        let spaces = builtin_spaces();
        let mut names: Vec<_> = spaces.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), spaces.len(), "builtin space names must be unique");
        for sp in &spaces {
            sp.validate().unwrap_or_else(|e| panic!("{}: {e:#}", sp.name));
            assert!(!sp.free_dims().is_empty(), "{}: a space must vary something", sp.name);
            let again = ScenarioSpace::from_json(&sp.to_json())
                .unwrap_or_else(|e| panic!("{}: {e:#}", sp.name));
            assert_eq!(sp.to_json(), again.to_json(), "{}", sp.name);
        }
    }

    #[test]
    fn sampling_is_a_pure_function_of_seed_and_index() {
        let sp = find_space("frontier").unwrap();
        for index in [0usize, 1, 7, 63] {
            let a = sp.sample_at(index).to_json().to_string_pretty();
            let b = sp.sample_at(index).to_json().to_string_pretty();
            assert_eq!(a, b, "index {index} must be deterministic");
        }
        // indexes are independent draws, not a shared stream: sampling
        // index 7 alone equals sampling it after 0..6
        let seq: Vec<_> = (0..8).map(|i| sp.sample_at(i).to_json().to_string_pretty()).collect();
        assert_eq!(seq[7], sp.sample_at(7).to_json().to_string_pretty());
        // and different indexes differ
        assert_ne!(seq[0], seq[1]);
    }

    #[test]
    fn samples_validate_and_round_trip() {
        for sp in builtin_spaces() {
            for index in 0..16 {
                let sc = sp.sample_at(index);
                sc.validate().unwrap_or_else(|e| panic!("{} sample {index}: {e:#}", sp.name));
                let again = Scenario::from_json(&sc.to_json())
                    .unwrap_or_else(|e| panic!("{} sample {index}: {e:#}", sp.name));
                assert_eq!(sc.to_json(), again.to_json(), "{} sample {index}", sp.name);
            }
        }
    }

    #[test]
    fn materializer_clamps_cross_dim_conflicts() {
        // a 1-server cluster with default worker bounds [4, 12] and no
        // CPU servers: workers clamp to the 4 GPUs, PSs go on-GPU
        let sp = ScenarioSpace {
            name: "clamp".into(),
            policies: vec!["SSGD".into()],
            gpu_servers: IntDim::Fixed(1),
            gpus_per_server: IntDim::Fixed(4),
            cpu_servers: IntDim::Fixed(0),
            min_workers: IntDim::Fixed(6),
            max_workers: IntDim::Fixed(12),
            ..Default::default()
        };
        sp.validate().unwrap();
        let sc = sp.sample_at(0);
        assert_eq!((sc.workload.min_workers, sc.workload.max_workers), (4, 4));
        assert_eq!(sc.workload.ps.on_gpu_prob, 1.0);
        sc.validate().unwrap();
    }

    #[test]
    fn arrival_load_compresses_the_span() {
        let sp = ScenarioSpace {
            name: "load".into(),
            policies: vec!["SSGD".into()],
            jobs: IntDim::Fixed(10),
            arrival_load: NumDim::Fixed(2.0),
            ..Default::default()
        };
        sp.validate().unwrap();
        let sc = sp.sample_at(0);
        // auto span 10·280 s halved by load 2
        match sc.workload.arrival {
            Arrival::Philly { span_s } => assert_eq!(span_s, 1400.0),
            ref other => panic!("unexpected arrival {other:?}"),
        }
    }

    #[test]
    fn dim_points_and_centers() {
        let d = NumDim::Range { lo: 0.0, hi: 4.0 };
        assert_eq!(d.center(), 2.0);
        assert_eq!(d.points(5), vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        let lg = NumDim::LogRange { lo: 0.25, hi: 1.0 };
        assert!((lg.center() - 0.5).abs() < 1e-12);
        let pts = lg.points(3);
        assert_eq!(pts.len(), 3);
        assert!((pts[0] - 0.25).abs() < 1e-12 && (pts[2] - 1.0).abs() < 1e-12);
        let i = IntDim::Range { lo: 10, hi: 12 };
        assert_eq!(i.points(5), vec![10, 11, 12], "rounded duplicates collapse");
        assert_eq!(IntDim::Choice(vec![3, 9]).points(2), vec![3, 9]);
        assert_eq!(fmt_f64(0.5), "0.5");
        assert_eq!(fmt_f64(1000.0), "1000");
    }

    #[test]
    fn free_dims_follow_dim_name_order() {
        let sp = find_space("mode_choice").unwrap();
        assert_eq!(sp.free_dims(), vec!["cpu_factor", "fault_rate"]);
        let sp = find_space("frontier").unwrap();
        let free = sp.free_dims();
        let order: Vec<_> =
            DIM_NAMES.iter().copied().filter(|d| free.contains(d)).collect();
        assert_eq!(free, order);
    }

    #[test]
    fn validation_errors_name_their_field() {
        let no_policy = err_of(r#"{"name": "x"}"#);
        assert!(no_policy.contains("space.policies"), "{no_policy}");

        let bad_range =
            err_of(r#"{"name": "x", "policies": ["SSGD"], "dims": {"jobs": {"range": [9, 2]}}}"#);
        assert!(bad_range.contains("space.dims.jobs"), "{bad_range}");

        let bad_log = err_of(
            r#"{"name": "x", "policies": ["SSGD"],
                "dims": {"cpu_factor": {"logrange": [0, 1]}}}"#,
        );
        assert!(bad_log.contains("space.dims.cpu_factor"), "{bad_log}");

        let two_forms = err_of(
            r#"{"name": "x", "policies": ["SSGD"],
                "dims": {"fault_rate": {"fixed": 1, "range": [0, 2]}}}"#,
        );
        assert!(two_forms.contains("space.dims.fault_rate"), "{two_forms}");

        let typo = err_of(r#"{"name": "x", "policies": ["SSGD"], "dims": {"jbos": 3}}"#);
        assert!(typo.contains("jbos"), "{typo}");

        let bad_arrival = err_of(
            r#"{"name": "x", "policies": ["SSGD"], "arrival": [{"kind": "warp"}]}"#,
        );
        assert!(bad_arrival.contains("space.arrival[0]"), "{bad_arrival}");

        let empty_choice = err_of(
            r#"{"name": "x", "policies": ["SSGD"], "dims": {"fault_rate": {"choice": []}}}"#,
        );
        assert!(empty_choice.contains("space.dims.fault_rate"), "{empty_choice}");
    }

    #[test]
    fn load_resolves_builtin_spaces_and_files() {
        assert_eq!(load("frontier").unwrap().name, "frontier");
        let err = format!("{:#}", load("not_a_space").err().unwrap());
        assert!(err.contains("mode_choice"), "must list built-ins: {err}");
        let err = format!("{:#}", load("no/such/space.json").err().unwrap());
        assert!(err.contains("not found"), "{err}");
    }
}
