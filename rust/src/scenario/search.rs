//! Counterfactual search over a [`ScenarioSpace`] (DESIGN.md §11): run
//! one-factor sensitivity probes (all dims at center, one swept across
//! its range) plus `count` random samples of the space, each × the
//! space's policy × arch grid, and assemble three reports:
//!
//! * `search_<name>.csv` / `.json` — every cell (probe + sample rows);
//! * `search_<name>_sensitivity.csv` — per free dimension, the spread
//!   of mean TTA and p99 JCT across its probe points, ranked by p99
//!   spread ("which knob most moves the tail?");
//! * `search_<name>_regret.csv` — per policy × arch, wins / mean / max
//!   regret in mean JCT vs the per-sample best ("at what fault rate
//!   does STAR's advantage collapse?" — scan the JSON `regret.samples`,
//!   sorted by fault rate, for the winner flip).
//!
//! Cells are pure functions of `(space, count, points, index)` — the
//! same contract generic scenarios have — so the search runs in-process
//! via [`crate::exp::sweep`] or scattered over the fabric via
//! `SweepSpec::Space`, byte-identically.

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::exp::{sweep, CellRows};
use crate::jsonio::{self, Json};
use crate::stats;
use crate::table::{self, Table};
use crate::trace::Arch;

use super::runner;
use super::space::{DimValues, ScenarioSpace};
use super::spec::{arch_tag, Scenario};

/// Invocation knobs of a search run (CLI-derived).
#[derive(Clone, Debug)]
pub struct SearchOpts {
    /// random samples of the space (on top of the sensitivity probes)
    pub count: usize,
    /// probe points per free dimension of the sensitivity sweep
    pub points: usize,
    pub quick: bool,
    pub jobs_override: Option<usize>,
    pub threads: usize,
    pub out_dir: PathBuf,
}

impl Default for SearchOpts {
    fn default() -> Self {
        SearchOpts {
            count: 16,
            points: 5,
            quick: false,
            jobs_override: None,
            threads: sweep::available_threads(),
            out_dir: PathBuf::from("results"),
        }
    }
}

/// What a cell probes: a sensitivity point or a random sample.
#[derive(Clone, Debug)]
pub enum CellKind {
    /// all dims at center, `dim` at probe point `point` (= `label`)
    Center { dim: &'static str, point: usize, label: String },
    /// random sample `index` of the space
    Sample { index: usize },
}

/// One planned search cell: a concrete scenario × one grid coordinate.
#[derive(Clone, Debug)]
pub struct SearchCell {
    pub scenario: Scenario,
    /// the dim assignment the scenario was materialized from
    pub values: DimValues,
    pub arch: Arch,
    pub policy: String,
    pub kind: CellKind,
}

/// The full deterministic cell list: sensitivity probes (free dims in
/// roster order × probe points), then samples `0..count` — each × the
/// policy × arch grid in [`sweep::cross`] order. Cell index `i` means
/// the same work in-process, on a fabric worker, and in a journal.
pub fn plan(space: &ScenarioSpace, count: usize, points: usize) -> Vec<SearchCell> {
    let grid = sweep::cross(&space.archs, &space.policies);
    let mut cells = Vec::new();
    for dim in space.free_dims() {
        for (pi, (label, values)) in space.dim_points(dim, points).into_iter().enumerate() {
            let sc =
                space.center_scenario(&format!("{}-c-{dim}-p{pi}", space.name), &values);
            for (arch, policy) in &grid {
                cells.push(SearchCell {
                    scenario: sc.clone(),
                    values: values.clone(),
                    arch: *arch,
                    policy: policy.clone(),
                    kind: CellKind::Center { dim, point: pi, label: label.clone() },
                });
            }
        }
    }
    for index in 0..count {
        let sc = space.sample_at(index);
        let values = space.sample_values_at(index).0;
        for (arch, policy) in &grid {
            cells.push(SearchCell {
                scenario: sc.clone(),
                values: values.clone(),
                arch: *arch,
                policy: policy.clone(),
                kind: CellKind::Sample { index },
            });
        }
    }
    cells
}

/// Compute one search cell standalone — the fabric worker entry point.
/// Rebuilds the plan from `(space, count, points)` so index `i` here
/// equals index `i` of the in-process sweep bit for bit.
pub fn compute_cell(
    space: &ScenarioSpace,
    count: usize,
    points: usize,
    jobs_override: Option<usize>,
    quick: bool,
    index: usize,
) -> crate::Result<CellRows> {
    space.validate().with_context(|| format!("space {:?}", space.name))?;
    let cells = plan(space, count, points);
    let cell = cells.get(index).with_context(|| {
        format!("cell index {index} out of range (search has {} cells)", cells.len())
    })?;
    run_cell(space, cell, jobs_override, quick)
}

/// Run one cell's driver and render its row pair — the only formatter
/// for search rows, shared by the in-process sweep and remote workers.
fn run_cell(
    space: &ScenarioSpace,
    cell: &SearchCell,
    jobs_override: Option<usize>,
    quick: bool,
) -> crate::Result<CellRows> {
    let sc = &cell.scenario;
    let jobs = runner::effective_jobs(sc, jobs_override, quick);
    let prep = runner::prepare(sc, jobs, quick)?;
    let s = runner::cell_summary(sc, &prep, cell.arch, &cell.policy);
    // -1 = "no job reached the target" (NaN is not valid JSON)
    let tta_mean = if s.tta.is_empty() { -1.0 } else { stats::mean(&s.tta) };
    let jct_mean = stats::mean(&s.jct);
    let jct_p99 = if s.jct.is_empty() { -1.0 } else { stats::percentile(&s.jct, 99.0) };
    let (kind, probe) = match &cell.kind {
        CellKind::Center { dim, label, .. } => ("center", format!("{dim}={label}")),
        CellKind::Sample { index } => ("sample", format!("s{index:03}")),
    };
    let csv = [
        table::s(kind),
        table::s(sc.name.as_str()),
        table::s(probe.as_str()),
        table::s(cell.policy.as_str()),
        table::s(arch_tag(cell.arch)),
        table::i(s.jobs as i64),
        table::i(prep.plan.len() as i64),
        table::f(tta_mean, 0),
        table::f(jct_mean, 0),
        table::f(jct_p99, 0),
        table::s(format!("{}/{}", s.tta_reached, s.jobs)),
    ]
    .iter()
    .map(|c| c.render())
    .collect();
    let json = jsonio::obj(vec![
        (
            "name",
            jsonio::s(&format!(
                "search/{}/{}/{}/{}",
                space.name,
                sc.name,
                cell.policy,
                arch_tag(cell.arch)
            )),
        ),
        ("kind", jsonio::s(kind)),
        ("probe", jsonio::s(&probe)),
        ("scenario", jsonio::s(&sc.name)),
        ("policy", jsonio::s(&cell.policy)),
        ("arch", jsonio::s(arch_tag(cell.arch))),
        ("iters", jsonio::num(s.jobs as f64)),
        // headline metric in the bench schema's slot: mean JCT
        ("ns_per_iter", jsonio::num(jct_mean * 1e9)),
        ("tta_mean_s", jsonio::num(tta_mean)),
        ("jct_mean_s", jsonio::num(jct_mean)),
        ("jct_p99_s", jsonio::num(jct_p99)),
        ("tta_reached", jsonio::num(s.tta_reached as f64)),
        ("jobs", jsonio::num(s.jobs as f64)),
        ("fault_count", jsonio::num(prep.plan.len() as f64)),
        // the full dim assignment, so every row is a labeled
        // counterfactual data point (ROADMAP item 4's corpus)
        ("knobs", space.knobs_json(&cell.values)),
    ]);
    Ok(CellRows { csv, json })
}

/// Run the whole search in-process and assemble the reports.
pub fn run(space: &ScenarioSpace, opts: &SearchOpts) -> crate::Result<()> {
    space.validate().with_context(|| format!("space {:?}", space.name))?;
    if opts.jobs_override == Some(0) {
        anyhow::bail!("--jobs: a search needs at least one job per scenario");
    }
    let cells = plan(space, opts.count, opts.points);
    let free = space.free_dims();
    eprintln!(
        "[search] {}: {} cells ({} free dims x ≤{} points + {} samples, {} policies x {} \
         archs) on {} thread(s)…",
        space.name,
        cells.len(),
        free.len(),
        opts.points,
        opts.count,
        space.policies.len(),
        space.archs.len(),
        opts.threads
    );
    let rows = sweep::run_indexed(&cells, opts.threads, |i, cell| {
        let t0 = std::time::Instant::now();
        let rows = run_cell(space, cell, opts.jobs_override, opts.quick)
            .unwrap_or_else(|e| panic!("search cell {i} failed: {e:#}"));
        eprintln!(
            "[search]   {}/{}/{}: {:.1}s wall",
            cell.scenario.name,
            cell.policy,
            arch_tag(cell.arch),
            t0.elapsed().as_secs_f64()
        );
        rows
    })?;
    assemble(space, &opts.out_dir, opts.count, opts.points, opts.quick, opts.jobs_override, &rows)
}

/// Assemble the reports from index-ordered cell rows. Both the
/// in-process sweep and the fabric dispatcher end here, and everything
/// is a pure function of `(space, invocation, rows)` — which is why a
/// dispatched search is byte-identical to `--threads 1`.
pub fn assemble(
    space: &ScenarioSpace,
    out_dir: &Path,
    count: usize,
    points: usize,
    quick: bool,
    jobs_override: Option<usize>,
    rows: &[CellRows],
) -> crate::Result<()> {
    let cells = plan(space, count, points);
    anyhow::ensure!(
        cells.len() == rows.len(),
        "search rows/plan mismatch: {} rows for {} planned cells",
        rows.len(),
        cells.len()
    );
    let mut t = Table::new(
        &format!("Search {} — {}", space.name, space.description),
        &[
            "kind",
            "scenario",
            "probe",
            "policy",
            "arch",
            "jobs",
            "faults",
            "tta_mean_s",
            "jct_mean_s",
            "jct_p99_s",
            "reached",
        ],
    );
    for r in rows {
        t.row(r.csv.clone());
    }
    t.print();
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let csv = out_dir.join(format!("search_{}.csv", space.name));
    t.save_csv(&csv).with_context(|| format!("saving {}", csv.display()))?;

    let sensitivity = sensitivity_report(space, points, &cells, rows);
    let regret = regret_report(space, &cells, rows);
    sensitivity_table(&sensitivity).save_csv(
        &out_dir.join(format!("search_{}_sensitivity.csv", space.name)),
    )?;
    regret_table(&regret).save_csv(&out_dir.join(format!("search_{}_regret.csv", space.name)))?;
    sensitivity_table(&sensitivity).print();
    regret_table(&regret).print();

    let mut invocation = vec![
        ("count", jsonio::num(count as f64)),
        ("points", jsonio::num(points as f64)),
        ("quick", jsonio::b(quick)),
    ];
    if let Some(jobs) = jobs_override {
        invocation.push(("jobs", jsonio::num(jobs as f64)));
    }
    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::scenario::search")),
        ("space", space.to_json()),
        // run-variant knobs (threads, fleet shape) are deliberately
        // absent — the artifact is run-invariant (DESIGN.md §10)
        ("invocation", jsonio::obj(invocation)),
        ("results", Json::Arr(rows.iter().map(|r| r.json.clone()).collect())),
        ("sensitivity", sensitivity_json(&sensitivity)),
        ("regret", regret_json(&regret)),
    ]);
    let path = out_dir.join(format!("search_{}.json", space.name));
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("search results written to {}", path.display());
    Ok(())
}

// -- sensitivity -------------------------------------------------------------

struct DimSensitivity {
    dim: &'static str,
    /// (label, mean tta over the point's grid cells or -1, mean p99 jct)
    points: Vec<(String, f64, f64)>,
    tta_spread: f64,
    p99_spread: f64,
}

fn row_num(r: &CellRows, key: &str) -> f64 {
    r.json.get(key).and_then(|v| v.num()).unwrap_or(-1.0)
}

/// Per free dimension: aggregate each probe point's grid cells, then
/// measure how far the point means move across the dimension's range.
/// Ranked by p99-JCT spread (descending) — the "which knob most moves
/// the tail" ordering.
fn sensitivity_report(
    space: &ScenarioSpace,
    points: usize,
    cells: &[SearchCell],
    rows: &[CellRows],
) -> Vec<DimSensitivity> {
    let mut report = Vec::new();
    for dim in space.free_dims() {
        let labels: Vec<String> =
            space.dim_points(dim, points).into_iter().map(|(l, _)| l).collect();
        let mut pts = Vec::with_capacity(labels.len());
        for (pi, label) in labels.iter().enumerate() {
            let matching: Vec<&CellRows> = cells
                .iter()
                .zip(rows)
                .filter(|(c, _)| {
                    matches!(&c.kind, CellKind::Center { dim: d, point, .. }
                        if *d == dim && *point == pi)
                })
                .map(|(_, r)| r)
                .collect();
            let ttas: Vec<f64> = matching
                .iter()
                .map(|r| row_num(r, "tta_mean_s"))
                .filter(|&v| v >= 0.0)
                .collect();
            let p99s: Vec<f64> = matching.iter().map(|r| row_num(r, "jct_p99_s")).collect();
            let tta = if ttas.is_empty() { -1.0 } else { stats::mean(&ttas) };
            let p99 = if p99s.is_empty() { -1.0 } else { stats::mean(&p99s) };
            pts.push((label.clone(), tta, p99));
        }
        report.push(DimSensitivity {
            dim,
            tta_spread: spread(pts.iter().map(|p| p.1).filter(|&v| v >= 0.0)),
            p99_spread: spread(pts.iter().map(|p| p.2)),
            points: pts,
        });
    }
    report.sort_by(|a, b| b.p99_spread.total_cmp(&a.p99_spread).then(a.dim.cmp(b.dim)));
    report
}

/// max − min over an iterator; -1 when fewer than two values (a spread
/// needs two points to mean anything).
fn spread(values: impl Iterator<Item = f64>) -> f64 {
    let vals: Vec<f64> = values.collect();
    if vals.len() < 2 {
        return -1.0;
    }
    let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    hi - lo
}

fn sensitivity_table(report: &[DimSensitivity]) -> Table {
    let mut t = Table::new(
        "One-factor sensitivity (center sweep, ranked by p99-JCT spread)",
        &["dim", "points", "tta_spread_s", "jct_p99_spread_s"],
    );
    for d in report {
        t.row(
            [
                table::s(d.dim),
                table::i(d.points.len() as i64),
                table::f(d.tta_spread, 0),
                table::f(d.p99_spread, 0),
            ]
            .iter()
            .map(|c| c.render())
            .collect(),
        );
    }
    t
}

fn sensitivity_json(report: &[DimSensitivity]) -> Json {
    Json::Arr(
        report
            .iter()
            .map(|d| {
                jsonio::obj(vec![
                    ("dim", jsonio::s(d.dim)),
                    (
                        "points",
                        Json::Arr(
                            d.points
                                .iter()
                                .map(|(label, tta, p99)| {
                                    jsonio::obj(vec![
                                        ("label", jsonio::s(label)),
                                        ("tta_mean_s", jsonio::num(*tta)),
                                        ("jct_p99_mean_s", jsonio::num(*p99)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("tta_spread_s", jsonio::num(d.tta_spread)),
                    ("jct_p99_spread_s", jsonio::num(d.p99_spread)),
                ])
            })
            .collect(),
    )
}

// -- regret ------------------------------------------------------------------

struct SampleRegret {
    index: usize,
    fault_rate: f64,
    /// grid-ordered (policy, arch, jct_mean_s, regret_s)
    cells: Vec<(String, Arch, f64, f64)>,
    winner: usize,
}

struct PolicyRegret {
    policy: String,
    arch: Arch,
    wins: usize,
    mean_regret: f64,
    max_regret: f64,
}

/// Per sample, score every grid cell by mean JCT against the
/// per-sample best; then aggregate wins and regret per policy × arch.
/// Samples come back sorted by fault rate, so the regret JSON reads as
/// "the winner as faults intensify".
fn regret_report(
    space: &ScenarioSpace,
    cells: &[SearchCell],
    rows: &[CellRows],
) -> (Vec<SampleRegret>, Vec<PolicyRegret>) {
    let grid = sweep::cross(&space.archs, &space.policies);
    let mut samples: Vec<SampleRegret> = Vec::new();
    let mut by_index: std::collections::BTreeMap<usize, Vec<f64>> =
        std::collections::BTreeMap::new();
    for (c, r) in cells.iter().zip(rows) {
        if let CellKind::Sample { index } = c.kind {
            by_index.entry(index).or_default().push(row_num(r, "jct_mean_s"));
        }
    }
    for (index, scores) in by_index {
        if scores.len() != grid.len() {
            continue; // incomplete sample group — impossible post-ensure
        }
        let mut winner = 0;
        for (k, &s) in scores.iter().enumerate() {
            if s < scores[winner] {
                winner = k;
            }
        }
        let best = scores[winner];
        let fault_rate = space.sample_values_at(index).0.fault_rate;
        let cells = grid
            .iter()
            .zip(&scores)
            .map(|((arch, policy), &jct)| (policy.clone(), *arch, jct, jct - best))
            .collect();
        samples.push(SampleRegret { index, fault_rate, cells, winner });
    }
    samples.sort_by(|a, b| a.fault_rate.total_cmp(&b.fault_rate).then(a.index.cmp(&b.index)));

    let by_policy = grid
        .iter()
        .enumerate()
        .map(|(k, (arch, policy))| {
            let regrets: Vec<f64> = samples.iter().map(|s| s.cells[k].3).collect();
            PolicyRegret {
                policy: policy.clone(),
                arch: *arch,
                wins: samples.iter().filter(|s| s.winner == k).count(),
                mean_regret: if regrets.is_empty() { -1.0 } else { stats::mean(&regrets) },
                max_regret: if regrets.is_empty() {
                    -1.0
                } else {
                    regrets.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                },
            }
        })
        .collect();
    (samples, by_policy)
}

fn regret_table((_, by_policy): &(Vec<SampleRegret>, Vec<PolicyRegret>)) -> Table {
    let mut t = Table::new(
        "Regret vs per-sample best (mean JCT)",
        &["policy", "arch", "wins", "mean_regret_s", "max_regret_s"],
    );
    for p in by_policy {
        t.row(
            [
                table::s(p.policy.as_str()),
                table::s(arch_tag(p.arch)),
                table::i(p.wins as i64),
                table::f(p.mean_regret, 1),
                table::f(p.max_regret, 1),
            ]
            .iter()
            .map(|c| c.render())
            .collect(),
        );
    }
    t
}

fn regret_json((samples, by_policy): &(Vec<SampleRegret>, Vec<PolicyRegret>)) -> Json {
    jsonio::obj(vec![
        (
            "samples",
            Json::Arr(
                samples
                    .iter()
                    .map(|s| {
                        jsonio::obj(vec![
                            ("index", jsonio::num(s.index as f64)),
                            ("fault_rate", jsonio::num(s.fault_rate)),
                            ("winner_policy", jsonio::s(&s.cells[s.winner].0)),
                            ("winner_arch", jsonio::s(arch_tag(s.cells[s.winner].1))),
                            ("best_jct_mean_s", jsonio::num(s.cells[s.winner].2)),
                            (
                                "cells",
                                Json::Arr(
                                    s.cells
                                        .iter()
                                        .map(|(policy, arch, jct, regret)| {
                                            jsonio::obj(vec![
                                                ("policy", jsonio::s(policy)),
                                                ("arch", jsonio::s(arch_tag(*arch))),
                                                ("jct_mean_s", jsonio::num(*jct)),
                                                ("regret_s", jsonio::num(*regret)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "by_policy",
            Json::Arr(
                by_policy
                    .iter()
                    .map(|p| {
                        jsonio::obj(vec![
                            ("policy", jsonio::s(&p.policy)),
                            ("arch", jsonio::s(arch_tag(p.arch))),
                            ("wins", jsonio::num(p.wins as f64)),
                            ("mean_regret_s", jsonio::num(p.mean_regret)),
                            ("max_regret_s", jsonio::num(p.max_regret)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::space::find_space;

    fn tiny_space() -> ScenarioSpace {
        use crate::scenario::space::{IntDim, NumDim};
        ScenarioSpace {
            name: "tiny_search".into(),
            policies: vec!["SSGD".into(), "STAR-H".into()],
            jobs: IntDim::Fixed(2),
            fault_rate: NumDim::Choice(vec![0.0, 4.0]),
            ..Default::default()
        }
    }

    #[test]
    fn plan_is_probes_then_samples_in_grid_order() {
        let sp = tiny_space();
        let cells = plan(&sp, 2, 2);
        // 1 free dim (fault_rate choice of 2) x 2 points x 2 grid cells
        // + 2 samples x 2 grid cells
        assert_eq!(cells.len(), 2 * 2 + 2 * 2);
        assert!(matches!(cells[0].kind, CellKind::Center { dim: "fault_rate", point: 0, .. }));
        assert_eq!(cells[0].policy, "SSGD");
        assert_eq!(cells[1].policy, "STAR-H");
        assert!(matches!(cells[4].kind, CellKind::Sample { index: 0 }));
        assert!(matches!(cells[7].kind, CellKind::Sample { index: 1 }));
        // probe scenarios share the space seeds: only the dim varies
        assert_eq!(cells[0].scenario.workload.seed, cells[2].scenario.workload.seed);
    }

    #[test]
    fn compute_cell_matches_the_planned_cell() {
        let sp = tiny_space();
        let cells = plan(&sp, 1, 2);
        let direct = run_cell(&sp, &cells[1], Some(2), true).unwrap();
        let via_index = compute_cell(&sp, 1, 2, Some(2), true, 1).unwrap();
        assert_eq!(direct.csv, via_index.csv);
        assert_eq!(direct.json, via_index.json);
        let err = format!("{:#}", compute_cell(&sp, 1, 2, Some(2), true, 99).unwrap_err());
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn search_runs_and_reports_are_complete() {
        let sp = tiny_space();
        let out = std::env::temp_dir().join("star_search_unit");
        let _ = std::fs::remove_dir_all(&out);
        let opts = SearchOpts {
            count: 2,
            points: 2,
            quick: true,
            jobs_override: Some(2),
            threads: 1,
            out_dir: out.clone(),
        };
        run(&sp, &opts).unwrap();
        let doc = Json::parse_file(&out.join("search_tiny_search.json")).unwrap();
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        let results = doc.get("results").unwrap().arr().unwrap();
        assert_eq!(results.len(), 8);
        // sensitivity: the single free dim is present with both points
        let sens = doc.get("sensitivity").unwrap().arr().unwrap();
        assert_eq!(sens.len(), 1);
        assert_eq!(sens[0].get("dim").unwrap().str().unwrap(), "fault_rate");
        assert_eq!(sens[0].get("points").unwrap().arr().unwrap().len(), 2);
        // regret: every sample scored, winner named, zero-regret winner
        let regret = doc.get("regret").unwrap();
        let samples = regret.get("samples").unwrap().arr().unwrap();
        assert_eq!(samples.len(), 2);
        for s in samples {
            let cells = s.get("cells").unwrap().arr().unwrap();
            assert_eq!(cells.len(), 2);
            let min_regret = cells
                .iter()
                .map(|c| c.get("regret_s").unwrap().num().unwrap())
                .fold(f64::INFINITY, f64::min);
            assert_eq!(min_regret, 0.0, "the winner has zero regret");
        }
        let by_policy = regret.get("by_policy").unwrap().arr().unwrap();
        assert_eq!(by_policy.len(), 2);
        let wins: f64 =
            by_policy.iter().map(|p| p.get("wins").unwrap().num().unwrap()).sum();
        assert_eq!(wins as usize, 2, "every sample has exactly one winner");
        for f in ["search_tiny_search.csv", "search_tiny_search_sensitivity.csv",
                  "search_tiny_search_regret.csv"] {
            assert!(out.join(f).is_file(), "{f} must be written");
        }
    }

    #[test]
    fn threads_do_not_change_the_bytes() {
        let sp = find_space("mode_choice").unwrap();
        let run_at = |threads: usize, tag: &str| -> (String, String) {
            let out = std::env::temp_dir().join(format!("star_search_threads_{tag}"));
            let _ = std::fs::remove_dir_all(&out);
            let opts = SearchOpts {
                count: 1,
                points: 2,
                quick: true,
                jobs_override: Some(2),
                threads,
                out_dir: out.clone(),
            };
            run(&sp, &opts).unwrap();
            (
                std::fs::read_to_string(out.join("search_mode_choice.json")).unwrap(),
                std::fs::read_to_string(out.join("search_mode_choice_regret.csv")).unwrap(),
            )
        };
        let serial = run_at(1, "serial");
        let parallel = run_at(4, "parallel");
        assert_eq!(serial, parallel, "search artifacts must be byte-identical at any --threads");
    }
}
