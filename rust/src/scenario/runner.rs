//! Scenario execution: delegated scenarios route through
//! [`crate::exp::dispatch`] (byte-identical to the `experiments`
//! binary); generic scenarios build the described cluster, workload, and
//! fault plan, then sweep the `arch × policy` grid through
//! [`crate::exp::sweep`] exactly like the resilience experiment —
//! per-cell drivers, order-preserving results, byte-identical at any
//! `--threads`.

use std::path::PathBuf;

use anyhow::Context;

use crate::baselines::make_policy;
use crate::cluster::ClusterConfig;
use crate::driver::{Driver, DriverConfig};
use crate::exp::{summarize, sweep, CellRows, ExpCtx};
use crate::faults::{span_for, FaultPlan};
use crate::jsonio::{self, Json};
use crate::stats;
use crate::table::{self, Table};
use crate::trace::{Arch, JobSpec};

use super::spec::{arch_tag, Scenario};
use super::workload;

/// Invocation knobs (CLI-derived; the spec stays immutable on disk).
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// shrink for smoke runs: caps jobs at 12 and bounds driver limits
    /// (the same clamps the resilience experiment's quick mode uses)
    pub quick: bool,
    pub out_dir: PathBuf,
    /// sweep width; results are byte-identical at any value
    pub threads: usize,
    /// `--jobs N`: run the scenario at a different job count without
    /// editing the spec
    pub jobs_override: Option<usize>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            quick: false,
            out_dir: PathBuf::from("results"),
            threads: sweep::available_threads(),
            jobs_override: None,
        }
    }
}

/// Run a scenario. Validates first, so a hand-written spec fails with a
/// field-naming error before any simulation starts.
pub fn run(sc: &Scenario, opts: &RunOpts) -> crate::Result<()> {
    sc.validate().with_context(|| format!("scenario {:?}", sc.name))?;
    // the override bypasses the spec's workload.jobs validation — re-check
    // (a 0-job run would emit NaN means into the JSON artifact)
    if opts.jobs_override == Some(0) {
        anyhow::bail!("--jobs: a scenario run needs at least one job");
    }
    if !sc.experiments.is_empty() {
        return run_delegated(sc, opts);
    }
    run_generic(sc, opts)
}

/// Delegated flavor: an `ExpCtx` derived from the spec, one
/// `exp::dispatch` per experiment id. Byte-identity with the
/// `experiments` binary holds because this is the same context type
/// driving the same dispatch — the scenario only carries the knobs.
fn run_delegated(sc: &Scenario, opts: &RunOpts) -> crate::Result<()> {
    let (fault_rate, fault_seed) = match sc.faults {
        super::spec::FaultRegime::Rate { rate, seed } => (rate, seed),
        _ => (0.0, 0), // validate_delegation rejects everything but Off/Rate
    };
    let ctx = ExpCtx {
        jobs: opts.jobs_override.unwrap_or(sc.workload.jobs),
        seed: sc.workload.seed,
        out_dir: opts.out_dir.clone(),
        quick: opts.quick,
        fault_rate,
        fault_seed,
        threads: opts.threads,
    };
    for id in &sc.experiments {
        eprintln!("[scenario] {} -> experiment {id}", sc.name);
        crate::exp::dispatch(id, &ctx)?;
    }
    Ok(())
}

/// The job count a generic run actually simulates: the `--jobs` override
/// (or the spec's), clamped to 12 under quick mode.
pub fn effective_jobs(sc: &Scenario, jobs_override: Option<usize>, quick: bool) -> usize {
    let j = jobs_override.unwrap_or(sc.workload.jobs);
    if quick {
        j.min(12)
    } else {
        j
    }
}

/// The generic sweep grid, arch-major — the serial row order. The fabric
/// dispatcher scatters this list, so cell index `i` means the same
/// `(arch, policy)` pair in-process, on a worker, and in a journal.
pub fn grid(sc: &Scenario) -> Vec<(Arch, String)> {
    sweep::cross(&sc.archs, &sc.policies)
}

/// Everything a generic cell needs beyond its `(arch, policy)`
/// coordinates — all of it a pure function of (spec, jobs, quick), so a
/// remote worker rebuilding it from the `SweepSpec` gets bit-identical
/// inputs. `pub(super)` so the space-search driver can run its own cells
/// through the exact same preparation.
pub(super) struct Prep {
    pub(super) trace: Vec<JobSpec>,
    pub(super) cluster: ClusterConfig,
    pub(super) plan: FaultPlan,
    pub(super) max_job_duration_s: f64,
    pub(super) max_updates_per_job: u64,
    pub(super) max_iters_per_job: u64,
}

/// Driver caps: spec overrides (0 = default), then quick-mode bounds
/// (heavily faulted jobs may never converge — same clamps as the
/// resilience experiment's quick mode).
pub(super) fn caps(sc: &Scenario, quick: bool) -> (f64, u64, u64) {
    let defaults = DriverConfig::default();
    let mut max_job_duration_s = if sc.driver.max_job_duration_s > 0.0 {
        sc.driver.max_job_duration_s
    } else {
        defaults.max_job_duration_s
    };
    let mut max_updates_per_job = if sc.driver.max_updates_per_job > 0 {
        sc.driver.max_updates_per_job
    } else {
        defaults.max_updates_per_job
    };
    let mut max_iters_per_job = if sc.driver.max_iters_per_job > 0 {
        sc.driver.max_iters_per_job
    } else {
        defaults.max_iters_per_job
    };
    if quick {
        max_job_duration_s = max_job_duration_s.min(12_000.0);
        max_updates_per_job = max_updates_per_job.min(25_000);
        max_iters_per_job = max_iters_per_job.min(40_000);
    }
    (max_job_duration_s, max_updates_per_job, max_iters_per_job)
}

pub(super) fn prepare(sc: &Scenario, jobs: usize, quick: bool) -> crate::Result<Prep> {
    let trace = workload::build(&sc.workload, jobs)?;
    let cluster = sc.cluster.to_config();
    let (max_job_duration_s, max_updates_per_job, max_iters_per_job) = caps(sc, quick);
    let span = span_for(&trace, max_job_duration_s);
    let plan = sc.faults.plan(&trace, span, cluster.total_servers());
    Ok(Prep { trace, cluster, plan, max_job_duration_s, max_updates_per_job, max_iters_per_job })
}

/// Run one prepared cell's driver and summarize it — the single driver
/// invocation shared by generic scenario rows and the space-search
/// driver (so both report the same simulation bit for bit).
pub(super) fn cell_summary(
    sc: &Scenario,
    prep: &Prep,
    arch: Arch,
    sys: &str,
) -> crate::exp::Summary {
    let cfg = DriverConfig {
        arch,
        cluster: prep.cluster.clone(),
        seed: sc.driver.seed,
        record_series: false,
        max_job_duration_s: prep.max_job_duration_s,
        max_updates_per_job: prep.max_updates_per_job,
        max_iters_per_job: prep.max_iters_per_job,
        faults: prep.plan.clone(),
        ..Default::default()
    };
    let name = sys.to_string();
    let driver = Driver::new(
        cfg,
        prep.trace.clone(),
        Box::new(move |_| make_policy(&name).expect("validated above")),
    );
    summarize(&driver.run().0)
}

/// Run one grid cell's driver and render its row pair — the *only*
/// formatter for generic scenario rows, shared by the in-process sweep
/// and remote workers.
fn cell_rows(sc: &Scenario, prep: &Prep, arch: Arch, sys: &str) -> CellRows {
    let s = cell_summary(sc, prep, arch, sys);
    // -1 = "no job reached the target" (NaN is not valid JSON)
    let tta_mean = if s.tta.is_empty() { -1.0 } else { stats::mean(&s.tta) };
    let jct_mean = stats::mean(&s.jct);
    let downtime_mean = stats::mean(&s.downtime);
    let rollbacks: f64 = s.rollbacks.iter().sum();
    let straggler_mean = stats::mean(&s.stragglers);
    let csv = [
        table::s(sys),
        table::s(arch_tag(arch)),
        table::i(s.jobs as i64),
        table::i(prep.plan.len() as i64),
        table::f(tta_mean, 0),
        table::f(jct_mean, 0),
        table::f(downtime_mean, 1),
        table::i(rollbacks as i64),
        table::f(straggler_mean, 1),
        table::s(format!("{}/{}", s.tta_reached, s.jobs)),
    ]
    .iter()
    .map(|c| c.render())
    .collect();
    let json = jsonio::obj(vec![
        ("name", jsonio::s(&format!("scenario/{}/{sys}/{}", sc.name, arch_tag(arch)))),
        ("iters", jsonio::num(s.jobs as f64)),
        // headline metric in the bench schema's slot: mean JCT
        ("ns_per_iter", jsonio::num(jct_mean * 1e9)),
        ("tta_mean_s", jsonio::num(tta_mean)),
        ("jct_mean_s", jsonio::num(jct_mean)),
        ("downtime_mean_s", jsonio::num(downtime_mean)),
        ("rollbacks", jsonio::num(rollbacks)),
        ("straggler_episodes_mean", jsonio::num(straggler_mean)),
        ("tta_reached", jsonio::num(s.tta_reached as f64)),
        ("jobs", jsonio::num(s.jobs as f64)),
        ("fault_count", jsonio::num(prep.plan.len() as f64)),
    ]);
    CellRows { csv, json }
}

/// Compute one generic grid cell standalone — the fabric worker entry
/// point. Validates and rebuilds the full preparation from the spec
/// (pure functions of it), so index `i` here equals index `i` of the
/// in-process sweep bit for bit.
pub fn compute_cell(
    sc: &Scenario,
    jobs_override: Option<usize>,
    quick: bool,
    index: usize,
) -> crate::Result<CellRows> {
    sc.validate().with_context(|| format!("scenario {:?}", sc.name))?;
    if !sc.experiments.is_empty() {
        anyhow::bail!("scenario {:?} delegates to experiments; not a generic grid", sc.name);
    }
    let cells = grid(sc);
    let (arch, sys) = cells
        .get(index)
        .with_context(|| format!("cell index {index} out of range (grid has {})", cells.len()))?
        .clone();
    let prep = prepare(sc, effective_jobs(sc, jobs_override, quick), quick)?;
    Ok(cell_rows(sc, &prep, arch, &sys))
}

/// Assemble the final artifacts from index-ordered cell rows: printed
/// table, `scenario_<name>.csv`, `scenario_<name>.json`. Both the serial
/// sweep and the fabric dispatcher end here — the artifacts are a pure
/// function of the merged rows plus the effective invocation, which is
/// why a dispatched run is byte-identical to a serial one.
pub fn assemble_generic(
    sc: &Scenario,
    out_dir: &std::path::Path,
    quick: bool,
    jobs: usize,
    rows: &[CellRows],
) -> crate::Result<()> {
    let mut t = Table::new(
        &format!("Scenario {} — {}", sc.name, sc.description),
        &[
            "policy",
            "arch",
            "jobs",
            "faults",
            "tta_mean_s",
            "jct_mean_s",
            "downtime_mean_s",
            "rollbacks",
            "stragglers_mean",
            "reached",
        ],
    );
    let mut results_json: Vec<Json> = Vec::new();
    for r in rows {
        t.row(r.csv.clone());
        results_json.push(r.json.clone());
    }
    t.print();

    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let csv = out_dir.join(format!("scenario_{}.csv", sc.name));
    t.save_csv(&csv).with_context(|| format!("saving {}", csv.display()))?;
    let (max_job_duration_s, _, _) = caps(sc, quick);
    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::scenario")),
        ("scenario", sc.to_json()),
        // what actually ran: --quick/--jobs change the workload without
        // touching the spec, so the artifact records the effective
        // invocation next to the (unmodified) spec it came from.
        // Run-variant knobs (thread count, dispatch fleet shape) are
        // deliberately absent: the artifact is run-invariant — identical
        // bytes at any --threads and under fabric dispatch
        (
            "invocation",
            jsonio::obj(vec![
                ("quick", jsonio::b(quick)),
                ("jobs", jsonio::num(jobs as f64)),
                ("max_job_duration_s", jsonio::num(max_job_duration_s)),
            ]),
        ),
        ("results", Json::Arr(results_json)),
    ]);
    let path = out_dir.join(format!("scenario_{}.json", sc.name));
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("scenario results written to {}", path.display());
    Ok(())
}

fn run_generic(sc: &Scenario, opts: &RunOpts) -> crate::Result<()> {
    let jobs = effective_jobs(sc, opts.jobs_override, opts.quick);
    let prep = prepare(sc, jobs, opts.quick)?;
    // policy names were checked by run()'s validate() — the per-cell
    // factories below run mid-simulation, where failing is no longer an
    // option (the same contract exp::run_system documents)
    let cells = grid(sc);
    eprintln!(
        "[scenario] {}: {} cells ({} archs x {} policies, {} jobs, {} faults) on {} thread(s)…",
        sc.name,
        cells.len(),
        sc.archs.len(),
        sc.policies.len(),
        prep.trace.len(),
        prep.plan.len(),
        opts.threads
    );
    let results = sweep::run_indexed(&cells, opts.threads, |_, (arch, sys)| {
        let t0 = std::time::Instant::now();
        let rows = cell_rows(sc, &prep, *arch, sys);
        eprintln!(
            "[scenario]   {sys}/{}: {:.1}s wall",
            arch_tag(*arch),
            t0.elapsed().as_secs_f64()
        );
        rows
    })?;
    assemble_generic(sc, &opts.out_dir, opts.quick, jobs, &results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::builtin::find_builtin;
    use crate::scenario::spec::{FaultRegime, WorkloadSpec};
    use crate::trace::Arch;

    fn opts(tag: &str) -> RunOpts {
        RunOpts {
            quick: true,
            out_dir: std::env::temp_dir().join(format!("star_scenario_{tag}")),
            threads: 1,
            jobs_override: Some(2),
        }
    }

    #[test]
    fn generic_scenario_runs_and_artifact_parses() {
        let sc = Scenario {
            name: "test_generic".to_string(),
            description: "storm on two policies".to_string(),
            workload: WorkloadSpec::philly(4, 0),
            faults: FaultRegime::Rate { rate: 2.0, seed: 7 },
            policies: vec!["SSGD".into(), "STAR-H".into()],
            archs: vec![Arch::Ps],
            ..Default::default()
        };
        let o = opts("generic");
        run(&sc, &o).unwrap();
        let doc = Json::parse_file(&o.out_dir.join("scenario_test_generic.json")).unwrap();
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        // the spec is embedded, so an artifact is self-describing
        let embedded = doc.get("scenario").unwrap();
        assert_eq!(embedded.get("name").unwrap().str().unwrap(), "test_generic");
        let results = doc.get("results").unwrap().arr().unwrap();
        assert_eq!(results.len(), 2, "2 policies x 1 arch");
        for r in results {
            assert!(r.get("jct_mean_s").unwrap().num().unwrap() > 0.0);
            assert_eq!(r.get("jobs").unwrap().num().unwrap() as usize, 2);
        }
        // the artifact records what actually ran (overrides included)
        let inv = doc.get("invocation").unwrap();
        assert_eq!(inv.get("jobs").unwrap().num().unwrap() as usize, 2);
        assert!(inv.get("quick").unwrap().boolean().unwrap());
        assert!(o.out_dir.join("scenario_test_generic.csv").exists());
    }

    #[test]
    fn delegated_builtin_is_byte_identical_to_dispatch() {
        // the acceptance contract: `star scenario run resilience` must
        // reproduce `experiments resilience` byte for byte
        let direct = ExpCtx {
            jobs: 2,
            quick: true,
            threads: 1,
            out_dir: std::env::temp_dir().join("star_scenario_direct"),
            ..Default::default()
        };
        crate::exp::dispatch("resilience", &direct).unwrap();
        let sc = find_builtin("resilience").unwrap();
        let o = opts("delegated");
        run(&sc, &o).unwrap();
        let a = std::fs::read(direct.out_dir.join("resilience.json")).unwrap();
        let b = std::fs::read(o.out_dir.join("resilience.json")).unwrap();
        assert_eq!(a, b, "scenario-run resilience.json differs from experiments-run");
        let a = std::fs::read(direct.out_dir.join("resilience.csv")).unwrap();
        let b = std::fs::read(o.out_dir.join("resilience.csv")).unwrap();
        assert_eq!(a, b, "scenario-run resilience.csv differs from experiments-run");
    }

    #[test]
    fn zero_jobs_override_is_rejected() {
        let sc = find_builtin("philly_default").unwrap();
        let o = RunOpts { jobs_override: Some(0), ..opts("zero") };
        let err = format!("{:#}", run(&sc, &o).err().expect("0 jobs must be rejected"));
        assert!(err.contains("--jobs"), "{err}");
    }

    #[test]
    fn quick_mode_clamps_jobs() {
        let sc = Scenario {
            name: "clamp".to_string(),
            description: "quick clamps".to_string(),
            workload: WorkloadSpec::philly(500, 0),
            policies: vec!["SSGD".into()],
            ..Default::default()
        };
        let o = RunOpts { jobs_override: None, ..opts("clamp") };
        run(&sc, &o).unwrap();
        let doc = Json::parse_file(&o.out_dir.join("scenario_clamp.json")).unwrap();
        let r = &doc.get("results").unwrap().arr().unwrap()[0];
        assert_eq!(r.get("jobs").unwrap().num().unwrap() as usize, 12);
    }
}
