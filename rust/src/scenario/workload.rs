//! Workload builder: turn a [`WorkloadSpec`] into a job trace.
//!
//! Two backends share the entry point:
//!
//! * **classic** — a spec matching the Philly family defaults delegates
//!   to [`crate::trace::generate`] unchanged, so `ExpCtx`, `star
//!   simulate`, and a `philly_default` scenario all draw byte-identical
//!   traces (the golden suites pin this transitively);
//! * **scenario generator** — any customized arrival process, model
//!   mix, or PS fleet runs the seeded streams below (forked like the
//!   fault classes, DESIGN.md §6: the arrival stream never perturbs the
//!   job-shape stream).

use crate::models::{Kind, ZOO};
use crate::simrng::Rng;
use crate::trace::{generate, JobSpec, TraceConfig};

use super::spec::{Arrival, ModelMix, WorkloadSpec};

/// Build `jobs` arrivals for `spec` (the job count is explicit so quick
/// modes and `--jobs` overrides can down-scale without editing the
/// spec). `Scenario::validate` bounds the spec fields, but the *derived*
/// quantities are re-checked here because `build` is also reachable with
/// a hand-built spec or a caller-chosen job count: a zero/non-finite
/// arrival rate (`jobs / span_s`) turns the Lewis–Shedler loop below
/// into `chance(NaN)`, which never accepts — an infinite loop, not an
/// error — so these reject up front with field-naming messages.
pub fn build(spec: &WorkloadSpec, jobs: usize) -> crate::Result<Vec<JobSpec>> {
    if jobs == 0 {
        anyhow::bail!("workload.jobs: a trace needs at least one job");
    }
    let span_s = spec.effective_span(jobs);
    if !span_s.is_finite() || span_s <= 0.0 {
        anyhow::bail!(
            "workload.arrival.span_s: effective span must be finite and > 0, got {span_s} \
             (span_s 0 means auto = jobs·280 s)"
        );
    }
    if spec.is_classic_philly() {
        return Ok(generate(&TraceConfig {
            jobs,
            seed: spec.seed,
            span_s,
            min_workers: spec.min_workers,
            max_workers: spec.max_workers,
        }));
    }
    let weights = model_weights(&spec.models)?;
    let mut root = Rng::new(spec.seed, 0x5CE0);
    // forked streams: changing the arrival family never re-shapes jobs
    let mut arrival_rng = root.fork(1);
    let mut shape_rng = root.fork(2);
    let base_rate = jobs as f64 / span_s; // arrivals per second
    // Lewis–Shedler thinning for the time-varying processes: candidates
    // arrive at the peak rate and are accepted with prob rate(t)/peak.
    // Freezing the rate at the previous arrival instead (what the
    // classic Philly generator does for its slow day/night cycle) would
    // let long low-rate gaps jump clear over short high-rate bursts,
    // systematically under-filling them.
    let peak = peak_mult(&spec.arrival);
    if !peak.is_finite() || peak <= 0.0 {
        anyhow::bail!(
            "workload.arrival.mult/peak_mult: the peak rate multiplier must be finite and \
             > 0 (it is the thinning envelope), got {peak}"
        );
    }
    let mut t = 0.0_f64;
    let mut out = Vec::with_capacity(jobs);
    for id in 0..jobs {
        loop {
            t += arrival_rng.exponential(peak * base_rate);
            if rate_mult(&spec.arrival, t) >= peak
                || arrival_rng.chance(rate_mult(&spec.arrival, t) / peak)
            {
                break;
            }
        }
        let workers = shape_rng.usize(spec.min_workers, spec.max_workers);
        let model = shape_rng.weighted_index(&weights);
        let ps_hi = if spec.ps.max_per_job == 0 {
            workers
        } else {
            spec.ps.max_per_job.min(workers)
        };
        let ps_lo = spec.ps.min_per_job.min(ps_hi);
        out.push(JobSpec {
            id,
            arrival_s: t.min(span_s),
            model,
            workers,
            ps_count: shape_rng.usize(ps_lo, ps_hi),
            ps_on_gpu_servers: shape_rng.chance(spec.ps.on_gpu_prob),
        });
    }
    Ok(out)
}

/// The arrival process's peak rate multiplier — the thinning envelope
/// (bounded by validation: `mult`/`peak_mult` ≤ 1000 keeps the expected
/// rejection work per accepted arrival bounded).
fn peak_mult(arrival: &Arrival) -> f64 {
    match *arrival {
        Arrival::Philly { .. } => 1.6,
        Arrival::Poisson { .. } => 1.0,
        Arrival::Bursty { mult, .. } => mult,
        Arrival::Diurnal { peak_mult, .. } => peak_mult,
    }
}

/// Instantaneous arrival-rate multiplier at simulated time `t`.
fn rate_mult(arrival: &Arrival, t: f64) -> f64 {
    match *arrival {
        // the paper's day/night mix (same constants as trace::generate)
        Arrival::Philly { .. } => {
            if (t / 86_400.0).fract() < 0.5 {
                1.6
            } else {
                0.6
            }
        }
        Arrival::Poisson { .. } => 1.0,
        Arrival::Bursty { burst_every_s, burst_len_s, mult, .. } => {
            if t.rem_euclid(burst_every_s) < burst_len_s {
                mult
            } else {
                1.0
            }
        }
        Arrival::Diurnal { period_s, peak_mult, .. } => {
            let phase = (std::f64::consts::TAU * t / period_s).sin();
            1.0 + (peak_mult - 1.0) * 0.5 * (1.0 + phase)
        }
    }
}

/// Per-zoo-index sampling weights for a mix.
fn model_weights(mix: &ModelMix) -> crate::Result<Vec<f64>> {
    let mut weights = vec![0.0; ZOO.len()];
    match mix {
        ModelMix::Uniform => weights.fill(1.0),
        ModelMix::Vision => {
            for (i, m) in ZOO.iter().enumerate() {
                if matches!(m.kind, Kind::Image) {
                    weights[i] = 1.0;
                }
            }
        }
        ModelMix::Nlp => {
            for (i, m) in ZOO.iter().enumerate() {
                if matches!(m.kind, Kind::Nlp) {
                    weights[i] = 1.0;
                }
            }
        }
        ModelMix::Weighted(ws) => {
            for (name, w) in ws {
                let (i, _) = crate::models::ModelSpec::by_name(name)
                    .ok_or_else(|| anyhow::anyhow!(
                        "workload.models.weights: unknown model {name:?}"
                    ))?;
                weights[i] += w;
            }
        }
    }
    if weights.iter().sum::<f64>() <= 0.0 {
        anyhow::bail!("workload.models: mix selects no model (weights sum to 0)");
    }
    Ok(weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::PsSpec;

    #[test]
    fn classic_family_is_byte_identical_to_trace_generate() {
        let spec = WorkloadSpec::philly(15, 3);
        let built = build(&spec, 15).unwrap();
        let direct = generate(&TraceConfig::paced(15, 3));
        assert_eq!(built.len(), direct.len());
        for (a, b) in built.iter().zip(&direct) {
            assert_eq!(a.arrival_s, b.arrival_s);
            assert_eq!(a.model, b.model);
            assert_eq!(a.workers, b.workers);
            assert_eq!(a.ps_count, b.ps_count);
            assert_eq!(a.ps_on_gpu_servers, b.ps_on_gpu_servers);
        }
    }

    #[test]
    fn generator_is_deterministic_and_well_formed() {
        let spec = WorkloadSpec {
            arrival: Arrival::Bursty {
                span_s: 4000.0,
                burst_every_s: 1000.0,
                burst_len_s: 200.0,
                mult: 8.0,
            },
            models: ModelMix::Vision,
            ps: PsSpec { on_gpu_prob: 1.0, min_per_job: 2, max_per_job: 3 },
            ..WorkloadSpec::philly(40, 9)
        };
        let a = build(&spec, 40).unwrap();
        let b = build(&spec, 40).unwrap();
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.model, y.model);
        }
        for (i, j) in a.iter().enumerate() {
            assert_eq!(j.id, i);
            assert!(j.arrival_s >= 0.0 && j.arrival_s <= 4000.0);
            assert!((4..=12).contains(&j.workers));
            assert!(matches!(ZOO[j.model].kind, Kind::Image), "vision mix only");
            assert!((2..=3).contains(&j.ps_count));
            assert!(j.ps_on_gpu_servers, "on_gpu_prob 1.0");
        }
        // arrivals are non-decreasing (generated as a running sum)
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        // a different seed moves the schedule
        let c = build(&WorkloadSpec { seed: 10, ..spec }, 40).unwrap();
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival_s != y.arrival_s));
    }

    #[test]
    fn nlp_and_weighted_mixes_restrict_models() {
        let nlp = WorkloadSpec {
            models: ModelMix::Nlp,
            ..WorkloadSpec::philly(30, 1)
        };
        for j in build(&nlp, 30).unwrap() {
            assert!(matches!(ZOO[j.model].kind, Kind::Nlp));
        }
        let dense = ZOO.iter().position(|m| m.name == "DenseNet121").unwrap();
        let weighted = WorkloadSpec {
            models: ModelMix::Weighted(vec![("DenseNet121".into(), 1.0)]),
            ..WorkloadSpec::philly(10, 1)
        };
        for j in build(&weighted, 10).unwrap() {
            assert_eq!(j.model, dense, "weight mass on a single model");
        }
        let unknown = WorkloadSpec {
            models: ModelMix::Weighted(vec![("NotAModel".into(), 1.0)]),
            ..WorkloadSpec::philly(10, 1)
        };
        assert!(build(&unknown, 10).is_err());
    }

    #[test]
    fn degenerate_rates_are_rejected_not_looped_on() {
        // regression: jobs 0 + auto span used to make base_rate 0/0 =
        // NaN, and the thinning loop's chance(NaN) never accepts — the
        // build hung forever instead of erroring
        let generator = WorkloadSpec {
            models: ModelMix::Vision, // any non-classic field → generator path
            ..WorkloadSpec::philly(40, 9)
        };
        let err = format!("{:#}", build(&generator, 0).unwrap_err());
        assert!(err.contains("workload.jobs"), "{err}");
        let err = format!("{:#}", build(&WorkloadSpec::philly(40, 9), 0).unwrap_err());
        assert!(err.contains("workload.jobs"), "classic path too: {err}");
        // a hand-built spec can smuggle in a span validate() would
        // reject; build must name the field, not divide by it
        for bad_span in [-100.0, f64::NAN, f64::INFINITY] {
            let spec = WorkloadSpec {
                arrival: Arrival::Poisson { span_s: bad_span },
                ..generator.clone()
            };
            let err = format!("{:#}", build(&spec, 10).unwrap_err());
            assert!(err.contains("workload.arrival.span_s"), "span {bad_span}: {err}");
        }
        // ...and a zero/NaN burst multiplier would zero the thinning
        // envelope: every candidate is rejected, another infinite loop
        for bad_mult in [0.0, -1.0, f64::NAN] {
            let spec = WorkloadSpec {
                arrival: Arrival::Bursty {
                    span_s: 4000.0,
                    burst_every_s: 1000.0,
                    burst_len_s: 200.0,
                    mult: bad_mult,
                },
                ..generator.clone()
            };
            let err = format!("{:#}", build(&spec, 10).unwrap_err());
            assert!(err.contains("peak rate multiplier"), "mult {bad_mult}: {err}");
        }
    }

    #[test]
    fn bursty_arrivals_cluster_inside_bursts() {
        // with a huge burst multiplier nearly all arrivals should land in
        // the burst windows (first 10% of every period)
        let spec = WorkloadSpec {
            arrival: Arrival::Bursty {
                span_s: 100_000.0,
                burst_every_s: 10_000.0,
                burst_len_s: 1_000.0,
                mult: 200.0,
            },
            models: ModelMix::Vision, // any non-classic field → generator path
            ..WorkloadSpec::philly(200, 4)
        };
        let jobs = build(&spec, 200).unwrap();
        let in_burst = jobs
            .iter()
            .filter(|j| j.arrival_s.rem_euclid(10_000.0) < 1_000.0)
            .count();
        assert!(
            in_burst * 2 > jobs.len(),
            "bursts at 200x must attract most arrivals: {in_burst}/{}",
            jobs.len()
        );
    }
}
