//! Built-in scenarios: every existing experiment family re-expressed as
//! a delegated [`Scenario`] (its grid knobs now live in data), plus the
//! generator-family scenarios no `experiments` subcommand can express.
//!
//! `star scenario run <name>` resolves here before touching the
//! filesystem; `star scenario list` prints this table.

use super::spec::{
    Arrival, ClusterShape, FaultRegime, PsSpec, Scenario, WorkloadSpec,
};
use crate::trace::Arch;

fn delegated(name: &str, description: &str, ids: &[&str]) -> Scenario {
    Scenario {
        name: name.to_string(),
        description: description.to_string(),
        experiments: ids.iter().map(|s| s.to_string()).collect(),
        ..Default::default()
    }
}

/// The built-in scenario table. Delegated entries reproduce the
/// `experiments` binary's outputs byte-identically (same `ExpCtx`
/// defaults: 120 jobs, seed 0, fault-free unless the experiment sweeps
/// its own rates); generic entries exercise the scenario engine.
pub fn builtins() -> Vec<Scenario> {
    vec![
        // -- delegated: the paper evaluation, grids in data ----------------
        delegated(
            "measure",
            "§III measurement study (figs 1-14 + table I) on the classic Philly workload",
            &["fig1", "fig8", "fig9", "fig11", "fig12", "fig13", "tab1", "fig14"],
        ),
        delegated(
            "eval",
            "§V headline comparison vs the six systems (figs 16-22)",
            &["fig16", "fig17", "fig18"],
        ),
        delegated("ablation", "§V-C ablations (figs 23-27)", &["fig23"]),
        delegated("overhead", "decision-path + end-to-end overhead (figs 28-29)", &["fig28", "fig29"]),
        delegated(
            "resilience",
            "TTA/JCT/downtime under failure rate x policy (DESIGN.md §7)",
            &["resilience"],
        ),
        delegated(
            "scale",
            "cluster-scale driver throughput benchmark (BENCH_driver.json)",
            &["scale"],
        ),
        delegated("all", "every paper artifact (the experiments binary's `all`)", &["all"]),
        // -- generic: shapes no experiment subcommand can express ----------
        Scenario {
            name: "philly_default".to_string(),
            description: "the classic Philly workload as a generic scenario \
                          (byte-identical trace to `star simulate`)"
                .to_string(),
            workload: WorkloadSpec::philly(60, 0),
            policies: vec!["SSGD".into(), "LGC".into(), "STAR-H".into()],
            archs: vec![Arch::Ps],
            ..Default::default()
        },
        Scenario {
            name: "fault_storm".to_string(),
            description: "background failures plus two concentrated fault storms"
                .to_string(),
            workload: WorkloadSpec::philly(48, 0),
            faults: FaultRegime::Storm {
                seed: 7,
                base_rate: 0.5,
                storm_rate: 12.0,
                windows: vec![(1800.0, 3000.0), (7200.0, 8400.0)],
            },
            policies: vec!["SSGD".into(), "STAR-H".into()],
            archs: vec![Arch::Ps],
            ..Default::default()
        },
        Scenario {
            name: "oversubscribed_cpu".to_string(),
            description: "PS-heavy fleet on servers with 45% of the CPU headroom \
                          (contention-driven stragglers)"
                .to_string(),
            cluster: ClusterShape { cpu_factor: 0.45, ..Default::default() },
            workload: WorkloadSpec {
                ps: PsSpec { on_gpu_prob: 0.8, min_per_job: 2, max_per_job: 0 },
                ..WorkloadSpec::philly(40, 0)
            },
            policies: vec!["SSGD".into(), "LB-BSP".into(), "STAR-H".into()],
            archs: vec![Arch::Ps],
            ..Default::default()
        },
        Scenario {
            name: "bursty_storm_oversub".to_string(),
            description: "bursty arrivals + fault storms on an oversubscribed \
                          CPU/bandwidth fleet, PS and AR - the what-if shape the \
                          experiment harness cannot express"
                .to_string(),
            cluster: ClusterShape { cpu_factor: 0.5, bw_factor: 0.7, ..Default::default() },
            workload: WorkloadSpec {
                arrival: Arrival::Bursty {
                    span_s: 0.0, // auto: jobs·280 s
                    burst_every_s: 2800.0,
                    burst_len_s: 400.0,
                    mult: 8.0,
                },
                ..WorkloadSpec::philly(48, 0)
            },
            faults: FaultRegime::Storm {
                seed: 7,
                base_rate: 0.5,
                storm_rate: 10.0,
                windows: vec![(2000.0, 3400.0), (9000.0, 10_400.0)],
            },
            policies: vec!["SSGD".into(), "STAR-H".into()],
            archs: vec![Arch::Ps, Arch::AllReduce],
            ..Default::default()
        },
    ]
}

/// Built-in names, table order (error messages, `--list`).
pub fn builtin_names() -> Vec<String> {
    builtins().into_iter().map(|s| s.name).collect()
}

/// Look a built-in up by name.
pub fn find_builtin(name: &str) -> Option<Scenario> {
    builtins().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_unique_and_valid() {
        let all = builtins();
        let mut names: Vec<&str> = all.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate built-in names");
        for sc in &all {
            sc.validate().unwrap_or_else(|e| panic!("builtin {:?}: {e:#}", sc.name));
            assert!(!sc.description.is_empty(), "{}: description required", sc.name);
        }
    }

    #[test]
    fn builtins_round_trip_through_json() {
        for sc in builtins() {
            let j = sc.to_json();
            let again = Scenario::from_json(&j)
                .unwrap_or_else(|e| panic!("builtin {:?}: {e:#}", sc.name));
            assert_eq!(j, again.to_json(), "{}", sc.name);
        }
    }

    #[test]
    fn find_builtin_resolves_known_names_only() {
        assert!(find_builtin("resilience").is_some());
        assert!(find_builtin("bursty_storm_oversub").is_some());
        assert!(find_builtin("nope").is_none());
        assert!(builtin_names().contains(&"philly_default".to_string()));
    }

    #[test]
    fn delegated_builtins_reference_valid_experiment_ids() {
        for sc in builtins() {
            for id in &sc.experiments {
                assert!(
                    crate::exp::EXPERIMENT_IDS.contains(&id.as_str()),
                    "{}: unknown experiment id {id:?}",
                    sc.name
                );
            }
        }
    }
}
