//! Scenario subsystem (DESIGN.md §9): one declarative description of a
//! whole what-if experiment — cluster shape, workload (arrival process,
//! job mix, PS fleet), fault regime, policy × arch grid, driver knobs —
//! parsed from JSON, validated with field-naming errors, and executed
//! either generically or by delegating to the existing experiment
//! harness (byte-identically).
//!
//! Layering (top-down):
//!
//! * [`spec`] — the [`Scenario`] description + JSON round-trip +
//!   validation;
//! * [`workload`] — spec → job trace; [`crate::trace::generate`] is the
//!   classic Philly backend, scenario generator families cover the rest;
//! * [`spec::FaultRegime`] — spec → [`crate::faults::FaultPlan`] (rate,
//!   full-config, and storm front-ends over the `faults` generators);
//! * [`runner`] — spec → results (sweep-parallel, artifact-emitting);
//! * [`builtin`] — the named scenarios behind `star scenario run <name>`
//!   (every experiment family as data, plus generator-family what-ifs);
//! * [`space`] — a *distribution* over scenarios ([`ScenarioSpace`],
//!   DESIGN.md §11): per-dimension ranges/choices plus a seeded,
//!   per-index-pure sampler behind `star scenario sample`;
//! * [`search`] — the counterfactual driver behind `star scenario
//!   search`: center-sweep sensitivity + per-sample regret reports over
//!   a space, in-process or dispatched over the fabric (§10).
//!
//! Example spec files live under `examples/scenarios/` and are parsed +
//! smoke-run by `tests/scenario_examples.rs` and the CI scenario step.

pub mod builtin;
pub mod runner;
pub mod search;
pub mod space;
pub mod spec;
pub mod workload;

pub use builtin::{builtin_names, builtins, find_builtin};
pub use runner::{run, RunOpts};
pub use space::{builtin_spaces, find_space, space_names, ScenarioSpace};
pub use spec::{
    arch_tag, parse_arch, Arrival, ClusterShape, DriverKnobs, FaultRegime, ModelMix, PsSpec,
    Scenario, WorkloadSpec,
};

/// Resolve a `star scenario run` target. Bare names resolve to
/// built-ins first — a stray file or directory in the cwd named like a
/// built-in must not shadow it (address such a file as `./name`).
/// Anything path-like (a `.json` suffix or a separator) reads the
/// filesystem; unknown bare names list the valid built-ins.
pub fn load(target: &str) -> crate::Result<Scenario> {
    let looks_like_path = target.ends_with(".json") || target.contains('/');
    if !looks_like_path {
        return find_builtin(target).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario {target:?} (built-ins: {}; or pass a .json spec file)",
                builtin_names().join(", ")
            )
        });
    }
    let path = std::path::Path::new(target);
    if path.is_file() {
        return Scenario::from_file(path);
    }
    Err(anyhow::anyhow!("scenario spec file {target:?} not found"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_resolves_builtins_and_lists_them_on_error() {
        assert_eq!(load("fault_storm").unwrap().name, "fault_storm");
        let err = format!("{:#}", load("not_a_scenario").err().unwrap());
        assert!(err.contains("philly_default"), "must list built-ins: {err}");
        assert!(err.contains(".json"), "must mention the file path option: {err}");
        // a missing path-like target names the file, not the built-ins
        let err = format!("{:#}", load("no/such/spec.json").err().unwrap());
        assert!(err.contains("not found"), "{err}");
    }

    #[test]
    fn bare_builtin_names_never_read_the_filesystem() {
        // a stray cwd file or directory named like a built-in must not
        // hijack it: bare names resolve against the built-in table first
        // (a same-named spec file is addressable as ./name or name.json)
        assert_eq!(load("resilience").unwrap().name, "resilience");
        assert_eq!(load("scale").unwrap().name, "scale");
    }

    #[test]
    fn load_reads_spec_files() {
        let dir = std::env::temp_dir().join("star_scenario_load_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        std::fs::write(&path, r#"{"name": "from-file", "policies": ["SSGD"]}"#).unwrap();
        let sc = load(path.to_str().unwrap()).unwrap();
        assert_eq!(sc.name, "from-file");
        // a malformed file errors with the path in the message
        std::fs::write(&path, "{ not json").unwrap();
        assert!(load(path.to_str().unwrap()).is_err());
    }
}
