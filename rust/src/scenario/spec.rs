//! The declarative [`Scenario`] spec: cluster shape, workload
//! (arrival process / job mix / PS fleet), fault regime, policy × arch
//! grid, and driver knobs — parsed from JSON ([`crate::jsonio`]),
//! validated with field-naming errors, and emitted back in a canonical
//! fully-expanded form (parse → emit → parse is identity; pinned by the
//! round-trip tests below and `tests/scenario_examples.rs`).

use std::path::Path;

use anyhow::{bail, Context};

use crate::cluster::ClusterConfig;
use crate::faults::{generate_plan, plan_at_rate, FaultConfig, FaultPlan};
use crate::jsonio::{self, Json};
use crate::models::ModelSpec;
use crate::trace::{Arch, JobSpec};

/// A complete scenario description. Two flavors share the type:
///
/// * **generic** — `policies` × `archs` cells over the described
///   workload/cluster/faults, run by [`crate::scenario::runner`];
/// * **delegated** — `experiments` names existing experiment ids, run
///   through [`crate::exp::dispatch`] with a context derived from this
///   spec (byte-identical to invoking the `experiments` binary).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    /// non-empty = delegated: run these experiment ids via `exp::dispatch`
    pub experiments: Vec<String>,
    pub cluster: ClusterShape,
    pub workload: WorkloadSpec,
    pub faults: FaultRegime,
    /// system names (see `baselines::make_policy`), generic flavor only
    pub policies: Vec<String>,
    pub archs: Vec<Arch>,
    pub driver: DriverKnobs,
}

/// Cluster shape + oversubscription factors. Factors scale the default
/// per-server capacities, so `cpu_factor: 0.5` is "the same testbed with
/// half the CPU headroom" — the oversubscribed regimes of the ROADMAP.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterShape {
    pub gpu_servers: usize,
    pub cpu_servers: usize,
    pub gpus_per_server: usize,
    /// multiplies gpu/cpu-server CPU capacity (1.0 = the paper testbed)
    pub cpu_factor: f64,
    /// multiplies gpu/cpu-server network capacity
    pub bw_factor: f64,
}

impl Default for ClusterShape {
    fn default() -> Self {
        let d = ClusterConfig::default();
        ClusterShape {
            gpu_servers: d.gpu_servers,
            cpu_servers: d.cpu_servers,
            gpus_per_server: d.gpus_per_server,
            cpu_factor: 1.0,
            bw_factor: 1.0,
        }
    }
}

impl ClusterShape {
    /// Materialize as a simulator [`ClusterConfig`] (defaults scaled by
    /// the oversubscription factors; contention knobs untouched).
    pub fn to_config(&self) -> ClusterConfig {
        let d = ClusterConfig::default();
        ClusterConfig {
            gpu_servers: self.gpu_servers,
            cpu_servers: self.cpu_servers,
            gpus_per_server: self.gpus_per_server,
            gpu_server_cpus: d.gpu_server_cpus * self.cpu_factor,
            cpu_server_cpus: d.cpu_server_cpus * self.cpu_factor,
            gpu_server_bw: d.gpu_server_bw * self.bw_factor,
            cpu_server_bw: d.cpu_server_bw * self.bw_factor,
            ..d
        }
    }

    fn from_json(j: &Json) -> crate::Result<ClusterShape> {
        check_keys(
            j,
            "cluster",
            &["gpu_servers", "cpu_servers", "gpus_per_server", "cpu_factor", "bw_factor"],
        )?;
        let d = ClusterShape::default();
        Ok(ClusterShape {
            gpu_servers: get_usize(j, "cluster", "gpu_servers", d.gpu_servers)?,
            cpu_servers: get_usize(j, "cluster", "cpu_servers", d.cpu_servers)?,
            gpus_per_server: get_usize(j, "cluster", "gpus_per_server", d.gpus_per_server)?,
            cpu_factor: get_f64(j, "cluster", "cpu_factor", d.cpu_factor)?,
            bw_factor: get_f64(j, "cluster", "bw_factor", d.bw_factor)?,
        })
    }

    fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("gpu_servers", jsonio::num(self.gpu_servers as f64)),
            ("cpu_servers", jsonio::num(self.cpu_servers as f64)),
            ("gpus_per_server", jsonio::num(self.gpus_per_server as f64)),
            ("cpu_factor", jsonio::num(self.cpu_factor)),
            ("bw_factor", jsonio::num(self.bw_factor)),
        ])
    }
}

/// Workload description: how many jobs arrive, when, and shaped how.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub jobs: usize,
    pub seed: u64,
    pub arrival: Arrival,
    pub min_workers: usize,
    pub max_workers: usize,
    pub models: ModelMix,
    pub ps: PsSpec,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            jobs: 120,
            seed: 0,
            arrival: Arrival::Philly { span_s: 0.0 },
            min_workers: 4,
            max_workers: 12,
            models: ModelMix::Uniform,
            ps: PsSpec::default(),
        }
    }
}

impl WorkloadSpec {
    /// The classic Philly family at the CLI pacing rule (`span_s: 0` =
    /// auto `jobs · 280 s`) — what `ExpCtx` and `star simulate` run.
    pub fn philly(jobs: usize, seed: u64) -> WorkloadSpec {
        WorkloadSpec { jobs, seed, ..Default::default() }
    }

    /// True when this spec is exactly the Philly family (arrival +
    /// uniform model mix + default PS fleet): the builder then delegates
    /// to [`crate::trace::generate`], byte-identical to the pre-scenario
    /// trace construction.
    pub fn is_classic_philly(&self) -> bool {
        matches!(self.arrival, Arrival::Philly { .. })
            && self.models == ModelMix::Uniform
            && self.ps == PsSpec::default()
    }

    /// The simulated span arrivals cover: explicit, or the pacing rule.
    pub fn effective_span(&self, jobs: usize) -> f64 {
        let span = *match &self.arrival {
            Arrival::Philly { span_s }
            | Arrival::Poisson { span_s }
            | Arrival::Bursty { span_s, .. }
            | Arrival::Diurnal { span_s, .. } => span_s,
        };
        if span > 0.0 {
            span
        } else {
            jobs as f64 * 280.0
        }
    }

    fn from_json(j: &Json) -> crate::Result<WorkloadSpec> {
        check_keys(
            j,
            "workload",
            &["jobs", "seed", "arrival", "min_workers", "max_workers", "models", "ps"],
        )?;
        let d = WorkloadSpec::default();
        Ok(WorkloadSpec {
            jobs: get_usize(j, "workload", "jobs", d.jobs)?,
            seed: get_u64(j, "workload", "seed", d.seed)?,
            arrival: match j.opt("arrival") {
                None => d.arrival,
                Some(v) => Arrival::from_json(v)?,
            },
            min_workers: get_usize(j, "workload", "min_workers", d.min_workers)?,
            max_workers: get_usize(j, "workload", "max_workers", d.max_workers)?,
            models: match j.opt("models") {
                None => d.models,
                Some(v) => ModelMix::from_json(v)?,
            },
            ps: match j.opt("ps") {
                None => d.ps,
                Some(v) => PsSpec::from_json(v)?,
            },
        })
    }

    fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("jobs", jsonio::num(self.jobs as f64)),
            ("seed", jsonio::num(self.seed as f64)),
            ("arrival", self.arrival.to_json()),
            ("min_workers", jsonio::num(self.min_workers as f64)),
            ("max_workers", jsonio::num(self.max_workers as f64)),
            ("models", self.models.to_json()),
            ("ps", self.ps.to_json()),
        ])
    }
}

/// Arrival process family. `span_s: 0` always means "auto": the CLI
/// pacing rule `jobs · 280 s`.
#[derive(Clone, Debug, PartialEq)]
pub enum Arrival {
    /// the paper's day/night two-level Poisson mix (§III)
    Philly { span_s: f64 },
    /// uniform-rate Poisson arrivals
    Poisson { span_s: f64 },
    /// baseline Poisson with periodic bursts: every `burst_every_s`
    /// seconds the rate runs at `mult`× for `burst_len_s` seconds
    Bursty { span_s: f64, burst_every_s: f64, burst_len_s: f64, mult: f64 },
    /// sinusoidal day/night rate: 1× at the trough, `peak_mult`× at the
    /// peak of each `period_s` cycle
    Diurnal { span_s: f64, period_s: f64, peak_mult: f64 },
}

impl Arrival {
    pub(crate) fn from_json(j: &Json) -> crate::Result<Arrival> {
        let kind = j
            .get("kind")
            .and_then(|v| v.str())
            .context("workload.arrival.kind")?;
        match kind {
            "philly" => {
                check_keys(j, "workload.arrival", &["kind", "span_s"])?;
                Ok(Arrival::Philly { span_s: get_f64(j, "workload.arrival", "span_s", 0.0)? })
            }
            "poisson" => {
                check_keys(j, "workload.arrival", &["kind", "span_s"])?;
                Ok(Arrival::Poisson { span_s: get_f64(j, "workload.arrival", "span_s", 0.0)? })
            }
            "bursty" => {
                check_keys(
                    j,
                    "workload.arrival",
                    &["kind", "span_s", "burst_every_s", "burst_len_s", "mult"],
                )?;
                Ok(Arrival::Bursty {
                    span_s: get_f64(j, "workload.arrival", "span_s", 0.0)?,
                    burst_every_s: get_f64(j, "workload.arrival", "burst_every_s", 3600.0)?,
                    burst_len_s: get_f64(j, "workload.arrival", "burst_len_s", 600.0)?,
                    mult: get_f64(j, "workload.arrival", "mult", 6.0)?,
                })
            }
            "diurnal" => {
                check_keys(j, "workload.arrival", &["kind", "span_s", "period_s", "peak_mult"])?;
                Ok(Arrival::Diurnal {
                    span_s: get_f64(j, "workload.arrival", "span_s", 0.0)?,
                    period_s: get_f64(j, "workload.arrival", "period_s", 86_400.0)?,
                    peak_mult: get_f64(j, "workload.arrival", "peak_mult", 3.0)?,
                })
            }
            other => bail!(
                "workload.arrival.kind: unknown kind {other:?} (philly, poisson, bursty, diurnal)"
            ),
        }
    }

    pub(crate) fn to_json(&self) -> Json {
        match *self {
            Arrival::Philly { span_s } => jsonio::obj(vec![
                ("kind", jsonio::s("philly")),
                ("span_s", jsonio::num(span_s)),
            ]),
            Arrival::Poisson { span_s } => jsonio::obj(vec![
                ("kind", jsonio::s("poisson")),
                ("span_s", jsonio::num(span_s)),
            ]),
            Arrival::Bursty { span_s, burst_every_s, burst_len_s, mult } => jsonio::obj(vec![
                ("kind", jsonio::s("bursty")),
                ("span_s", jsonio::num(span_s)),
                ("burst_every_s", jsonio::num(burst_every_s)),
                ("burst_len_s", jsonio::num(burst_len_s)),
                ("mult", jsonio::num(mult)),
            ]),
            Arrival::Diurnal { span_s, period_s, peak_mult } => jsonio::obj(vec![
                ("kind", jsonio::s("diurnal")),
                ("span_s", jsonio::num(span_s)),
                ("period_s", jsonio::num(period_s)),
                ("peak_mult", jsonio::num(peak_mult)),
            ]),
        }
    }
}

/// Per-job model sampling: uniform over the zoo (the Philly default),
/// restricted to vision/NLP, or explicitly weighted by zoo name.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelMix {
    Uniform,
    Vision,
    Nlp,
    /// (zoo model name, weight ≥ 0) — sorted by name for canonical emit
    Weighted(Vec<(String, f64)>),
}

impl ModelMix {
    pub(crate) fn from_json(j: &Json) -> crate::Result<ModelMix> {
        match j {
            Json::Str(s) => match s.as_str() {
                "uniform" => Ok(ModelMix::Uniform),
                "vision" => Ok(ModelMix::Vision),
                "nlp" => Ok(ModelMix::Nlp),
                other => bail!(
                    "workload.models: unknown mix {other:?} (uniform, vision, nlp, or \
                     {{\"weights\": {{\"Model\": w, …}}}})"
                ),
            },
            Json::Obj(_) => {
                check_keys(j, "workload.models", &["weights"])?;
                let w = j.get("weights").context("workload.models")?;
                let map = w.obj().context("workload.models.weights")?;
                let mut out = Vec::with_capacity(map.len());
                for (name, v) in map {
                    let weight = v
                        .num()
                        .with_context(|| format!("workload.models.weights.{name}"))?;
                    out.push((name.clone(), weight));
                }
                Ok(ModelMix::Weighted(out))
            }
            _ => bail!("workload.models: must be a mix name or a weights object"),
        }
    }

    pub(crate) fn to_json(&self) -> Json {
        match self {
            ModelMix::Uniform => jsonio::s("uniform"),
            ModelMix::Vision => jsonio::s("vision"),
            ModelMix::Nlp => jsonio::s("nlp"),
            ModelMix::Weighted(ws) => jsonio::obj(vec![(
                "weights",
                Json::Obj(ws.iter().map(|(n, w)| (n.clone(), Json::Num(*w))).collect()),
            )]),
        }
    }
}

/// PS-fleet shape: where PSs land and how many a job runs. The Philly
/// default is `U[1, workers]` PSs, half the jobs co-locating them on
/// their GPU servers; a PS-heavy fleet raises `min_per_job` and
/// `on_gpu_prob`.
#[derive(Clone, Debug, PartialEq)]
pub struct PsSpec {
    /// probability a job's PSs land on its GPU servers (vs CPU servers)
    pub on_gpu_prob: f64,
    /// lower bound on per-job PS count
    pub min_per_job: usize,
    /// upper bound on per-job PS count; 0 = the job's worker count
    pub max_per_job: usize,
}

impl Default for PsSpec {
    fn default() -> Self {
        PsSpec { on_gpu_prob: 0.5, min_per_job: 1, max_per_job: 0 }
    }
}

impl PsSpec {
    fn from_json(j: &Json) -> crate::Result<PsSpec> {
        check_keys(j, "workload.ps", &["on_gpu_prob", "min_per_job", "max_per_job"])?;
        let d = PsSpec::default();
        Ok(PsSpec {
            on_gpu_prob: get_f64(j, "workload.ps", "on_gpu_prob", d.on_gpu_prob)?,
            min_per_job: get_usize(j, "workload.ps", "min_per_job", d.min_per_job)?,
            max_per_job: get_usize(j, "workload.ps", "max_per_job", d.max_per_job)?,
        })
    }

    fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("on_gpu_prob", jsonio::num(self.on_gpu_prob)),
            ("min_per_job", jsonio::num(self.min_per_job as f64)),
            ("max_per_job", jsonio::num(self.max_per_job as f64)),
        ])
    }
}

/// Scenario-driven front-end over the `faults` plan generators.
#[derive(Clone, Debug)]
pub enum FaultRegime {
    /// fault-free (bit-identical to the pre-faults simulator)
    Off,
    /// default MTBFs scaled by `rate` — the `--fault-rate` recipe
    /// ([`plan_at_rate`])
    Rate { rate: f64, seed: u64 },
    /// full [`FaultConfig`] override ([`generate_plan`])
    Config(FaultConfig),
    /// background `base_rate` plus storm windows at `storm_rate`: inside
    /// each `[from_s, to_s)` window the storm stream replaces the base
    /// stream — fault storms, deterministic per seed
    Storm { seed: u64, base_rate: f64, storm_rate: f64, windows: Vec<(f64, f64)> },
}

impl FaultRegime {
    /// Build the plan this regime injects into `trace` over `span_s`
    /// seconds on a `servers`-server cluster. Pure and deterministic —
    /// the same discipline as [`generate_plan`].
    pub fn plan(&self, trace: &[JobSpec], span_s: f64, servers: usize) -> FaultPlan {
        match self {
            FaultRegime::Off => FaultPlan::default(),
            FaultRegime::Rate { rate, seed } => plan_at_rate(*rate, *seed, trace, span_s, servers),
            FaultRegime::Config(cfg) => generate_plan(cfg, trace, span_s, servers),
            FaultRegime::Storm { seed, base_rate, storm_rate, windows } => {
                let inside = |t: f64| windows.iter().any(|&(a, b)| t >= a && t < b);
                let mut base = plan_at_rate(*base_rate, *seed, trace, span_s, servers);
                base.faults.retain(|f| !inside(f.at));
                // independent storm stream: changing the base rate never
                // moves in-window fault times (and vice versa)
                let mut storm =
                    plan_at_rate(*storm_rate, seed ^ 0x5702, trace, span_s, servers);
                storm.faults.retain(|f| inside(f.at));
                base.merge(storm)
            }
        }
    }

    fn from_json(j: &Json) -> crate::Result<FaultRegime> {
        let kind = j.get("kind").and_then(|v| v.str()).context("faults.kind")?;
        match kind {
            "off" => {
                check_keys(j, "faults", &["kind"])?;
                Ok(FaultRegime::Off)
            }
            "rate" => {
                check_keys(j, "faults", &["kind", "rate", "seed"])?;
                Ok(FaultRegime::Rate {
                    rate: get_f64(j, "faults", "rate", 1.0)?,
                    seed: get_u64(j, "faults", "seed", 0)?,
                })
            }
            "config" => {
                check_keys(
                    j,
                    "faults",
                    &[
                        "kind",
                        "seed",
                        "worker_mtbf_s",
                        "ps_mtbf_s",
                        "server_mtbf_s",
                        "degradation_mtbf_s",
                        "restart_s",
                        "outage_s",
                        "degradation_s",
                        "degradation_mag",
                        "checkpoint_every_updates",
                    ],
                )?;
                let d = FaultConfig::default();
                Ok(FaultRegime::Config(FaultConfig {
                    seed: get_u64(j, "faults", "seed", d.seed)?,
                    worker_mtbf_s: get_f64(j, "faults", "worker_mtbf_s", d.worker_mtbf_s)?,
                    ps_mtbf_s: get_f64(j, "faults", "ps_mtbf_s", d.ps_mtbf_s)?,
                    server_mtbf_s: get_f64(j, "faults", "server_mtbf_s", d.server_mtbf_s)?,
                    degradation_mtbf_s: get_f64(
                        j,
                        "faults",
                        "degradation_mtbf_s",
                        d.degradation_mtbf_s,
                    )?,
                    restart_s: get_pair(j, "faults", "restart_s", d.restart_s)?,
                    outage_s: get_pair(j, "faults", "outage_s", d.outage_s)?,
                    degradation_s: get_pair(j, "faults", "degradation_s", d.degradation_s)?,
                    degradation_mag: get_pair(j, "faults", "degradation_mag", d.degradation_mag)?,
                    checkpoint_every_updates: get_u64(
                        j,
                        "faults",
                        "checkpoint_every_updates",
                        d.checkpoint_every_updates,
                    )?,
                }))
            }
            "storm" => {
                check_keys(j, "faults", &["kind", "seed", "base_rate", "storm_rate", "windows"])?;
                let mut windows = Vec::new();
                if let Some(w) = j.opt("windows") {
                    for (i, win) in w.arr().context("faults.windows")?.iter().enumerate() {
                        windows.push(
                            pair_of(win).with_context(|| format!("faults.windows[{i}]"))?,
                        );
                    }
                }
                Ok(FaultRegime::Storm {
                    seed: get_u64(j, "faults", "seed", 0)?,
                    base_rate: get_f64(j, "faults", "base_rate", 0.0)?,
                    storm_rate: get_f64(j, "faults", "storm_rate", 8.0)?,
                    windows,
                })
            }
            other => bail!("faults.kind: unknown kind {other:?} (off, rate, config, storm)"),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            FaultRegime::Off => jsonio::obj(vec![("kind", jsonio::s("off"))]),
            FaultRegime::Rate { rate, seed } => jsonio::obj(vec![
                ("kind", jsonio::s("rate")),
                ("rate", jsonio::num(*rate)),
                ("seed", jsonio::num(*seed as f64)),
            ]),
            FaultRegime::Config(c) => jsonio::obj(vec![
                ("kind", jsonio::s("config")),
                ("seed", jsonio::num(c.seed as f64)),
                ("worker_mtbf_s", jsonio::num(c.worker_mtbf_s)),
                ("ps_mtbf_s", jsonio::num(c.ps_mtbf_s)),
                ("server_mtbf_s", jsonio::num(c.server_mtbf_s)),
                ("degradation_mtbf_s", jsonio::num(c.degradation_mtbf_s)),
                ("restart_s", jsonio::nums(&[c.restart_s.0, c.restart_s.1])),
                ("outage_s", jsonio::nums(&[c.outage_s.0, c.outage_s.1])),
                ("degradation_s", jsonio::nums(&[c.degradation_s.0, c.degradation_s.1])),
                (
                    "degradation_mag",
                    jsonio::nums(&[c.degradation_mag.0, c.degradation_mag.1]),
                ),
                (
                    "checkpoint_every_updates",
                    jsonio::num(c.checkpoint_every_updates as f64),
                ),
            ]),
            FaultRegime::Storm { seed, base_rate, storm_rate, windows } => jsonio::obj(vec![
                ("kind", jsonio::s("storm")),
                ("seed", jsonio::num(*seed as f64)),
                ("base_rate", jsonio::num(*base_rate)),
                ("storm_rate", jsonio::num(*storm_rate)),
                (
                    "windows",
                    Json::Arr(windows.iter().map(|&(a, b)| jsonio::nums(&[a, b])).collect()),
                ),
            ]),
        }
    }
}

/// Driver overrides; 0 = keep the [`crate::driver::DriverConfig`] default.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DriverKnobs {
    pub seed: u64,
    pub max_job_duration_s: f64,
    pub max_updates_per_job: u64,
    pub max_iters_per_job: u64,
}

impl DriverKnobs {
    pub(crate) fn from_json(j: &Json) -> crate::Result<DriverKnobs> {
        check_keys(
            j,
            "driver",
            &["seed", "max_job_duration_s", "max_updates_per_job", "max_iters_per_job"],
        )?;
        Ok(DriverKnobs {
            seed: get_u64(j, "driver", "seed", 0)?,
            max_job_duration_s: get_f64(j, "driver", "max_job_duration_s", 0.0)?,
            max_updates_per_job: get_u64(j, "driver", "max_updates_per_job", 0)?,
            max_iters_per_job: get_u64(j, "driver", "max_iters_per_job", 0)?,
        })
    }

    pub(crate) fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("seed", jsonio::num(self.seed as f64)),
            ("max_job_duration_s", jsonio::num(self.max_job_duration_s)),
            ("max_updates_per_job", jsonio::num(self.max_updates_per_job as f64)),
            ("max_iters_per_job", jsonio::num(self.max_iters_per_job as f64)),
        ])
    }
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: String::new(),
            description: String::new(),
            experiments: Vec::new(),
            cluster: ClusterShape::default(),
            workload: WorkloadSpec::default(),
            faults: FaultRegime::Off,
            policies: Vec::new(),
            archs: vec![Arch::Ps],
            driver: DriverKnobs::default(),
        }
    }
}

impl Scenario {
    pub fn from_file(path: &Path) -> crate::Result<Scenario> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j).with_context(|| format!("scenario {}", path.display()))
    }

    /// Parse and validate. Defaults are the paper testbed + classic
    /// Philly workload, so a minimal spec is just a name and a policy
    /// list (or an `experiments` delegation).
    pub fn from_json(j: &Json) -> crate::Result<Scenario> {
        check_keys(
            j,
            "scenario",
            &[
                "name",
                "description",
                "experiments",
                "cluster",
                "workload",
                "faults",
                "policies",
                "archs",
                "driver",
            ],
        )?;
        let d = Scenario::default();
        let sc = Scenario {
            name: j.get("name").and_then(|v| v.str()).context("scenario.name")?.to_string(),
            description: match j.opt("description") {
                None => String::new(),
                Some(v) => v.str().context("scenario.description")?.to_string(),
            },
            experiments: get_str_list(j, "experiments")?,
            cluster: match j.opt("cluster") {
                None => d.cluster,
                Some(v) => ClusterShape::from_json(v)?,
            },
            workload: match j.opt("workload") {
                None => d.workload,
                Some(v) => WorkloadSpec::from_json(v)?,
            },
            faults: match j.opt("faults") {
                None => d.faults,
                Some(v) => FaultRegime::from_json(v)?,
            },
            policies: get_str_list(j, "policies")?,
            archs: match j.opt("archs") {
                None => d.archs,
                Some(v) => {
                    let mut archs = Vec::new();
                    for (i, a) in v.arr().context("archs")?.iter().enumerate() {
                        let tag = a.str().with_context(|| format!("archs[{i}]"))?;
                        archs.push(parse_arch(tag).with_context(|| format!("archs[{i}]"))?);
                    }
                    archs
                }
            },
            driver: match j.opt("driver") {
                None => d.driver,
                Some(v) => DriverKnobs::from_json(v)?,
            },
        };
        sc.validate()?;
        Ok(sc)
    }

    /// Canonical fully-expanded emission (every default made explicit),
    /// so parse → emit → parse is the identity.
    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("name", jsonio::s(&self.name)),
            ("description", jsonio::s(&self.description)),
            (
                "experiments",
                Json::Arr(self.experiments.iter().map(|e| jsonio::s(e)).collect()),
            ),
            ("cluster", self.cluster.to_json()),
            ("workload", self.workload.to_json()),
            ("faults", self.faults.to_json()),
            ("policies", Json::Arr(self.policies.iter().map(|p| jsonio::s(p)).collect())),
            (
                "archs",
                Json::Arr(self.archs.iter().map(|&a| jsonio::s(arch_tag(a))).collect()),
            ),
            ("driver", self.driver.to_json()),
        ])
    }

    /// Every validation rule names the offending field, so a bad spec
    /// tells its author what to fix instead of panicking mid-run.
    pub fn validate(&self) -> crate::Result<()> {
        // -- name ----------------------------------------------------------
        if self.name.is_empty()
            || !self
                .name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        {
            bail!(
                "scenario.name: must be non-empty and use only [A-Za-z0-9._-] \
                 (it keys result artifacts), got {:?}",
                self.name
            );
        }

        // -- cluster -------------------------------------------------------
        let c = &self.cluster;
        if c.gpu_servers == 0 || c.gpus_per_server == 0 {
            bail!("cluster.gpu_servers and cluster.gpus_per_server must be ≥ 1");
        }
        if !(c.cpu_factor > 0.0 && c.cpu_factor.is_finite()) {
            bail!("cluster.cpu_factor must be a positive number, got {}", c.cpu_factor);
        }
        if !(c.bw_factor > 0.0 && c.bw_factor.is_finite()) {
            bail!("cluster.bw_factor must be a positive number, got {}", c.bw_factor);
        }

        // -- workload ------------------------------------------------------
        let w = &self.workload;
        if w.jobs == 0 {
            bail!("workload.jobs: must be ≥ 1");
        }
        if w.min_workers == 0 {
            bail!("workload.min_workers: must be ≥ 1 (a job needs at least one worker)");
        }
        if w.min_workers > w.max_workers {
            bail!(
                "workload.min_workers ({}) must be ≤ workload.max_workers ({})",
                w.min_workers,
                w.max_workers
            );
        }
        let total_gpus = c.gpu_servers * c.gpus_per_server;
        if w.max_workers > total_gpus {
            bail!(
                "workload.max_workers ({}) exceeds the cluster's total GPU count ({}): \
                 the largest job could never place",
                w.max_workers,
                total_gpus
            );
        }
        self.validate_arrival()?;
        self.validate_models()?;
        let ps = &w.ps;
        if !(0.0..=1.0).contains(&ps.on_gpu_prob) {
            bail!("workload.ps.on_gpu_prob: must be in [0, 1], got {}", ps.on_gpu_prob);
        }
        if ps.min_per_job == 0 {
            bail!("workload.ps.min_per_job: must be ≥ 1 (PS architecture needs a server)");
        }
        if ps.max_per_job != 0 && ps.max_per_job < ps.min_per_job {
            bail!(
                "workload.ps.max_per_job ({}) must be 0 (= worker count) or ≥ min_per_job ({})",
                ps.max_per_job,
                ps.min_per_job
            );
        }
        if c.cpu_servers == 0 && ps.on_gpu_prob < 1.0 {
            bail!(
                "cluster.cpu_servers is 0 but workload.ps.on_gpu_prob ({}) < 1: \
                 CPU-server PS placement would have no candidate servers",
                ps.on_gpu_prob
            );
        }

        // -- faults --------------------------------------------------------
        self.validate_faults()?;

        // -- driver --------------------------------------------------------
        if self.driver.max_job_duration_s < 0.0 {
            bail!("driver.max_job_duration_s: must be ≥ 0 (0 = driver default)");
        }

        // -- grid / delegation --------------------------------------------
        if self.experiments.is_empty() {
            if self.policies.is_empty() {
                bail!(
                    "policies: a generic scenario needs at least one policy \
                     (or set \"experiments\" to delegate to the experiment harness)"
                );
            }
            for (i, p) in self.policies.iter().enumerate() {
                crate::baselines::make_policy(p).with_context(|| format!("policies[{i}]"))?;
            }
            if self.archs.is_empty() {
                bail!("archs: must name at least one architecture (ps, ar)");
            }
        } else {
            self.validate_delegation()?;
        }
        Ok(())
    }

    fn validate_arrival(&self) -> crate::Result<()> {
        let span = |s: f64| -> crate::Result<()> {
            if s < 0.0 || !s.is_finite() {
                bail!("workload.arrival.span_s: must be ≥ 0 (0 = auto jobs·280 s), got {s}");
            }
            Ok(())
        };
        match self.workload.arrival {
            Arrival::Philly { span_s } | Arrival::Poisson { span_s } => span(span_s)?,
            Arrival::Bursty { span_s, burst_every_s, burst_len_s, mult } => {
                span(span_s)?;
                if burst_every_s <= 0.0 {
                    bail!("workload.arrival.burst_every_s: must be > 0, got {burst_every_s}");
                }
                if burst_len_s <= 0.0 || burst_len_s > burst_every_s {
                    bail!(
                        "workload.arrival.burst_len_s: must be in (0, burst_every_s = \
                         {burst_every_s}], got {burst_len_s}"
                    );
                }
                if !(1.0..=1000.0).contains(&mult) {
                    bail!(
                        "workload.arrival.mult: must be in [1, 1000] (it bounds the \
                         thinning sampler's rejection work), got {mult}"
                    );
                }
            }
            Arrival::Diurnal { span_s, period_s, peak_mult } => {
                span(span_s)?;
                if period_s <= 0.0 {
                    bail!("workload.arrival.period_s: must be > 0, got {period_s}");
                }
                if !(1.0..=1000.0).contains(&peak_mult) {
                    bail!(
                        "workload.arrival.peak_mult: must be in [1, 1000] (it bounds the \
                         thinning sampler's rejection work), got {peak_mult}"
                    );
                }
            }
        }
        Ok(())
    }

    fn validate_models(&self) -> crate::Result<()> {
        if let ModelMix::Weighted(ws) = &self.workload.models {
            let mut total = 0.0;
            for (name, weight) in ws {
                if ModelSpec::by_name(name).is_none() {
                    bail!(
                        "workload.models.weights: unknown model {name:?} (known: {})",
                        crate::models::ZOO
                            .iter()
                            .map(|m| m.name)
                            .collect::<Vec<_>>()
                            .join(", ")
                    );
                }
                if *weight < 0.0 || !weight.is_finite() {
                    bail!("workload.models.weights.{name}: must be ≥ 0, got {weight}");
                }
                total += weight;
            }
            if total <= 0.0 {
                bail!("workload.models.weights: weights must sum to > 0");
            }
        }
        Ok(())
    }

    fn validate_faults(&self) -> crate::Result<()> {
        match &self.faults {
            FaultRegime::Off => {}
            FaultRegime::Rate { rate, .. } => {
                if *rate < 0.0 || !rate.is_finite() {
                    bail!("faults.rate: must be ≥ 0 (0 = fault-free), got {rate}");
                }
            }
            FaultRegime::Config(c) => {
                for (field, v) in [
                    ("worker_mtbf_s", c.worker_mtbf_s),
                    ("ps_mtbf_s", c.ps_mtbf_s),
                    ("server_mtbf_s", c.server_mtbf_s),
                    ("degradation_mtbf_s", c.degradation_mtbf_s),
                ] {
                    if v < 0.0 || !v.is_finite() {
                        bail!("faults.{field}: must be ≥ 0 (0 disables the class), got {v}");
                    }
                }
                for (field, (lo, hi)) in [
                    ("restart_s", c.restart_s),
                    ("outage_s", c.outage_s),
                    ("degradation_s", c.degradation_s),
                    ("degradation_mag", c.degradation_mag),
                ] {
                    if !(lo >= 0.0 && hi >= lo && hi.is_finite()) {
                        bail!("faults.{field}: must be a [lo, hi] pair with 0 ≤ lo ≤ hi");
                    }
                }
                if c.degradation_mag.1 > 1.0 {
                    bail!(
                        "faults.degradation_mag: magnitudes are capacity fractions, hi must \
                         be ≤ 1, got {}",
                        c.degradation_mag.1
                    );
                }
            }
            FaultRegime::Storm { base_rate, storm_rate, windows, .. } => {
                if *base_rate < 0.0 || !base_rate.is_finite() {
                    bail!("faults.base_rate: must be ≥ 0, got {base_rate}");
                }
                if *storm_rate < 0.0 || !storm_rate.is_finite() {
                    bail!("faults.storm_rate: must be ≥ 0, got {storm_rate}");
                }
                for (i, &(a, b)) in windows.iter().enumerate() {
                    if !(a >= 0.0 && b > a && b.is_finite()) {
                        bail!(
                            "faults.windows[{i}]: must be [from_s, to_s] with 0 ≤ from < to, \
                             got [{a}, {b}]"
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Delegated scenarios run through `ExpCtx`, which owns the classic
    /// Philly workload and the paper testbed — reject spec fields the
    /// delegation would silently ignore.
    fn validate_delegation(&self) -> crate::Result<()> {
        for (i, id) in self.experiments.iter().enumerate() {
            if !crate::exp::EXPERIMENT_IDS.contains(&id.as_str()) {
                bail!(
                    "experiments[{i}]: unknown experiment id {id:?} (valid: {})",
                    crate::exp::EXPERIMENT_IDS.join(", ")
                );
            }
        }
        if !self.policies.is_empty() {
            bail!(
                "policies: delegated scenarios run each experiment's own policy grid — \
                 leave policies empty (or drop \"experiments\" for a generic scenario)"
            );
        }
        if self.cluster != ClusterShape::default() {
            bail!(
                "cluster: delegated experiments always run the paper testbed — leave \
                 cluster at defaults (or drop \"experiments\" for a generic scenario)"
            );
        }
        let classic = WorkloadSpec {
            jobs: self.workload.jobs,
            seed: self.workload.seed,
            ..Default::default()
        };
        if self.workload != classic {
            bail!(
                "workload: delegated experiments always run the classic Philly workload — \
                 only workload.jobs and workload.seed apply (or drop \"experiments\" for a \
                 generic scenario)"
            );
        }
        if !matches!(self.faults, FaultRegime::Off | FaultRegime::Rate { .. }) {
            bail!(
                "faults: delegated experiments support only the \"off\" and \"rate\" \
                 regimes (the --fault-rate recipe); storm/config regimes need a generic \
                 scenario"
            );
        }
        if self.archs != vec![Arch::Ps] {
            bail!(
                "archs: delegated experiments run each experiment's own PS/AR grid — \
                 leave archs unset (or drop \"experiments\" for a generic scenario)"
            );
        }
        if self.driver != DriverKnobs::default() {
            bail!(
                "driver: delegated experiments use the harness driver defaults — leave \
                 driver at defaults (or drop \"experiments\" for a generic scenario)"
            );
        }
        Ok(())
    }
}

/// The canonical short tag for an architecture (spec emission, artifact
/// names, CLI tables) — the single inverse of [`parse_arch`].
pub fn arch_tag(a: Arch) -> &'static str {
    match a {
        Arch::Ps => "ps",
        Arch::AllReduce => "ar",
    }
}

/// Parse an architecture tag (`ps`, `ar`/`allreduce`) — shared by the
/// scenario spec and the `star` CLI's `--arch` option.
pub fn parse_arch(s: &str) -> crate::Result<Arch> {
    match s {
        "ps" => Ok(Arch::Ps),
        "ar" | "allreduce" => Ok(Arch::AllReduce),
        other => bail!("unknown arch {other:?} (ps, ar)"),
    }
}

// -- field helpers (every error names `path.key`) ---------------------------

pub(crate) fn check_keys(j: &Json, path: &str, allowed: &[&str]) -> crate::Result<()> {
    for k in j.obj().with_context(|| format!("{path}: expected a JSON object"))?.keys() {
        if !allowed.contains(&k.as_str()) {
            bail!("{path}: unknown key {k:?} (allowed: {})", allowed.join(", "));
        }
    }
    Ok(())
}

pub(crate) fn get_f64(j: &Json, path: &str, key: &str, default: f64) -> crate::Result<f64> {
    match j.opt(key) {
        None => Ok(default),
        Some(v) => v.num().with_context(|| format!("{path}.{key}")),
    }
}

pub(crate) fn get_u64(j: &Json, path: &str, key: &str, default: u64) -> crate::Result<u64> {
    match j.opt(key) {
        None => Ok(default),
        Some(v) => v.u64().with_context(|| format!("{path}.{key}")),
    }
}

pub(crate) fn get_usize(j: &Json, path: &str, key: &str, default: usize) -> crate::Result<usize> {
    Ok(get_u64(j, path, key, default as u64)? as usize)
}

fn get_pair(j: &Json, path: &str, key: &str, default: (f64, f64)) -> crate::Result<(f64, f64)> {
    match j.opt(key) {
        None => Ok(default),
        Some(v) => pair_of(v).with_context(|| format!("{path}.{key}")),
    }
}

fn pair_of(v: &Json) -> crate::Result<(f64, f64)> {
    let a = v.arr()?;
    if a.len() != 2 {
        bail!("expected a [lo, hi] pair, got {} elements", a.len());
    }
    Ok((a[0].num()?, a[1].num()?))
}

pub(crate) fn get_str_list(j: &Json, key: &str) -> crate::Result<Vec<String>> {
    match j.opt(key) {
        None => Ok(Vec::new()),
        Some(v) => {
            let mut out = Vec::new();
            for (i, item) in v.arr().with_context(|| key.to_string())?.iter().enumerate() {
                out.push(item.str().with_context(|| format!("{key}[{i}]"))?.to_string());
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> crate::Result<Scenario> {
        Scenario::from_json(&Json::parse(text).unwrap())
    }

    fn err_of(text: &str) -> String {
        format!("{:#}", parse(text).err().expect("spec must be rejected"))
    }

    const FULL: &str = r#"{
        "name": "kitchen-sink",
        "description": "every knob",
        "cluster": {"gpu_servers": 6, "cpu_servers": 2, "cpu_factor": 0.5, "bw_factor": 0.8},
        "workload": {
            "jobs": 30, "seed": 3,
            "arrival": {"kind": "bursty", "span_s": 9000, "burst_every_s": 3000,
                        "burst_len_s": 500, "mult": 5},
            "min_workers": 4, "max_workers": 10,
            "models": {"weights": {"DenseNet121": 3, "LSTM": 1}},
            "ps": {"on_gpu_prob": 0.9, "min_per_job": 2, "max_per_job": 4}
        },
        "faults": {"kind": "storm", "seed": 7, "base_rate": 0.5, "storm_rate": 10,
                   "windows": [[1000, 2000], [5000, 6500]]},
        "policies": ["SSGD", "STAR-H"],
        "archs": ["ps", "ar"],
        "driver": {"seed": 1, "max_job_duration_s": 9000}
    }"#;

    #[test]
    fn parse_emit_parse_is_identity() {
        let s1 = parse(FULL).unwrap();
        let j = s1.to_json();
        let s2 = Scenario::from_json(&j).unwrap();
        assert_eq!(j, s2.to_json());
        // and the emitted text itself is stable
        assert_eq!(j.to_string_pretty(), s2.to_json().to_string_pretty());
    }

    #[test]
    fn minimal_spec_fills_defaults() {
        let sc = parse(r#"{"name": "tiny", "policies": ["SSGD"]}"#).unwrap();
        assert_eq!(sc.workload.jobs, 120);
        assert!(sc.workload.is_classic_philly());
        assert_eq!(sc.archs, vec![Arch::Ps]);
        assert!(matches!(sc.faults, FaultRegime::Off));
        // defaults round-trip too
        let again = Scenario::from_json(&sc.to_json()).unwrap();
        assert_eq!(sc.to_json(), again.to_json());
    }

    #[test]
    fn validation_errors_name_their_field() {
        let zero_workers = err_of(
            r#"{"name": "x", "policies": ["SSGD"],
                "workload": {"min_workers": 0}}"#,
        );
        assert!(zero_workers.contains("workload.min_workers"), "{zero_workers}");

        let zero_jobs = err_of(r#"{"name": "x", "policies": ["SSGD"], "workload": {"jobs": 0}}"#);
        assert!(zero_jobs.contains("workload.jobs"), "{zero_jobs}");

        let bad_seed =
            err_of(r#"{"name": "x", "policies": ["SSGD"], "workload": {"seed": -1}}"#);
        assert!(bad_seed.contains("workload.seed"), "{bad_seed}");

        let bad_policy = err_of(r#"{"name": "x", "policies": ["SSGD", "NotASystem"]}"#);
        assert!(bad_policy.contains("policies[1]"), "{bad_policy}");
        assert!(bad_policy.contains("unknown system"), "{bad_policy}");

        let bad_arch = err_of(r#"{"name": "x", "policies": ["SSGD"], "archs": ["mesh"]}"#);
        assert!(bad_arch.contains("archs[0]"), "{bad_arch}");

        let bad_model = err_of(
            r#"{"name": "x", "policies": ["SSGD"],
                "workload": {"models": {"weights": {"NotAModel": 1}}}}"#,
        );
        assert!(bad_model.contains("workload.models.weights"), "{bad_model}");
        assert!(bad_model.contains("NotAModel"), "{bad_model}");

        let bad_window = err_of(
            r#"{"name": "x", "policies": ["SSGD"],
                "faults": {"kind": "storm", "windows": [[200, 100]]}}"#,
        );
        assert!(bad_window.contains("faults.windows[0]"), "{bad_window}");

        let bad_name = err_of(r#"{"name": "no spaces allowed", "policies": ["SSGD"]}"#);
        assert!(bad_name.contains("scenario.name"), "{bad_name}");

        let typo = err_of(r#"{"name": "x", "policies": ["SSGD"], "wrkload": {}}"#);
        assert!(typo.contains("wrkload"), "{typo}");
    }

    #[test]
    fn validation_rejects_oversized_jobs_and_empty_grids() {
        let too_big = err_of(
            r#"{"name": "x", "policies": ["SSGD"],
                "cluster": {"gpu_servers": 1},
                "workload": {"max_workers": 12}}"#,
        );
        assert!(too_big.contains("workload.max_workers"), "{too_big}");

        let no_policy = err_of(r#"{"name": "x"}"#);
        assert!(no_policy.contains("policies"), "{no_policy}");
    }

    #[test]
    fn delegation_is_validated() {
        let ok = parse(
            r#"{"name": "res", "experiments": ["resilience"],
                "workload": {"jobs": 4, "seed": 2},
                "faults": {"kind": "rate", "rate": 1, "seed": 7}}"#,
        )
        .unwrap();
        assert_eq!(ok.experiments, vec!["resilience".to_string()]);

        let bad_id = err_of(r#"{"name": "x", "experiments": ["fig99"]}"#);
        assert!(bad_id.contains("experiments[0]"), "{bad_id}");
        assert!(bad_id.contains("resilience"), "error must list valid ids: {bad_id}");

        let with_policies =
            err_of(r#"{"name": "x", "experiments": ["fig8"], "policies": ["SSGD"]}"#);
        assert!(with_policies.contains("policies"), "{with_policies}");

        let with_storm = err_of(
            r#"{"name": "x", "experiments": ["fig8"], "faults": {"kind": "storm"}}"#,
        );
        assert!(with_storm.contains("faults"), "{with_storm}");

        // a non-default archs list would be silently ignored — reject it
        let with_archs = err_of(r#"{"name": "x", "experiments": ["fig8"], "archs": ["ar"]}"#);
        assert!(with_archs.contains("archs"), "{with_archs}");
        // …but an explicit default is fine
        assert!(parse(r#"{"name": "x", "experiments": ["fig8"], "archs": ["ps"]}"#).is_ok());
    }

    #[test]
    fn storm_regime_confines_and_merges_streams() {
        let trace = crate::trace::generate(&crate::trace::TraceConfig::paced(10, 0));
        let windows = vec![(500.0, 900.0), (1500.0, 1800.0)];
        let storm = FaultRegime::Storm {
            seed: 3,
            base_rate: 0.0,
            storm_rate: 40.0,
            windows: windows.clone(),
        };
        let plan = storm.plan(&trace, 2800.0, 8);
        assert!(!plan.is_empty(), "a rate-40 storm must schedule faults");
        for f in &plan.faults {
            assert!(
                windows.iter().any(|&(a, b)| f.at >= a && f.at < b),
                "fault at {} outside every storm window",
                f.at
            );
        }
        assert_eq!(plan.checkpoint_every_updates, 200, "cadence adopted from storm stream");
        // with a base rate, out-of-window faults appear and match the
        // pure base stream's schedule (independent streams)
        let with_base = FaultRegime::Storm {
            seed: 3,
            base_rate: 2.0,
            storm_rate: 40.0,
            windows: windows.clone(),
        }
        .plan(&trace, 2800.0, 8);
        let base_only = FaultRegime::Rate { rate: 2.0, seed: 3 }.plan(&trace, 2800.0, 8);
        let outside: Vec<_> = with_base
            .faults
            .iter()
            .filter(|f| !windows.iter().any(|&(a, b)| f.at >= a && f.at < b))
            .collect();
        let expect: Vec<_> = base_only
            .faults
            .iter()
            .filter(|f| !windows.iter().any(|&(a, b)| f.at >= a && f.at < b))
            .collect();
        assert_eq!(outside, expect);
    }

    #[test]
    fn rate_regime_matches_plan_at_rate() {
        let trace = crate::trace::generate(&crate::trace::TraceConfig::paced(8, 0));
        let a = FaultRegime::Rate { rate: 2.0, seed: 5 }.plan(&trace, 10_000.0, 8);
        let b = plan_at_rate(2.0, 5, &trace, 10_000.0, 8);
        assert_eq!(a, b);
        assert!(FaultRegime::Off.plan(&trace, 10_000.0, 8).is_empty());
    }

    #[test]
    fn oversubscribed_cluster_scales_capacities() {
        let shape = ClusterShape { cpu_factor: 0.5, bw_factor: 0.25, ..Default::default() };
        let cfg = shape.to_config();
        let d = ClusterConfig::default();
        assert_eq!(cfg.gpu_server_cpus, d.gpu_server_cpus * 0.5);
        assert_eq!(cfg.cpu_server_cpus, d.cpu_server_cpus * 0.5);
        assert_eq!(cfg.gpu_server_bw, d.gpu_server_bw * 0.25);
        assert_eq!(cfg.total_servers(), d.total_servers());
    }
}
