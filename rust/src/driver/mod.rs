//! Trace-driven execution engine.
//!
//! Runs a set of jobs (from `trace`) over the `cluster` contention model
//! with a pluggable per-job [`Policy`] (STAR variants live in [`crate::star`],
//! the six comparison systems in [`crate::baselines`]). Execution is a
//! discrete-event simulation at *gradient-report* granularity:
//!
//! * each worker's iteration time is computed from its resource shares at
//!   the iteration's start (preprocess ∝ 1/cpu, GPU constant per model —
//!   homogeneous GPUs — and communication ∝ bytes/min(worker, PS share)),
//! * the job's current [`SyncMode`] decides when gradient reports become
//!   parameter updates (SSGD barrier, per-report ASGD, x-arrival groups,
//!   predicted-time clusters, first-K, AR ring + parent wait),
//! * every update advances the PGNS progress model; TTA/JCT/convergence
//!   are read off it, straggler counts off the §II deviation ratios.
//!
//! The engine is layered (DESIGN.md §8); this file is the orchestrator:
//!
//! * [`events`] — the [`Event`] vocabulary + stable-heap scheduling,
//! * [`membership`] — round membership over the live set ([`LiveSet`],
//!   barrier/group rules, AR ring chaining, [`first_k_split`]),
//! * [`itertime`] — the §2.2 iteration-time composition,
//! * [`faulting`] — §7 plan-event translation and crash/restart logic,
//! * [`stats`] — [`JobStats`]/[`IterBreakdown`]/[`ServerRecord`]
//!   accumulation.
//!
//! ## Hot-path discipline (DESIGN.md §3)
//!
//! The per-event dispatch path is **zero-clone and allocation-free in
//! steady state**: [`crate::trace::JobSpec`], [`DriverMode`] and
//! [`crate::faults::Fault`] are `Copy`; throttle lists and placement
//! vectors are read in place through disjoint field borrows; round
//! membership fills reusable scratch buffers
//! (`membership::*_into`); and per-iteration straggler rows live in a
//! ring-indexed slab (`stats::RoundSlab`) instead of a `BTreeMap`.

use crate::cluster::{Cluster, ClusterConfig, Res, TaskId};
use crate::faults::FaultPlan;
use crate::models::ModelSpec;
use crate::predict::{Confusion, History, IterTimeModel, ResourcePredictor};
use crate::prevent::CommTree;
use crate::progress::ProgressModel;
use crate::simrng::Rng;
use crate::sync::SyncMode;
use crate::trace::{place_job, Arch, JobSpec, Placement};

pub mod events;
mod faulting;
pub mod itertime;
pub mod membership;
pub mod stats;

pub use self::events::{Event, EventQueue};
pub use self::membership::{first_k_split, LiveSet};
pub use self::stats::{
    peak_rss_bytes, reset_peak_rss, IterBreakdown, JobStats, ServerRecord, StatStream, StreamAgg,
    SERIES_CAP,
};

/// Extended mode set used at driver level: LGC's first-K is a distinct
/// grouping rule (uses only the K fastest reports per round). `Copy` —
/// modes are read on every dispatch and must never be cloned there.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriverMode {
    Sync(SyncMode),
    /// one update per round from the first K reports; the rest are dropped
    FirstK(usize),
}

impl DriverMode {
    /// Allocation-free label for hot logging/stats paths. The
    /// parameterized form (x, K, t_w values) is [`DriverMode::describe`].
    pub fn name(&self) -> &'static str {
        match self {
            DriverMode::Sync(m) => m.static_name(),
            DriverMode::FirstK(_) => "first-K",
        }
    }

    /// Human-readable form including the mode's parameters (allocates).
    pub fn describe(&self) -> String {
        match self {
            DriverMode::Sync(m) => m.name(),
            DriverMode::FirstK(k) => format!("first-{k}"),
        }
    }
}

/// What a policy sees at decision time (predictions, not ground truth).
pub struct RoundObs<'a> {
    pub job: usize,
    pub n: usize,
    pub arch: Arch,
    pub spec: &'static ModelSpec,
    /// parameter updates applied so far
    pub step: u64,
    /// accumulated statistical progress (PGNS index)
    pub progress: f64,
    pub now: f64,
    /// predicted next-iteration times per worker (STAR pipeline output;
    /// baselines may ignore and use `last_times`)
    pub predicted_times: &'a [f64],
    /// last completed iteration time per worker (NaN until measured)
    pub last_times: &'a [f64],
    /// current value (accuracy %, or perplexity)
    pub value: f64,
    /// per-worker straggler flags STAR predicted (from predicted_times)
    pub predicted_stragglers: &'a [bool],
    /// per-worker liveness (fault injection): policies must not build
    /// schedules around dead workers — the driver already excludes them
    /// from barriers, groups and rings
    pub live: &'a [bool],
}

impl<'a> RoundObs<'a> {
    /// Membership view over the liveness mask — the shared primitive
    /// policies use instead of re-counting live workers by hand.
    pub fn live_set(&self) -> LiveSet<'a> {
        LiveSet::new(self.live)
    }
}

/// A policy's decision for the upcoming window.
#[derive(Clone, Debug)]
pub struct PolicyDecision {
    pub mode: DriverMode,
    /// learning rate was rescaled for the effective batch (§IV-C / O7)
    pub lr_rescaled: bool,
    /// training pause charged to the job (heuristic decision time, §V)
    pub pause_s: f64,
    /// decision latency accounted even when overlapped (Fig 28 bookkeeping)
    pub overhead_s: f64,
    /// per-worker batch fraction (LB-BSP resizing); empty = all 1.0
    pub batch_frac: Vec<f64>,
    /// asymptote floor on x/N for accuracy accounting (Zeno++ validation
    /// filtering keeps accuracy near-synchronous despite 1-report updates)
    pub x_floor: f64,
    /// per-own-worker resource-cap multipliers (§IV-D1 group
    /// equalization: fast group members yield resources, finishing at
    /// their group's deadline at zero TTA cost); empty = all 1.0
    pub self_caps: Vec<f64>,
    /// communication tree to install (None = keep current)
    pub tree: Option<CommTree>,
    /// resource-cap multipliers to impose on co-located tasks (§IV-D1)
    pub deprive: Vec<(TaskId, f64)>,
}

impl PolicyDecision {
    pub fn simple(mode: DriverMode) -> Self {
        PolicyDecision {
            mode,
            lr_rescaled: false,
            pause_s: 0.0,
            overhead_s: 0.0,
            batch_frac: Vec::new(),
            x_floor: 0.0,
            self_caps: Vec::new(),
            tree: None,
            deprive: Vec::new(),
        }
    }
}

/// A per-job synchronization policy (system under test).
///
/// `Send` so a whole run cell — cluster, driver, and its policies — can
/// be constructed and executed inside a sweep worker thread
/// ([`crate::exp::sweep`]).
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    /// Called roughly once per round (every N gradient reports).
    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision;
    /// Feedback after an update was applied (realized seconds per unit of
    /// value improvement) — used by STAR-ML online training.
    fn feedback(&mut self, _step: u64, _time_per_progress: f64) {}
    /// Whether this policy wants STAR's balanced PS placement (§IV-D2a).
    fn balanced_placement(&self) -> bool {
        false
    }
    /// Whether this policy wants the §IV-D2b communication tree.
    fn wants_tree(&self) -> bool {
        false
    }
}

/// Factory building one fresh [`Policy`] per admitted job. `Send` (like
/// the policies it makes) so drivers can be built inside sweep threads.
pub type PolicyFactory = Box<dyn Fn(&JobSpec) -> Box<dyn Policy> + Send>;

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    pub arch: Arch,
    pub cluster: ClusterConfig,
    pub seed: u64,
    /// hard per-job caps (safety)
    pub max_updates_per_job: u64,
    pub max_iters_per_job: u64,
    pub max_job_duration_s: f64,
    pub record_series: bool,
    /// sample cadence for server records (Fig 9), 0 = off
    pub server_sample_period_s: f64,
    /// tree branching factor for §IV-D2b
    pub tree_branching: usize,
    /// static throttles applied at placement: (job, worker_rank,
    /// cpu_frac, bw_frac) — the paper's cpulimit/tc experiments
    pub throttles: Vec<(usize, usize, f64, f64)>,
    /// injected failure schedule (empty = fault-free, bit-identical to
    /// the pre-faults simulator)
    pub faults: FaultPlan,
    /// stream finished-job stats into a bounded running aggregate
    /// ([`stats::StreamAgg`]) instead of accumulating `Vec<JobStats>` —
    /// the memory bound that makes 10⁶-job traces tractable. Collect the
    /// aggregate via [`Driver::run_streaming`]; the plain accessors then
    /// return an empty stats vec.
    pub streaming_stats: bool,
    /// collect per-phase wall-clock counters ([`PhaseProfile`], the
    /// `star simulate --profile` table). Off by default: the timers cost
    /// two `Instant::now` calls per event when enabled, zero when not.
    pub profile: bool,
    /// threads for parallel share-epoch prefill (DESIGN.md §13): before
    /// each round's serial composition loop, the epochs the round will
    /// touch are filled concurrently via [`Cluster::prefill_epochs`].
    /// `<= 1` disables prefill entirely (the byte-exact legacy path —
    /// and every other value is byte-identical to it, pinned by
    /// `tests/prefill_equivalence.rs` and the CI artifact diff).
    pub prefill_threads: usize,
    /// accrue per-fill wall time into [`RunMetrics::fill_wall_s`] even
    /// when `profile` is off (the `scale` cells want fill timing without
    /// paying for full event-dispatch profiling)
    pub fill_timing: bool,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            arch: Arch::Ps,
            cluster: ClusterConfig::default(),
            seed: 0,
            max_updates_per_job: 200_000,
            max_iters_per_job: 120_000,
            max_job_duration_s: 40_000.0,
            record_series: true,
            server_sample_period_s: 0.0,
            tree_branching: 3,
            throttles: Vec::new(),
            faults: FaultPlan::default(),
            streaming_stats: false,
            profile: false,
            prefill_threads: 1,
            fill_timing: false,
        }
    }
}

/// Lightweight per-phase wall-clock counters (`star simulate --profile`):
/// where a run's real time goes, from plain `Instant` pairs instead of a
/// profiler. The sub-phases nest inside `dispatch_s` (total event
/// handling), so `dispatch_s - (itertime_s + decide_s + stats_s)` is the
/// residual orchestration cost (grouping, queue ops, fault transitions).
/// All zeros unless [`DriverConfig::profile`] was set.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseProfile {
    /// total event-dispatch wall seconds (contains the sub-phases)
    pub dispatch_s: f64,
    /// share fills + iteration-time composition ([`itertime::breakdown`])
    pub itertime_s: f64,
    /// policy decision time ([`Policy::decide`])
    pub decide_s: f64,
    /// straggler-accounting time ([`stats`] row recording/scoring)
    pub stats_s: f64,
    pub itertime_calls: u64,
    pub decide_calls: u64,
    pub stats_calls: u64,
}

/// Run-level instrumentation returned by [`Driver::run_instrumented`]:
/// the numbers `BENCH_driver.json` tracks across PRs.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunMetrics {
    /// events the engine processed (the determinism probe)
    pub events: u64,
    /// high-water mark of the event queue
    pub peak_queue_depth: usize,
    /// wall-clock seconds of the event loop
    pub wall_s: f64,
    /// jobs that terminated during the run — the figure that stays
    /// meaningful under `streaming_stats`, where no `Vec<JobStats>`
    /// accumulates
    pub jobs_finished: u64,
    /// process peak resident set (`VmHWM` from `/proc/self/status`) read
    /// at the end of the run; `None` off Linux. Monotonic per process —
    /// callers comparing cells should [`stats::reset_peak_rss`] first.
    pub peak_rss_bytes: Option<u64>,
    /// per-phase timing counters (all zero unless `cfg.profile`)
    pub profile: PhaseProfile,
    /// share-epoch recomputations over the whole run
    /// ([`Cluster::epoch_fills`]) — invariant across `prefill_threads`
    /// settings, which the determinism tests exploit
    pub epoch_fills: u64,
    /// cumulative wall seconds inside epoch fills
    /// ([`Cluster::fill_wall_s`]; zero unless `cfg.profile` or
    /// `cfg.fill_timing` enabled fill timing)
    pub fill_wall_s: f64,
}

impl RunMetrics {
    /// Events per wall-clock second — the headline throughput figure.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.events as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

// ---------------------------------------------------------------------------
// Internal per-job state
// ---------------------------------------------------------------------------

struct JobRun {
    job: JobSpec,
    placement: Placement,
    policy: Box<dyn Policy>,
    progress: ProgressModel,
    mode: DriverMode,
    lr_rescaled: bool,
    x_floor: f64,
    tree: CommTree,
    batch_frac: Vec<f64>,

    // prediction pipeline
    histories: Vec<History>,
    iter_model: IterTimeModel,

    // event-machine state
    started_at: f64,
    /// the hot per-worker state (iteration clocks, liveness, prediction
    /// outputs) as one struct-of-arrays block — every event touches
    /// several of these arrays, so they live together (DESIGN.md §12)
    wb: membership::WorkerBlock,
    /// reports waiting to be grouped: (worker, ready_at, version_at_start)
    pending: Vec<(usize, f64, u64)>,
    /// dynamic-x cluster assignment (worker -> group) when in DynamicX
    dyn_groups: Vec<usize>,
    reports_since_decision: usize,
    ar_flush_scheduled: bool,
    /// time of the last AR-ring aggregation (late child gradients whose
    /// iteration started before this are computed on stale params and are
    /// discarded by the parent, per §IV-B)
    last_ar_flush_t: f64,
    mode_just_switched: bool,
    /// no iteration may start before this time (decision pause, §V)
    pause_until: f64,

    // fault state (the per-worker half — alive/down_since/restart_at —
    // lives in `wb` with the rest of the hot per-worker arrays)
    /// per-PS restart deadline (same extension rule)
    ps_restart_at: Vec<f64>,
    /// PSs of this job currently down; updates stall while > 0
    ps_down: usize,
    /// when the current PS stall window opened (NaN while all PSs are
    /// up) — overlapping PS crashes count the union window once
    ps_down_since: f64,
    /// rollback target for PS crashes (refreshed every
    /// `faults.checkpoint_every_updates` updates)
    checkpoint: crate::progress::Snapshot,

    // per-iteration-index straggler accounting (ring slab, DESIGN.md §3)
    round_times: stats::RoundSlab,

    /// deprivations this job imposed on co-located tasks (§IV-D1), undone
    /// at its next decision: (task, old_cpu_cap, old_bw_cap)
    imposed: Vec<(TaskId, f64, f64)>,

    stats: JobStats,
    finished: bool,
}

/// The trace driver: runs all jobs to completion under their policies.
pub struct Driver {
    pub cfg: DriverConfig,
    pub cluster: Cluster,
    engine: EventQueue,
    rng: Rng,
    /// boxed so a 10⁶-slot trace costs 8 B per empty slot, not
    /// `size_of::<JobRun>()` (hundreds of bytes) — only admitted jobs
    /// pay for their state
    jobs: Vec<Option<Box<JobRun>>>,
    specs: Vec<JobSpec>,
    wait_queue: Vec<usize>,
    make_policy: PolicyFactory,
    pub finished: Vec<JobStats>,
    pub server_records: Vec<ServerRecord>,
    /// running aggregate replacing `finished` when
    /// [`DriverConfig::streaming_stats`] is set
    stream: Option<stats::StreamAgg>,
    jobs_done: u64,

    // hot-loop scratch, reused across events (DESIGN.md §3). Buffers are
    // `mem::take`n around re-entrant calls, so the loop allocates nothing
    // once they reach working-set size.
    /// NaN-safe predicted times (`fill_predicted_safe` target)
    pt_scratch: Vec<f64>,
    /// AR ring chaining order
    order_scratch: Vec<usize>,
    /// firing update group / first-K members
    group_scratch: Vec<usize>,
    /// first-K dropped workers
    drop_scratch: Vec<usize>,
    /// first-K arrival order
    arrival_scratch: Vec<usize>,
    /// (server, res) keys for the next round's parallel epoch prefill
    prefill_keys: Vec<(usize, Res)>,

    profile_on: bool,
    profile: PhaseProfile,
}

impl Driver {
    pub fn new(cfg: DriverConfig, specs: Vec<JobSpec>, make_policy: PolicyFactory) -> Self {
        let mut cluster_cfg = cfg.cluster.clone();
        cluster_cfg.seed ^= cfg.seed;
        let mut cluster = Cluster::new(cluster_cfg);
        let mut engine = EventQueue::for_cluster(cluster.server_count());
        for j in &specs {
            engine.schedule_at(j.arrival_s, Event::Arrive(j.id));
        }
        if cfg.server_sample_period_s > 0.0 {
            engine.schedule_at(cfg.server_sample_period_s, Event::ServerSample);
        }
        faulting::register_plan(&cfg.faults, &mut cluster, &mut engine);
        cluster.set_fill_timing(cfg.profile || cfg.fill_timing);
        let n_jobs = specs.len();
        Driver {
            rng: Rng::new(cfg.seed, 0xd21fe4),
            profile_on: cfg.profile,
            stream: cfg.streaming_stats.then(stats::StreamAgg::default),
            cfg,
            cluster,
            engine,
            jobs: (0..n_jobs).map(|_| None).collect(),
            specs,
            wait_queue: Vec::new(),
            make_policy,
            finished: Vec::new(),
            server_records: Vec::new(),
            jobs_done: 0,
            pt_scratch: Vec::new(),
            order_scratch: Vec::new(),
            group_scratch: Vec::new(),
            drop_scratch: Vec::new(),
            arrival_scratch: Vec::new(),
            prefill_keys: Vec::new(),
            profile: PhaseProfile::default(),
        }
    }

    /// Run the full trace; returns (per-job stats, server records).
    pub fn run(self) -> (Vec<JobStats>, Vec<ServerRecord>) {
        let (stats, records, _) = self.run_counted();
        (stats, records)
    }

    /// Like [`Driver::run`], additionally returning the number of events
    /// the engine processed — the determinism suite compares this across
    /// replays to pin the FIFO tie-break and event-machine structure.
    pub fn run_counted(self) -> (Vec<JobStats>, Vec<ServerRecord>, u64) {
        let (stats, records, metrics) = self.run_instrumented();
        (stats, records, metrics.events)
    }

    /// Like [`Driver::run`], additionally returning [`RunMetrics`]:
    /// processed events, peak queue depth, wall seconds, and — when
    /// [`DriverConfig::profile`] is set — the per-phase timing counters.
    /// Instrumentation reads clocks only; it cannot perturb the trace.
    pub fn run_instrumented(mut self) -> (Vec<JobStats>, Vec<ServerRecord>, RunMetrics) {
        let metrics = self.drive();
        (self.finished, self.server_records, metrics)
    }

    /// Run to completion in streaming-stats mode: per-job stats are
    /// folded into a bounded [`stats::StreamAgg`] at termination instead
    /// of accumulating — the only run entry point whose memory does not
    /// grow with the trace length. The aggregate matches folding a
    /// non-streaming run's `finished` vec exactly (same fold order:
    /// termination order), pinned by `tests/partitioned_equivalence.rs`.
    pub fn run_streaming(mut self) -> (stats::StreamAgg, Vec<ServerRecord>, RunMetrics) {
        if self.stream.is_none() {
            self.stream = Some(stats::StreamAgg::default());
        }
        let metrics = self.drive();
        (self.stream.unwrap(), self.server_records, metrics)
    }

    fn drive(&mut self) -> RunMetrics {
        let run_t0 = std::time::Instant::now();
        while let Some((t, ev)) = self.engine.next() {
            let t0 = if self.profile_on { Some(std::time::Instant::now()) } else { None };
            match ev {
                Event::Arrive(job) => self.try_place(job, t),
                Event::WorkerDone { job, worker, iter } => self.worker_done(job, worker, iter, t),
                Event::ArFlush { job } => self.ar_flush(job, t),
                Event::ServerSample => {
                    self.sample_servers(t);
                    if self.jobs.iter().any(|j| j.is_some()) || !self.wait_queue.is_empty() {
                        self.engine
                            .schedule_in(self.cfg.server_sample_period_s, Event::ServerSample);
                    }
                }
                Event::Fault(idx) => self.handle_fault(idx, t),
                Event::WorkerRestart { job, worker } => self.worker_restart(job, worker, t),
                Event::PsRestart { job, ps_idx } => self.ps_restart(job, ps_idx, t),
            }
            if let Some(t0) = t0 {
                self.profile.dispatch_s += t0.elapsed().as_secs_f64();
            }
        }
        RunMetrics {
            events: self.engine.events_processed(),
            peak_queue_depth: self.engine.peak_pending(),
            wall_s: run_t0.elapsed().as_secs_f64(),
            jobs_finished: self.jobs_done,
            peak_rss_bytes: stats::peak_rss_bytes(),
            profile: self.profile,
            epoch_fills: self.cluster.epoch_fills(),
            fill_wall_s: self.cluster.fill_wall_s(),
        }
    }

    fn sample_servers(&mut self, t: f64) {
        for s in 0..self.cluster.server_count() {
            let rec = ServerRecord {
                time: t,
                server: s,
                ps_hosted: self.cluster.ps_count(s),
                cpu_util: self.cluster.utilization(s, Res::Cpu, t),
                bw_util: self.cluster.utilization(s, Res::Bw, t),
            };
            self.server_records.push(rec);
        }
    }

    fn try_place(&mut self, job: usize, t: f64) {
        let spec = self.specs[job];
        let policy = (self.make_policy)(&spec);
        let balanced = policy.balanced_placement();
        match place_job(&mut self.cluster, &spec, balanced) {
            Ok(placement) => {
                let n = spec.workers;
                let model_spec = spec.spec();
                let tree = if policy.wants_tree() {
                    CommTree::build(&vec![model_spec.worker_bw; n], self.cfg.tree_branching)
                } else {
                    CommTree::flat(n)
                };
                let progress = ProgressModel::new(model_spec, n);
                let checkpoint = progress.snapshot();
                let run = JobRun {
                    progress,
                    checkpoint,
                    wb: membership::WorkerBlock::new(n, t),
                    ps_restart_at: vec![f64::NAN; placement.ps_tasks.len()],
                    ps_down: 0,
                    ps_down_since: f64::NAN,
                    placement,
                    mode: DriverMode::Sync(SyncMode::Ssgd),
                    lr_rescaled: true,
                    x_floor: 0.0,
                    tree,
                    batch_frac: vec![1.0; n],
                    histories: (0..n).map(|_| History::new()).collect(),
                    iter_model: IterTimeModel::new(),
                    started_at: t,
                    pending: Vec::new(),
                    dyn_groups: vec![0; n],
                    reports_since_decision: usize::MAX / 2, // force first decision
                    ar_flush_scheduled: false,
                    last_ar_flush_t: -1.0,
                    mode_just_switched: false,
                    pause_until: 0.0,
                    round_times: stats::RoundSlab::default(),
                    imposed: Vec::new(),
                    stats: JobStats {
                        job: spec.id,
                        model: spec.model,
                        workers: n,
                        system: policy.name().to_string(),
                        arrival_s: spec.arrival_s,
                        start_s: t,
                        end_s: 0.0,
                        tta_s: None,
                        jct_s: 0.0,
                        converged_value: 0.0,
                        is_nlp: model_spec.kind == crate::models::Kind::Nlp,
                        updates: 0,
                        iters_total: 0,
                        straggler_iters: 0,
                        straggler_episodes: 0,
                        decision_pause_total_s: 0.0,
                        decision_overhead_total_s: 0.0,
                        decision_count: 0,
                        prediction: Confusion::default(),
                        series: vec![Vec::new(); n],
                        value_series: Vec::new(),
                        mode_switches: 0,
                        downtime_s: 0.0,
                        rollbacks: 0,
                    },
                    policy,
                    job: spec,
                    finished: false,
                };
                // re-apply static throttles in place: the list is read
                // through a disjoint field borrow, never cloned (this
                // path re-runs on every wait-queue re-placement)
                for &(tj, rank, cpu, bw) in &self.cfg.throttles {
                    if tj == job && rank < n {
                        let tid = run.placement.worker_tasks[rank];
                        self.cluster.set_throttles(
                            tid,
                            cpu.clamp(0.01, 1.0),
                            bw.clamp(0.01, 1.0),
                        );
                    }
                }
                self.jobs[job] = Some(Box::new(run));
                self.decide(job, t);
                // after decide (it may impose caps, bumping generations)
                self.prefill_round(job, None, t);
                for w in 0..n {
                    self.start_iteration(job, w, t);
                }
            }
            Err(_) => {
                self.wait_queue.push(job);
            }
        }
    }

    /// One worker's §2.2 iteration breakdown at `t` (see [`itertime`]).
    fn iteration_breakdown(&mut self, job: usize, worker: usize, t: f64) -> IterBreakdown {
        let run = self.jobs[job].as_ref().expect("job running");
        let inp = itertime::IterInputs {
            arch: self.cfg.arch,
            spec: run.job.spec(),
            tree: &run.tree,
            worker_task: run.placement.worker_tasks[worker],
            ps_tasks: &run.placement.ps_tasks,
            batch_frac: run.batch_frac[worker],
        };
        let t0 = if self.profile_on { Some(std::time::Instant::now()) } else { None };
        let bd = itertime::breakdown(&mut self.cluster, &mut self.rng, &inp, t);
        if let Some(t0) = t0 {
            self.profile.itertime_s += t0.elapsed().as_secs_f64();
            self.profile.itertime_calls += 1;
        }
        bd
    }

    fn start_iteration(&mut self, job: usize, worker: usize, t: f64) {
        let t = {
            let run = self.jobs[job].as_mut().expect("job running");
            if run.finished || run.wb.busy[worker] || !run.wb.is_alive(worker) {
                return;
            }
            t.max(run.pause_until)
        };
        let bd = self.iteration_breakdown(job, worker, t);
        let run = self.jobs[job].as_mut().expect("job running");
        let spec = run.job.spec();
        run.wb.busy[worker] = true;
        run.wb.iter_start[worker] = t;
        run.wb.param_version_at_start[worker] = run.progress.step;
        let iter = run.wb.iter_idx[worker];

        // predicted time for this iteration: predicted resources (AR over
        // the history; the LSTM artifact path is exercised by e2e_train)
        // through the online regressor
        let (pc, pb) = ArFallback.predict(&run.histories[worker]);
        let feats = IterTimeModel::features(
            spec.pre_cpu_ms,
            spec.gpu_ms,
            spec.grad_mb,
            (pc * spec.worker_cpu).max(1e-3),
            (pb * spec.worker_bw * 4.0).max(1e-3),
        );
        run.wb.predicted_times[worker] = if run.iter_model.trained() {
            run.iter_model.predict(&feats)
        } else if run.wb.last_times[worker].is_finite() {
            run.wb.last_times[worker]
        } else {
            bd.total_s // bootstrap
        };

        // observe for online regressor training (features at actual shares)
        let actual_feats = IterTimeModel::features(
            spec.pre_cpu_ms,
            spec.gpu_ms,
            spec.grad_mb,
            bd.cpu_share,
            bd.bw_share,
        );
        run.iter_model.observe(&actual_feats, bd.total_s);

        // resource history (normalized to demand)
        run.histories[worker].push(
            (bd.cpu_share / spec.worker_cpu).clamp(0.0, 1.0),
            (bd.bw_share / (spec.worker_bw * 4.0)).clamp(0.0, 1.0),
            bd.total_s,
        );

        // record series (strided cap)
        if self.cfg.record_series && run.stats.series[worker].len() < SERIES_CAP {
            run.stats.series[worker].push(bd);
        }

        run.wb.last_times[worker] = bd.total_s;
        self.engine.schedule_at(t + bd.total_s, Event::WorkerDone { job, worker, iter });
    }

    /// Fill the share epochs an imminent fan-out of `start_iteration`
    /// calls will query, across `cfg.prefill_threads` scoped workers
    /// (DESIGN.md §13). Eligibility mirrors `start_iteration` exactly
    /// (skip finished/busy/dead, query at `t.max(pause_until)`), so the
    /// collected keys are precisely the epochs the serial loop would
    /// fill lazily — `epoch_fills` is invariant and every artifact is
    /// byte-identical at any thread count. `members: None` means the
    /// whole worker set (initial placement).
    fn prefill_round(&mut self, job: usize, members: Option<&[usize]>, t: f64) {
        let threads = self.cfg.prefill_threads;
        if threads <= 1 {
            return;
        }
        let Some(run) = self.jobs[job].as_ref() else { return };
        if run.finished {
            return;
        }
        let t = t.max(run.pause_until);
        fn collect(run: &JobRun, cluster: &Cluster, keys: &mut Vec<(usize, Res)>, w: usize) {
            if run.wb.busy[w] || !run.wb.is_alive(w) {
                return;
            }
            let s = cluster.task(run.placement.worker_tasks[w]).server;
            keys.push((s, Res::Cpu));
            keys.push((s, Res::Bw));
        }
        self.prefill_keys.clear();
        match members {
            Some(ms) => {
                for &w in ms {
                    collect(run, &self.cluster, &mut self.prefill_keys, w);
                }
            }
            None => {
                for w in 0..run.job.workers {
                    collect(run, &self.cluster, &mut self.prefill_keys, w);
                }
            }
        }
        if self.prefill_keys.is_empty() {
            return; // nobody starts, so nothing gets queried
        }
        if matches!(self.cfg.arch, Arch::Ps) {
            // every starting worker's breakdown also sums the PS-side
            // bandwidth fan-in ([`itertime::breakdown`])
            for &tid in &run.placement.ps_tasks {
                let s = self.cluster.task(tid).server;
                self.prefill_keys.push((s, Res::Bw));
            }
        }
        let keys = std::mem::take(&mut self.prefill_keys);
        self.cluster.prefill_epochs(&keys, t, threads);
        self.prefill_keys = keys;
    }

    fn worker_done(&mut self, job: usize, worker: usize, iter: u64, t: f64) {
        {
            let Some(run) = self.jobs[job].as_mut() else { return };
            if run.finished || run.wb.iter_idx[worker] != iter {
                return; // stale event
            }
            run.wb.busy[worker] = false;
            run.wb.iter_idx[worker] += 1;
            run.stats.iters_total += 1;
            let dur = t - run.wb.iter_start[worker];
            let version = run.wb.param_version_at_start[worker];
            // AR ring: a removed worker's gradient that missed its round's
            // aggregation window is discarded (the ring has moved on).
            // The ring is chained over *live* workers only — dead members
            // are re-chained around per §IV-B's removed-straggler
            // machinery, so removal counts apply to the survivors.
            let mut dropped = false;
            if let DriverMode::Sync(SyncMode::ArRing { removed, .. }) = run.mode {
                if removed > 0 && run.wb.iter_start[worker] < run.last_ar_flush_t {
                    fill_predicted_safe(
                        &run.wb.predicted_times,
                        &run.wb.last_times,
                        &mut self.pt_scratch,
                    );
                    membership::ring_order_into(
                        run.wb.alive(),
                        &self.pt_scratch,
                        &mut self.order_scratch,
                    );
                    let (_, out) = membership::ring_split(&self.order_scratch, removed);
                    if out.contains(&worker) {
                        dropped = true;
                    }
                }
            }
            if !dropped {
                run.pending.push((worker, t, version));
            }
            run.reports_since_decision += 1;

            // straggler accounting for this iteration index; the minimum
            // per-worker index is the slab's reclamation watermark
            let flag_pred = run.wb.predicted_flags[worker];
            let min_iter = run.wb.iter_idx.iter().copied().min().unwrap_or(0);
            let t0 = if self.profile_on { Some(std::time::Instant::now()) } else { None };
            stats::record_report(
                &mut run.stats,
                &mut run.round_times,
                &mut run.wb.straggling,
                iter,
                min_iter,
                (worker, dur, flag_pred),
            );
            if let Some(t0) = t0 {
                self.profile.stats_s += t0.elapsed().as_secs_f64();
                self.profile.stats_calls += 1;
            }
        }

        // group into updates per current mode
        self.process_pending(job, t);

        // re-decide roughly once per round (of the *live* membership —
        // shrunken rounds still get their per-round decision cadence)
        let redecide = {
            let Some(run) = self.jobs[job].as_ref() else { return };
            let live = run.wb.live_count().max(1);
            !run.finished && run.reports_since_decision >= live
        };
        if redecide {
            self.decide(job, t);
            // the decision may have changed the grouping rule (or reset a
            // scheduled AR flush): re-evaluate pending reports so nobody
            // waits on a rule that no longer exists
            self.process_pending(job, t);
        }

        self.check_termination(job, t);

        // restart the worker if the grouping logic left it idle (it is not
        // in any pending set and not restarted by an update)
        let restart = {
            match self.jobs[job].as_ref() {
                Some(run) => {
                    !run.finished && !run.wb.busy[worker] && !waiting_in_pending(run, worker)
                }
                None => false,
            }
        };
        if restart {
            self.start_iteration(job, worker, t);
        }
    }

    /// Apply mode-specific grouping to pending reports at time `t`.
    ///
    /// All membership counts go through [`membership`] and are over the
    /// *live* workers (fault injection): an SSGD barrier shrinks when a
    /// member dies mid-iteration, x-order groups re-form over survivors,
    /// and the AR ring re-chains around dead workers. With no faults
    /// `live == n` and the grouping is bit-identical to the fault-free
    /// engine.
    fn process_pending(&mut self, job: usize, t: f64) {
        loop {
            let fired = {
                let Some(run) = self.jobs[job].as_ref() else { return };
                if run.finished || run.ps_down > 0 {
                    // a crashed PS holds all updates until it restarts
                    return;
                }
                membership::next_update_group_into(
                    &run.mode,
                    &run.pending,
                    run.wb.alive(),
                    &run.dyn_groups,
                    &mut self.group_scratch,
                )
            };
            if !fired {
                break;
            }
            // take the buffer around the re-entrant call; its capacity is
            // reused, so the loop still allocates nothing in steady state
            let members = std::mem::take(&mut self.group_scratch);
            self.fire_update(job, &members, t);
            self.group_scratch = members;
        }

        // AR-ring and first-K need scheduled/threshold handling
        let special = {
            let Some(run) = self.jobs[job].as_ref() else { return };
            run.mode
        };
        match special {
            DriverMode::Sync(SyncMode::ArRing { removed, tw_ms }) => {
                let Some(run) = self.jobs[job].as_mut() else { return };
                // the ring chains over live workers; dead members are
                // bypassed like removed stragglers (§IV-B)
                fill_predicted_safe(
                    &run.wb.predicted_times,
                    &run.wb.last_times,
                    &mut self.pt_scratch,
                );
                membership::ring_order_into(
                    run.wb.alive(),
                    &self.pt_scratch,
                    &mut self.order_scratch,
                );
                if self.order_scratch.is_empty() {
                    return;
                }
                let (ring, _) = membership::ring_split(&self.order_scratch, removed);
                let ring_reported =
                    ring.iter().all(|&w| run.pending.iter().any(|&(pw, _, _)| pw == w));
                if ring_reported && !run.ar_flush_scheduled {
                    run.ar_flush_scheduled = true;
                    self.engine.schedule_at(t + tw_ms / 1e3, Event::ArFlush { job });
                }
            }
            DriverMode::FirstK(k) => {
                let fire = {
                    let Some(run) = self.jobs[job].as_mut() else { return };
                    let live = run.wb.live_count();
                    self.arrival_scratch.clear();
                    self.arrival_scratch.extend(run.pending.iter().map(|&(w, _, _)| w));
                    let fired = membership::first_k_split_into(
                        &self.arrival_scratch,
                        k,
                        live,
                        &mut self.group_scratch,
                        &mut self.drop_scratch,
                    );
                    if fired {
                        // first K by arrival; later arrivals are dropped as
                        // they come (their pending entries are flushed)
                        let members = &self.group_scratch;
                        run.pending.retain(|&(w, _, _)| members.contains(&w));
                    }
                    fired
                };
                if fire {
                    let members = std::mem::take(&mut self.group_scratch);
                    self.fire_update(job, &members, t);
                    self.group_scratch = members;
                    // dropped workers restart immediately (their gradient
                    // is discarded)
                    let dropped = std::mem::take(&mut self.drop_scratch);
                    for &w in &dropped {
                        self.start_iteration(job, w, t);
                    }
                    self.drop_scratch = dropped;
                }
            }
            _ => {}
        }
    }

    fn ar_flush(&mut self, job: usize, t: f64) {
        let stale = {
            let Some(run) = self.jobs[job].as_ref() else { return };
            !run.finished && !run.ar_flush_scheduled
        };
        if stale {
            // the flush this event belonged to was cancelled by a mode
            // switch; re-evaluate so a new flush can be scheduled
            self.process_pending(job, t);
            return;
        }
        let fire = {
            let Some(run) = self.jobs[job].as_mut() else { return };
            if run.finished || !run.ar_flush_scheduled || run.ps_down > 0 {
                return;
            }
            run.ar_flush_scheduled = false;
            run.last_ar_flush_t = t;
            self.group_scratch.clear();
            self.group_scratch.extend(run.pending.iter().map(|&(w, _, _)| w));
            !self.group_scratch.is_empty()
        };
        if fire {
            let members = std::mem::take(&mut self.group_scratch);
            self.fire_update(job, &members, t);
            self.group_scratch = members;
        }
        self.check_termination(job, t);
    }

    /// Apply one parameter update from `members`' pending reports; frees
    /// those workers to start their next iteration at `t`.
    fn fire_update(&mut self, job: usize, members: &[usize], t: f64) {
        {
            let Some(run) = self.jobs[job].as_mut() else { return };
            let version_now = run.progress.step;
            let mut staleness_sum = 0.0;
            let mut found = 0usize;
            run.pending.retain(|&(w, _, v)| {
                if members.contains(&w) {
                    // saturating: a checkpoint rollback can revert the
                    // step counter below a report's read version
                    staleness_sum += version_now.saturating_sub(v) as f64;
                    found += 1;
                    false
                } else {
                    true
                }
            });
            debug_assert_eq!(found, members.len(), "members must be pending");
            let staleness = staleness_sum / members.len().max(1) as f64;
            let reports = members.len().max(1);
            // x_floor (Zeno++ validation filtering) improves converged
            // *quality* only — the statistical batch stays `reports`
            let mix_reports = ((run.x_floor * run.job.workers as f64).ceil() as usize)
                .max(reports)
                .min(run.job.workers);
            let value_before = run.progress.value();
            run.progress.apply_update_mix(reports, mix_reports, staleness, run.lr_rescaled);
            run.stats.updates += 1;
            let value_after = run.progress.value();

            // ML feedback: realized seconds per unit of value improvement
            let dv = (value_after - value_before).abs().max(1e-12);
            let span = run
                .wb
                .last_times
                .iter()
                .filter(|x| x.is_finite())
                .fold(0.0f64, |a, &b| a.max(b));
            let step = run.progress.step;
            run.policy.feedback(step, span / dv);

            if run.stats.tta_s.is_none() && run.progress.reached_target() {
                run.stats.tta_s = Some(t - run.started_at);
            }

            // periodic checkpoint: the PS-crash rollback target
            let every = self.cfg.faults.checkpoint_every_updates;
            if every > 0 && run.progress.step % every == 0 {
                run.checkpoint = run.progress.snapshot();
            }
        }

        self.prefill_round(job, Some(members), t);
        for &w in members {
            self.start_iteration(job, w, t);
        }
    }

    fn decide(&mut self, job: usize, t: f64) {
        // undo previously imposed deprivations — in place, through
        // disjoint field borrows (jobs vs cluster), so nothing is cloned
        // or reallocated
        {
            let Some(run) = self.jobs[job].as_mut() else { return };
            for &(task, cpu_cap, bw_cap) in &run.imposed {
                self.cluster.set_caps(task, cpu_cap, bw_cap);
            }
            run.imposed.clear();
        }

        let decision = {
            let run = self.jobs[job].as_mut().unwrap();
            run.reports_since_decision = 0;
            let spec = run.job.spec();
            fill_predicted_safe(&run.wb.predicted_times, &run.wb.last_times, &mut self.pt_scratch);
            run.wb.predicted_flags = crate::predict::straggler_flags(&self.pt_scratch);
            // a dead worker is not a straggler — it is outside the round
            // entirely until it restarts
            for w in 0..run.job.workers {
                if !run.wb.is_alive(w) {
                    run.wb.predicted_flags[w] = false;
                }
            }
            let obs = RoundObs {
                job,
                n: run.job.workers,
                arch: self.cfg.arch,
                spec,
                step: run.progress.step,
                progress: run.progress.progress,
                now: t,
                predicted_times: &self.pt_scratch,
                last_times: &run.wb.last_times,
                value: run.progress.value(),
                predicted_stragglers: &run.wb.predicted_flags,
                live: run.wb.alive(),
            };
            let t0 = if self.profile_on { Some(std::time::Instant::now()) } else { None };
            let d = run.policy.decide(&obs);
            if let Some(t0) = t0 {
                self.profile.decide_s += t0.elapsed().as_secs_f64();
                self.profile.decide_calls += 1;
            }
            d
        };

        let run = self.jobs[job].as_mut().unwrap();
        run.mode_just_switched = decision.mode != run.mode;
        if run.mode_just_switched {
            run.stats.mode_switches += 1;
            run.ar_flush_scheduled = false;
        }
        if matches!(decision.mode, DriverMode::Sync(SyncMode::DynamicX)) {
            // pt_scratch still holds this decision's predicted times
            let clusters = crate::sync::cluster_times(&self.pt_scratch, 0.15, 0.02);
            for (g, c) in clusters.iter().enumerate() {
                for &w in c {
                    run.dyn_groups[w] = g;
                }
            }
        }
        run.mode = decision.mode;
        run.lr_rescaled = decision.lr_rescaled;
        run.x_floor = decision.x_floor;
        if !decision.batch_frac.is_empty() {
            run.batch_frac = decision.batch_frac;
        }
        if let Some(tree) = decision.tree {
            run.tree = tree;
        }
        // the decision pause halts training only when it actually changes
        // the mode (an unchanged decision is absorbed by the running round)
        let effective_pause = if run.mode_just_switched && decision.pause_s > 0.0 {
            run.pause_until = t + decision.pause_s;
            decision.pause_s
        } else {
            0.0
        };
        run.stats.decision_pause_total_s += effective_pause;
        run.stats.decision_overhead_total_s += decision.overhead_s + effective_pause;
        run.stats.decision_count += 1;
        if run.stats.value_series.len() < 20_000 {
            run.stats.value_series.push((t - run.started_at, run.progress.value()));
        }

        // demand factors for the selected mode (O5). The placement
        // vectors are iterated in place (jobs and cluster are disjoint
        // fields) — the old per-decision clones of worker_tasks/ps_tasks/
        // self_caps/deprive are gone.
        let (fc, fb) = demand_factor(&run.mode, run.job.workers);
        let spec = run.job.spec();
        let (asgd_c, asgd_b) = (spec.asgd_cpu_factor, spec.asgd_bw_factor);
        let (base_wc, base_wb) = (spec.worker_cpu, spec.worker_bw);
        let (ps_fc, ps_fb) = (spec.ps_cpu_factor, spec.ps_bw_factor);
        for (w, &wt) in run.placement.worker_tasks.iter().enumerate() {
            self.cluster.set_demands(
                wt,
                base_wc * (1.0 + (asgd_c - 1.0) * (fc - 1.0)),
                base_wb * (1.0 + (asgd_b - 1.0) * (fb - 1.0)),
            );
            // §IV-D1 group equalization: fast members yield headroom
            let cap = decision.self_caps.get(w).copied().unwrap_or(1.0).clamp(0.05, 1.0);
            self.cluster.set_caps(wt, cap, cap);
        }
        for &pt in &run.placement.ps_tasks {
            self.cluster.set_demands(
                pt,
                base_wc * ps_fc * (1.0 + (asgd_c - 1.0) * (fc - 1.0)),
                base_wb * ps_fb * (1.0 + (asgd_b - 1.0) * (fb - 1.0)),
            );
        }

        // §IV-D1 deprivations requested by the policy
        for (task, frac) in decision.deprive {
            if task < self.cluster.task_count() && self.cluster.task(task).active {
                let old_c = self.cluster.task(task).cpu_cap;
                let old_b = self.cluster.task(task).bw_cap;
                run.imposed.push((task, old_c, old_b));
                self.cluster.set_caps(
                    task,
                    (old_c * frac).clamp(0.05, 1.0),
                    (old_b * frac).clamp(0.05, 1.0),
                );
            }
        }
    }

    fn check_termination(&mut self, job: usize, t: f64) {
        let done = {
            let Some(run) = self.jobs[job].as_mut() else { return };
            if run.finished {
                return;
            }
            let done = run.progress.converged_at(t - run.started_at)
                || run.stats.updates >= self.cfg.max_updates_per_job
                || run.stats.iters_total >= self.cfg.max_iters_per_job
                || (t - run.started_at) >= self.cfg.max_job_duration_s;
            if done {
                run.finished = true;
                run.stats.end_s = t;
                run.stats.jct_s = t - run.started_at;
                run.stats.converged_value = run.progress.value();
                // close out downtime for workers/PSs still dead at the end
                for w in 0..run.job.workers {
                    if !run.wb.is_alive(w) && run.wb.down_since[w].is_finite() {
                        run.stats.downtime_s += t - run.wb.down_since[w];
                        run.wb.down_since[w] = f64::NAN;
                    }
                }
                if run.ps_down > 0 && run.ps_down_since.is_finite() {
                    run.stats.downtime_s += t - run.ps_down_since;
                    run.ps_down_since = f64::NAN;
                }
            }
            done
        };
        if !done {
            return;
        }
        let run = *self.jobs[job].take().unwrap();
        for &tid in run.placement.worker_tasks.iter().chain(&run.placement.ps_tasks) {
            self.cluster.remove_task(tid);
        }
        for (task, c, b) in run.imposed {
            self.cluster.set_caps(task, c, b);
        }
        self.jobs_done += 1;
        // streaming mode folds into the bounded aggregate instead of
        // growing `finished` with the trace (DESIGN.md §12)
        if let Some(agg) = self.stream.as_mut() {
            agg.fold(&run.stats);
        } else {
            self.finished.push(run.stats);
        }
        // admit queued jobs
        let queue = std::mem::take(&mut self.wait_queue);
        for j in queue {
            self.try_place(j, t);
        }
    }
}

/// Fill `out` with NaN-safe predicted iteration times: the prediction if
/// finite, else the last measured time, else 0.5 s (bootstrap). The
/// allocation-free replacement for the old `JobRun::predicted_times_safe`
/// (which built a fresh `Vec` on every AR-drop check and decision).
fn fill_predicted_safe(predicted: &[f64], last: &[f64], out: &mut Vec<f64>) {
    out.clear();
    out.extend(predicted.iter().zip(last).map(|(&p, &l)| {
        if p.is_finite() {
            p
        } else if l.is_finite() {
            l
        } else {
            0.5
        }
    }));
}

fn waiting_in_pending(run: &JobRun, worker: usize) -> bool {
    run.pending.iter().any(|&(w, _, _)| w == worker)
}

/// AR(1) resource fallback predictor (stateless).
struct ArFallback;

impl ResourcePredictor for ArFallback {
    fn predict(&mut self, h: &History) -> (f64, f64) {
        crate::predict::ArPredictor.predict(h)
    }
}

/// Demand multipliers (cpu, bw) in [1, asgd_factor] interpolated by how
/// asynchronous the mode is: SSGD = 1, ASGD = full factor (O5), x-order
/// scales with the number of update groups per round.
pub fn demand_factor(mode: &DriverMode, n: usize) -> (f64, f64) {
    let groups = match mode {
        DriverMode::Sync(SyncMode::Ssgd) => 1.0,
        DriverMode::Sync(SyncMode::Asgd) => n as f64,
        DriverMode::Sync(SyncMode::StaticX(x)) => (n as f64 / *x as f64).max(1.0),
        DriverMode::Sync(SyncMode::DynamicX) => 2.0, // typical cluster count
        DriverMode::Sync(SyncMode::ArRing { .. }) => 1.2,
        DriverMode::FirstK(k) => (n as f64 / *k as f64).max(1.0),
    };
    // dampened: partial modes sit well below full-ASGD consumption (the
    // PS still batches most traffic); full ASGD keeps the O5 factor
    let f = if n > 1 { (groups - 1.0) / (n as f64 - 1.0) } else { 0.0 };
    let f = if f >= 0.999 { 1.0 } else { 0.5 * f };
    (1.0 + f, 1.0 + f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{Fault, PlannedFault};
    use crate::trace::TraceConfig;

    /// Trivial fixed-mode policy for driver tests.
    struct Always(DriverMode, &'static str);

    impl Policy for Always {
        fn name(&self) -> &'static str {
            self.1
        }

        fn decide(&mut self, _obs: &RoundObs) -> PolicyDecision {
            let mut d = PolicyDecision::simple(self.0);
            d.lr_rescaled = true;
            d
        }
    }

    fn tiny_trace(n_jobs: usize) -> Vec<JobSpec> {
        let cfg = TraceConfig { jobs: n_jobs, span_s: 100.0, ..Default::default() };
        crate::trace::generate(&cfg)
    }

    fn run_with(mode: DriverMode, n_jobs: usize) -> Vec<JobStats> {
        let cfg = DriverConfig {
            max_updates_per_job: 4000,
            max_iters_per_job: 8000,
            max_job_duration_s: 8000.0,
            ..Default::default()
        };
        let driver = Driver::new(
            cfg,
            tiny_trace(n_jobs),
            Box::new(move |_| Box::new(Always(mode, "test")) as Box<dyn Policy>),
        );
        let (stats, _) = driver.run();
        stats
    }

    #[test]
    fn driver_is_send() {
        // the sweep harness builds one driver per worker thread; a non-
        // Send field sneaking into the run cell must fail to compile here
        fn is_send<T: Send>() {}
        is_send::<Driver>();
        is_send::<PolicyFactory>();
    }

    #[test]
    fn ssgd_jobs_complete_and_progress() {
        let stats = run_with(DriverMode::Sync(SyncMode::Ssgd), 3);
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert!(s.updates > 0, "job {} made no updates", s.job);
            assert!(s.jct_s > 0.0);
            if !s.is_nlp {
                assert!(s.converged_value > 40.0, "acc {}", s.converged_value);
            }
        }
    }

    #[test]
    fn asgd_more_updates_per_iteration_than_ssgd() {
        let a = run_with(DriverMode::Sync(SyncMode::Asgd), 2);
        let s = run_with(DriverMode::Sync(SyncMode::Ssgd), 2);
        let a_ratio: f64 =
            a.iter().map(|x| x.updates as f64 / x.iters_total.max(1) as f64).sum::<f64>();
        let s_ratio: f64 =
            s.iter().map(|x| x.updates as f64 / x.iters_total.max(1) as f64).sum::<f64>();
        assert!(a_ratio > 2.0 * s_ratio, "{a_ratio} vs {s_ratio}");
    }

    #[test]
    fn all_modes_run_to_completion() {
        for mode in [
            DriverMode::Sync(SyncMode::StaticX(2)),
            DriverMode::Sync(SyncMode::DynamicX),
            DriverMode::Sync(SyncMode::ArRing { removed: 1, tw_ms: 60.0 }),
            DriverMode::FirstK(3),
        ] {
            let stats = run_with(mode, 2);
            assert_eq!(stats.len(), 2, "{mode:?}");
            for s in &stats {
                assert!(s.updates > 0, "{mode:?}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_with(DriverMode::Sync(SyncMode::Ssgd), 2);
        let b = run_with(DriverMode::Sync(SyncMode::Ssgd), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.jct_s, y.jct_s);
            assert_eq!(x.updates, y.updates);
            assert_eq!(x.straggler_iters, y.straggler_iters);
        }
    }

    #[test]
    fn run_metrics_report_events_and_queue_depth() {
        let mk = |profile: bool| {
            let cfg = DriverConfig {
                max_updates_per_job: 500,
                max_iters_per_job: 2000,
                max_job_duration_s: 4000.0,
                profile,
                ..Default::default()
            };
            Driver::new(
                cfg,
                tiny_trace(2),
                Box::new(|_| {
                    Box::new(Always(DriverMode::Sync(SyncMode::Ssgd), "t")) as Box<dyn Policy>
                }),
            )
        };
        let (stats, _, m) = mk(false).run_instrumented();
        assert_eq!(stats.len(), 2);
        assert!(m.events > 0);
        assert!(m.peak_queue_depth > 0);
        assert!(m.wall_s > 0.0);
        assert!(m.events_per_sec() > 0.0);
        assert!(m.epoch_fills > 0, "a run must fill share epochs");
        // profiling off: no timers accumulate
        assert_eq!(m.profile.dispatch_s, 0.0);
        assert_eq!(m.profile.decide_calls, 0);
        assert_eq!(m.fill_wall_s, 0.0, "fill timing off unless profile/fill_timing");

        // profiling on: phases accumulate, sub-phases nest under dispatch,
        // and the trace itself is unchanged (instrumentation only reads
        // clocks)
        let (stats_p, _, mp) = mk(true).run_instrumented();
        assert_eq!(mp.events, m.events, "profiling must not perturb the trace");
        for (a, b) in stats.iter().zip(&stats_p) {
            assert_eq!(a.jct_s, b.jct_s);
            assert_eq!(a.updates, b.updates);
        }
        assert!(mp.profile.dispatch_s > 0.0);
        assert!(mp.profile.itertime_calls > 0);
        assert!(mp.profile.decide_calls > 0);
        assert!(mp.profile.stats_calls > 0);
        let subs = mp.profile.itertime_s + mp.profile.decide_s + mp.profile.stats_s;
        assert!(
            subs <= mp.profile.dispatch_s + 1e-6,
            "sub-phases ({subs}) must nest inside dispatch ({})",
            mp.profile.dispatch_s
        );
        // profiling also turns on fill timing, and the fill count is an
        // artifact of the trace, not the instrumentation
        assert_eq!(mp.epoch_fills, m.epoch_fills);
        assert!(mp.fill_wall_s > 0.0, "profile mode must time fills");
        assert!(
            mp.fill_wall_s <= mp.profile.itertime_s + 1e-6,
            "fills ({}) happen inside the itertime phase ({})",
            mp.fill_wall_s,
            mp.profile.itertime_s
        );
    }

    /// Driver-level thread-count invariance (DESIGN.md §13): the same
    /// trace with `prefill_threads` 1 (prefill disabled, the legacy
    /// query-path fills) and 4 (parallel prefill before every round)
    /// must produce identical stats, event counts, and fill counts.
    #[test]
    fn prefill_threads_do_not_perturb_the_trace() {
        let mk = |prefill_threads: usize| {
            let cfg = DriverConfig {
                max_updates_per_job: 500,
                max_iters_per_job: 2000,
                max_job_duration_s: 4000.0,
                prefill_threads,
                ..Default::default()
            };
            Driver::new(
                cfg,
                tiny_trace(3),
                Box::new(|_| {
                    Box::new(Always(DriverMode::Sync(SyncMode::Ssgd), "t")) as Box<dyn Policy>
                }),
            )
        };
        let (stats1, _, m1) = mk(1).run_instrumented();
        let (stats4, _, m4) = mk(4).run_instrumented();
        assert_eq!(m1.events, m4.events, "event count must be invariant");
        assert_eq!(m1.epoch_fills, m4.epoch_fills, "fill count must be invariant");
        assert_eq!(stats1.len(), stats4.len());
        for (a, b) in stats1.iter().zip(&stats4) {
            assert_eq!(a.jct_s, b.jct_s);
            assert_eq!(a.tta_s, b.tta_s);
            assert_eq!(a.updates, b.updates);
            assert_eq!(a.iters_total, b.iters_total);
            assert_eq!(a.straggler_iters, b.straggler_iters);
            // per-iteration breakdowns: the rawest observable of the
            // share path, compared bit-for-bit
            assert_eq!(a.series.len(), b.series.len());
            for (sw, dw) in a.series.iter().zip(&b.series) {
                assert_eq!(sw.len(), dw.len());
                for (si, di) in sw.iter().zip(dw) {
                    assert_eq!(si.total_s, di.total_s);
                    assert_eq!(si.cpu_share, di.cpu_share);
                    assert_eq!(si.bw_share, di.bw_share);
                }
            }
        }
    }

    #[test]
    fn stragglers_exist_under_contention() {
        let stats = run_with(DriverMode::Sync(SyncMode::Ssgd), 6);
        let total: u64 = stats.iter().map(|s| s.straggler_iters).sum();
        assert!(total > 0, "contention must generate stragglers");
    }

    #[test]
    fn tta_before_jct_when_reached() {
        let stats = run_with(DriverMode::Sync(SyncMode::Ssgd), 3);
        for s in &stats {
            if let Some(tta) = s.tta_s {
                assert!(tta <= s.jct_s + 1e-6);
            }
        }
    }

    #[test]
    fn series_recorded_and_bounded() {
        let stats = run_with(DriverMode::Sync(SyncMode::Ssgd), 2);
        for s in &stats {
            assert!(!s.series.is_empty());
            let mut any = false;
            for w in &s.series {
                assert!(w.len() <= SERIES_CAP);
                for it in w {
                    assert!(it.total_s > 0.0);
                    assert!(it.comm_s >= 0.0 && it.pre_s >= 0.0);
                    any = true;
                }
            }
            assert!(any);
        }
    }

    #[test]
    fn demand_factor_interpolates() {
        assert_eq!(demand_factor(&DriverMode::Sync(SyncMode::Ssgd), 8), (1.0, 1.0));
        let (c, b) = demand_factor(&DriverMode::Sync(SyncMode::Asgd), 8);
        assert_eq!((c, b), (2.0, 2.0));
        let (c2, _) = demand_factor(&DriverMode::Sync(SyncMode::StaticX(4)), 8);
        assert!(c2 > 1.0 && c2 < c);
    }

    #[test]
    fn demand_factor_edge_cases() {
        // n = 1: no mode can be asynchronous with a single worker — every
        // factor collapses to SSGD-like demand
        assert_eq!(demand_factor(&DriverMode::Sync(SyncMode::Asgd), 1), (1.0, 1.0));
        assert_eq!(demand_factor(&DriverMode::Sync(SyncMode::Ssgd), 1), (1.0, 1.0));
        assert_eq!(demand_factor(&DriverMode::Sync(SyncMode::DynamicX), 1), (1.0, 1.0));
        assert_eq!(demand_factor(&DriverMode::FirstK(1), 1), (1.0, 1.0));
        // FirstK with k ≥ n is one group per round, i.e. SSGD-like
        assert_eq!(demand_factor(&DriverMode::FirstK(8), 8), (1.0, 1.0));
        // degenerate k = 0 saturates to the full-ASGD factor instead of
        // dividing by zero (k = 0 is unreachable from the policies, which
        // clamp K to the live count ≥ 1 — pinned here as documentation)
        assert_eq!(demand_factor(&DriverMode::FirstK(0), 8), (2.0, 2.0));
    }

    #[test]
    fn driver_mode_names_are_static() {
        // name() is allocation-free for hot logging/stats paths…
        assert_eq!(DriverMode::Sync(SyncMode::Ssgd).name(), "SSGD");
        assert_eq!(DriverMode::Sync(SyncMode::Asgd).name(), "ASGD");
        assert_eq!(DriverMode::Sync(SyncMode::StaticX(3)).name(), "static-x");
        assert_eq!(DriverMode::Sync(SyncMode::DynamicX).name(), "dynamic-x");
        assert_eq!(
            DriverMode::Sync(SyncMode::ArRing { removed: 1, tw_ms: 60.0 }).name(),
            "ring"
        );
        assert_eq!(DriverMode::FirstK(5).name(), "first-K");
        // …while describe() keeps the parameterized form
        assert_eq!(DriverMode::FirstK(5).describe(), "first-5");
        assert_eq!(DriverMode::Sync(SyncMode::StaticX(3)).describe(), "3-order");
    }

    #[test]
    fn queueing_admits_jobs_later() {
        // 12 jobs over a tiny arrival window exceed the 40-GPU cluster;
        // all must still finish via the wait queue
        let stats = run_with(DriverMode::Sync(SyncMode::Ssgd), 12);
        assert_eq!(stats.len(), 12);
    }

    fn plan_of(faults: Vec<PlannedFault>) -> FaultPlan {
        FaultPlan { faults, checkpoint_every_updates: 50 }
    }

    fn run_with_faults(
        mode: DriverMode,
        n_jobs: usize,
        faults: Vec<PlannedFault>,
    ) -> Vec<JobStats> {
        let cfg = DriverConfig {
            max_updates_per_job: 4000,
            max_iters_per_job: 8000,
            max_job_duration_s: 8000.0,
            faults: plan_of(faults),
            ..Default::default()
        };
        let driver = Driver::new(
            cfg,
            tiny_trace(n_jobs),
            Box::new(move |_| Box::new(Always(mode, "test")) as Box<dyn Policy>),
        );
        let (stats, _) = driver.run();
        stats
    }

    #[test]
    fn worker_crash_shrinks_barrier_and_job_completes() {
        // crash worker 0 of every job early, restart 300 s later: SSGD
        // must keep firing (shrunken barrier) and every job still finishes
        // t=150: every job has arrived (the tiny trace spans 100 s)
        let faults: Vec<PlannedFault> = (0..3)
            .map(|j| PlannedFault {
                at: 150.0 + j as f64,
                fault: Fault::WorkerCrash { job: j, rank: 0, restart_s: 300.0 },
            })
            .collect();
        let stats = run_with_faults(DriverMode::Sync(SyncMode::Ssgd), 3, faults);
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert!(s.updates > 0, "job {} made no updates", s.job);
            assert!(s.downtime_s > 0.0, "crash must accrue downtime");
        }
    }

    #[test]
    fn ps_crash_rolls_back_and_inflates_jct() {
        let clean = run_with(DriverMode::Sync(SyncMode::Ssgd), 2);
        let faults: Vec<PlannedFault> = (0..2)
            .flat_map(|j| {
                (1..6).map(move |k| PlannedFault {
                    at: 250.0 * k as f64 + j as f64,
                    fault: Fault::PsCrash { job: j, idx: 0, restart_s: 60.0 },
                })
            })
            .collect();
        let faulted = run_with_faults(DriverMode::Sync(SyncMode::Ssgd), 2, faults);
        let jct = |v: &[JobStats]| v.iter().map(|s| s.jct_s).sum::<f64>();
        assert!(
            jct(&faulted) > jct(&clean),
            "rollbacks must inflate JCT: {} !> {}",
            jct(&faulted),
            jct(&clean)
        );
        let rollbacks: u64 = faulted.iter().map(|s| s.rollbacks).sum();
        assert!(rollbacks > 0, "PS crashes must register rollbacks");
    }

    #[test]
    fn all_modes_survive_faults() {
        for mode in [
            DriverMode::Sync(SyncMode::Ssgd),
            DriverMode::Sync(SyncMode::Asgd),
            DriverMode::Sync(SyncMode::StaticX(2)),
            DriverMode::Sync(SyncMode::DynamicX),
            DriverMode::Sync(SyncMode::ArRing { removed: 1, tw_ms: 60.0 }),
            DriverMode::FirstK(3),
        ] {
            let faults = vec![
                PlannedFault {
                    at: 60.0,
                    fault: Fault::WorkerCrash { job: 0, rank: 1, restart_s: 120.0 },
                },
                PlannedFault {
                    at: 200.0,
                    fault: Fault::PsCrash { job: 0, idx: 0, restart_s: 45.0 },
                },
                PlannedFault {
                    at: 400.0,
                    fault: Fault::ServerOutage { server: 0, dur_s: 90.0, restart_s: 30.0 },
                },
                PlannedFault {
                    at: 600.0,
                    fault: Fault::Degradation {
                        server: 1,
                        dur_s: 120.0,
                        cpu_frac: 0.5,
                        bw_frac: 0.5,
                    },
                },
            ];
            let stats = run_with_faults(mode, 2, faults);
            assert_eq!(stats.len(), 2, "{mode:?}");
            for s in &stats {
                assert!(s.updates > 0, "{mode:?}: no updates under faults");
            }
        }
    }

    #[test]
    fn outage_extends_restart_of_already_down_workers() {
        // 4 workers on GPU server 0 (PS on a CPU server, unaffected).
        // Worker 0 crashes at t=150 (restart due 250); a server outage at
        // t=200 (300 s + 30 s restart) must pull it into the outage —
        // everyone returns at 530, and the stale restart at 250 is void.
        let spec = JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: 0,
            workers: 4,
            ps_count: 1,
            ps_on_gpu_servers: false,
        };
        let faults = vec![
            PlannedFault {
                at: 150.0,
                fault: Fault::WorkerCrash { job: 0, rank: 0, restart_s: 100.0 },
            },
            PlannedFault {
                at: 200.0,
                fault: Fault::ServerOutage { server: 0, dur_s: 300.0, restart_s: 30.0 },
            },
        ];
        let cfg = DriverConfig {
            max_updates_per_job: 4000,
            max_iters_per_job: 8000,
            max_job_duration_s: 8000.0,
            faults: plan_of(faults),
            ..Default::default()
        };
        let driver = Driver::new(
            cfg,
            vec![spec],
            Box::new(|_| {
                Box::new(Always(DriverMode::Sync(SyncMode::Ssgd), "test")) as Box<dyn Policy>
            }),
        );
        let (stats, _) = driver.run();
        // worker 0: 150→530 (380 s); workers 1–3: 200→530 (330 s each)
        let want = 380.0 + 3.0 * 330.0;
        assert!(
            (stats[0].downtime_s - want).abs() < 1e-6,
            "downtime {} != {want} (outage must extend the earlier crash)",
            stats[0].downtime_s
        );
    }

    #[test]
    fn faulted_replay_is_deterministic() {
        let faults = || {
            vec![
                PlannedFault {
                    at: 50.0,
                    fault: Fault::WorkerCrash { job: 0, rank: 0, restart_s: 150.0 },
                },
                PlannedFault {
                    at: 300.0,
                    fault: Fault::PsCrash { job: 1, idx: 0, restart_s: 40.0 },
                },
                PlannedFault {
                    at: 500.0,
                    fault: Fault::ServerOutage { server: 0, dur_s: 60.0, restart_s: 20.0 },
                },
            ]
        };
        let a = run_with_faults(DriverMode::Sync(SyncMode::Ssgd), 2, faults());
        let b = run_with_faults(DriverMode::Sync(SyncMode::Ssgd), 2, faults());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.jct_s, y.jct_s);
            assert_eq!(x.updates, y.updates);
            assert_eq!(x.downtime_s, y.downtime_s);
            assert_eq!(x.rollbacks, y.rollbacks);
        }
    }

    #[test]
    fn fault_events_on_finished_or_unknown_jobs_are_ignored() {
        let faults = vec![
            // job id beyond the trace
            PlannedFault {
                at: 10.0,
                fault: Fault::WorkerCrash { job: 99, rank: 0, restart_s: 10.0 },
            },
            // rank beyond the job's workers
            PlannedFault {
                at: 20.0,
                fault: Fault::WorkerCrash { job: 0, rank: 99, restart_s: 10.0 },
            },
            // far past every job's completion
            PlannedFault {
                at: 1e7,
                fault: Fault::PsCrash { job: 0, idx: 0, restart_s: 10.0 },
            },
        ];
        let stats = run_with_faults(DriverMode::Sync(SyncMode::Ssgd), 2, faults);
        assert_eq!(stats.len(), 2);
        for s in &stats {
            assert_eq!(s.downtime_s, 0.0);
            assert_eq!(s.rollbacks, 0);
        }
    }

    #[test]
    fn server_sampling_produces_records() {
        let cfg = DriverConfig {
            max_updates_per_job: 300,
            max_iters_per_job: 2000,
            max_job_duration_s: 4000.0,
            server_sample_period_s: 50.0,
            ..Default::default()
        };
        let driver = Driver::new(
            cfg,
            tiny_trace(2),
            Box::new(|_| Box::new(Always(DriverMode::Sync(SyncMode::Ssgd), "t"))),
        );
        let (_, records) = driver.run();
        assert!(!records.is_empty());
        for r in &records {
            assert!((0.0..=1.0).contains(&r.cpu_util));
            assert!((0.0..=1.0).contains(&r.bw_util));
        }
    }
}
