//! §2.2 iteration-time composition: one worker's iteration duration from
//! its resource shares at the iteration's start.
//!
//! * preprocess ∝ `worker_cpu / cpu_share` (full-demand cost scaled by
//!   the granted CPU),
//! * GPU compute constant per model — homogeneous GPUs — with ±2%
//!   jitter drawn from the driver's RNG stream,
//! * communication ∝ `bytes / min(worker link, PS fan-in / flows)` with
//!   the §IV-D2b tree's hop penalty (PS), or `bytes / link` (AR).
//!
//! The function mutates `cluster` (share-epoch fills) and `rng` (GPU
//! jitter) in exactly the order the monolithic driver did, so replays
//! stay bit-identical across the refactor.
//!
//! ## The prefill contract (DESIGN.md §13)
//!
//! The share queries below — the worker's (CPU, BW) pair plus, under the
//! PS architecture, every PS task's BW share — define the epoch key set
//! of one composition. `Driver::prefill_round` collects exactly these
//! keys for every worker that will start in an imminent round and fills
//! them through [`Cluster::prefill_epochs`] *before* the serial
//! composition loop runs. Because an epoch fill draws only from
//! per-server deterministic streams (never from the driver `rng` passed
//! here), pre-filling changes neither this function's inputs nor any RNG
//! draw — the jitter stream is consumed in the same loop, in the same
//! order, whether the epochs were filled eagerly, in parallel, or
//! lazily by the `worker_shares` call below.

use crate::cluster::{Cluster, TaskId};
use crate::models::ModelSpec;
use crate::prevent::CommTree;
use crate::simrng::Rng;
use crate::trace::Arch;

use super::stats::IterBreakdown;

/// Immutable inputs of one composition: the job's architecture, model
/// spec, installed communication tree, the worker/PS task handles, and
/// the worker's current batch fraction (LB-BSP resizing).
pub struct IterInputs<'a> {
    pub arch: Arch,
    pub spec: &'static ModelSpec,
    pub tree: &'a CommTree,
    pub worker_task: TaskId,
    pub ps_tasks: &'a [TaskId],
    pub batch_frac: f64,
}

/// Compose one worker's iteration breakdown from cluster state at `t`.
///
/// Share queries are batched through the cluster's epoch cache: the
/// worker's CPU+BW pair and the PS fan-in sum cost one water-fill per
/// (server, resource) per simulated instant, no matter how many workers
/// start an iteration at that instant (SSGD rounds start a whole group
/// at once).
pub fn breakdown(cluster: &mut Cluster, rng: &mut Rng, inp: &IterInputs, t: f64) -> IterBreakdown {
    let spec = inp.spec;
    let bf = inp.batch_frac;
    let (cpu_share, bw_share) = cluster.worker_shares(inp.worker_task, t);
    let cpu_share = cpu_share.max(1e-3);
    let bw_share = bw_share.max(1e-3);

    // preprocess: pre_cpu_ms at full demand share, scaled by granted CPU
    let pre_s = spec.pre_cpu_ms / 1000.0 * bf * (spec.worker_cpu / cpu_share);
    // GPU compute: constant per model (homogeneous GPUs), mild jitter
    let gpu_s = spec.gpu_ms / 1000.0 * bf * rng.range(0.98, 1.02);

    // communication: min(worker link, PS-side aggregate / direct flows)
    let gbits = 2.0 * spec.grad_mb * 8.0 / 1000.0;
    let comm_s = match inp.arch {
        Arch::Ps => {
            let ps_share: f64 = cluster.bw_share_sum(inp.ps_tasks, t).max(1e-3);
            let flows = inp.tree.effective_flows() as f64;
            let eff = bw_share.min(ps_share / flows);
            gbits / eff * inp.tree.hop_penalty(0.03)
        }
        Arch::AllReduce => gbits / bw_share,
    };
    let total = pre_s + gpu_s + comm_s;
    IterBreakdown { pre_s, gpu_s, comm_s, total_s: total, cpu_share, bw_share }
}
