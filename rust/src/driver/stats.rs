//! Per-job outcome records and their accumulation: [`IterBreakdown`]
//! rows, [`JobStats`], [`ServerRecord`] samples, and the §II straggler
//! accounting over completed iteration indices.
//!
//! This layer is write-only bookkeeping — nothing here feeds back into
//! scheduling decisions, so moving a stat cannot change a trace.

use std::collections::BTreeMap;

use crate::predict::{Confusion, STRAGGLER_DEV};

/// Per-iteration measured breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct IterBreakdown {
    pub pre_s: f64,
    pub gpu_s: f64,
    pub comm_s: f64,
    pub total_s: f64,
    pub cpu_share: f64,
    pub bw_share: f64,
}

/// Recorded per-job outcome.
#[derive(Clone, Debug)]
pub struct JobStats {
    pub job: usize,
    pub model: usize,
    pub workers: usize,
    pub system: String,
    pub arrival_s: f64,
    pub start_s: f64,
    pub end_s: f64,
    pub tta_s: Option<f64>,
    pub jct_s: f64,
    pub converged_value: f64,
    pub is_nlp: bool,
    pub updates: u64,
    pub iters_total: u64,
    pub straggler_iters: u64,
    pub straggler_episodes: u64,
    pub decision_pause_total_s: f64,
    pub decision_overhead_total_s: f64,
    pub decision_count: u64,
    pub prediction: Confusion,
    /// sampled per-iteration series per worker (bounded by `SERIES_CAP`)
    pub series: Vec<Vec<IterBreakdown>>,
    /// (sim time since job start, value) samples taken at decision points
    pub value_series: Vec<(f64, f64)>,
    pub mode_switches: u64,
    /// total seconds the job's workers spent dead (summed per worker)
    /// plus PS-restart stalls (fault injection)
    pub downtime_s: f64,
    /// checkpoint rollbacks suffered (PS crashes / server outages)
    pub rollbacks: u64,
}

/// Cap on recorded iteration rows per worker (sampled with stride).
pub const SERIES_CAP: usize = 500;

/// A server-utilization record (Fig 9 / Fig 10 evidence).
#[derive(Clone, Copy, Debug)]
pub struct ServerRecord {
    pub time: f64,
    pub server: usize,
    pub ps_hosted: usize,
    pub cpu_util: f64,
    pub bw_util: f64,
}

/// Record one completed iteration into the per-index straggler
/// accounting. When every worker's duration for `iter` is in, the row is
/// scored against the §II deviation-ratio threshold: prediction confusion
/// updates, straggler iterations count, and episode boundaries are
/// tracked through `straggling` (one flag per worker, `len == n`).
pub(crate) fn record_report(
    stats: &mut JobStats,
    round_times: &mut BTreeMap<u64, Vec<(usize, f64, bool)>>,
    straggling: &mut [bool],
    iter: u64,
    worker: usize,
    dur: f64,
    flag_pred: bool,
) {
    round_times.entry(iter).or_default().push((worker, dur, flag_pred));
    let n = straggling.len();
    if round_times.get(&iter).map(|v| v.len()) == Some(n) {
        let row = round_times.remove(&iter).unwrap();
        let min = row.iter().map(|&(_, d, _)| d).fold(f64::INFINITY, f64::min).max(1e-9);
        for &(w, d, pred) in &row {
            let is_straggler = (d - min) / min > STRAGGLER_DEV;
            stats.prediction.add(pred, is_straggler);
            if is_straggler {
                stats.straggler_iters += 1;
                if !straggling[w] {
                    stats.straggler_episodes += 1;
                    straggling[w] = true;
                }
            } else {
                straggling[w] = false;
            }
        }
    }
}
