//! Per-job outcome records and their accumulation: [`IterBreakdown`]
//! rows, [`JobStats`], [`ServerRecord`] samples, and the §II straggler
//! accounting over completed iteration indices.
//!
//! This layer is write-only bookkeeping — nothing here feeds back into
//! scheduling decisions, so moving a stat cannot change a trace.

use crate::predict::{Confusion, STRAGGLER_DEV};

/// Per-iteration measured breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct IterBreakdown {
    pub pre_s: f64,
    pub gpu_s: f64,
    pub comm_s: f64,
    pub total_s: f64,
    pub cpu_share: f64,
    pub bw_share: f64,
}

/// Recorded per-job outcome.
#[derive(Clone, Debug)]
pub struct JobStats {
    pub job: usize,
    pub model: usize,
    pub workers: usize,
    pub system: String,
    pub arrival_s: f64,
    pub start_s: f64,
    pub end_s: f64,
    pub tta_s: Option<f64>,
    pub jct_s: f64,
    pub converged_value: f64,
    pub is_nlp: bool,
    pub updates: u64,
    pub iters_total: u64,
    pub straggler_iters: u64,
    pub straggler_episodes: u64,
    pub decision_pause_total_s: f64,
    pub decision_overhead_total_s: f64,
    pub decision_count: u64,
    pub prediction: Confusion,
    /// sampled per-iteration series per worker (bounded by `SERIES_CAP`)
    pub series: Vec<Vec<IterBreakdown>>,
    /// (sim time since job start, value) samples taken at decision points
    pub value_series: Vec<(f64, f64)>,
    pub mode_switches: u64,
    /// total seconds the job's workers spent dead (summed per worker)
    /// plus PS-restart stalls (fault injection)
    pub downtime_s: f64,
    /// checkpoint rollbacks suffered (PS crashes / server outages)
    pub rollbacks: u64,
}

/// Cap on recorded iteration rows per worker (sampled with stride).
pub const SERIES_CAP: usize = 500;

/// A server-utilization record (Fig 9 / Fig 10 evidence).
#[derive(Clone, Copy, Debug)]
pub struct ServerRecord {
    pub time: f64,
    pub server: usize,
    pub ps_hosted: usize,
    pub cpu_util: f64,
    pub bw_util: f64,
}

/// Per-iteration-index round state: a ring-indexed slab keyed on round
/// offset (DESIGN.md §3), replacing the old `BTreeMap<u64, Vec<…>>`.
///
/// Iteration indices arrive from a narrow sliding window — each worker
/// walks its own index counter forward by one — so the live rows fit a
/// power-of-two ring addressed by `iter & mask`. `base` trails the
/// slowest worker's counter: the driver passes its current minimum and
/// the ring reclaims every slot behind it. Completed rows flip a
/// `present` bit and keep their entry buffers, so steady-state recording
/// allocates nothing; crash-skipped indices are [`RoundSlab::mark_dead`]
/// so a row that can never complete (the old map kept it forever) is
/// dropped instead of pinning the ring.
#[derive(Clone, Debug, Default)]
pub(crate) struct RoundSlab {
    /// lowest iteration index the ring can still hold a row for
    base: u64,
    /// power-of-two ring; a row for `iter` lives at `iter & (len - 1)`
    rows: Vec<RoundRow>,
    /// indices ≥ `base` that can never complete (a crash skipped them);
    /// reports for them are discarded, exactly as the map's leaked rows
    /// were never scored
    dead: Vec<u64>,
}

#[derive(Clone, Debug, Default)]
struct RoundRow {
    iter: u64,
    present: bool,
    entries: Vec<(usize, f64, bool)>,
}

impl RoundSlab {
    /// Record one `(worker, duration, predicted-flag)` report for `iter`.
    /// Returns the completed row's entries (in arrival order, exactly as
    /// the map accumulated them) once all `n` workers have reported, else
    /// `None`. `min_iter` is the caller's current minimum per-worker
    /// iteration index — the watermark below which no further report can
    /// arrive.
    fn record(
        &mut self,
        iter: u64,
        report: (usize, f64, bool),
        n: usize,
        min_iter: u64,
    ) -> Option<&[(usize, f64, bool)]> {
        if self.dead.contains(&iter) {
            return None;
        }
        // the row being filled right now must stay addressable
        self.advance(min_iter.min(iter));
        self.ensure_capacity(iter);
        let mask = self.rows.len() as u64 - 1;
        let slot = (iter & mask) as usize;
        let row = &mut self.rows[slot];
        if !row.present {
            row.present = true;
            row.iter = iter;
            row.entries.clear();
        }
        debug_assert_eq!(row.iter, iter, "round slab collision");
        row.entries.push(report);
        if row.entries.len() == n {
            row.present = false;
            Some(&self.rows[slot].entries)
        } else {
            None
        }
    }

    /// A crash skipped `iter` for some worker: the row can never reach
    /// `n` reports. Drop what exists and discard future reports for it.
    pub(crate) fn mark_dead(&mut self, iter: u64) {
        if iter < self.base {
            return;
        }
        if !self.rows.is_empty() {
            let slot = (iter & (self.rows.len() as u64 - 1)) as usize;
            let row = &mut self.rows[slot];
            if row.present && row.iter == iter {
                row.present = false;
            }
        }
        if !self.dead.contains(&iter) {
            self.dead.push(iter);
        }
    }

    /// Slide `base` up to `min_iter`, reclaiming empty/dead slots. A
    /// present row below `min_iter` cannot exist (every worker either
    /// reported or crash-skipped each index it passed), so the walk only
    /// crosses reclaimable slots.
    fn advance(&mut self, min_iter: u64) {
        if self.rows.is_empty() {
            self.base = self.base.max(min_iter);
        } else {
            let mask = self.rows.len() as u64 - 1;
            while self.base < min_iter {
                let row = &self.rows[(self.base & mask) as usize];
                if row.present && row.iter == self.base {
                    // cannot happen (see doc comment) — but never reclaim
                    // a live row if the invariant is somehow violated
                    break;
                }
                self.base += 1;
            }
        }
        if !self.dead.is_empty() {
            let base = self.base;
            self.dead.retain(|&d| d >= base);
        }
    }

    /// Grow the ring so `iter` is addressable from `base` (next power of
    /// two, rows re-homed by their own index).
    fn ensure_capacity(&mut self, iter: u64) {
        debug_assert!(iter >= self.base);
        let needed = (iter - self.base + 1) as usize;
        if needed <= self.rows.len() {
            return;
        }
        let new_len = needed.next_power_of_two().max(8);
        let new_mask = new_len as u64 - 1;
        let mut new_rows = vec![RoundRow::default(); new_len];
        for row in self.rows.drain(..) {
            if row.present {
                let slot = (row.iter & new_mask) as usize;
                new_rows[slot] = row;
            }
        }
        self.rows = new_rows;
    }

    #[cfg(test)]
    fn occupied(&self) -> usize {
        self.rows.iter().filter(|r| r.present).count()
    }
}

/// Record one completed iteration into the per-index straggler
/// accounting. When every worker's duration for `iter` is in, the row is
/// scored against the §II deviation-ratio threshold: prediction confusion
/// updates, straggler iterations count, and episode boundaries are
/// tracked through `straggling` (one flag per worker, `len == n`).
/// `report` is `(worker, duration, predicted-flag)`; `min_iter` is the
/// job's minimum per-worker iteration index (the slab's reclamation
/// watermark — it never affects what gets scored).
pub(crate) fn record_report(
    stats: &mut JobStats,
    round_times: &mut RoundSlab,
    straggling: &mut [bool],
    iter: u64,
    min_iter: u64,
    report: (usize, f64, bool),
) {
    let n = straggling.len();
    if let Some(row) = round_times.record(iter, report, n, min_iter) {
        let min = row.iter().map(|&(_, d, _)| d).fold(f64::INFINITY, f64::min).max(1e-9);
        for &(w, d, pred) in row {
            let is_straggler = (d - min) / min > STRAGGLER_DEV;
            stats.prediction.add(pred, is_straggler);
            if is_straggler {
                stats.straggler_iters += 1;
                if !straggling[w] {
                    stats.straggler_episodes += 1;
                    straggling[w] = true;
                }
            } else {
                straggling[w] = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> JobStats {
        JobStats {
            job: 0,
            model: 0,
            workers: 3,
            system: "test".into(),
            arrival_s: 0.0,
            start_s: 0.0,
            end_s: 0.0,
            tta_s: None,
            jct_s: 0.0,
            converged_value: 0.0,
            is_nlp: false,
            updates: 0,
            iters_total: 0,
            straggler_iters: 0,
            straggler_episodes: 0,
            decision_pause_total_s: 0.0,
            decision_overhead_total_s: 0.0,
            decision_count: 0,
            prediction: Confusion::default(),
            series: Vec::new(),
            value_series: Vec::new(),
            mode_switches: 0,
            downtime_s: 0.0,
            rollbacks: 0,
        }
    }

    #[test]
    fn slab_scores_complete_rows_like_the_map_did() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 3];
        // iteration 0: worker 2 is 2x the min -> one straggler iteration
        record_report(&mut s, &mut slab, &mut straggling, 0, 0, (0, 1.0, false));
        record_report(&mut s, &mut slab, &mut straggling, 0, 0, (1, 1.05, false));
        assert_eq!(s.straggler_iters, 0, "incomplete row must not score");
        record_report(&mut s, &mut slab, &mut straggling, 0, 0, (2, 2.0, true));
        assert_eq!(s.straggler_iters, 1);
        assert_eq!(s.straggler_episodes, 1);
        assert!(straggling[2]);
        assert_eq!(slab.occupied(), 0, "completed row must free its slot");
        // iteration 1: all tight -> episode closes
        for w in 0..3 {
            record_report(&mut s, &mut slab, &mut straggling, 1, 1, (w, 1.0, false));
        }
        assert_eq!(s.straggler_iters, 1);
        assert!(!straggling[2]);
    }

    #[test]
    fn slab_interleaved_rounds_and_base_reclamation() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 2];
        // two workers drift apart: w0 races ahead, w1 lags
        for iter in 0..40u64 {
            record_report(&mut s, &mut slab, &mut straggling, iter, 0, (0, 1.0, false));
        }
        assert_eq!(slab.occupied(), 40);
        for iter in 0..40u64 {
            // w1 catches up; min_iter trails at `iter`
            record_report(&mut s, &mut slab, &mut straggling, iter, iter, (1, 1.0, false));
        }
        assert_eq!(slab.occupied(), 0);
        assert!(slab.base >= 39, "base must reclaim completed slots");
        assert_eq!(s.straggler_iters, 0);
    }

    #[test]
    fn slab_dead_rows_are_dropped_and_discarded() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 3];
        // w0 and w1 report iteration 5; w2 crash-skips it
        record_report(&mut s, &mut slab, &mut straggling, 5, 5, (0, 1.0, false));
        record_report(&mut s, &mut slab, &mut straggling, 5, 5, (1, 9.0, true));
        slab.mark_dead(5);
        assert_eq!(slab.occupied(), 0, "dead row must release its slot");
        // a late report for the dead index is discarded, not re-created
        record_report(&mut s, &mut slab, &mut straggling, 5, 5, (2, 1.0, false));
        assert_eq!(slab.occupied(), 0);
        assert_eq!(s.straggler_iters, 0, "dead rows never score");
        // marking dead before any report also discards later reports
        slab.mark_dead(6);
        record_report(&mut s, &mut slab, &mut straggling, 6, 5, (0, 1.0, false));
        assert_eq!(slab.occupied(), 0);
        // the dead list drains once the watermark passes the index
        record_report(&mut s, &mut slab, &mut straggling, 9, 9, (0, 1.0, false));
        assert!(slab.dead.is_empty(), "passed dead indices must be pruned");
    }

    #[test]
    fn slab_grows_past_initial_capacity() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 2];
        // spread 0..100 with the watermark pinned at 0 forces growth
        for iter in 0..100u64 {
            record_report(&mut s, &mut slab, &mut straggling, iter, 0, (0, 1.0, false));
        }
        assert_eq!(slab.occupied(), 100);
        assert!(slab.rows.len() >= 100);
        // completing them all (in a scrambled order) still scores rows
        for iter in (0..100u64).rev() {
            record_report(&mut s, &mut slab, &mut straggling, iter, 0, (1, 1.0, false));
        }
        assert_eq!(slab.occupied(), 0);
    }
}
