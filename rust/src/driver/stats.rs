//! Per-job outcome records and their accumulation: [`IterBreakdown`]
//! rows, [`JobStats`], [`ServerRecord`] samples, and the §II straggler
//! accounting over completed iteration indices.
//!
//! This layer is write-only bookkeeping — nothing here feeds back into
//! scheduling decisions, so moving a stat cannot change a trace.

use crate::predict::{Confusion, STRAGGLER_DEV};

/// Per-iteration measured breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct IterBreakdown {
    pub pre_s: f64,
    pub gpu_s: f64,
    pub comm_s: f64,
    pub total_s: f64,
    pub cpu_share: f64,
    pub bw_share: f64,
}

/// Recorded per-job outcome.
#[derive(Clone, Debug)]
pub struct JobStats {
    pub job: usize,
    pub model: usize,
    pub workers: usize,
    pub system: String,
    pub arrival_s: f64,
    pub start_s: f64,
    pub end_s: f64,
    pub tta_s: Option<f64>,
    pub jct_s: f64,
    pub converged_value: f64,
    pub is_nlp: bool,
    pub updates: u64,
    pub iters_total: u64,
    pub straggler_iters: u64,
    pub straggler_episodes: u64,
    pub decision_pause_total_s: f64,
    pub decision_overhead_total_s: f64,
    pub decision_count: u64,
    pub prediction: Confusion,
    /// sampled per-iteration series per worker (bounded by `SERIES_CAP`)
    pub series: Vec<Vec<IterBreakdown>>,
    /// (sim time since job start, value) samples taken at decision points
    pub value_series: Vec<(f64, f64)>,
    pub mode_switches: u64,
    /// total seconds the job's workers spent dead (summed per worker)
    /// plus PS-restart stalls (fault injection)
    pub downtime_s: f64,
    /// checkpoint rollbacks suffered (PS crashes / server outages)
    pub rollbacks: u64,
}

/// Cap on recorded iteration rows per worker (sampled with stride).
pub const SERIES_CAP: usize = 500;

/// A server-utilization record (Fig 9 / Fig 10 evidence).
#[derive(Clone, Copy, Debug)]
pub struct ServerRecord {
    pub time: f64,
    pub server: usize,
    pub ps_hosted: usize,
    pub cpu_util: f64,
    pub bw_util: f64,
}

/// Per-iteration-index round state: a ring-indexed slab keyed on round
/// offset (DESIGN.md §3), replacing the old `BTreeMap<u64, Vec<…>>`.
///
/// Iteration indices arrive from a narrow sliding window — each worker
/// walks its own index counter forward by one — so the live rows fit a
/// power-of-two ring addressed by `iter & mask`. `base` trails the
/// slowest worker's counter: the driver passes its current minimum and
/// the ring reclaims every slot behind it. Completed rows flip a
/// `present` bit and keep their entry buffers, so steady-state recording
/// allocates nothing; crash-skipped indices are [`RoundSlab::mark_dead`]
/// so a row that can never complete (the old map kept it forever) is
/// dropped instead of pinning the ring.
#[derive(Clone, Debug, Default)]
pub(crate) struct RoundSlab {
    /// lowest iteration index the ring can still hold a row for
    base: u64,
    /// power-of-two ring; a row for `iter` lives at `iter & (len - 1)`
    rows: Vec<RoundRow>,
    /// indices ≥ `base` that can never complete (a crash skipped them);
    /// reports for them are discarded, exactly as the map's leaked rows
    /// were never scored
    dead: Vec<u64>,
}

#[derive(Clone, Debug, Default)]
struct RoundRow {
    iter: u64,
    present: bool,
    entries: Vec<(usize, f64, bool)>,
}

impl RoundSlab {
    /// Record one `(worker, duration, predicted-flag)` report for `iter`.
    /// Returns the completed row's entries (in arrival order, exactly as
    /// the map accumulated them) once all `n` workers have reported, else
    /// `None`. `min_iter` is the caller's current minimum per-worker
    /// iteration index — the watermark below which no further report can
    /// arrive.
    fn record(
        &mut self,
        iter: u64,
        report: (usize, f64, bool),
        n: usize,
        min_iter: u64,
    ) -> Option<&[(usize, f64, bool)]> {
        if self.dead.contains(&iter) {
            return None;
        }
        // the row being filled right now must stay addressable
        self.advance(min_iter.min(iter));
        self.ensure_capacity(iter);
        let mask = self.rows.len() as u64 - 1;
        let slot = (iter & mask) as usize;
        let row = &mut self.rows[slot];
        if !row.present {
            row.present = true;
            row.iter = iter;
            row.entries.clear();
        }
        debug_assert_eq!(row.iter, iter, "round slab collision");
        row.entries.push(report);
        if row.entries.len() == n {
            row.present = false;
            Some(&self.rows[slot].entries)
        } else {
            None
        }
    }

    /// A crash skipped `iter` for some worker: the row can never reach
    /// `n` reports. Drop what exists and discard future reports for it.
    pub(crate) fn mark_dead(&mut self, iter: u64) {
        if iter < self.base {
            return;
        }
        if !self.rows.is_empty() {
            let slot = (iter & (self.rows.len() as u64 - 1)) as usize;
            let row = &mut self.rows[slot];
            if row.present && row.iter == iter {
                row.present = false;
            }
        }
        if !self.dead.contains(&iter) {
            self.dead.push(iter);
        }
    }

    /// Slide `base` up to `min_iter`, reclaiming empty/dead slots. A
    /// present row below `min_iter` cannot exist (every worker either
    /// reported or crash-skipped each index it passed), so the walk only
    /// crosses reclaimable slots.
    fn advance(&mut self, min_iter: u64) {
        if self.rows.is_empty() {
            self.base = self.base.max(min_iter);
        } else {
            let mask = self.rows.len() as u64 - 1;
            while self.base < min_iter {
                let row = &self.rows[(self.base & mask) as usize];
                if row.present && row.iter == self.base {
                    // cannot happen (see doc comment) — but never reclaim
                    // a live row if the invariant is somehow violated
                    break;
                }
                self.base += 1;
            }
        }
        if !self.dead.is_empty() {
            let base = self.base;
            self.dead.retain(|&d| d >= base);
        }
    }

    /// Grow the ring so `iter` is addressable from `base` (next power of
    /// two, rows re-homed by their own index).
    fn ensure_capacity(&mut self, iter: u64) {
        debug_assert!(iter >= self.base);
        let needed = (iter - self.base + 1) as usize;
        if needed <= self.rows.len() {
            return;
        }
        let new_len = needed.next_power_of_two().max(8);
        let new_mask = new_len as u64 - 1;
        let mut new_rows = vec![RoundRow::default(); new_len];
        for row in self.rows.drain(..) {
            if row.present {
                let slot = (row.iter & new_mask) as usize;
                new_rows[slot] = row;
            }
        }
        self.rows = new_rows;
    }

    #[cfg(test)]
    fn occupied(&self) -> usize {
        self.rows.iter().filter(|r| r.present).count()
    }
}

/// Record one completed iteration into the per-index straggler
/// accounting. When every worker's duration for `iter` is in, the row is
/// scored against the §II deviation-ratio threshold: prediction confusion
/// updates, straggler iterations count, and episode boundaries are
/// tracked through `straggling` (one flag per worker, `len == n`).
/// `report` is `(worker, duration, predicted-flag)`; `min_iter` is the
/// job's minimum per-worker iteration index (the slab's reclamation
/// watermark — it never affects what gets scored).
pub(crate) fn record_report(
    stats: &mut JobStats,
    round_times: &mut RoundSlab,
    straggling: &mut [bool],
    iter: u64,
    min_iter: u64,
    report: (usize, f64, bool),
) {
    let n = straggling.len();
    if let Some(row) = round_times.record(iter, report, n, min_iter) {
        let min = row.iter().map(|&(_, d, _)| d).fold(f64::INFINITY, f64::min).max(1e-9);
        for &(w, d, pred) in row {
            let is_straggler = (d - min) / min > STRAGGLER_DEV;
            stats.prediction.add(pred, is_straggler);
            if is_straggler {
                stats.straggler_iters += 1;
                if !straggling[w] {
                    stats.straggler_episodes += 1;
                    straggling[w] = true;
                }
            } else {
                straggling[w] = false;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Streaming aggregation (DESIGN.md §12): bounded memory at 10⁶ jobs
// ---------------------------------------------------------------------------

/// Exact-value buffer size per [`StatStream`] before it collapses into
/// the log₂ histogram. 4096 f64s = 32 KiB per metric; below this cap
/// quantiles are exact (identical to sorting the accumulate-then-
/// summarize vector), beyond it they are bucket-geometric approximations
/// within a √2 factor.
pub const STREAM_EXACT_CAP: usize = 4096;

/// One metric's running aggregate: exact count/sum/min/max always, plus
/// quantiles — exact below [`STREAM_EXACT_CAP`] samples, log₂-histogram
/// approximate beyond. Memory is bounded at `STREAM_EXACT_CAP` f64s +
/// 128 buckets no matter how many values stream through.
#[derive(Clone, Debug)]
pub struct StatStream {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// exact samples until the cap; drained into `hist` on spill
    buf: Vec<f64>,
    /// log₂ buckets (index = ⌊log₂ v⌋ + 64, clamped) once spilled
    hist: Vec<u64>,
}

impl Default for StatStream {
    fn default() -> Self {
        StatStream {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buf: Vec::new(),
            hist: Vec::new(),
        }
    }
}

impl StatStream {
    fn bucket(v: f64) -> usize {
        // v ≤ 0 (or subnormal-small) pins to bucket 0
        ((v.max(1e-18).log2().floor() as i64) + 64).clamp(0, 127) as usize
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.hist.is_empty() && self.buf.len() < STREAM_EXACT_CAP {
            self.buf.push(v);
        } else {
            if self.hist.is_empty() {
                // spill: fold the exact buffer into buckets once
                self.hist = vec![0u64; 128];
                for &b in &self.buf {
                    self.hist[Self::bucket(b)] += 1;
                }
                self.buf = Vec::new();
            }
            self.hist[Self::bucket(v)] += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile `q` in [0, 1]. Exact (nearest-rank over the sorted
    /// samples) while un-spilled; once spilled, the geometric midpoint of
    /// the bucket holding rank ⌈q·count⌉, clamped into [min, max].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        if self.hist.is_empty() {
            let mut v = self.buf.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            v[(rank - 1) as usize]
        } else {
            let mut seen = 0u64;
            for (i, &c) in self.hist.iter().enumerate() {
                seen += c;
                if seen >= rank {
                    let mid = 2f64.powi(i as i32 - 64) * std::f64::consts::SQRT_2;
                    return mid.clamp(self.min, self.max);
                }
            }
            self.max
        }
    }
}

/// Bounded running aggregate over finished jobs — what
/// `--streaming-stats` accumulates instead of `Vec<JobStats>`
/// (DESIGN.md §12). Folding is order-sensitive only through float
/// summation, and the driver folds in termination order — exactly the
/// order `finished` is pushed in — so a streamed run's aggregate equals
/// folding a non-streaming run's `finished` vec bit for bit (pinned by
/// `tests/partitioned_equivalence.rs`).
#[derive(Clone, Debug, Default)]
pub struct StreamAgg {
    pub jobs: u64,
    pub jct_s: StatStream,
    /// only jobs that reached their target accuracy
    pub tta_s: StatStream,
    /// admission queueing delay (start - arrival)
    pub queue_s: StatStream,
    pub updates: StatStream,
    pub iters: StatStream,
    pub downtime_s: StatStream,
    pub straggler_iters: u64,
    pub straggler_episodes: u64,
    pub mode_switches: u64,
    pub rollbacks: u64,
}

impl StreamAgg {
    /// Fold one finished job in.
    pub fn fold(&mut self, s: &JobStats) {
        self.jobs += 1;
        self.jct_s.push(s.jct_s);
        if let Some(t) = s.tta_s {
            self.tta_s.push(t);
        }
        self.queue_s.push(s.start_s - s.arrival_s);
        self.updates.push(s.updates as f64);
        self.iters.push(s.iters_total as f64);
        self.downtime_s.push(s.downtime_s);
        self.straggler_iters += s.straggler_iters;
        self.straggler_episodes += s.straggler_episodes;
        self.mode_switches += s.mode_switches;
        self.rollbacks += s.rollbacks;
    }

    /// The accumulate-then-summarize reference path: fold a finished
    /// vec in order. Equals the streamed aggregate for the same run.
    pub fn from_stats(stats: &[JobStats]) -> Self {
        let mut agg = StreamAgg::default();
        for s in stats {
            agg.fold(s);
        }
        agg
    }
}

// ---------------------------------------------------------------------------
// Peak-RSS probe (BENCH_driver.json memory column)
// ---------------------------------------------------------------------------

/// Process peak resident set in bytes: `VmHWM` from `/proc/self/status`
/// on Linux, `None` elsewhere or on any read/parse failure (the bench
/// emits JSON `null` then — a missing probe must never fail a run).
pub fn peak_rss_bytes() -> Option<u64> {
    if !cfg!(target_os = "linux") {
        return None;
    }
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = text.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Best-effort reset of the `VmHWM` high-water mark (write `"5"` to
/// `/proc/self/clear_refs`), so serially-run bench cells each report
/// their own peak instead of the process-lifetime maximum. Returns
/// whether the reset took; callers must tolerate `false` (older kernels,
/// non-Linux) — the probe then reports a process-wide upper bound.
pub fn reset_peak_rss() -> bool {
    cfg!(target_os = "linux") && std::fs::write("/proc/self/clear_refs", "5").is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> JobStats {
        JobStats {
            job: 0,
            model: 0,
            workers: 3,
            system: "test".into(),
            arrival_s: 0.0,
            start_s: 0.0,
            end_s: 0.0,
            tta_s: None,
            jct_s: 0.0,
            converged_value: 0.0,
            is_nlp: false,
            updates: 0,
            iters_total: 0,
            straggler_iters: 0,
            straggler_episodes: 0,
            decision_pause_total_s: 0.0,
            decision_overhead_total_s: 0.0,
            decision_count: 0,
            prediction: Confusion::default(),
            series: Vec::new(),
            value_series: Vec::new(),
            mode_switches: 0,
            downtime_s: 0.0,
            rollbacks: 0,
        }
    }

    #[test]
    fn slab_scores_complete_rows_like_the_map_did() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 3];
        // iteration 0: worker 2 is 2x the min -> one straggler iteration
        record_report(&mut s, &mut slab, &mut straggling, 0, 0, (0, 1.0, false));
        record_report(&mut s, &mut slab, &mut straggling, 0, 0, (1, 1.05, false));
        assert_eq!(s.straggler_iters, 0, "incomplete row must not score");
        record_report(&mut s, &mut slab, &mut straggling, 0, 0, (2, 2.0, true));
        assert_eq!(s.straggler_iters, 1);
        assert_eq!(s.straggler_episodes, 1);
        assert!(straggling[2]);
        assert_eq!(slab.occupied(), 0, "completed row must free its slot");
        // iteration 1: all tight -> episode closes
        for w in 0..3 {
            record_report(&mut s, &mut slab, &mut straggling, 1, 1, (w, 1.0, false));
        }
        assert_eq!(s.straggler_iters, 1);
        assert!(!straggling[2]);
    }

    #[test]
    fn slab_interleaved_rounds_and_base_reclamation() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 2];
        // two workers drift apart: w0 races ahead, w1 lags
        for iter in 0..40u64 {
            record_report(&mut s, &mut slab, &mut straggling, iter, 0, (0, 1.0, false));
        }
        assert_eq!(slab.occupied(), 40);
        for iter in 0..40u64 {
            // w1 catches up; min_iter trails at `iter`
            record_report(&mut s, &mut slab, &mut straggling, iter, iter, (1, 1.0, false));
        }
        assert_eq!(slab.occupied(), 0);
        assert!(slab.base >= 39, "base must reclaim completed slots");
        assert_eq!(s.straggler_iters, 0);
    }

    #[test]
    fn slab_dead_rows_are_dropped_and_discarded() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 3];
        // w0 and w1 report iteration 5; w2 crash-skips it
        record_report(&mut s, &mut slab, &mut straggling, 5, 5, (0, 1.0, false));
        record_report(&mut s, &mut slab, &mut straggling, 5, 5, (1, 9.0, true));
        slab.mark_dead(5);
        assert_eq!(slab.occupied(), 0, "dead row must release its slot");
        // a late report for the dead index is discarded, not re-created
        record_report(&mut s, &mut slab, &mut straggling, 5, 5, (2, 1.0, false));
        assert_eq!(slab.occupied(), 0);
        assert_eq!(s.straggler_iters, 0, "dead rows never score");
        // marking dead before any report also discards later reports
        slab.mark_dead(6);
        record_report(&mut s, &mut slab, &mut straggling, 6, 5, (0, 1.0, false));
        assert_eq!(slab.occupied(), 0);
        // the dead list drains once the watermark passes the index
        record_report(&mut s, &mut slab, &mut straggling, 9, 9, (0, 1.0, false));
        assert!(slab.dead.is_empty(), "passed dead indices must be pruned");
    }

    #[test]
    fn slab_grows_past_initial_capacity() {
        let mut s = stats();
        let mut slab = RoundSlab::default();
        let mut straggling = [false; 2];
        // spread 0..100 with the watermark pinned at 0 forces growth
        for iter in 0..100u64 {
            record_report(&mut s, &mut slab, &mut straggling, iter, 0, (0, 1.0, false));
        }
        assert_eq!(slab.occupied(), 100);
        assert!(slab.rows.len() >= 100);
        // completing them all (in a scrambled order) still scores rows
        for iter in (0..100u64).rev() {
            record_report(&mut s, &mut slab, &mut straggling, iter, 0, (1, 1.0, false));
        }
        assert_eq!(slab.occupied(), 0);
    }

    #[test]
    fn stat_stream_exact_below_cap() {
        let mut st = StatStream::default();
        for v in [3.0, 1.0, 2.0, 4.0] {
            st.push(v);
        }
        assert_eq!(st.count, 4);
        assert_eq!(st.sum, 10.0);
        assert_eq!(st.min, 1.0);
        assert_eq!(st.max, 4.0);
        assert_eq!(st.mean(), 2.5);
        // nearest-rank: q=0.5 over 4 samples -> rank 2 -> 2.0
        assert_eq!(st.quantile(0.5), 2.0);
        assert_eq!(st.quantile(0.0), 1.0);
        assert_eq!(st.quantile(1.0), 4.0);
    }

    #[test]
    fn stat_stream_spills_to_bounded_histogram() {
        let mut st = StatStream::default();
        let n = STREAM_EXACT_CAP * 3;
        for i in 0..n {
            st.push(1.0 + (i % 100) as f64);
        }
        assert_eq!(st.count, n as u64);
        assert!(st.buf.is_empty(), "spilled stream must drop the exact buffer");
        assert_eq!(st.hist.len(), 128, "histogram memory is fixed");
        // exact moments survive the spill
        assert_eq!(st.min, 1.0);
        assert_eq!(st.max, 100.0);
        assert!((st.mean() - 50.5).abs() < 1e-9);
        // quantiles stay within the log2-bucket factor-of-2 guarantee
        let p50 = st.quantile(0.5);
        assert!((25.0..=100.0).contains(&p50), "p50 {p50} off by more than a bucket");
        // degenerate inputs bucket safely
        st.push(0.0);
        st.push(-5.0);
        assert_eq!(st.min, -5.0);
    }

    #[test]
    fn stream_agg_folds_and_matches_reference() {
        let mut a = stats();
        a.jct_s = 100.0;
        a.tta_s = Some(60.0);
        a.updates = 10;
        a.straggler_iters = 3;
        let mut b = stats();
        b.jct_s = 50.0;
        b.tta_s = None;
        b.rollbacks = 2;
        let both = vec![a.clone(), b.clone()];
        let reference = StreamAgg::from_stats(&both);
        let mut streamed = StreamAgg::default();
        streamed.fold(&a);
        streamed.fold(&b);
        assert_eq!(streamed.jobs, 2);
        assert_eq!(streamed.jct_s.sum, reference.jct_s.sum);
        assert_eq!(streamed.jct_s.quantile(0.5), reference.jct_s.quantile(0.5));
        assert_eq!(streamed.tta_s.count, 1, "only reached-target jobs count toward TTA");
        assert_eq!(streamed.straggler_iters, 3);
        assert_eq!(streamed.rollbacks, 2);
    }

    #[test]
    fn peak_rss_probe_is_sane_or_absent() {
        match peak_rss_bytes() {
            // a test process has certainly touched > 1 MB and < 1 TB
            Some(b) => assert!((1 << 20..1u64 << 40).contains(&b), "VmHWM {b} implausible"),
            None => assert!(!cfg!(target_os = "linux"), "probe must parse on Linux"),
        }
        // reset is best-effort by contract: either outcome is legal, it
        // just must not panic
        let _ = reset_peak_rss();
    }
}
