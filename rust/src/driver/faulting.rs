//! Fault-injection layer (DESIGN.md §7): plan-event translation plus the
//! crash / restart / outage transitions of the driver state machine.
//!
//! The membership consequences of a fault (shrunken barriers, re-formed
//! groups, re-chained rings) live in [`super::membership`]; this module
//! owns the *state* transitions — suspending cluster tasks, rollback to
//! the last checkpoint, restart-deadline extension, downtime accrual —
//! and hands control back to the orchestrator's `process_pending` /
//! `check_termination` so the shrunken round can fire.

use crate::faults::Fault;

use super::*;

/// Translate a fault plan into driver inputs: degradation windows are
/// stateless capacity cuts, registered with the cluster up-front so share
/// epochs see them at any time; everything else becomes a scheduled
/// [`Event::Fault`].
pub(super) fn register_plan(plan: &FaultPlan, cluster: &mut Cluster, engine: &mut EventQueue) {
    for (i, pf) in plan.faults.iter().enumerate() {
        match pf.fault {
            Fault::Degradation { server, dur_s, cpu_frac, bw_frac } => {
                if server < cluster.server_count() {
                    cluster.add_degradation(server, pf.at, pf.at + dur_s, cpu_frac, bw_frac);
                }
            }
            _ => engine.schedule_at(pf.at, Event::Fault(i)),
        }
    }
}

impl Driver {
    pub(super) fn handle_fault(&mut self, idx: usize, t: f64) {
        let fault = self.cfg.faults.faults[idx].fault;
        match fault {
            Fault::WorkerCrash { job, rank, restart_s } => {
                self.crash_worker(job, rank, t, restart_s);
            }
            Fault::PsCrash { job, idx, restart_s } => {
                self.crash_ps(job, idx, t, restart_s);
            }
            Fault::ServerOutage { server, dur_s, restart_s } => {
                self.server_outage(server, t, dur_s, restart_s);
            }
            // degradation windows are registered with the cluster at
            // construction and never become events
            Fault::Degradation { .. } => {}
        }
    }

    /// Worker `rank` of `job` dies at `t`: its in-flight gradient is
    /// lost, its cluster task suspends (invalidating the share cache),
    /// and the current round re-forms over the survivors. It restarts
    /// `restart_s` later. Crashing an *already-down* worker (a server
    /// outage catching one mid-restart) extends its restart deadline —
    /// the earlier pending restart event goes stale.
    pub(super) fn crash_worker(&mut self, job: usize, worker: usize, t: f64, restart_s: f64) {
        let due = t + restart_s.max(0.0);
        let task = {
            let Some(run) = self.jobs.get_mut(job).and_then(|j| j.as_mut()) else { return };
            if run.finished || worker >= run.job.workers {
                return;
            }
            if !run.wb.is_alive(worker) {
                // already down: only push the restart deadline out
                if run.wb.restart_at[worker].is_nan() || run.wb.restart_at[worker] < due {
                    run.wb.restart_at[worker] = due;
                    self.engine.schedule_at(due, Event::WorkerRestart { job, worker });
                }
                return;
            }
            run.wb.set_alive(worker, false);
            run.wb.busy[worker] = false;
            // invalidate the in-flight WorkerDone (its iter no longer
            // matches). The skipped index can never complete its
            // straggler-accounting row — mark it dead so the round slab
            // reclaims it (the old BTreeMap leaked one row per crash)
            run.round_times.mark_dead(run.wb.iter_idx[worker]);
            run.wb.iter_idx[worker] += 1;
            run.pending.retain(|&(w, _, _)| w != worker);
            run.wb.down_since[worker] = t;
            run.wb.restart_at[worker] = due;
            run.wb.straggling[worker] = false;
            run.placement.worker_tasks[worker]
        };
        self.cluster.suspend_task(task);
        self.engine.schedule_at(due, Event::WorkerRestart { job, worker });
        // a shrunken barrier / group may now be complete
        self.process_pending(job, t);
        self.check_termination(job, t);
    }

    pub(super) fn worker_restart(&mut self, job: usize, worker: usize, t: f64) {
        let task = {
            let Some(run) = self.jobs.get_mut(job).and_then(|j| j.as_mut()) else { return };
            if run.finished || worker >= run.job.workers || run.wb.is_alive(worker) {
                return;
            }
            if t < run.wb.restart_at[worker] {
                return; // stale: a later fault extended the restart
            }
            run.wb.set_alive(worker, true);
            if run.wb.down_since[worker].is_finite() {
                run.stats.downtime_s += t - run.wb.down_since[worker];
            }
            run.wb.down_since[worker] = f64::NAN;
            run.wb.restart_at[worker] = f64::NAN;
            run.placement.worker_tasks[worker]
        };
        self.cluster.resume_task(task);
        self.start_iteration(job, worker, t);
    }

    /// PS `idx` of `job` dies at `t`: parameter state is lost — progress
    /// rolls back to the last checkpoint, unapplied reports are
    /// discarded, and updates stall until the PS restarts `restart_s`
    /// later. Crashing an already-down PS (server outage mid-restart)
    /// extends the restart deadline without a second rollback — the
    /// parameter state is already lost.
    pub(super) fn crash_ps(&mut self, job: usize, idx: usize, t: f64, restart_s: f64) {
        let due = t + restart_s.max(0.0);
        let task = match self.jobs.get(job).and_then(|j| j.as_ref()) {
            Some(run) if !run.finished && idx < run.placement.ps_tasks.len() => {
                run.placement.ps_tasks[idx]
            }
            _ => return,
        };
        if self.cluster.is_suspended(task) {
            // already down: only push the restart deadline out
            let run = self.jobs[job].as_mut().expect("checked above");
            if run.ps_restart_at[idx].is_nan() || run.ps_restart_at[idx] < due {
                run.ps_restart_at[idx] = due;
                self.engine.schedule_at(due, Event::PsRestart { job, ps_idx: idx });
            }
            return;
        }
        self.cluster.suspend_task(task);
        {
            let run = self.jobs[job].as_mut().expect("checked above");
            let now_rel = t - run.started_at;
            run.progress.restore(&run.checkpoint, now_rel);
            run.stats.rollbacks += 1;
            // reports computed against the lost parameter state are
            // discarded; `ps_down` stalls all updates until the restart
            // (deliberately NOT via `pause_until`: a long pause would make
            // iteration starts query cluster shares far in the future,
            // outside the share engine's non-decreasing-time contract).
            // Downtime is measured as the *realized* stall window (like
            // worker downtime), so overlapping PS crashes — e.g. a server
            // outage hitting several PSs of one job — count once
            if run.ps_down == 0 {
                run.ps_down_since = t;
            }
            run.ps_restart_at[idx] = due;
            run.pending.clear();
            run.ps_down += 1;
            run.ar_flush_scheduled = false;
        }
        self.engine.schedule_at(due, Event::PsRestart { job, ps_idx: idx });
        self.check_termination(job, t);
    }

    pub(super) fn ps_restart(&mut self, job: usize, ps_idx: usize, t: f64) {
        let task = match self.jobs.get(job).and_then(|j| j.as_ref()) {
            Some(run) if !run.finished && ps_idx < run.placement.ps_tasks.len() => {
                run.placement.ps_tasks[ps_idx]
            }
            _ => return,
        };
        if !self.cluster.is_suspended(task) {
            return;
        }
        {
            let run = self.jobs[job].as_ref().expect("checked above");
            if t < run.ps_restart_at[ps_idx] {
                return; // stale: a later fault extended the restart
            }
        }
        self.cluster.resume_task(task);
        let all_up = {
            let run = self.jobs[job].as_mut().expect("checked above");
            run.ps_restart_at[ps_idx] = f64::NAN;
            run.ps_down = run.ps_down.saturating_sub(1);
            if run.ps_down == 0 && run.ps_down_since.is_finite() {
                run.stats.downtime_s += t - run.ps_down_since;
                run.ps_down_since = f64::NAN;
            }
            run.ps_down == 0
        };
        if all_up {
            self.process_pending(job, t);
            self.kick_idle_workers(job, t);
        }
    }

    /// Whole-server outage: every co-located task of every running job on
    /// `server` fails at once — workers crash, PSs roll back — and all of
    /// them restart once the server returns (`dur_s + restart_s` later).
    /// Tasks already down when the outage hits have their restart
    /// deadlines extended (crash_worker/crash_ps handle that case).
    pub(super) fn server_outage(&mut self, server: usize, t: f64, dur_s: f64, restart_s: f64) {
        let mut workers: Vec<(usize, usize)> = Vec::new();
        let mut pss: Vec<(usize, usize)> = Vec::new();
        for (job, slot) in self.jobs.iter().enumerate() {
            let Some(run) = slot else { continue };
            if run.finished {
                continue;
            }
            for (w, &tid) in run.placement.worker_tasks.iter().enumerate() {
                if self.cluster.task(tid).server == server {
                    workers.push((job, w));
                }
            }
            for (i, &tid) in run.placement.ps_tasks.iter().enumerate() {
                if self.cluster.task(tid).server == server {
                    pss.push((job, i));
                }
            }
        }
        let back = dur_s.max(0.0) + restart_s.max(0.0);
        for (job, w) in workers {
            self.crash_worker(job, w, t, back);
        }
        for (job, i) in pss {
            self.crash_ps(job, i, t, back);
        }
    }

    /// Start an iteration on every live worker that is neither computing
    /// nor waiting in a pending set (used after PS recovery, when cleared
    /// reports would otherwise leave reporters idle forever).
    pub(super) fn kick_idle_workers(&mut self, job: usize, t: f64) {
        let idle: Vec<usize> = match self.jobs.get(job).and_then(|j| j.as_ref()) {
            Some(run) if !run.finished => (0..run.job.workers)
                .filter(|&w| {
                    run.wb.is_alive(w) && !run.wb.busy[w] && !waiting_in_pending(run, w)
                })
                .collect(),
            _ => return,
        };
        for w in idle {
            self.start_iteration(job, w, t);
        }
    }
}
