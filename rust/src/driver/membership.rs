//! Round membership (DESIGN.md §8): *who is in the round* as a
//! first-class layer.
//!
//! Under fault injection every grouping rule must count the **live**
//! workers — an SSGD barrier shrinks when a member dies mid-iteration,
//! x-order groups re-form over survivors, the AR ring re-chains around
//! dead members, and LGC's first-K clamps to the live count. Before this
//! module each policy and each driver branch re-derived that arithmetic
//! ad hoc, which is exactly where the double-shrink LGC and stale-restart
//! bugs of the resilience work came from. Now the driver, `sync`'s round
//! semantics, the STAR controller and the `baselines` all consume the
//! same primitives:
//!
//! * [`LiveSet`] — a view over a per-worker liveness mask (counts, ids),
//!   reachable from policies through `RoundObs::live_set`;
//! * [`next_update_group`] — which pending gradient reports form the next
//!   parameter update under a [`DriverMode`] (the SSGD barrier, ASGD
//!   per-report, static/dynamic x-order group rules);
//! * [`ring_order`] / [`ring_split`] — AR ring chaining over the live
//!   set, ordered by predicted iteration time, with the removed-straggler
//!   tail split off (`removed` clamped so the ring keeps ≥ 1 member);
//! * [`first_k_split`] — LGC's first-K-by-arrival rule with its
//!   explicit drop set;
//! * [`mask_dead_with_live_min`] — the policy-side convention that a
//!   dead worker is *outside* the round, not a straggler inside it.
//!
//! Contract: with no faults (`live == n`, all true) every function here
//! reduces bit-identically to the fault-free grouping rules — pinned by
//! the golden-trace suite.

use crate::sync::SyncMode;

use super::DriverMode;

/// A read-only membership view over a job's per-worker liveness mask.
#[derive(Clone, Copy)]
pub struct LiveSet<'a> {
    mask: &'a [bool],
}

impl<'a> LiveSet<'a> {
    pub fn new(mask: &'a [bool]) -> Self {
        LiveSet { mask }
    }

    /// Number of live workers — the barrier size of a shrunken SSGD
    /// round. (Deliberately no `len`/`is_empty`: on a type named
    /// `LiveSet` they would read as live-membership queries while a
    /// mask-length reading would also be defensible — an ambiguity trap
    /// in the layer everything else trusts.)
    pub fn count(&self) -> usize {
        live_count(self.mask)
    }

    /// Live worker ranks in rank order.
    pub fn ids(&self) -> Vec<usize> {
        live_ids(self.mask)
    }

    pub fn is_live(&self, worker: usize) -> bool {
        self.mask.get(worker).copied().unwrap_or(false)
    }
}

/// Number of live workers in `mask`.
pub fn live_count(mask: &[bool]) -> usize {
    mask.iter().filter(|&&a| a).count()
}

/// Live worker ranks in rank order.
pub fn live_ids(mask: &[bool]) -> Vec<usize> {
    mask.iter().enumerate().filter(|&(_, &a)| a).map(|(w, _)| w).collect()
}

/// Struct-of-arrays block of a job's hottest per-worker state
/// (DESIGN.md §12). The driver used to scatter these twelve vectors
/// across `JobRun`, so one `worker_done` touched twelve far-apart heap
/// allocations; grouping them in one block keeps the whole per-worker
/// working set of an event in a handful of cache lines, and owning the
/// liveness mask here lets the block maintain `live_count` as an O(1)
/// counter instead of the O(n) mask scan the hot paths did per event.
///
/// Invariant: `alive_count == alive.iter().filter(|a| **a).count()` at
/// all times — `alive` is private and only mutable through
/// [`WorkerBlock::set_alive`].
pub struct WorkerBlock {
    pub iter_idx: Vec<u64>,
    pub iter_start: Vec<f64>,
    pub param_version_at_start: Vec<u64>,
    pub last_times: Vec<f64>,
    pub busy: Vec<bool>,
    pub predicted_times: Vec<f64>,
    pub predicted_flags: Vec<bool>,
    pub straggling: Vec<bool>,
    /// crash time per down worker (NaN while alive) — downtime accounting
    pub down_since: Vec<f64>,
    /// per-worker restart deadline (NaN while alive); a later fault
    /// extends it and earlier pending restart events become stale
    pub restart_at: Vec<f64>,
    alive: Vec<bool>,
    alive_count: usize,
}

impl WorkerBlock {
    /// A block for `n` workers, all alive and idle, clocks at `t`.
    pub fn new(n: usize, t: f64) -> Self {
        WorkerBlock {
            iter_idx: vec![0; n],
            iter_start: vec![t; n],
            param_version_at_start: vec![0; n],
            last_times: vec![f64::NAN; n],
            busy: vec![false; n],
            predicted_times: vec![f64::NAN; n],
            predicted_flags: vec![false; n],
            straggling: vec![false; n],
            down_since: vec![f64::NAN; n],
            restart_at: vec![f64::NAN; n],
            alive: vec![true; n],
            alive_count: n,
        }
    }

    /// The per-worker liveness mask (read-only — see [`WorkerBlock::set_alive`]).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    pub fn is_alive(&self, worker: usize) -> bool {
        self.alive[worker]
    }

    /// Flip a worker's liveness, maintaining the O(1) live counter.
    pub fn set_alive(&mut self, worker: usize, value: bool) {
        if self.alive[worker] != value {
            self.alive[worker] = value;
            if value {
                self.alive_count += 1;
            } else {
                self.alive_count -= 1;
            }
        }
    }

    /// Number of live workers — O(1), equal to [`live_count`] over the mask.
    pub fn live_count(&self) -> usize {
        self.alive_count
    }
}

/// Replace dead workers' predicted times with the live minimum, so they
/// neither read as stragglers nor distort x-order grouping (a dead worker
/// is outside the round entirely until it restarts). No-op when no live
/// worker has a finite prediction.
pub fn mask_dead_with_live_min(predicted: &mut [f64], live: &[bool]) {
    let live_min = predicted
        .iter()
        .zip(live)
        .filter(|&(_, &a)| a)
        .map(|(&p, _)| p)
        .fold(f64::INFINITY, f64::min);
    if live_min.is_finite() {
        for (p, &a) in predicted.iter_mut().zip(live) {
            if !a {
                *p = live_min;
            }
        }
    }
}

/// Which pending reports form the next parameter update under `mode`.
///
/// `pending` holds `(worker, ready_at, version_at_start)` in arrival
/// order; `dyn_groups` is the worker → cluster assignment used by
/// DynamicX. Returns `None` while no rule fires — the AR ring and
/// first-K are *not* handled here (they need scheduled / threshold
/// handling, see [`ring_order`] and [`first_k_split`]).
pub fn next_update_group(
    mode: &DriverMode,
    pending: &[(usize, f64, u64)],
    live: &[bool],
    dyn_groups: &[usize],
) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    if next_update_group_into(mode, pending, live, dyn_groups, &mut out) {
        Some(out)
    } else {
        None
    }
}

/// Allocation-free [`next_update_group`]: fills `out` with the firing
/// group's workers and returns whether a rule fired (`out` is cleared
/// either way). This is the driver's per-report hot path — with a reused
/// `out` buffer the grouping decision allocates nothing.
pub fn next_update_group_into(
    mode: &DriverMode,
    pending: &[(usize, f64, u64)],
    live: &[bool],
    dyn_groups: &[usize],
    out: &mut Vec<usize>,
) -> bool {
    out.clear();
    let n_live = live_count(live);
    match mode {
        DriverMode::Sync(SyncMode::Ssgd) => {
            // barrier over the live membership
            if n_live > 0 && pending.len() >= n_live {
                out.extend(pending.iter().map(|&(w, _, _)| w));
                true
            } else {
                false
            }
        }
        DriverMode::Sync(SyncMode::Asgd) => match pending.first() {
            Some(&(w, _, _)) => {
                out.push(w);
                true
            }
            None => false,
        },
        DriverMode::Sync(SyncMode::StaticX(x)) => {
            let x = (*x).clamp(1, n_live.max(1));
            if pending.len() >= x {
                out.extend(pending[..x].iter().map(|&(w, _, _)| w));
                true
            } else {
                false
            }
        }
        DriverMode::Sync(SyncMode::DynamicX) => {
            // a group fires when every *live* member has reported.
            // Group ids index the prediction clusters, so they are dense
            // in [0, n): scanning ids in ascending order visits exactly
            // the groups the old BTreeSet pass did, in the same order,
            // without building the set. Groups with no pending reports
            // are skipped before the O(n) live-membership count, so the
            // common case (one pending report) stays O(n + p).
            for g in 0..live.len() {
                out.extend(
                    pending
                        .iter()
                        .filter(|&&(w, _, _)| dyn_groups[w] == g)
                        .map(|&(w, _, _)| w),
                );
                if out.is_empty() {
                    continue;
                }
                let needed = live
                    .iter()
                    .enumerate()
                    .filter(|&(w, &a)| a && dyn_groups[w] == g)
                    .count();
                if out.len() >= needed {
                    return true;
                }
                out.clear();
            }
            false
        }
        DriverMode::Sync(SyncMode::ArRing { .. }) | DriverMode::FirstK(_) => false,
    }
}

/// AR ring chaining order: the live workers sorted by predicted
/// iteration time (dead members are bypassed like §IV-B's removed
/// stragglers). Empty when no worker is live.
pub fn ring_order(live: &[bool], predicted: &[f64]) -> Vec<usize> {
    let mut order = Vec::new();
    ring_order_into(live, predicted, &mut order);
    order
}

/// Allocation-free [`ring_order`]: fills `order` in place (cleared
/// first). Same stable sort, same tie-breaking — bit-identical chains.
pub fn ring_order_into(live: &[bool], predicted: &[f64], order: &mut Vec<usize>) {
    order.clear();
    order.extend(live.iter().enumerate().filter(|&(_, &a)| a).map(|(w, _)| w));
    order.sort_by(|&a, &b| predicted[a].partial_cmp(&predicted[b]).unwrap());
}

/// Split a ring order into `(ring, removed_tail)`. `removed` is clamped
/// so the ring keeps at least one member; removal counts are relative to
/// the *live* order (counting dead workers again would shrink the ring
/// twice).
pub fn ring_split(order: &[usize], removed: usize) -> (&[usize], &[usize]) {
    let r = removed.min(order.len().saturating_sub(1));
    order.split_at(order.len() - r)
}

/// The LGC first-K grouping rule as a pure function: given the pending
/// reporters in arrival order and `live` current members, the first
/// `k` (clamped to the live count) form the update and the rest are
/// explicitly dropped. Returns `([], [])` while the threshold is unmet.
/// Exposed for the conservation property tests.
pub fn first_k_split(arrival: &[usize], k: usize, live: usize) -> (Vec<usize>, Vec<usize>) {
    let mut members = Vec::new();
    let mut dropped = Vec::new();
    first_k_split_into(arrival, k, live, &mut members, &mut dropped);
    (members, dropped)
}

/// Allocation-free [`first_k_split`]: fills `members`/`dropped` in place
/// (both cleared first) and returns whether the threshold was met — with
/// reused buffers the per-report first-K check allocates nothing.
pub fn first_k_split_into(
    arrival: &[usize],
    k: usize,
    live: usize,
    members: &mut Vec<usize>,
    dropped: &mut Vec<usize>,
) -> bool {
    members.clear();
    dropped.clear();
    let k = k.clamp(1, live.max(1));
    if arrival.len() < k {
        return false;
    }
    members.extend_from_slice(&arrival[..k]);
    dropped.extend_from_slice(&arrival[k..]);
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_set_counts_and_ids() {
        let mask = [true, false, true, true, false];
        let ls = LiveSet::new(&mask);
        assert_eq!(ls.count(), 3);
        assert_eq!(ls.ids(), vec![0, 2, 3]);
        assert!(ls.is_live(0) && !ls.is_live(1));
        assert!(!ls.is_live(99), "out-of-range rank is not live");
        let empty = LiveSet::new(&[]);
        assert_eq!(empty.count(), 0);
        assert!(empty.ids().is_empty());
    }

    // -- first_k_split edge cases (issue satellite) ----------------------

    #[test]
    fn first_k_zero_clamps_to_one() {
        // k = 0 is a degenerate request: the rule still forms an update
        // from the first arrival (an update needs ≥ 1 gradient)
        let (members, dropped) = first_k_split(&[3, 1, 2], 0, 3);
        assert_eq!(members, vec![3]);
        assert_eq!(dropped, vec![1, 2]);
    }

    #[test]
    fn first_k_exceeding_live_clamps_to_live() {
        // K > live: the barrier can never exceed the live membership
        let (members, dropped) = first_k_split(&[4, 0, 2], 10, 3);
        assert_eq!(members, vec![4, 0, 2]);
        assert!(dropped.is_empty());
        // with only 2 live the same arrivals split at 2
        let (members, dropped) = first_k_split(&[4, 0, 2], 10, 2);
        assert_eq!(members, vec![4, 0]);
        assert_eq!(dropped, vec![2]);
    }

    #[test]
    fn first_k_empty_arrival_is_below_threshold() {
        assert_eq!(first_k_split(&[], 3, 8), (Vec::new(), Vec::new()));
        // even the k = 0 degenerate form needs one arrival
        assert_eq!(first_k_split(&[], 0, 8), (Vec::new(), Vec::new()));
    }

    #[test]
    fn first_k_single_live_worker() {
        // live = 1 clamps any k to 1: the sole survivor forms the update
        let (members, dropped) = first_k_split(&[5], 3, 1);
        assert_eq!(members, vec![5]);
        assert!(dropped.is_empty());
        // live = 0 (transiently possible mid-outage) behaves like live = 1
        let (members, _) = first_k_split(&[5], 3, 0);
        assert_eq!(members, vec![5]);
    }

    // -- ring chaining ---------------------------------------------------

    #[test]
    fn ring_order_skips_dead_and_sorts_by_prediction() {
        let live = [true, true, false, true];
        let pred = [0.9, 0.3, 0.1, 0.5];
        // worker 2 is fastest but dead; live order sorts 1 < 3 < 0
        assert_eq!(ring_order(&live, &pred), vec![1, 3, 0]);
    }

    #[test]
    fn ring_split_clamps_to_keep_one_member() {
        let order = [1, 3, 0];
        let (ring, out) = ring_split(&order, 1);
        assert_eq!(ring, &[1, 3]);
        assert_eq!(out, &[0]);
        // removal can never empty the ring
        let (ring, out) = ring_split(&order, 10);
        assert_eq!(ring, &[1]);
        assert_eq!(out, &[3, 0]);
        // empty order stays empty on both sides
        let (ring, out) = ring_split(&[], 2);
        assert!(ring.is_empty() && out.is_empty());
    }

    // -- update grouping over live membership ----------------------------

    #[test]
    fn ssgd_barrier_shrinks_to_live_count() {
        let mode = DriverMode::Sync(SyncMode::Ssgd);
        let live = [true, false, true, true];
        let groups = [0usize; 4];
        // 2 of 3 live reported: barrier not met
        let pending = [(0, 1.0, 0u64), (2, 1.1, 0)];
        assert_eq!(next_update_group(&mode, &pending, &live, &groups), None);
        // all 3 live reported: fires with exactly the pending reporters
        let pending = [(0, 1.0, 0u64), (2, 1.1, 0), (3, 1.2, 0)];
        assert_eq!(
            next_update_group(&mode, &pending, &live, &groups),
            Some(vec![0, 2, 3])
        );
    }

    #[test]
    fn asgd_fires_per_report_static_x_clamps_to_live() {
        let live = [true, true, false, false];
        let groups = [0usize; 4];
        let pending = [(1, 1.0, 0u64)];
        assert_eq!(
            next_update_group(&DriverMode::Sync(SyncMode::Asgd), &pending, &live, &groups),
            Some(vec![1])
        );
        // x = 3 > 2 live: clamps to 2, fires once two reports are in
        let mode = DriverMode::Sync(SyncMode::StaticX(3));
        assert_eq!(next_update_group(&mode, &pending, &live, &groups), None);
        let pending = [(1, 1.0, 0u64), (0, 1.2, 0)];
        assert_eq!(next_update_group(&mode, &pending, &live, &groups), Some(vec![1, 0]));
    }

    #[test]
    fn dynamic_x_counts_only_live_group_members() {
        let mode = DriverMode::Sync(SyncMode::DynamicX);
        let live = [true, true, false, true];
        let groups = [0usize, 0, 0, 1];
        // group 0 has live members {0, 1}; dead worker 2 must not hold it
        let pending = [(0, 1.0, 0u64), (1, 1.1, 0)];
        assert_eq!(next_update_group(&mode, &pending, &live, &groups), Some(vec![0, 1]));
    }

    #[test]
    fn ar_and_first_k_are_not_grouped_here() {
        let live = [true; 3];
        let groups = [0usize; 3];
        let pending = [(0, 1.0, 0u64), (1, 1.1, 0), (2, 1.2, 0)];
        let ar = DriverMode::Sync(SyncMode::ArRing { removed: 1, tw_ms: 60.0 });
        assert_eq!(next_update_group(&ar, &pending, &live, &groups), None);
        assert_eq!(next_update_group(&DriverMode::FirstK(2), &pending, &live, &groups), None);
    }

    // -- scratch-buffer variants match the allocating forms --------------

    #[test]
    fn into_variants_match_allocating_forms() {
        let mut rng = crate::simrng::Rng::seeded(31);
        let mut group = vec![99usize]; // deliberately dirty scratch
        let mut order = vec![7usize];
        let mut members = vec![1usize];
        let mut dropped = vec![2usize];
        for _ in 0..300 {
            let n = rng.usize(1, 12);
            let live: Vec<bool> = (0..n).map(|_| rng.chance(0.8)).collect();
            let dyn_groups: Vec<usize> = (0..n).map(|_| rng.usize(0, n - 1)).collect();
            let predicted: Vec<f64> = (0..n).map(|_| rng.range(0.05, 5.0)).collect();
            let mut pending: Vec<(usize, f64, u64)> = Vec::new();
            for w in 0..n {
                if rng.chance(0.6) {
                    pending.push((w, rng.range(0.0, 9.0), 0));
                }
            }
            // arrival order is not rank order in general
            if pending.len() > 1 {
                let i = rng.usize(0, pending.len() - 1);
                pending.swap(0, i);
            }
            for mode in [
                DriverMode::Sync(SyncMode::Ssgd),
                DriverMode::Sync(SyncMode::Asgd),
                DriverMode::Sync(SyncMode::StaticX(rng.usize(1, n))),
                DriverMode::Sync(SyncMode::DynamicX),
                DriverMode::Sync(SyncMode::ArRing { removed: 1, tw_ms: 30.0 }),
                DriverMode::FirstK(rng.usize(0, n)),
            ] {
                let want = next_update_group(&mode, &pending, &live, &dyn_groups);
                let fired =
                    next_update_group_into(&mode, &pending, &live, &dyn_groups, &mut group);
                assert_eq!(want.is_some(), fired, "{mode:?}");
                if let Some(w) = want {
                    assert_eq!(w, group, "{mode:?}");
                }
            }
            ring_order_into(&live, &predicted, &mut order);
            assert_eq!(ring_order(&live, &predicted), order);
            let arrival: Vec<usize> = pending.iter().map(|&(w, _, _)| w).collect();
            let k = rng.usize(0, n);
            let lc = live_count(&live);
            let (wm, wd) = first_k_split(&arrival, k, lc);
            let fired = first_k_split_into(&arrival, k, lc, &mut members, &mut dropped);
            assert_eq!(fired, !wm.is_empty());
            assert_eq!(wm, members);
            assert_eq!(wd, dropped);
        }
    }

    #[test]
    fn worker_block_maintains_live_count() {
        let mut wb = WorkerBlock::new(5, 2.0);
        assert_eq!(wb.live_count(), 5);
        assert_eq!(wb.live_count(), live_count(wb.alive()));
        assert_eq!(wb.iter_start, vec![2.0; 5]);
        wb.set_alive(2, false);
        wb.set_alive(4, false);
        wb.set_alive(4, false); // idempotent: no double-decrement
        assert_eq!(wb.live_count(), 3);
        assert_eq!(wb.live_count(), live_count(wb.alive()));
        assert!(!wb.is_alive(2) && wb.is_alive(0));
        wb.set_alive(2, true);
        wb.set_alive(2, true); // idempotent: no double-increment
        assert_eq!(wb.live_count(), 4);
        assert_eq!(wb.live_count(), live_count(wb.alive()));
    }

    #[test]
    fn dead_predictions_masked_to_live_min() {
        let live = [true, false, true];
        let mut pred = [0.6, 9.0, 0.4];
        mask_dead_with_live_min(&mut pred, &live);
        assert_eq!(pred, [0.6, 0.4, 0.4]);
        // no finite live prediction: untouched
        let mut pred = [f64::INFINITY, 3.0];
        mask_dead_with_live_min(&mut pred, &[true, false]);
        assert_eq!(pred[1], 3.0);
    }
}
