//! Event vocabulary of the trace driver.
//!
//! The driver is a discrete-event machine: every state change enters
//! through exactly one of the [`Event`] variants below, scheduled on a
//! stable-heap queue ([`crate::sim::Engine`]) whose ties break FIFO by
//! insertion sequence — the property that makes replays bit-identical
//! (pinned by `tests/golden_traces.rs` via processed-event counts).
//!
//! Layering (DESIGN.md §8): this module owns *what can happen*;
//! [`super::membership`] owns *who is in the round*, [`super::itertime`]
//! owns *how long an iteration takes*, [`super::faulting`] owns the §7
//! failure transitions, and `mod.rs` orchestrates.

use crate::sim::Engine;

/// One schedulable driver event.
pub enum Event {
    /// a job from the trace reaches its arrival time
    Arrive(usize),
    /// a worker's iteration completes (stale if `iter` no longer matches)
    WorkerDone { job: usize, worker: usize, iter: u64 },
    /// the AR ring's parent-wait window closes (§IV-B)
    ArFlush { job: usize },
    /// periodic server-utilization sampling tick (Fig 9)
    ServerSample,
    /// an entry of the fault plan comes due (index into `cfg.faults`)
    Fault(usize),
    /// a crashed worker finishes restarting
    WorkerRestart { job: usize, worker: usize },
    /// a crashed PS finishes restarting
    PsRestart { job: usize, ps_idx: usize },
}

/// The driver's event queue: a stable binary heap with FIFO tie-break.
pub type EventQueue = Engine<Event>;
