//! Event vocabulary of the trace driver.
//!
//! The driver is a discrete-event machine: every state change enters
//! through exactly one of the [`Event`] variants below, scheduled on a
//! stable-heap queue ([`crate::sim::Engine`]) whose ties break FIFO by
//! insertion sequence — the property that makes replays bit-identical
//! (pinned by `tests/golden_traces.rs` via processed-event counts).
//!
//! Layering (DESIGN.md §8): this module owns *what can happen*;
//! [`super::membership`] owns *who is in the round*, [`super::itertime`]
//! owns *how long an iteration takes*, [`super::faulting`] owns the §7
//! failure transitions, and `mod.rs` orchestrates.

use crate::sim::{ShardedEngine, SimTime};

/// One schedulable driver event.
pub enum Event {
    /// a job from the trace reaches its arrival time
    Arrive(usize),
    /// a worker's iteration completes (stale if `iter` no longer matches)
    WorkerDone { job: usize, worker: usize, iter: u64 },
    /// the AR ring's parent-wait window closes (§IV-B)
    ArFlush { job: usize },
    /// periodic server-utilization sampling tick (Fig 9)
    ServerSample,
    /// an entry of the fault plan comes due (index into `cfg.faults`)
    Fault(usize),
    /// a crashed worker finishes restarting
    WorkerRestart { job: usize, worker: usize },
    /// a crashed PS finishes restarting
    PsRestart { job: usize, ps_idx: usize },
}

/// The driver's event queue: job-partitioned sub-heaps with FIFO
/// tie-break, byte-identical in pop order to the old global heap (the
/// `(at, seq)` total order is shard-independent — see
/// [`crate::sim::ShardedEngine`]).
///
/// Partition key: every job-carrying event lands on shard
/// `job % nshards` (a job's whole event stream stays in one small
/// heap — the server-partition locality the job's placement induces);
/// the two server-less variants (`ServerSample`, `Fault`) pin to
/// shard 0. The key only picks *which heap sifts*, never the order, so
/// golden traces and `run_counted` event counts are unchanged at any
/// shard count.
pub struct EventQueue {
    inner: ShardedEngine<Event>,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new(1)
    }
}

impl EventQueue {
    /// `nshards` clamped to `1..=`[`crate::sim::MAX_SHARDS`].
    pub fn new(nshards: usize) -> Self {
        EventQueue { inner: ShardedEngine::new(nshards) }
    }

    /// Shard count for a cluster of `servers` servers: one shard per
    /// ~8 servers, so the paper testbed (8 servers) keeps a single
    /// heap and a 1000× cluster (8000 servers) saturates the cap.
    pub fn for_cluster(servers: usize) -> Self {
        Self::new(servers.div_ceil(8))
    }

    pub fn num_shards(&self) -> usize {
        self.inner.num_shards()
    }

    fn shard_of(&self, event: &Event) -> usize {
        match *event {
            Event::Arrive(job)
            | Event::WorkerDone { job, .. }
            | Event::ArFlush { job }
            | Event::WorkerRestart { job, .. }
            | Event::PsRestart { job, .. } => job % self.inner.num_shards(),
            Event::ServerSample | Event::Fault(_) => 0,
        }
    }

    pub fn schedule_at(&mut self, at: SimTime, event: Event) {
        let shard = self.shard_of(&event);
        self.inner.schedule_at(shard, at, event);
    }

    pub fn schedule_in(&mut self, delay: SimTime, event: Event) {
        let shard = self.shard_of(&event);
        self.inner.schedule_in(shard, delay, event);
    }

    pub fn next(&mut self) -> Option<(SimTime, Event)> {
        self.inner.next()
    }

    pub fn now(&self) -> SimTime {
        self.inner.now()
    }

    pub fn events_processed(&self) -> u64 {
        self.inner.events_processed()
    }

    pub fn pending(&self) -> usize {
        self.inner.pending()
    }

    pub fn peak_pending(&self) -> usize {
        self.inner.peak_pending()
    }

    pub fn peek_time(&self) -> Option<SimTime> {
        self.inner.peek_time()
    }
}
