//! The STAR controller (Fig 15): per-iteration straggler prediction →
//! synchronization-mode determination (STAR-H heuristic / STAR-ML
//! regressor / STAR- early decision) → resource-aware prevention, as a
//! [`Policy`] for the trace driver. Ablation switches (§V-C) turn each
//! ingredient off.

use std::time::Instant;

use crate::decide::{
    choose_ar_heuristic, choose_ps_heuristic, expected_reports, Decision, DeciderKind, MlDecider,
};
use crate::driver::{DriverMode, Policy, PolicyDecision, RoundObs};
use crate::prevent::CommTree;
use crate::sync::{candidate_modes_ar, candidate_modes_ps, SyncMode};
use crate::trace::Arch;

/// The t_w grid STAR-H enumerates for AR (§V: 30–210 ms).
pub const TW_GRID_MS: [f64; 7] = [30.0, 60.0, 90.0, 120.0, 150.0, 180.0, 210.0];

/// Ablation switches (§V-C variant names in comments); the default (all
/// off) is full STAR.
#[derive(Clone, Debug, Default)]
pub struct Ablation {
    /// /SP: replace STAR's resource-based prediction with the
    /// fixed-duration rule of [29]
    pub use_fixed_duration_prediction: bool,
    /// /xS: only SSGD/ASGD available (no x-order modes)
    pub no_x_order: bool,
    /// /DS: drop the dynamic-x-order mode
    pub no_dynamic: bool,
    /// /PS: drop "preventing stragglers upon mode change" entirely
    pub no_prevention: bool,
    /// /W: drop the worker-equalization part of prevention
    pub no_worker_equalize: bool,
    /// /RS: ignore resource sensitivity / training stage in deprivation
    pub no_sensitivity: bool,
    /// /Mu: greedy most-capacity placement instead of Muri-style balance
    pub greedy_placement: bool,
    /// /N: placement without balancing the number of high-load tasks
    pub no_balance_count: bool,
    /// /Tree: no communication-tree amortization
    pub no_tree: bool,
}

/// STAR as a driver policy.
pub struct Star {
    pub kind: DeciderKind,
    pub ablation: Ablation,
    name: &'static str,
    ml: MlDecider,
    /// paper-scale decision pause for the heuristic (§V: ~970 ms python;
    /// our measured rust latency is recorded separately in Fig 28)
    pub pause_h_s: f64,
    /// simulated overlapped ML inference latency (§V: ~644 ms total ÷ jobs)
    pub overhead_ml_s: f64,
    /// STAR-: stale predictions (previous round's) are used
    early_prev_predictions: Vec<f64>,
    /// measured wall-clock of our rust decision path
    pub wall_ns_total: u128,
    pub wall_decisions: u64,
    fixed_rule: Option<crate::predict::FixedDurationRule>,
    last_feats: Vec<([f64; crate::decide::ML_FEATURES], u64)>,
    /// hysteresis: keep the current mode unless the best candidate is
    /// materially better (avoids mode thrash + repeated switch pauses)
    last_mode: Option<SyncMode>,
    pub hysteresis: f64,
    /// worker count the §IV-D2b tree was already installed for: the tree
    /// is a pure function of n here, so later decisions send `None` and
    /// the driver keeps the installed one (saves a build+clone per round)
    tree_installed_n: Option<usize>,
    /// scratch for the per-group equalization pass (allocation-free rounds)
    eq_times: Vec<f64>,
    eq_fixed: Vec<f64>,
    eq_caps: Vec<f64>,
}

impl Star {
    pub fn new(kind: DeciderKind) -> Self {
        let name = match kind {
            DeciderKind::Heuristic => "STAR-H",
            DeciderKind::Ml => "STAR-ML",
            DeciderKind::Early => "STAR-",
        };
        Star {
            kind,
            ablation: Ablation::default(),
            name,
            ml: MlDecider::new(),
            pause_h_s: 0.97,
            overhead_ml_s: 0.20,
            early_prev_predictions: Vec::new(),
            wall_ns_total: 0,
            wall_decisions: 0,
            fixed_rule: None,
            last_feats: Vec::new(),
            last_mode: None,
            hysteresis: 0.12,
            tree_installed_n: None,
            eq_times: Vec::new(),
            eq_fixed: Vec::new(),
            eq_caps: Vec::new(),
        }
    }

    /// §IV-D2b tree to ship with this decision: built once per worker
    /// count, `None` afterwards (the driver keeps the installed tree).
    fn tree_update(&mut self, n: usize) -> Option<CommTree> {
        if self.tree_installed_n == Some(n) {
            None
        } else {
            self.tree_installed_n = Some(n);
            Some(CommTree::build(&vec![1.0; n], 3))
        }
    }

    pub fn with_ablation(kind: DeciderKind, ablation: Ablation, name: &'static str) -> Self {
        let mut s = Self::new(kind);
        s.ablation = ablation;
        s.name = name;
        s
    }

    fn candidates(&self, n: usize, arch: Arch, stragglers: usize) -> Vec<SyncMode> {
        match arch {
            Arch::Ps => {
                if self.ablation.no_x_order {
                    vec![SyncMode::Ssgd, SyncMode::Asgd]
                } else {
                    let mut v = candidate_modes_ps(n);
                    if self.ablation.no_dynamic {
                        v.retain(|m| *m != SyncMode::DynamicX);
                    }
                    v
                }
            }
            Arch::AllReduce => candidate_modes_ar(stragglers.max(1), &TW_GRID_MS),
        }
    }

    fn heuristic_decision(&self, obs: &RoundObs, predicted: &[f64], stragglers: usize) -> Decision {
        match obs.arch {
            Arch::Ps => {
                if self.ablation.no_x_order {
                    // rank only SSGD vs ASGD
                    let mut ranked: Vec<(SyncMode, f64)> = [SyncMode::Ssgd, SyncMode::Asgd]
                        .into_iter()
                        .map(|m| {
                            let est = crate::decide::time_to_progress_ps(
                                obs.spec, obs.progress, obs.n, &m, predicted,
                            );
                            (m, est)
                        })
                        .collect();
                    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                    let (mode, est) = ranked[0];
                    let lr = crate::decide::lr_for_mode(obs.spec, obs.n, &mode, predicted);
                    Decision { mode, lr, est, ranked }
                } else if self.ablation.no_dynamic {
                    let mut d = choose_ps_heuristic(obs.spec, obs.progress, obs.n, predicted);
                    d.ranked.retain(|(m, _)| *m != SyncMode::DynamicX);
                    let (mode, est) = d.ranked[0];
                    d.mode = mode;
                    d.est = est;
                    d
                } else {
                    choose_ps_heuristic(obs.spec, obs.progress, obs.n, predicted)
                }
            }
            Arch::AllReduce => {
                choose_ar_heuristic(obs.spec, obs.progress, obs.n, stragglers, &TW_GRID_MS, predicted)
            }
        }
    }
}

impl Policy for Star {
    fn name(&self) -> &'static str {
        self.name
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        let wall = Instant::now();

        // -- straggler prediction (§IV-A; /SP swaps in the [29] rule) -----
        let mut predicted: Vec<f64> = if self.ablation.use_fixed_duration_prediction {
            let rule = self
                .fixed_rule
                .get_or_insert_with(|| crate::predict::FixedDurationRule::new(obs.n, 5.0));
            let last: Vec<f64> = obs
                .last_times
                .iter()
                .map(|&t| if t.is_finite() { t } else { 0.5 })
                .collect();
            let flags = rule.observe(obs.now, &last);
            // rule only yields flags; synthesize times: flagged workers
            // keep their slow time, others get the min
            let min = last.iter().cloned().fold(f64::INFINITY, f64::min);
            last.iter()
                .zip(&flags)
                .map(|(&t, &f)| if f { t } else { min })
                .collect()
        } else if self.kind == DeciderKind::Early && !self.early_prev_predictions.is_empty() {
            // STAR-: decide with the previous round's predictions
            self.early_prev_predictions.clone()
        } else {
            obs.predicted_times.to_vec()
        };
        if self.kind == DeciderKind::Early {
            self.early_prev_predictions = obs.predicted_times.to_vec();
        }

        // dead workers (fault injection) are outside the round: the
        // shared membership layer gives them the live minimum so they
        // neither read as stragglers nor distort the x-order grouping the
        // driver re-forms over survivors
        crate::driver::membership::mask_dead_with_live_min(&mut predicted, obs.live);

        let flags = crate::predict::straggler_flags(&predicted);
        let stragglers = flags.iter().filter(|&&f| f).count();

        // no predicted stragglers -> SSGD (Fig 15: "otherwise SSGD")
        if stragglers == 0 {
            let mode = match obs.arch {
                Arch::Ps => SyncMode::Ssgd,
                Arch::AllReduce => SyncMode::ArRing { removed: 0, tw_ms: 0.0 },
            };
            self.last_mode = Some(mode);
            let mode = DriverMode::Sync(mode);
            self.wall_ns_total += wall.elapsed().as_nanos();
            self.wall_decisions += 1;
            let mut d = PolicyDecision::simple(mode);
            d.lr_rescaled = true;
            if !self.ablation.no_tree {
                d.tree = self.tree_update(obs.n);
            }
            return d;
        }

        // -- mode determination (§IV-C) ------------------------------------
        let mut decision = if self.kind == DeciderKind::Ml && self.ml.trained() {
            // periodic continued distillation keeps the regressor pinned
            // to the (cheap, µs-scale) heuristic across training stages
            if self.wall_decisions % 4 == 0 {
                let d = self.heuristic_decision(obs, &predicted, stragglers);
                for (m, est) in &d.ranked {
                    let x = MlDecider::features(obs.spec, obs.progress, obs.n, &predicted, m);
                    self.ml.observe(&x, *est);
                }
            }
            let cands = self.candidates(obs.n, obs.arch, stragglers);
            self.ml.choose(obs.spec, obs.progress, obs.n, &predicted, cands)
        } else {
            let d = self.heuristic_decision(obs, &predicted, stragglers);
            // §IV-C2: the regressor is bootstrapped from the heuristic's
            // own estimates (distillation) until it takes over
            if self.kind == DeciderKind::Ml {
                for (m, est) in &d.ranked {
                    let x = MlDecider::features(obs.spec, obs.progress, obs.n, &predicted, m);
                    self.ml.observe(&x, *est);
                }
            }
            d
        };
        // hysteresis: stick with the current mode unless the winner beats
        // it by more than `hysteresis`
        if let Some(last) = &self.last_mode {
            if let Some((_, last_est)) = decision.ranked.iter().find(|(m, _)| m == last) {
                if *last_est <= decision.est * (1.0 + self.hysteresis) {
                    decision.mode = *last;
                    decision.est = *last_est;
                }
            }
        }
        self.last_mode = Some(decision.mode);

        // remember features for online ML training (trained on heuristic
        // outcomes first, then refined; §IV-C2)
        if self.kind == DeciderKind::Ml {
            let x = MlDecider::features(obs.spec, obs.progress, obs.n, &predicted, &decision.mode);
            self.last_feats.push((x, obs.step));
            if self.last_feats.len() > 64 {
                self.last_feats.remove(0);
            }
        }

        // -- prevention (§IV-D1): group equalization ------------------------
        // Within each gradient group the slowest member sets the deadline;
        // faster members yield CPU/bandwidth so they complete exactly at
        // that deadline — freed resources flow to co-located tasks through
        // the cluster's fair-sharing, at zero TTA cost to this job.
        let deprive = Vec::new();
        let mut self_caps = Vec::new();
        if !self.ablation.no_prevention && !self.ablation.no_worker_equalize {
            let groups: Vec<Vec<usize>> = match &decision.mode {
                SyncMode::Ssgd => vec![(0..obs.n).collect()],
                SyncMode::StaticX(x) => {
                    let mut order: Vec<usize> = (0..obs.n).collect();
                    order.sort_by(|&a, &b| predicted[a].partial_cmp(&predicted[b]).unwrap());
                    order.chunks(*x).map(|c| c.to_vec()).collect()
                }
                SyncMode::DynamicX => crate::sync::cluster_times(&predicted, 0.15, 0.02),
                SyncMode::Asgd => Vec::new(),
                SyncMode::ArRing { removed, .. } => {
                    let keep = obs.n - removed.min(&(obs.n - 1));
                    let mut order: Vec<usize> = (0..obs.n).collect();
                    order.sort_by(|&a, &b| predicted[a].partial_cmp(&predicted[b]).unwrap());
                    vec![order[..keep].to_vec()]
                }
            };
            if !groups.is_empty() {
                self_caps = vec![1.0; obs.n];
                let fixed = obs.spec.gpu_ms / 1000.0;
                for g in &groups {
                    self.eq_times.clear();
                    self.eq_times.extend(g.iter().map(|&w| predicted[w]));
                    let deadline = self.eq_times.iter().cloned().fold(0.0, f64::max);
                    self.eq_fixed.clear();
                    self.eq_fixed.resize(g.len(), fixed);
                    crate::prevent::equalize_group_into(
                        &self.eq_times,
                        &self.eq_fixed,
                        &mut self.eq_caps,
                    );
                    for (k, &w) in g.iter().enumerate() {
                        // conservative: predictions are noisy, so reclaim
                        // only part of the headroom, and only when the gap
                        // to the group deadline is material — an over-
                        // tight cap would itself manufacture a straggler
                        if deadline > 1.3 * self.eq_times[k] {
                            self_caps[w] = 1.0 - 0.4 * (1.0 - self.eq_caps[k]);
                        }
                    }
                }
            }
        }

        let reports = expected_reports(obs.n, &decision.mode, &predicted) as usize;
        let _ = reports;

        let (pause, overhead) = match self.kind {
            DeciderKind::Heuristic => (self.pause_h_s, 0.0),
            DeciderKind::Ml => (0.0, self.overhead_ml_s),
            DeciderKind::Early => (0.0, self.pause_h_s), // overlapped, but accounted
        };

        self.wall_ns_total += wall.elapsed().as_nanos();
        self.wall_decisions += 1;

        let mut d = PolicyDecision::simple(DriverMode::Sync(decision.mode));
        d.lr_rescaled = true; // §IV-C: STAR always rescales LR on switch
        d.pause_s = pause;
        d.overhead_s = overhead;
        d.deprive = deprive;
        d.self_caps = self_caps;
        if !self.ablation.no_tree {
            d.tree = self.tree_update(obs.n);
        }
        d
    }

    fn feedback(&mut self, step: u64, time_per_progress: f64) {
        // outcome refinement: low-rate, bounded — realized seconds-per-
        // value-unit is far noisier than the heuristic's estimates, so it
        // nudges rather than dominates the distilled regressor
        if self.kind == DeciderKind::Ml && step % 64 == 0 {
            if let Some(idx) = self.last_feats.iter().position(|&(_, s)| s <= step) {
                let (x, _) = self.last_feats.remove(idx);
                let clamped = time_per_progress.clamp(1e-3, 1e3);
                self.ml.observe(&x, clamped);
            }
        }
    }

    fn balanced_placement(&self) -> bool {
        !(self.ablation.no_balance_count || self.ablation.greedy_placement)
    }

    fn wants_tree(&self) -> bool {
        !self.ablation.no_tree
    }
}

/// Named ablation constructors (§V-C).
pub fn ablations() -> Vec<(&'static str, Ablation)> {
    vec![
        ("STAR/SP", Ablation { use_fixed_duration_prediction: true, ..Default::default() }),
        ("STAR/xS", Ablation { no_x_order: true, ..Default::default() }),
        ("STAR/DS", Ablation { no_dynamic: true, ..Default::default() }),
        ("STAR/PS", Ablation { no_prevention: true, ..Default::default() }),
        ("STAR/W", Ablation { no_worker_equalize: true, ..Default::default() }),
        ("STAR/RS", Ablation { no_sensitivity: true, ..Default::default() }),
        ("STAR/Mu", Ablation { greedy_placement: true, ..Default::default() }),
        ("STAR/N", Ablation { no_balance_count: true, ..Default::default() }),
        ("STAR/Tree", Ablation { no_tree: true, ..Default::default() }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ZOO;

    /// all-live mask large enough for every test's worker count
    const LIVE: [bool; 16] = [true; 16];

    fn obs<'a>(
        predicted: &'a [f64],
        last: &'a [f64],
        flags: &'a [bool],
        arch: Arch,
    ) -> RoundObs<'a> {
        RoundObs {
            job: 0,
            n: predicted.len(),
            arch,
            spec: &ZOO[0],
            step: 500,
            progress: 100.0,
            now: 100.0,
            predicted_times: predicted,
            last_times: last,
            value: 50.0,
            predicted_stragglers: flags,
            live: &LIVE[..predicted.len()],
        }
    }

    #[test]
    fn no_straggler_means_ssgd() {
        let mut star = Star::new(DeciderKind::Heuristic);
        let p = vec![0.3; 8];
        let f = vec![false; 8];
        let d = star.decide(&obs(&p, &p, &f, Arch::Ps));
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Ssgd));
        assert_eq!(d.pause_s, 0.0, "no decision pause when no straggler");
    }

    #[test]
    fn straggler_triggers_heuristic_with_pause() {
        let mut star = Star::new(DeciderKind::Heuristic);
        let mut p = vec![0.3; 8];
        p[0] = 3.0;
        let f = crate::predict::straggler_flags(&p);
        let d = star.decide(&obs(&p, &p, &f, Arch::Ps));
        assert_ne!(d.mode, DriverMode::Sync(SyncMode::Ssgd));
        assert!(d.pause_s > 0.0);
        assert!(d.lr_rescaled);
    }

    #[test]
    fn dead_worker_is_not_a_straggler() {
        let mut star = Star::new(DeciderKind::Heuristic);
        let mut p = vec![0.3; 8];
        p[0] = 3.0; // would be a straggler…
        let f = crate::predict::straggler_flags(&p);
        let mut o = obs(&p, &p, &f, Arch::Ps);
        let mut live = vec![true; 8];
        live[0] = false; // …but it is dead: the driver runs without it
        o.live = &live;
        let d = star.decide(&o);
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Ssgd), "no live straggler => SSGD");
    }

    #[test]
    fn ml_defers_to_heuristic_until_trained() {
        let mut star = Star::new(DeciderKind::Ml);
        let mut p = vec![0.3; 8];
        p[0] = 3.0;
        let f = crate::predict::straggler_flags(&p);
        let d = star.decide(&obs(&p, &p, &f, Arch::Ps));
        // untrained: heuristic path, but no pause (ML overlaps by §V)
        assert_eq!(d.pause_s, 0.0);
        assert!(d.overhead_s > 0.0);
        assert_ne!(d.mode, DriverMode::Sync(SyncMode::Ssgd));
    }

    #[test]
    fn early_variant_uses_stale_predictions() {
        let mut star = Star::new(DeciderKind::Early);
        let p1 = vec![0.3; 8]; // first round: uniform
        let f1 = vec![false; 8];
        let _ = star.decide(&obs(&p1, &p1, &f1, Arch::Ps));
        // second round: a straggler appears, but STAR- decides on round-1
        // predictions => still SSGD
        let mut p2 = vec![0.3; 8];
        p2[0] = 3.0;
        let f2 = crate::predict::straggler_flags(&p2);
        let d = star.decide(&obs(&p2, &p2, &f2, Arch::Ps));
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Ssgd));
        // third round: now it sees them
        let d3 = star.decide(&obs(&p2, &p2, &f2, Arch::Ps));
        assert_ne!(d3.mode, DriverMode::Sync(SyncMode::Ssgd));
    }

    #[test]
    fn ar_arch_yields_ring_modes() {
        let mut star = Star::new(DeciderKind::Heuristic);
        let mut p = vec![0.3; 8];
        p[0] = 3.0;
        let f = crate::predict::straggler_flags(&p);
        let d = star.decide(&obs(&p, &p, &f, Arch::AllReduce));
        assert!(matches!(d.mode, DriverMode::Sync(SyncMode::ArRing { .. })));
    }

    #[test]
    fn xs_ablation_limits_candidates() {
        let abl = Ablation { no_x_order: true, ..Default::default() };
        let mut star = Star::with_ablation(DeciderKind::Heuristic, abl, "STAR/xS");
        let mut p = vec![0.3; 8];
        p[0] = 30.0;
        let f = crate::predict::straggler_flags(&p);
        let d = star.decide(&obs(&p, &p, &f, Arch::Ps));
        assert!(
            matches!(d.mode, DriverMode::Sync(SyncMode::Ssgd) | DriverMode::Sync(SyncMode::Asgd)),
            "{:?}",
            d.mode
        );
        assert_eq!(d.mode, DriverMode::Sync(SyncMode::Asgd), "severe straggler => ASGD");
    }

    #[test]
    fn tree_ablation_disables_tree() {
        let abl = Ablation { no_tree: true, ..Default::default() };
        let mut star = Star::with_ablation(DeciderKind::Heuristic, abl, "STAR/Tree");
        assert!(!star.wants_tree());
        let p = vec![0.3; 8];
        let f = vec![false; 8];
        let d = star.decide(&obs(&p, &p, &f, Arch::Ps));
        assert!(d.tree.is_none());
    }

    #[test]
    fn wall_clock_measured() {
        let mut star = Star::new(DeciderKind::Heuristic);
        let mut p = vec![0.3; 8];
        p[0] = 3.0;
        let f = crate::predict::straggler_flags(&p);
        for _ in 0..5 {
            let _ = star.decide(&obs(&p, &p, &f, Arch::Ps));
        }
        assert_eq!(star.wall_decisions, 5);
        assert!(star.wall_ns_total > 0);
    }

    #[test]
    fn ablation_list_matches_paper_variants() {
        let names: Vec<&str> = ablations().iter().map(|(n, _)| *n).collect();
        for want in ["STAR/SP", "STAR/xS", "STAR/DS", "STAR/PS", "STAR/W", "STAR/RS",
                     "STAR/Mu", "STAR/N", "STAR/Tree"] {
            assert!(names.contains(&want), "{want}");
        }
    }
}
