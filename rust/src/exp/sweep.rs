//! Parallel sweep harness: run independent experiment cells — one
//! (cluster, driver) pair per (seed, policy, rate) combination — across
//! every core with `std::thread::scope`.
//!
//! Each cell is a pure function of its inputs (the simulator owns all of
//! its RNG state, see DESIGN.md §6), so parallel execution is safe and
//! the only thing the harness must guarantee is **ordering**: results
//! come back in item order regardless of which thread finished first,
//! making a `--threads N` sweep byte-identical to `--threads 1` (pinned
//! by `exp::resilience` tests and the CI diff step). No RNG, cluster, or
//! driver state is ever shared across threads — workers pull cell
//! *indices* from an atomic counter and build everything cell-local.
//!
//! The harness also times each cell, so a sweep can report its
//! parallelism: `cells_s_sum` (Σ per-cell wall) vs `wall_s` (sweep
//! wall) gives the realized concurrency, recorded in a `star-bench-v1`
//! artifact (`BENCH_sweep.json`) and tracked across PRs like the perf
//! benches. The true wall-time *speedup* is the `wall_s` ratio between
//! a `--threads 1` and a `--threads N` artifact of the same grid (CI
//! computes it from its serial + parallel resilience runs).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::Context;

use crate::jsonio::{self, Json};

/// Default worker count: all available cores (1 if undetectable).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a `--threads` request: 0 (the CLI default when the flag is
/// absent) means all available cores.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Cross product in outer-major order — the canonical grid layout every
/// sweep (resilience's rate × policy, a scenario's arch × policy) lays
/// its cells out in, so tables and artifacts emit rows in the same order
/// regardless of which harness built the grid.
pub fn cross<A: Clone, B: Clone>(outer: &[A], inner: &[B]) -> Vec<(A, B)> {
    let mut out = Vec::with_capacity(outer.len() * inner.len());
    for a in outer {
        for b in inner {
            out.push((a.clone(), b.clone()));
        }
    }
    out
}

/// Render a `catch_unwind` payload as the message the panicking cell
/// raised (panics carry `&str` or `String` in practice).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f(index, &item)` over every item on up to `threads` workers and
/// return the results **in item order**. `threads <= 1` runs inline
/// (bit-and-byte identical output either way — the contract callers rely
/// on for deterministic sweep artifacts).
///
/// A panicking cell no longer aborts the whole sweep: each cell runs
/// under `catch_unwind`, the remaining cells still execute, and the
/// sweep then fails with the poisoned cells' indices, inputs, and panic
/// messages named.
pub fn run_indexed<T, R, F>(items: &[T], threads: usize, f: F) -> crate::Result<Vec<R>>
where
    T: Sync + std::fmt::Debug,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    Ok(run_cells(items, threads, f)?.0)
}

/// Like [`run_indexed`], additionally returning per-cell wall seconds
/// (item order) and the sweep's total wall seconds.
pub fn run_cells<T, R, F>(items: &[T], threads: usize, f: F) -> crate::Result<(Vec<R>, Vec<f64>, f64)>
where
    T: Sync + std::fmt::Debug,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let t0 = Instant::now();
    let threads = threads.clamp(1, items.len().max(1));
    let mut tagged: Vec<(usize, R, f64)> = Vec::with_capacity(items.len());
    // (index, panic message) per poisoned cell; collected, not fatal
    // mid-sweep, so every healthy cell still completes
    let mut poisoned: Vec<(usize, String)> = Vec::new();
    if threads <= 1 {
        for (i, item) in items.iter().enumerate() {
            let c0 = Instant::now();
            match catch_unwind(AssertUnwindSafe(|| f(i, item))) {
                Ok(r) => tagged.push((i, r, c0.elapsed().as_secs_f64())),
                Err(p) => poisoned.push((i, panic_message(p))),
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        let next_ref = &next;
        let f_ref = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    s.spawn(move || {
                        let mut out: Vec<(usize, R, f64)> = Vec::new();
                        let mut bad: Vec<(usize, String)> = Vec::new();
                        loop {
                            let i = next_ref.fetch_add(1, Ordering::Relaxed);
                            if i >= items.len() {
                                break;
                            }
                            let c0 = Instant::now();
                            match catch_unwind(AssertUnwindSafe(|| f_ref(i, &items[i]))) {
                                Ok(r) => out.push((i, r, c0.elapsed().as_secs_f64())),
                                Err(p) => bad.push((i, panic_message(p))),
                            }
                        }
                        (out, bad)
                    })
                })
                .collect();
            for h in handles {
                // cells are caught individually, so a worker thread can
                // only die outside any cell — treat that as fatal too
                match h.join() {
                    Ok((out, bad)) => {
                        tagged.extend(out);
                        poisoned.extend(bad);
                    }
                    Err(p) => poisoned.push((usize::MAX, panic_message(p))),
                }
            }
        });
        tagged.sort_by_key(|&(i, _, _)| i);
        poisoned.sort_by_key(|&(i, _)| i);
    }
    if !poisoned.is_empty() {
        let detail: Vec<String> = poisoned
            .iter()
            .map(|(i, msg)| match items.get(*i) {
                Some(item) => format!("cell {i} (input {item:?}): {msg}"),
                None => format!("sweep worker: {msg}"),
            })
            .collect();
        anyhow::bail!(
            "sweep failed: {} of {} cell(s) panicked — {}",
            poisoned.len(),
            items.len(),
            detail.join("; ")
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut results = Vec::with_capacity(tagged.len());
    let mut cell_s = Vec::with_capacity(tagged.len());
    for (_, r, dt) in tagged {
        results.push(r);
        cell_s.push(dt);
    }
    Ok((results, cell_s, wall))
}

/// Write a `star-bench-v1` artifact recording a sweep's wall time, the
/// summed per-cell wall seconds, thread count, and the realized
/// concurrency (`cells_s_sum / wall_s` — how many cells were in flight
/// on average). Concurrency is *not* the serial-vs-parallel wall
/// speedup: under memory/cache contention concurrent cells individually
/// slow down, inflating `cells_s_sum` relative to a true serial run.
/// The honest speedup number is the ratio of `wall_s` between two
/// artifacts of the same sweep at `--threads 1` and `--threads N` —
/// which is exactly what CI computes from its serial and parallel
/// resilience runs.
pub fn write_sweep_bench(
    path: &Path,
    name: &str,
    threads: usize,
    cell_s: &[f64],
    wall_s: f64,
) -> crate::Result<()> {
    let cells = cell_s.len();
    let cells_s_sum: f64 = cell_s.iter().sum();
    let concurrency = if wall_s > 0.0 { cells_s_sum / wall_s } else { 1.0 };
    let per_cell_ns = if cells > 0 { wall_s * 1e9 / cells as f64 } else { 0.0 };
    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::exp::sweep")),
        (
            "results",
            Json::Arr(vec![jsonio::obj(vec![
                ("name", jsonio::s(name)),
                ("iters", jsonio::num(cells as f64)),
                ("ns_per_iter", jsonio::num(per_cell_ns)),
                ("threads", jsonio::num(threads as f64)),
                ("cells", jsonio::num(cells as f64)),
                ("wall_s", jsonio::num(wall_s)),
                ("cells_s_sum", jsonio::num(cells_s_sum)),
                ("concurrency", jsonio::num(concurrency)),
            ])]),
        ),
    ]);
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing sweep bench {}", path.display()))?;
    println!("sweep bench written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_is_outer_major() {
        assert_eq!(
            cross(&[0usize, 1], &["a", "b", "c"]),
            vec![(0, "a"), (0, "b"), (0, "c"), (1, "a"), (1, "b"), (1, "c")]
        );
        assert!(cross::<usize, usize>(&[], &[1, 2]).is_empty());
        assert!(cross(&[1, 2], &Vec::<usize>::new()).is_empty());
    }

    #[test]
    fn results_come_back_in_item_order() {
        let items: Vec<usize> = (0..64).collect();
        for threads in [1, 2, 8, 100] {
            let out = run_indexed(&items, threads, |i, &x| {
                assert_eq!(i, x);
                x * 3
            })
            .unwrap();
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>(), "{threads}");
        }
    }

    #[test]
    fn parallel_equals_serial_exactly() {
        // a cell whose output depends only on its inputs must sweep to
        // identical results at any thread count
        let items: Vec<u64> = (0..40).collect();
        let cell = |_: usize, &seed: &u64| -> Vec<f64> {
            let mut rng = crate::simrng::Rng::seeded(seed);
            (0..100).map(|_| rng.range(0.0, 1.0)).collect()
        };
        let serial = run_indexed(&items, 1, cell).unwrap();
        let parallel = run_indexed(&items, available_threads().max(2), cell).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn empty_and_single_item_sweeps() {
        let empty: Vec<u32> = Vec::new();
        assert!(run_indexed(&empty, 8, |_, &x| x).unwrap().is_empty());
        assert_eq!(run_indexed(&[7u32], 8, |_, &x| x + 1).unwrap(), vec![8]);
    }

    #[test]
    fn cells_are_timed_and_wall_reported() {
        let items = [1u32, 2, 3];
        let (out, cell_s, wall_s) = run_cells(&items, 2, |_, &x| x).unwrap();
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(cell_s.len(), 3);
        assert!(cell_s.iter().all(|&t| t >= 0.0));
        assert!(wall_s >= 0.0);
    }

    #[test]
    fn poisoned_cell_fails_the_sweep_naming_index_and_input() {
        // one panicking cell must not abort the process or hide which
        // cell died; healthy cells still run (observed via the counter)
        use std::sync::atomic::AtomicUsize;
        for threads in [1, 4] {
            let ran = AtomicUsize::new(0);
            let items: Vec<u32> = (0..8).collect();
            let err = run_indexed(&items, threads, |_, &x| {
                if x == 5 {
                    panic!("cell exploded on purpose");
                }
                ran.fetch_add(1, Ordering::Relaxed);
                x
            })
            .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("cell 5"), "{msg}");
            assert!(msg.contains("input 5"), "{msg}");
            assert!(msg.contains("cell exploded on purpose"), "{msg}");
            assert_eq!(ran.load(Ordering::Relaxed), 7, "threads={threads}");
        }
    }

    #[test]
    fn multiple_poisoned_cells_are_all_reported() {
        let items: Vec<u32> = (0..6).collect();
        let err = run_indexed(&items, 1, |_, &x| {
            if x % 2 == 1 {
                panic!("odd cell {x}");
            }
            x
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("3 of 6"), "{msg}");
        assert!(msg.contains("cell 1") && msg.contains("cell 3") && msg.contains("cell 5"), "{msg}");
    }

    #[test]
    fn bench_artifact_roundtrips() {
        let path = std::env::temp_dir().join("star_sweep_bench_test.json");
        write_sweep_bench(&path, "sweep/test", 4, &[0.5, 0.5, 1.0], 0.5).unwrap();
        let doc = Json::parse_file(&path).unwrap();
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        let r = &doc.get("results").unwrap().arr().unwrap()[0];
        assert_eq!(r.get("name").unwrap().str().unwrap(), "sweep/test");
        assert_eq!(r.get("threads").unwrap().num().unwrap(), 4.0);
        assert_eq!(r.get("cells").unwrap().num().unwrap(), 3.0);
        assert!((r.get("concurrency").unwrap().num().unwrap() - 4.0).abs() < 1e-9);
        let _ = std::fs::remove_file(&path);
    }
}
