//! §V-C ablations: Figs 23–27 (TTA, JCT, accuracy, perplexity, straggler
//! counts for the STAR variants).

use super::{band_str, band_str_f, run_systems, summarize, ExpCtx};
use crate::stats;
use crate::table::Table;
use crate::trace::Arch;

/// Variant set of §V-C. STAR-H carries /SP, /DS and /xS (per the paper);
/// all others are evaluated on the full STAR too.
pub fn ablation_systems() -> Vec<&'static str> {
    vec![
        "STAR-H", "STAR/SP", "STAR/xS", "STAR/DS", "STAR/PS", "STAR/W", "STAR/RS", "STAR/Mu",
        "STAR/N", "STAR/Tree",
    ]
}

pub fn fig23_to_27(ctx: &ExpCtx, which: &str) -> crate::Result<()> {
    for arch in [Arch::Ps, Arch::AllReduce] {
        let tag = if arch == Arch::Ps { "ps" } else { "ar" };
        let results = run_systems(ctx, &ablation_systems(), arch)?;

        let mk = |title: String, cols: &[&str]| Table::new(&title, cols);
        let mut t23 = mk(format!("Fig 23 ({tag}) — TTA per job (s), STAR variants"),
                         &["variant", "mean", "p1", "p99", "vs_STAR"]);
        let mut t24 = mk(format!("Fig 24 ({tag}) — JCT per job (s), STAR variants"),
                         &["variant", "mean", "p1", "p99", "vs_STAR"]);
        let mut t25 = mk(format!("Fig 25 ({tag}) — accuracy per image job (%), STAR variants"),
                         &["variant", "mean", "p1", "p99", "vs_STAR"]);
        let mut t26 = mk(format!("Fig 26 ({tag}) — perplexity per NLP job, STAR variants"),
                         &["variant", "mean", "p1", "p99", "vs_STAR"]);
        let mut t27 = mk(format!("Fig 27 ({tag}) — straggler episodes per job, STAR variants"),
                         &["variant", "mean", "p1", "p99", "vs_STAR"]);

        let base = summarize(&results["STAR-H"]);
        for sys in ablation_systems() {
            let s = summarize(&results[sys]);
            let rel = |v: f64, b: f64| -> String {
                if b.abs() < 1e-9 {
                    "-".into()
                } else {
                    format!("{:+.0}%", (v / b - 1.0) * 100.0)
                }
            };
            let mut row = vec![sys.to_string()];
            row.extend(band_str(stats::band(&s.tta)));
            row.push(rel(stats::mean(&s.tta), stats::mean(&base.tta)));
            t23.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str(stats::band(&s.jct)));
            row.push(rel(stats::mean(&s.jct), stats::mean(&base.jct)));
            t24.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str_f(stats::band(&s.acc), 2));
            row.push(format!("{:+.2}", stats::mean(&s.acc) - stats::mean(&base.acc)));
            t25.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str_f(stats::band(&s.ppl), 1));
            row.push(format!("{:+.1}", stats::mean(&s.ppl) - stats::mean(&base.ppl)));
            t26.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str(stats::band(&s.stragglers)));
            row.push(rel(stats::mean(&s.stragglers), stats::mean(&base.stragglers)));
            t27.row(row);
        }

        let print_one = |id: &str, t: &Table| -> crate::Result<()> {
            if which == id || which == "all" || which == "fig23" {
                t.print();
                println!();
                ctx.save(&format!("{id}_{tag}"), t)?;
            }
            Ok(())
        };
        print_one("fig23", &t23)?;
        print_one("fig24", &t24)?;
        print_one("fig25", &t25)?;
        print_one("fig26", &t26)?;
        print_one("fig27", &t27)?;
    }
    println!("(paper: every removed ingredient raises TTA/JCT and straggler counts, and lowers accuracy)\n");
    Ok(())
}
