//! `scale` — the cluster-scale single-run throughput benchmark: drives
//! synthetic clusters at 1×/10×/50×/500×/1000× the paper's testbed
//! (up to 8000 servers and a 10⁶-job trace, PS and AR, faults on)
//! through one `Driver::run` each and reports **events/sec**, wall
//! seconds, **peak RSS**, and the peak event-queue depth per cell
//! (`BENCH_driver.json`, `star-bench-v1`). This is the datapoint the
//! sweep-level benches cannot give: how fast one *inner* event loop
//! runs, which is what the Parsimon-style what-if ambitions of the
//! ROADMAP are bounded by.
//!
//! Giant cells (≥100k jobs) run with `streaming_stats` on — finished
//! jobs fold into running aggregates instead of a `Vec<JobStats>` — and
//! with the smoke-style convergence caps, so memory and wall time stay
//! bounded by the live-job working set, not the trace length
//! (DESIGN.md §12).
//!
//! Cells are independent (one cluster+driver each) but run **serially**
//! — unlike every other sweep — because the per-cell wall-clock IS the
//! measurement: concurrent cells would contend for cores and distort
//! the events/sec figure the baseline diff regresses against.
//! The artifact embeds a committed pre-refactor
//! baseline (`BENCH_driver.baseline.json`, override with
//! `STAR_DRIVER_BASELINE`) when one is present, so the events/sec
//! trajectory is diffable per cell; CI's `scale --smoke` step warns on
//! >15% regressions (advisory — wall-clock numbers are machine-noisy).

use std::path::Path;

use anyhow::Context;

use super::{sweep, ExpCtx};
use crate::baselines::make_policy;
use crate::cluster::ClusterConfig;
use crate::driver::{Driver, DriverConfig, RunMetrics};
use crate::faults::span_for;
use crate::jsonio::{self, Json};
use crate::scenario::arch_tag;
use crate::table::{self, Table};
use crate::trace::{generate, Arch, TraceConfig};

/// One grid cell: (label, cluster-scale factor, jobs). A factor-k cell
/// runs 5·k GPU + 3·k CPU servers (so 50× = 250 + 150 = 400 servers).
pub type ScaleSpec = (&'static str, usize, usize);

/// The benchmark grid. Smoke keeps CI wall time bounded; the full grid
/// climbs to the datacenter cells — 500× is 4000 servers / 100k jobs,
/// 1000× is 8000 servers with a 10⁶-job synthetic trace.
pub fn default_grid(smoke: bool) -> Vec<ScaleSpec> {
    if smoke {
        vec![("paper", 1, 8), ("10x", 10, 40)]
    } else {
        vec![
            ("paper", 1, 40),
            ("10x", 10, 400),
            ("50x", 50, 2000),
            ("500x", 500, 100_000),
            ("1000x", 1000, 1_000_000),
        ]
    }
}

/// Cells at or past this job count stream their stats and run under the
/// smoke convergence caps even on the full grid (see module doc).
const GIANT_CELL_JOBS: usize = 100_000;

/// The injected failure-rate multiplier: the throughput figure must be
/// measured with the resilience machinery live, not on the easy path.
const FAULT_RATE: f64 = 1.0;

struct CellOut {
    label: &'static str,
    arch: Arch,
    servers: usize,
    workers: usize,
    /// the grid's requested job count — keys the baseline diff, so it
    /// must be a pure grid parameter, not a run outcome
    jobs: usize,
    /// jobs that actually ran to completion (reported, never a key)
    finished: usize,
    metrics: RunMetrics,
}

fn run_cell(ctx: &ExpCtx, system: &str, spec: ScaleSpec, arch: Arch, smoke: bool) -> CellOut {
    let (label, factor, jobs) = spec;
    // each cell measures its own high-water mark (best-effort: on
    // kernels without clear_refs the probe reports the process peak)
    crate::driver::reset_peak_rss();
    let cluster = ClusterConfig {
        gpu_servers: 5 * factor,
        cpu_servers: 3 * factor,
        ..Default::default()
    };
    let servers = cluster.total_servers();
    // arrival rate scales with the cluster so concurrency stays high at
    // every factor (the paper cell reduces to the usual 280 s/job pacing)
    let trace = generate(&TraceConfig::paced_scaled(jobs, ctx.seed, factor));
    let workers: usize = trace.iter().map(|j| j.workers).sum();
    let giant = jobs >= GIANT_CELL_JOBS;
    let mut cfg = DriverConfig {
        arch,
        cluster,
        seed: ctx.seed,
        record_series: false,
        streaming_stats: giant,
        // the scale bench measures the parallel-prefill hot path and
        // reports fill counters per cell (DESIGN.md §13); artifacts
        // stay byte-identical at any thread count, so using all cores
        // here cannot perturb the events/jobs columns
        prefill_threads: sweep::resolve_threads(0),
        fill_timing: true,
        ..Default::default()
    };
    if smoke || giant {
        // bounded cells (heavily faulted jobs may never converge);
        // giant cells take the caps on the full grid too — the figure
        // of merit is event throughput, not converged-loss fidelity
        cfg.max_job_duration_s = 6000.0;
        cfg.max_updates_per_job = 10_000;
        cfg.max_iters_per_job = 20_000;
    }
    // the scenario layer's rate regime — the same `--fault-rate` recipe
    // as everywhere else (byte-identical to the old direct plan_at_rate)
    cfg.faults = crate::scenario::FaultRegime::Rate { rate: FAULT_RATE, seed: ctx.fault_seed }
        .plan(&trace, span_for(&trace, cfg.max_job_duration_s), servers);
    let name = system.to_string();
    let driver = Driver::new(
        cfg,
        trace,
        Box::new(move |_| make_policy(&name).expect("validated by caller")),
    );
    let metrics = if giant {
        let (_agg, _, metrics) = driver.run_streaming();
        metrics
    } else {
        let (_stats, _, metrics) = driver.run_instrumented();
        metrics
    };
    let finished = metrics.jobs_finished as usize;
    CellOut { label, arch, servers, workers, jobs, finished, metrics }
}

/// Baseline events/sec per cell name, read from a previously committed
/// `BENCH_driver.json`-format file. `None` when no baseline is available
/// — including the committed empty-results placeholder (a fresh checkout
/// before the first toolchain run must still print the arming hint).
fn load_baseline() -> Option<Json> {
    let path = std::env::var("STAR_DRIVER_BASELINE")
        .unwrap_or_else(|_| "BENCH_driver.baseline.json".into());
    let doc = Json::parse_file(Path::new(&path)).ok()?;
    match doc.get("results").ok().and_then(|r| r.arr().ok()) {
        Some(results) if !results.is_empty() => Some(doc),
        _ => None,
    }
}

fn baseline_events_per_sec(baseline: &Json, name: &str) -> Option<f64> {
    for r in baseline.get("results").ok()?.arr().ok()? {
        if r.get("name").ok().and_then(|n| n.str().ok()) == Some(name) {
            return r.get("events_per_sec").ok()?.num().ok();
        }
    }
    None
}

pub fn scale(ctx: &ExpCtx, smoke: bool) -> crate::Result<()> {
    run_grid(ctx, &default_grid(smoke), smoke)
}

/// Run a scale grid (each (cell, arch) pair is an independent driver)
/// and emit the table + `BENCH_driver.json` under `ctx.out_dir`.
pub fn run_grid(ctx: &ExpCtx, grid: &[ScaleSpec], smoke: bool) -> crate::Result<()> {
    let system = "STAR-H";
    make_policy(system)?;
    let runs: Vec<(ScaleSpec, Arch)> = grid
        .iter()
        .flat_map(|&spec| [(spec, Arch::Ps), (spec, Arch::AllReduce)])
        .collect();
    eprintln!(
        "[exp] scale: {} cells ({} scales × 2 archs, {system}, faults at rate {FAULT_RATE}), \
         run serially — wall-clock per cell is the measurement (the grid fixes each cell's \
         job count; --jobs/--threads are ignored here)",
        runs.len(),
        grid.len(),
    );
    // threads fixed at 1: concurrent cells would contend for cores and
    // corrupt the events/sec figure the baseline diff regresses against
    let (results, _cell_s, sweep_wall_s) = sweep::run_cells(&runs, 1, |_, run| {
        let (spec, arch) = *run;
        let t0 = std::time::Instant::now();
        let out = run_cell(ctx, system, spec, arch, smoke);
        eprintln!(
            "[exp]   {}/{}: {} events in {:.1}s wall ({:.0} events/s)",
            out.label,
            arch_tag(out.arch),
            out.metrics.events,
            t0.elapsed().as_secs_f64(),
            out.metrics.events_per_sec()
        );
        out
    })?;

    let baseline = load_baseline();
    let mut t = Table::new(
        &format!("Scale — single-run driver throughput ({system}, faults on)"),
        &[
            "cell",
            "arch",
            "servers",
            "workers",
            "jobs",
            "events",
            "events_per_sec",
            "wall_s",
            "epoch_fills",
            "fill_s",
            "peak_queue",
            "peak_rss_mb",
        ],
    );
    let mut results_json: Vec<Json> = Vec::new();
    for out in &results {
        let m = &out.metrics;
        let eps = m.events_per_sec();
        t.rowf(&[
            table::s(out.label),
            table::s(arch_tag(out.arch)),
            table::i(out.servers as i64),
            table::i(out.workers as i64),
            table::i(out.jobs as i64),
            table::i(m.events as i64),
            table::f(eps, 0),
            table::f(m.wall_s, 2),
            table::i(m.epoch_fills as i64),
            table::f(m.fill_wall_s, 2),
            table::i(m.peak_queue_depth as i64),
            match m.peak_rss_bytes {
                Some(b) => table::f(b as f64 / (1024.0 * 1024.0), 1),
                None => table::s("-"),
            },
        ]);
        // the name keys the baseline diff, so it must pin the workload
        // from pure grid parameters (requested jobs, smoke caps): the
        // smoke and full grids reuse cell labels with different jobs and
        // caps, and a run-outcome-derived key would silently rename a
        // cell whenever behavior changes — disarming the very guard
        let name = format!(
            "driver/scale={}/{}/jobs={}{}",
            out.label,
            arch_tag(out.arch),
            out.jobs,
            if smoke { "/smoke" } else { "" }
        );
        let ns_per_event = if m.events > 0 { m.wall_s * 1e9 / m.events as f64 } else { 0.0 };
        let mut pairs = vec![
            ("name", jsonio::s(&name)),
            ("iters", jsonio::num(m.events as f64)),
            ("ns_per_iter", jsonio::num(ns_per_event)),
            ("events", jsonio::num(m.events as f64)),
            ("events_per_sec", jsonio::num(eps)),
            ("wall_s", jsonio::num(m.wall_s)),
            ("epoch_fills", jsonio::num(m.epoch_fills as f64)),
            ("fill_s", jsonio::num(m.fill_wall_s)),
            ("peak_queue_depth", jsonio::num(m.peak_queue_depth as f64)),
            // null (never 0) when /proc/self/status is unreadable, so
            // the CI RSS diff can tell "no probe" from "tiny footprint"
            (
                "peak_rss_bytes",
                match m.peak_rss_bytes {
                    Some(b) => jsonio::num(b as f64),
                    None => Json::Null,
                },
            ),
            ("servers", jsonio::num(out.servers as f64)),
            ("workers", jsonio::num(out.workers as f64)),
            ("jobs", jsonio::num(out.jobs as f64)),
            ("jobs_finished", jsonio::num(out.finished as f64)),
        ];
        if let Some(b) = baseline.as_ref() {
            match baseline_events_per_sec(b, &name) {
                Some(base) => {
                    let delta_pct = if base > 0.0 { (eps / base - 1.0) * 100.0 } else { 0.0 };
                    pairs.push(("baseline_events_per_sec", jsonio::num(base)));
                    pairs.push(("delta_pct", jsonio::num(delta_pct)));
                    println!(
                        "{name}: {eps:.0} events/s vs baseline {base:.0} ({delta_pct:+.1}%)"
                    );
                }
                // an armed baseline that cannot see a cell is a blind
                // guard — say so instead of silently skipping
                None => println!(
                    "warning: {name}: no matching baseline entry — events/sec diff skipped \
                     for this cell (grid changed? regenerate the baseline)"
                ),
            }
        }
        results_json.push(jsonio::obj(pairs));
    }
    t.print();
    if baseline.is_none() {
        println!(
            "(no BENCH_driver.baseline.json with results — commit one from a pre-change run \
             to arm the events/sec diff)"
        );
    }

    std::fs::create_dir_all(&ctx.out_dir)
        .with_context(|| format!("creating {}", ctx.out_dir.display()))?;
    ctx.save("scale", &t)?;
    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::exp::scale")),
        ("sweep_wall_s", jsonio::num(sweep_wall_s)),
        ("results", Json::Arr(results_json)),
    ]);
    let path = ctx.out_dir.join("BENCH_driver.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("driver bench written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_grid_runs_and_artifact_parses() {
        let ctx = ExpCtx {
            jobs: 2,
            quick: true,
            out_dir: std::env::temp_dir().join("star_scale_test"),
            ..Default::default()
        };
        // a tiny grid keeps the debug-mode test cheap; the cell machinery
        // (scaled cluster, fault plan, instrumented run) is the real one
        run_grid(&ctx, &[("tiny", 1, 2)], true).unwrap();
        let doc = Json::parse_file(&ctx.out_dir.join("BENCH_driver.json")).unwrap();
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        let results = doc.get("results").unwrap().arr().unwrap();
        assert_eq!(results.len(), 2, "one PS and one AR cell");
        for r in results {
            assert!(r.get("events").unwrap().num().unwrap() > 0.0);
            assert!(r.get("events_per_sec").unwrap().num().unwrap() > 0.0);
            // §13 fill counters: every cell water-fills at least once,
            // and timing is armed (fill_timing) so the wall is nonzero
            assert!(r.get("epoch_fills").unwrap().num().unwrap() > 0.0);
            assert!(r.get("fill_s").unwrap().num().unwrap() > 0.0);
            assert!(r.get("peak_queue_depth").unwrap().num().unwrap() > 0.0);
            assert!(r.get("wall_s").unwrap().num().unwrap() > 0.0);
            // present in every row; null only where /proc is unreadable
            let rss = r.get("peak_rss_bytes").expect("peak_rss_bytes key");
            if let Ok(b) = rss.num() {
                assert!(b > 0.0, "probe must never report zero RSS");
            }
        }
        let names: Vec<&str> =
            results.iter().map(|r| r.get("name").unwrap().str().unwrap()).collect();
        // names pin the workload (jobs + smoke caps) so baseline diffs
        // can never compare across grids
        assert!(names.contains(&"driver/scale=tiny/ps/jobs=2/smoke"), "{names:?}");
        assert!(names.contains(&"driver/scale=tiny/ar/jobs=2/smoke"), "{names:?}");
    }

    #[test]
    fn scaled_cluster_cells_use_bigger_clusters() {
        let ctx = ExpCtx {
            out_dir: std::env::temp_dir().join("star_scale_test2"),
            ..Default::default()
        };
        let out = run_cell(&ctx, "SSGD", ("2x", 2, 2), Arch::Ps, true);
        assert_eq!(out.servers, 16, "factor 2 doubles the 8-server testbed");
        assert!(out.workers >= 8, "trace workers counted");
        assert!(out.metrics.events > 0);
    }

    #[test]
    fn default_grids_cover_paper_and_10x() {
        for smoke in [true, false] {
            let g = default_grid(smoke);
            assert!(g.iter().any(|&(l, f, _)| l == "paper" && f == 1));
            assert!(g.iter().any(|&(l, f, _)| l == "10x" && f == 10));
        }
        let full = default_grid(false);
        assert!(full.iter().any(|&(l, f, _)| l == "50x" && f == 50));
        assert!(full.iter().any(|&(l, f, _)| l == "500x" && f == 500));
        // the datacenter cell: 1000x cluster, 10^6-job trace, streamed
        assert!(full.iter().any(|&(l, f, j)| l == "1000x" && f == 1000 && j == 1_000_000));
    }
}
