//! `resilience` — the experiment axis the paper's title promises but its
//! evaluation never isolates: TTA / JCT / downtime under injected
//! failures, swept over failure rate × policy on the same trace.
//!
//! For every rate the *same* seeded [`FaultPlan`] is injected into every
//! policy's replay, so differences are attributable to the policy alone.
//! Each (rate, policy) cell is an independent cluster+driver pair and a
//! pure function of its inputs, so the grid runs `ctx.threads`-wide
//! through [`super::sweep`]; rows are emitted in sweep order, which makes
//! `--threads N` output byte-identical to `--threads 1` (pinned by the
//! tests below and a CI diff). Emits the usual CSV table plus a
//! `star-bench-v1` JSON artifact (`results/resilience.json`) so the
//! TTA-under-failures trajectory is tracked across PRs exactly like the
//! perf benches, and `results/BENCH_sweep.json` recording the sweep's
//! wall time and realized concurrency (see [`super::sweep`]).

use super::{summarize, sweep, ExpCtx};
use crate::baselines::make_policy;
use crate::driver::{Driver, DriverConfig, JobStats};
use crate::faults::{span_for, FaultPlan};
use crate::jsonio::{self, Json};
use crate::stats;
use crate::table::{self, Table};
use crate::trace::Arch;

/// Failure-rate multipliers swept (0 = the fault-free control).
pub const RATES: [f64; 3] = [0.0, 1.0, 4.0];

fn systems(quick: bool) -> Vec<&'static str> {
    if quick {
        vec!["SSGD", "LGC", "STAR-H"]
    } else {
        vec![
            "SSGD", "ASGD", "Sync-Switch", "LB-BSP", "LGC", "Zeno++", "STAR-H", "STAR-ML",
        ]
    }
}

fn run_with_plan(
    ctx: &ExpCtx,
    system: &str,
    trace: &[crate::trace::JobSpec],
    plan: &FaultPlan,
) -> crate::Result<Vec<JobStats>> {
    make_policy(system)?;
    let mut cfg = DriverConfig {
        arch: Arch::Ps,
        seed: ctx.seed,
        record_series: false,
        faults: plan.clone(),
        ..Default::default()
    };
    if ctx.quick {
        // under heavy failure rates a job may never converge; keep smoke
        // runs bounded instead of riding the 40 000 s duration cap
        cfg.max_job_duration_s = 12_000.0;
        cfg.max_updates_per_job = 25_000;
        cfg.max_iters_per_job = 40_000;
    }
    let name = system.to_string();
    let driver = Driver::new(
        cfg,
        trace.to_vec(),
        Box::new(move |_| make_policy(&name).expect("validated above")),
    );
    Ok(driver.run().0)
}

pub fn resilience(ctx: &ExpCtx) -> crate::Result<()> {
    let trace = ctx.trace();
    let base_cfg = DriverConfig::default();
    let servers = base_cfg.cluster.total_servers();
    let span = span_for(&trace, base_cfg.max_job_duration_s);
    let systems = systems(ctx.quick);
    crate::baselines::validate_systems(&systems)?;

    // the sweep grid, rate-major (the serial row order); plans come from
    // the scenario layer's rate regime — the same `--fault-rate` recipe
    // every other entry point injects (byte-identical to plan_at_rate)
    let plans: Vec<(f64, FaultPlan)> = RATES
        .iter()
        .map(|&rate| {
            let plan = crate::scenario::FaultRegime::Rate { rate, seed: ctx.fault_seed }
                .plan(&trace, span, servers);
            (rate, plan)
        })
        .collect();
    let rate_indices: Vec<usize> = (0..plans.len()).collect();
    let cells: Vec<(usize, &'static str)> = sweep::cross(&rate_indices, &systems);

    eprintln!(
        "[exp] resilience: {} cells ({} rates × {} systems, {} jobs) on {} thread(s)…",
        cells.len(),
        plans.len(),
        systems.len(),
        trace.len(),
        ctx.threads
    );
    // cells return Result and errors propagate after the join (a worker-
    // thread panic would abort the whole sweep without naming the cell)
    let (results, cell_s, wall_s) = sweep::run_cells(
        &cells,
        ctx.threads,
        |_, &(ri, sys)| -> crate::Result<Vec<JobStats>> {
            let (rate, plan) = &plans[ri];
            let t0 = std::time::Instant::now();
            let stats = run_with_plan(ctx, sys, &trace, plan)?;
            eprintln!(
                "[exp]   {sys} @ rate {rate} ({} faults): {:.1}s wall",
                plan.len(),
                t0.elapsed().as_secs_f64()
            );
            Ok(stats)
        },
    );
    let results = results.into_iter().collect::<crate::Result<Vec<_>>>()?;

    let mut t = Table::new(
        "Resilience — TTA/JCT/downtime under injected failures (PS architecture)",
        &[
            "system",
            "fault_rate",
            "faults",
            "tta_mean_s",
            "jct_mean_s",
            "downtime_mean_s",
            "rollbacks",
            "reached",
        ],
    );
    let mut results_json: Vec<Json> = Vec::new();
    let mut ssgd_jct_by_rate: Vec<(f64, f64)> = Vec::new();

    for (&(ri, sys), stats) in cells.iter().zip(&results) {
        let (rate, plan) = &plans[ri];
        let rate = *rate;
        let s = summarize(stats);
        // -1 = "no job reached the target" (NaN is not valid JSON)
        let tta_mean = if s.tta.is_empty() { -1.0 } else { stats::mean(&s.tta) };
        let jct_mean = stats::mean(&s.jct);
        let downtime_mean = stats::mean(&s.downtime);
        let rollbacks: f64 = s.rollbacks.iter().sum();
        if sys == "SSGD" {
            ssgd_jct_by_rate.push((rate, jct_mean));
        }
        t.rowf(&[
            table::s(sys),
            table::f(rate, 1),
            table::i(plan.len() as i64),
            table::f(tta_mean, 0),
            table::f(jct_mean, 0),
            table::f(downtime_mean, 1),
            table::i(rollbacks as i64),
            table::s(format!("{}/{}", s.tta_reached, s.jobs)),
        ]);
        results_json.push(jsonio::obj(vec![
            ("name", jsonio::s(&format!("resilience/{sys}/rate={rate}"))),
            ("iters", jsonio::num(s.jobs as f64)),
            // headline metric in the bench schema's slot: mean JCT
            // (includes jobs that never reach TTA under failures)
            ("ns_per_iter", jsonio::num(jct_mean * 1e9)),
            ("tta_mean_s", jsonio::num(tta_mean)),
            ("jct_mean_s", jsonio::num(jct_mean)),
            ("downtime_mean_s", jsonio::num(downtime_mean)),
            ("rollbacks", jsonio::num(rollbacks)),
            ("tta_reached", jsonio::num(s.tta_reached as f64)),
            ("fault_count", jsonio::num(plan.len() as f64)),
        ]));
    }

    t.print();
    for w in ssgd_jct_by_rate.windows(2) {
        let ((r0, j0), (r1, j1)) = (w[0], w[1]);
        println!(
            "SSGD mean JCT {j0:.0}s @ rate {r0} -> {j1:.0}s @ rate {r1} ({:+.0}%)",
            (j1 / j0.max(1e-9) - 1.0) * 100.0
        );
    }
    println!("(failures must cost the barrier-bound SSGD most; STAR's x-order modes absorb them)\n");
    if let Err(e) = std::fs::create_dir_all(&ctx.out_dir) {
        eprintln!("warning: could not create {}: {e}", ctx.out_dir.display());
    }
    ctx.save("resilience", &t);

    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::exp::resilience")),
        ("results", Json::Arr(results_json)),
    ]);
    let path = ctx.out_dir.join("resilience.json");
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("resilience results written to {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    // the parallelism win, tracked across PRs (deliberately a separate
    // artifact: wall times vary run to run, resilience.json must not)
    sweep::write_sweep_bench(
        &ctx.out_dir.join("BENCH_sweep.json"),
        "sweep/resilience",
        ctx.threads,
        &cell_s,
        wall_s,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::plan_at_rate;

    #[test]
    fn resilience_runs_end_to_end_quick() {
        let ctx = ExpCtx {
            jobs: 3,
            quick: true,
            fault_seed: 7,
            out_dir: std::env::temp_dir().join("star_resilience_test"),
            ..Default::default()
        };
        resilience(&ctx).unwrap();
        // the JSON artifact parses and carries the schema tag
        let doc = Json::parse_file(&ctx.out_dir.join("resilience.json")).unwrap();
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        let results = doc.get("results").unwrap().arr().unwrap();
        assert_eq!(results.len(), RATES.len() * systems(true).len());
        for r in results {
            assert!(r.get("jct_mean_s").unwrap().num().unwrap() > 0.0);
        }
        // the sweep bench artifact records the grid and thread count
        let bench = Json::parse_file(&ctx.out_dir.join("BENCH_sweep.json")).unwrap();
        let cell = &bench.get("results").unwrap().arr().unwrap()[0];
        assert_eq!(cell.get("name").unwrap().str().unwrap(), "sweep/resilience");
        assert_eq!(
            cell.get("cells").unwrap().num().unwrap() as usize,
            RATES.len() * systems(true).len()
        );
        assert_eq!(cell.get("threads").unwrap().num().unwrap() as usize, ctx.threads);
        assert!(cell.get("concurrency").unwrap().num().unwrap() > 0.0);
    }

    #[test]
    fn parallel_sweep_is_byte_identical_to_serial() {
        // the acceptance contract: `--threads 1` and `--threads N` must
        // produce the same resilience.json and CSV, byte for byte.
        // One job keeps the doubled sweep cheap under debug `cargo test`;
        // CI additionally diffs the full `--quick --jobs 4` grid in
        // release (serial vs parallel `experiments resilience` runs)
        let mk = |tag: &str, threads: usize| ExpCtx {
            jobs: 1,
            quick: true,
            fault_seed: 7,
            threads,
            out_dir: std::env::temp_dir().join(format!("star_resilience_{tag}")),
            ..Default::default()
        };
        let serial = mk("serial", 1);
        let parallel = mk("parallel", sweep::available_threads().max(2));
        resilience(&serial).unwrap();
        resilience(&parallel).unwrap();
        let a = std::fs::read(serial.out_dir.join("resilience.json")).unwrap();
        let b = std::fs::read(parallel.out_dir.join("resilience.json")).unwrap();
        assert_eq!(a, b, "parallel resilience.json differs from serial");
        let a = std::fs::read(serial.out_dir.join("resilience.csv")).unwrap();
        let b = std::fs::read(parallel.out_dir.join("resilience.csv")).unwrap();
        assert_eq!(a, b, "parallel resilience.csv differs from serial");
    }

    #[test]
    fn faults_strictly_increase_ssgd_tta() {
        // acceptance criterion: on the same trace, the injected plan must
        // strictly increase SSGD's time-to-accuracy (proxied by JCT for
        // jobs the faults keep from ever reaching the target)
        let ctx = ExpCtx {
            jobs: 3,
            quick: true,
            fault_seed: 7,
            out_dir: std::env::temp_dir().join("star_resilience_test2"),
            ..Default::default()
        };
        let trace = ctx.trace();
        let cfg = DriverConfig::default();
        let plan = plan_at_rate(
            6.0,
            ctx.fault_seed,
            &trace,
            span_for(&trace, cfg.max_job_duration_s),
            cfg.cluster.total_servers(),
        );
        assert!(!plan.is_empty());
        let clean = run_with_plan(&ctx, "SSGD", &trace, &FaultPlan::default()).unwrap();
        let faulted = run_with_plan(&ctx, "SSGD", &trace, &plan).unwrap();
        // TTA where both runs reached it, JCT as the censored fallback
        let score = |v: &[JobStats]| -> f64 {
            v.iter().map(|s| s.tta_s.unwrap_or(s.jct_s)).sum::<f64>()
        };
        assert!(
            score(&faulted) > score(&clean),
            "faults must strictly increase SSGD TTA: {} !> {}",
            score(&faulted),
            score(&clean)
        );
    }
}
