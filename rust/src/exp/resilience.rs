//! `resilience` — the experiment axis the paper's title promises but its
//! evaluation never isolates: TTA / JCT / downtime under injected
//! failures, swept over failure rate × policy on the same trace.
//!
//! For every rate the *same* seeded [`FaultPlan`] is injected into every
//! policy's replay, so differences are attributable to the policy alone.
//! Each (rate, policy) cell is an independent cluster+driver pair and a
//! pure function of its inputs, so the grid runs `ctx.threads`-wide
//! through [`super::sweep`]; rows are emitted in sweep order, which makes
//! `--threads N` output byte-identical to `--threads 1` (pinned by the
//! tests below and a CI diff). Emits the usual CSV table plus a
//! `star-bench-v1` JSON artifact (`results/resilience.json`) so the
//! TTA-under-failures trajectory is tracked across PRs exactly like the
//! perf benches, and `results/BENCH_sweep.json` recording the sweep's
//! wall time and realized concurrency (see [`super::sweep`]).

use anyhow::Context;

use super::{summarize, sweep, CellRows, ExpCtx};
use crate::baselines::make_policy;
use crate::driver::{Driver, DriverConfig, JobStats};
use crate::faults::{span_for, FaultPlan};
use crate::jsonio::{self, Json};
use crate::stats;
use crate::table::{self, Table};
use crate::trace::Arch;

/// Failure-rate multipliers swept (0 = the fault-free control).
pub const RATES: [f64; 3] = [0.0, 1.0, 4.0];

fn systems(quick: bool) -> Vec<&'static str> {
    if quick {
        vec!["SSGD", "LGC", "STAR-H"]
    } else {
        vec![
            "SSGD", "ASGD", "Sync-Switch", "LB-BSP", "LGC", "Zeno++", "STAR-H", "STAR-ML",
        ]
    }
}

fn run_with_plan(
    ctx: &ExpCtx,
    system: &str,
    trace: &[crate::trace::JobSpec],
    plan: &FaultPlan,
) -> crate::Result<Vec<JobStats>> {
    make_policy(system)?;
    let mut cfg = DriverConfig {
        arch: Arch::Ps,
        seed: ctx.seed,
        record_series: false,
        faults: plan.clone(),
        ..Default::default()
    };
    if ctx.quick {
        // under heavy failure rates a job may never converge; keep smoke
        // runs bounded instead of riding the 40 000 s duration cap
        cfg.max_job_duration_s = 12_000.0;
        cfg.max_updates_per_job = 25_000;
        cfg.max_iters_per_job = 40_000;
    }
    let name = system.to_string();
    let driver = Driver::new(
        cfg,
        trace.to_vec(),
        Box::new(move |_| make_policy(&name).expect("validated above")),
    );
    Ok(driver.run().0)
}

/// The sweep grid, rate-major (the serial row order): every
/// `(rate_index, system)` pair, exactly as [`resilience`] sweeps them.
/// The fabric dispatcher scatters this same list, so cell index `i`
/// means the same cell in-process, on a worker, and in a journal.
pub fn cell_specs(quick: bool) -> Vec<(usize, &'static str)> {
    let rate_indices: Vec<usize> = (0..RATES.len()).collect();
    sweep::cross(&rate_indices, &systems(quick))
}

/// Human-readable cell name for dispatch logs and errors.
pub fn cell_label(rate_index: usize, system: &str) -> String {
    let rate = RATES.get(rate_index).copied().unwrap_or(f64::NAN);
    format!("{system}@rate={rate}")
}

/// Render one cell's stats into its portable row pair — the *only*
/// formatter for resilience rows, shared by the in-process sweep and
/// remote workers, so both produce bit-identical strings and numbers.
fn rows_for(system: &str, rate: f64, fault_count: usize, stats: &[JobStats]) -> CellRows {
    let s = summarize(stats);
    // -1 = "no job reached the target" (NaN is not valid JSON)
    let tta_mean = if s.tta.is_empty() { -1.0 } else { stats::mean(&s.tta) };
    let jct_mean = stats::mean(&s.jct);
    let downtime_mean = stats::mean(&s.downtime);
    let rollbacks: f64 = s.rollbacks.iter().sum();
    let csv = [
        table::s(system),
        table::f(rate, 1),
        table::i(fault_count as i64),
        table::f(tta_mean, 0),
        table::f(jct_mean, 0),
        table::f(downtime_mean, 1),
        table::i(rollbacks as i64),
        table::s(format!("{}/{}", s.tta_reached, s.jobs)),
    ]
    .iter()
    .map(|c| c.render())
    .collect();
    let json = jsonio::obj(vec![
        ("name", jsonio::s(&format!("resilience/{system}/rate={rate}"))),
        ("iters", jsonio::num(s.jobs as f64)),
        // headline metric in the bench schema's slot: mean JCT
        // (includes jobs that never reach TTA under failures)
        ("ns_per_iter", jsonio::num(jct_mean * 1e9)),
        ("fault_rate", jsonio::num(rate)),
        ("tta_mean_s", jsonio::num(tta_mean)),
        ("jct_mean_s", jsonio::num(jct_mean)),
        ("downtime_mean_s", jsonio::num(downtime_mean)),
        ("rollbacks", jsonio::num(rollbacks)),
        ("tta_reached", jsonio::num(s.tta_reached as f64)),
        ("fault_count", jsonio::num(fault_count as f64)),
    ]);
    CellRows { csv, json }
}

/// Compute one grid cell standalone — the fabric worker entry point.
/// Rebuilds the trace and the cell's fault plan from the context alone
/// (both are pure functions of their seeds), so a remote worker needs
/// nothing but the `SweepSpec` to reproduce the in-process cell exactly.
pub fn compute_cell(ctx: &ExpCtx, rate_index: usize, system: &str) -> crate::Result<CellRows> {
    let rate = *RATES
        .get(rate_index)
        .with_context(|| format!("rate index {rate_index} out of range (grid has {})", RATES.len()))?;
    let trace = ctx.trace();
    let base_cfg = DriverConfig::default();
    let plan = crate::scenario::FaultRegime::Rate { rate, seed: ctx.fault_seed }.plan(
        &trace,
        span_for(&trace, base_cfg.max_job_duration_s),
        base_cfg.cluster.total_servers(),
    );
    let stats = run_with_plan(ctx, system, &trace, &plan)?;
    Ok(rows_for(system, rate, plan.len(), &stats))
}

/// Assemble the final artifacts from index-ordered cell rows: the
/// printed table + SSGD summary, `resilience.csv`, `resilience.json`.
/// Both the serial sweep and the fabric dispatcher end here, which is
/// what makes a dispatched run byte-identical to `--threads 1` — the
/// artifacts are a pure function of the merged rows.
pub fn assemble(ctx: &ExpCtx, rows: &[CellRows]) -> crate::Result<()> {
    let mut t = Table::new(
        "Resilience — TTA/JCT/downtime under injected failures (PS architecture)",
        &[
            "system",
            "fault_rate",
            "faults",
            "tta_mean_s",
            "jct_mean_s",
            "downtime_mean_s",
            "rollbacks",
            "reached",
        ],
    );
    let mut results_json: Vec<Json> = Vec::new();
    let mut ssgd_jct_by_rate: Vec<(f64, f64)> = Vec::new();
    for r in rows {
        t.row(r.csv.clone());
        if r.csv.first().map(String::as_str) == Some("SSGD") {
            let rate = r.json.get("fault_rate").and_then(|v| v.num()).unwrap_or(f64::NAN);
            let jct = r.json.get("jct_mean_s").and_then(|v| v.num()).unwrap_or(f64::NAN);
            ssgd_jct_by_rate.push((rate, jct));
        }
        results_json.push(r.json.clone());
    }

    t.print();
    for w in ssgd_jct_by_rate.windows(2) {
        let ((r0, j0), (r1, j1)) = (w[0], w[1]);
        println!(
            "SSGD mean JCT {j0:.0}s @ rate {r0} -> {j1:.0}s @ rate {r1} ({:+.0}%)",
            (j1 / j0.max(1e-9) - 1.0) * 100.0
        );
    }
    println!("(failures must cost the barrier-bound SSGD most; STAR's x-order modes absorb them)\n");
    std::fs::create_dir_all(&ctx.out_dir)
        .with_context(|| format!("creating {}", ctx.out_dir.display()))?;
    ctx.save("resilience", &t)?;

    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::exp::resilience")),
        ("results", Json::Arr(results_json)),
    ]);
    let path = ctx.out_dir.join("resilience.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("resilience results written to {}", path.display());
    Ok(())
}

pub fn resilience(ctx: &ExpCtx) -> crate::Result<()> {
    let trace = ctx.trace();
    let base_cfg = DriverConfig::default();
    let servers = base_cfg.cluster.total_servers();
    let span = span_for(&trace, base_cfg.max_job_duration_s);
    crate::baselines::validate_systems(&systems(ctx.quick))?;

    // plans are precomputed once per rate (cells at the same rate share
    // one); they come from the scenario layer's rate regime — the same
    // `--fault-rate` recipe every other entry point injects
    // (byte-identical to plan_at_rate, and to what a fabric worker
    // rebuilds cell-locally in compute_cell)
    let plans: Vec<(f64, FaultPlan)> = RATES
        .iter()
        .map(|&rate| {
            let plan = crate::scenario::FaultRegime::Rate { rate, seed: ctx.fault_seed }
                .plan(&trace, span, servers);
            (rate, plan)
        })
        .collect();
    let cells = cell_specs(ctx.quick);

    eprintln!(
        "[exp] resilience: {} cells ({} rates × {} systems, {} jobs) on {} thread(s)…",
        cells.len(),
        plans.len(),
        cells.len() / plans.len().max(1),
        trace.len(),
        ctx.threads
    );
    // cells return Result and errors propagate after the join; a
    // panicking cell fails the sweep with its index and inputs named
    // (sweep::run_cells catches per cell) instead of aborting everything
    let (results, cell_s, wall_s) = sweep::run_cells(
        &cells,
        ctx.threads,
        |_, &(ri, sys)| -> crate::Result<CellRows> {
            let (rate, plan) = &plans[ri];
            let t0 = std::time::Instant::now();
            let stats = run_with_plan(ctx, sys, &trace, plan)?;
            eprintln!(
                "[exp]   {sys} @ rate {rate} ({} faults): {:.1}s wall",
                plan.len(),
                t0.elapsed().as_secs_f64()
            );
            Ok(rows_for(sys, *rate, plan.len(), &stats))
        },
    )?;
    let rows = results.into_iter().collect::<crate::Result<Vec<_>>>()?;

    assemble(ctx, &rows)?;

    // the parallelism win, tracked across PRs (deliberately a separate
    // artifact: wall times vary run to run, resilience.json must not)
    sweep::write_sweep_bench(
        &ctx.out_dir.join("BENCH_sweep.json"),
        "sweep/resilience",
        ctx.threads,
        &cell_s,
        wall_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::plan_at_rate;

    #[test]
    fn resilience_runs_end_to_end_quick() {
        let ctx = ExpCtx {
            jobs: 3,
            quick: true,
            fault_seed: 7,
            out_dir: std::env::temp_dir().join("star_resilience_test"),
            ..Default::default()
        };
        resilience(&ctx).unwrap();
        // the JSON artifact parses and carries the schema tag
        let doc = Json::parse_file(&ctx.out_dir.join("resilience.json")).unwrap();
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
        let results = doc.get("results").unwrap().arr().unwrap();
        assert_eq!(results.len(), RATES.len() * systems(true).len());
        for r in results {
            assert!(r.get("jct_mean_s").unwrap().num().unwrap() > 0.0);
        }
        // the sweep bench artifact records the grid and thread count
        let bench = Json::parse_file(&ctx.out_dir.join("BENCH_sweep.json")).unwrap();
        let cell = &bench.get("results").unwrap().arr().unwrap()[0];
        assert_eq!(cell.get("name").unwrap().str().unwrap(), "sweep/resilience");
        assert_eq!(
            cell.get("cells").unwrap().num().unwrap() as usize,
            RATES.len() * systems(true).len()
        );
        assert_eq!(cell.get("threads").unwrap().num().unwrap() as usize, ctx.threads);
        assert!(cell.get("concurrency").unwrap().num().unwrap() > 0.0);
    }

    #[test]
    fn parallel_sweep_is_byte_identical_to_serial() {
        // the acceptance contract: `--threads 1` and `--threads N` must
        // produce the same resilience.json and CSV, byte for byte.
        // One job keeps the doubled sweep cheap under debug `cargo test`;
        // CI additionally diffs the full `--quick --jobs 4` grid in
        // release (serial vs parallel `experiments resilience` runs)
        let mk = |tag: &str, threads: usize| ExpCtx {
            jobs: 1,
            quick: true,
            fault_seed: 7,
            threads,
            out_dir: std::env::temp_dir().join(format!("star_resilience_{tag}")),
            ..Default::default()
        };
        let serial = mk("serial", 1);
        let parallel = mk("parallel", sweep::available_threads().max(2));
        resilience(&serial).unwrap();
        resilience(&parallel).unwrap();
        let a = std::fs::read(serial.out_dir.join("resilience.json")).unwrap();
        let b = std::fs::read(parallel.out_dir.join("resilience.json")).unwrap();
        assert_eq!(a, b, "parallel resilience.json differs from serial");
        let a = std::fs::read(serial.out_dir.join("resilience.csv")).unwrap();
        let b = std::fs::read(parallel.out_dir.join("resilience.csv")).unwrap();
        assert_eq!(a, b, "parallel resilience.csv differs from serial");
    }

    #[test]
    fn cell_specs_are_rate_major_and_labelled() {
        let cells = cell_specs(true);
        assert_eq!(cells.len(), RATES.len() * systems(true).len());
        assert_eq!(cells[0], (0, "SSGD"));
        assert_eq!(cells[systems(true).len()], (1, "SSGD"), "rate-major order");
        assert_eq!(cell_label(0, "SSGD"), "SSGD@rate=0");
        assert_eq!(cell_label(2, "LGC"), "LGC@rate=4");
    }

    #[test]
    fn compute_cell_rejects_out_of_range_rate_index() {
        let ctx = ExpCtx { jobs: 1, quick: true, ..Default::default() };
        let err = compute_cell(&ctx, RATES.len(), "SSGD").unwrap_err();
        assert!(format!("{err:#}").contains("out of range"));
    }

    #[test]
    fn faults_strictly_increase_ssgd_tta() {
        // acceptance criterion: on the same trace, the injected plan must
        // strictly increase SSGD's time-to-accuracy (proxied by JCT for
        // jobs the faults keep from ever reaching the target)
        let ctx = ExpCtx {
            jobs: 3,
            quick: true,
            fault_seed: 7,
            out_dir: std::env::temp_dir().join("star_resilience_test2"),
            ..Default::default()
        };
        let trace = ctx.trace();
        let cfg = DriverConfig::default();
        let plan = plan_at_rate(
            6.0,
            ctx.fault_seed,
            &trace,
            span_for(&trace, cfg.max_job_duration_s),
            cfg.cluster.total_servers(),
        );
        assert!(!plan.is_empty());
        let clean = run_with_plan(&ctx, "SSGD", &trace, &FaultPlan::default()).unwrap();
        let faulted = run_with_plan(&ctx, "SSGD", &trace, &plan).unwrap();
        // TTA where both runs reached it, JCT as the censored fallback
        let score = |v: &[JobStats]| -> f64 {
            v.iter().map(|s| s.tta_s.unwrap_or(s.jct_s)).sum::<f64>()
        };
        assert!(
            score(&faulted) > score(&clean),
            "faults must strictly increase SSGD TTA: {} !> {}",
            score(&faulted),
            score(&clean)
        );
    }
}
