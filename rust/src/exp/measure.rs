//! §III measurement study: Figs 1–14 + Table I.
//!
//! The whole family is also addressable as the built-in `measure`
//! scenario (`star scenario run measure`) — a delegated
//! [`crate::scenario::Scenario`] that reproduces these outputs
//! byte-identically through the same [`ExpCtx`] knobs.

use super::{run_system, ExpCtx};
use crate::baselines::make_policy;
use crate::driver::{
    Driver, DriverConfig, DriverMode, JobStats, Policy, PolicyDecision, PolicyFactory, RoundObs,
};
use crate::models::ZOO;
use crate::predict::STRAGGLER_DEV;
use crate::stats;
use crate::sync::SyncMode;
use crate::table::{self, Table};
use crate::trace::{Arch, JobSpec};

/// A fixed-mode policy used by the single-job experiments.
pub struct Fixed {
    pub mode: DriverMode,
    pub rescaled: bool,
    pub label: &'static str,
}

impl Policy for Fixed {
    fn name(&self) -> &'static str {
        self.label
    }

    fn decide(&mut self, _obs: &RoundObs) -> PolicyDecision {
        let mut d = PolicyDecision::simple(self.mode);
        d.lr_rescaled = self.rescaled;
        d
    }
}

/// Switch SSGD → ASGD at a given update step (Table I / Fig 11).
pub struct SwitchAt {
    pub at_step: u64,
    pub rescaled_after: bool,
}

impl Policy for SwitchAt {
    fn name(&self) -> &'static str {
        "SSGD->ASGD"
    }

    fn decide(&mut self, obs: &RoundObs) -> PolicyDecision {
        if obs.step >= self.at_step {
            let mut d = PolicyDecision::simple(DriverMode::Sync(SyncMode::Asgd));
            d.lr_rescaled = self.rescaled_after;
            d
        } else {
            let mut d = PolicyDecision::simple(DriverMode::Sync(SyncMode::Ssgd));
            d.lr_rescaled = true;
            d
        }
    }
}

/// Single-job spec helper.
pub fn single_job(model: usize, workers: usize) -> Vec<JobSpec> {
    vec![JobSpec {
        id: 0,
        arrival_s: 0.0,
        model,
        workers,
        ps_count: 1,
        ps_on_gpu_servers: false,
    }]
}

/// Run one job under a policy with optional worker-1 throttle.
pub fn run_single(
    model: usize,
    workers: usize,
    make: PolicyFactory,
    throttle: Option<(f64, f64)>,
    seed: u64,
) -> JobStats {
    let mut cfg = DriverConfig { seed, record_series: true, ..Default::default() };
    if let Some((cpu, bw)) = throttle {
        cfg.throttles.push((0, 1, cpu, bw));
    }
    let driver = Driver::new(cfg, single_job(model, workers), make);
    let (mut stats, _) = driver.run();
    stats.remove(0)
}

// ---------------------------------------------------------------------------
// Figs 1–7 (one SSGD measurement run feeds them all)
// ---------------------------------------------------------------------------

pub fn fig1_to_7(ctx: &ExpCtx, only: &str) -> crate::Result<()> {
    eprintln!("[exp] measurement run (SSGD, series)…");
    let (stats, _) = run_system(ctx, "SSGD", Arch::Ps, true, 0.0)?;

    // per-job per-iteration rows of (total, pre, gpu, comm) deviations
    let mut dev_total = Vec::new();
    let mut dev_gpu = Vec::new();
    let mut dev_pre = Vec::new();
    let mut dev_comm = Vec::new();
    let mut comm_share = Vec::new();
    let mut job_straggler_frac = Vec::new();
    let mut change_ratios = Vec::new();
    let mut bins_counts = Vec::new();
    let mut persist = Vec::new();
    let mut corr_cpu = Vec::new();
    let mut corr_bw = Vec::new();
    let mut corr_gpu = Vec::new();

    for s in &stats {
        let iters = s.series.iter().map(|w| w.len()).min().unwrap_or(0);
        if iters < 8 {
            continue;
        }
        let n = s.series.len();
        let mut strag_iters = 0usize;
        let mut strag_run = vec![0u64; n];
        let mut max_min_cpu = Vec::new();
        let mut max_min_bw = Vec::new();
        let mut max_min_gpu = Vec::new();
        let mut dev_series = Vec::new();
        for j in 0..iters {
            let row: Vec<_> = (0..n).map(|w| s.series[w][j]).collect();
            let dev = |f: &dyn Fn(&crate::driver::IterBreakdown) -> f64,
                       out: &mut Vec<f64>|
             -> f64 {
                let vals: Vec<f64> = row.iter().map(|b| f(b)).collect();
                let min = vals.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-9);
                let max = vals.iter().cloned().fold(0.0, f64::max);
                let d = (max - min) / min;
                out.push(d);
                d
            };
            let d_total = dev(&|b| b.total_s, &mut dev_total);
            dev(&|b| b.gpu_s, &mut dev_gpu);
            dev(&|b| b.pre_s, &mut dev_pre);
            dev(&|b| b.comm_s, &mut dev_comm);
            dev_series.push(d_total);
            if d_total > STRAGGLER_DEV {
                strag_iters += 1;
            }
            for b in &row {
                comm_share.push(b.comm_s / b.total_s.max(1e-9));
            }
            // per-iteration straggler persistence runs
            let min = row.iter().map(|b| b.total_s).fold(f64::INFINITY, f64::min).max(1e-9);
            for (w, b) in row.iter().enumerate() {
                if (b.total_s - min) / min > STRAGGLER_DEV {
                    strag_run[w] += 1;
                } else if strag_run[w] > 0 {
                    persist.push(strag_run[w] as f64);
                    strag_run[w] = 0;
                }
            }
            // resource max-min across workers this iteration
            let mm = |f: &dyn Fn(&crate::driver::IterBreakdown) -> f64| {
                let vals: Vec<f64> = row.iter().map(|b| f(b)).collect();
                vals.iter().cloned().fold(0.0f64, f64::max)
                    - vals.iter().cloned().fold(f64::INFINITY, f64::min)
            };
            max_min_cpu.push(mm(&|b| b.cpu_share));
            max_min_bw.push(mm(&|b| b.bw_share));
            max_min_gpu.push(mm(&|b| b.gpu_s));
            // fig 6: occupied bins of worker iteration times
            let times: Vec<f64> = row.iter().map(|b| b.total_s).collect();
            bins_counts.push(stats::occupied_bins(&times, 8) as f64);
        }
        job_straggler_frac.push(strag_iters as f64 / iters as f64);
        // fig 5: consecutive change ratios per worker
        for w in 0..n {
            for j in 1..iters {
                let a = s.series[w][j - 1].total_s;
                let b = s.series[w][j].total_s;
                change_ratios.push((b - a) / a.max(1e-9));
            }
        }
        // fig 4: correlation of max-min resource vs iteration deviation
        corr_cpu.push(stats::pearson(&max_min_cpu, &dev_series));
        corr_bw.push(stats::pearson(&max_min_bw, &dev_series));
        corr_gpu.push(stats::pearson(&max_min_gpu, &dev_series));
    }

    // ---- Fig 1: CDFs of iterations vs deviation ratios -----------------
    let grid = stats::grid(0.0, 3.0, 13);
    let mut t1 = Table::new(
        "Fig 1 — CDF of iterations vs deviation ratio (pooled over jobs)",
        &["dev_ratio", "iteration", "gpu", "preproc", "comm"],
    );
    let c_t = stats::cdf_at(&dev_total, &grid);
    let c_g = stats::cdf_at(&dev_gpu, &grid);
    let c_p = stats::cdf_at(&dev_pre, &grid);
    let c_c = stats::cdf_at(&dev_comm, &grid);
    for (i, &g) in grid.iter().enumerate() {
        t1.rowf(&[
            table::f(g, 2),
            table::f(c_t[i], 3),
            table::f(c_g[i], 3),
            table::f(c_p[i], 3),
            table::f(c_c[i], 3),
        ]);
    }
    let over50 =
        job_straggler_frac.iter().filter(|&&f| f > 0.5).count() as f64
            / job_straggler_frac.len().max(1) as f64;
    let strag_frac_overall =
        dev_total.iter().filter(|&&d| d > STRAGGLER_DEV).count() as f64
            / dev_total.len().max(1) as f64;
    if only == "fig1" || only == "all" {
        t1.print();
        println!(
            "O1 check: {:.0}% of iterations experience stragglers (paper: 65%); \
             {:.0}% of jobs have >50% straggler iterations (paper: 47%)\n",
            strag_frac_overall * 100.0,
            over50 * 100.0
        );
        ctx.save("fig1", &t1)?;
    }

    // ---- Fig 2: communication share ------------------------------------
    if only == "fig2" || only == "fig1" || only == "all" {
        let mut t2 = Table::new(
            "Fig 2 — CDF of worker-iterations vs comm share of iteration time",
            &["comm_share", "cdf"],
        );
        let g2 = stats::grid(0.0, 1.0, 11);
        let c2 = stats::cdf_at(&comm_share, &g2);
        for (i, &g) in g2.iter().enumerate() {
            t2.rowf(&[table::f(g, 2), table::f(c2[i], 3)]);
        }
        let in_range = comm_share.iter().filter(|&&c| (0.5..=0.93).contains(&c)).count() as f64
            / comm_share.len().max(1) as f64;
        t2.print();
        println!(
            "Fig 2 check: {:.0}% of comm shares in [50%, 93%] (paper: 75%)\n",
            in_range * 100.0
        );
        ctx.save("fig2", &t2)?;
    }

    // ---- Fig 3: iteration-time series (DenseNet121 job) ----------------
    if only == "fig3" || only == "fig1" || only == "all" {
        let dense = ZOO.iter().position(|m| m.name == "DenseNet121").unwrap();
        let job = stats.iter().find(|s| s.model == dense && s.series.len() >= 4);
        let mut t3 = Table::new(
            "Fig 3 — iteration times of four workers (DenseNet121), s",
            &["iter", "w0", "w1", "w2", "w3"],
        );
        if let Some(s) = job {
            let iters = s.series.iter().take(4).map(|w| w.len()).min().unwrap_or(0);
            for j in (0..iters.min(200)).step_by(5) {
                t3.rowf(&[
                    table::i(j as i64),
                    table::f(s.series[0][j].total_s, 3),
                    table::f(s.series[1][j].total_s, 3),
                    table::f(s.series[2][j].total_s, 3),
                    table::f(s.series[3][j].total_s, 3),
                ]);
            }
        }
        t3.print();
        ctx.save("fig3", &t3)?;
        println!();
    }

    // ---- Fig 4: correlation coefficients --------------------------------
    if only == "fig4" || only == "fig1" || only == "all" {
        let mut t4 = Table::new(
            "Fig 4 — corr(max-min resource usage, iteration deviation) across jobs",
            &["resource", "mean", "p10", "p90", "frac_in_[0.5,1]"],
        );
        for (name, v) in [("GPU", &corr_gpu), ("CPU", &corr_cpu), ("Bandwidth", &corr_bw)] {
            let hi = v.iter().filter(|&&c| c >= 0.5).count() as f64 / v.len().max(1) as f64;
            t4.rowf(&[
                table::s(name),
                table::f(stats::mean(v), 3),
                table::f(stats::percentile(v, 10.0), 3),
                table::f(stats::percentile(v, 90.0), 3),
                table::pct(hi),
            ]);
        }
        t4.print();
        println!("(paper: 13.8% of CPU and 17.1% of bandwidth coefficients in [0.5,1]; GPU within [-0.3,0.3])\n");
        ctx.save("fig4", &t4)?;
    }

    // ---- Fig 5: consecutive iteration change ratio ----------------------
    if only == "fig5" || only == "fig1" || only == "all" {
        let mut t5 = Table::new(
            "Fig 5 — CDF of consecutive-iteration change ratio",
            &["change_ratio", "cdf"],
        );
        let g5 = stats::grid(-1.0, 2.0, 13);
        let c5 = stats::cdf_at(&change_ratios, &g5);
        for (i, &g) in g5.iter().enumerate() {
            t5.rowf(&[table::f(g, 2), table::f(c5[i], 3)]);
        }
        let up = change_ratios.iter().filter(|&&c| c > 0.2).count() as f64
            / change_ratios.len().max(1) as f64;
        let down = change_ratios.iter().filter(|&&c| c < -0.2).count() as f64
            / change_ratios.len().max(1) as f64;
        t5.print();
        println!(
            "Fig 5 check: {:.0}% increases >20%, {:.0}% decreases >20% (paper: 23% / 21%)\n",
            up * 100.0,
            down * 100.0
        );
        ctx.save("fig5", &t5)?;
    }

    // ---- Fig 6: occupied-bin PDF ----------------------------------------
    if only == "fig6" || only == "fig1" || only == "all" {
        let mut t6 = Table::new(
            "Fig 6 — PDF of iterations vs #bins spanned by worker times (8 bins)",
            &["bins", "pdf"],
        );
        let total = bins_counts.len().max(1) as f64;
        for b in 1..=8 {
            let frac = bins_counts.iter().filter(|&&x| x as usize == b).count() as f64 / total;
            t6.rowf(&[table::i(b as i64), table::f(frac, 3)]);
        }
        t6.print();
        println!("(paper: iterations span 4–8 bins with nontrivial mass)\n");
        ctx.save("fig6", &t6)?;
    }

    // ---- Fig 7: straggler persistence ------------------------------------
    if only == "fig7" || only == "fig1" || only == "all" {
        let mut t7 = Table::new(
            "Fig 7 — CDF of stragglers vs persistence (iterations)",
            &["iterations", "cdf"],
        );
        let g7 = vec![1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0];
        let c7 = stats::cdf_at(&persist, &g7);
        for (i, &g) in g7.iter().enumerate() {
            t7.rowf(&[table::f(g, 0), table::f(c7[i], 3)]);
        }
        t7.print();
        println!("(paper: durations 0.1–419 s; some stragglers persist >100 iterations)\n");
        ctx.save("fig7", &t7)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 8: PS vs worker resource usage, SSGD vs ASGD
// ---------------------------------------------------------------------------

pub fn fig8(ctx: &ExpCtx) -> crate::Result<()> {
    let mut t = Table::new(
        "Fig 8 — average resource usage of PS and worker1 (demand model, per model)",
        &[
            "model", "ps_cpu_ssgd", "ps_cpu_asgd", "w1_cpu_ssgd", "w1_cpu_asgd",
            "ps_bw_ssgd", "ps_bw_asgd", "w1_bw_ssgd", "w1_bw_asgd",
        ],
    );
    for m in ZOO {
        let w_cpu = m.worker_cpu;
        let w_bw = m.worker_bw;
        let ps_cpu = w_cpu * m.ps_cpu_factor;
        let ps_bw = w_bw * m.ps_bw_factor;
        t.rowf(&[
            table::s(m.name),
            table::f(ps_cpu, 2),
            table::f(ps_cpu * m.asgd_cpu_factor, 2),
            table::f(w_cpu, 2),
            table::f(w_cpu * m.asgd_cpu_factor, 2),
            table::f(ps_bw, 2),
            table::f(ps_bw * m.asgd_bw_factor, 2),
            table::f(w_bw, 2),
            table::f(w_bw * m.asgd_bw_factor, 2),
        ]);
    }
    t.print();
    println!(
        "O4/O5 check: PS consumes {:.0}–{:.0}% more CPU and {:.0}–{:.0}% more bandwidth \
         than a worker; ASGD multiplies CPU ×{:.2}–{:.2} and bandwidth ×{:.2}–{:.2}\n",
        (ZOO.iter().map(|m| m.ps_cpu_factor).fold(f64::INFINITY, f64::min) - 1.0) * 100.0,
        (ZOO.iter().map(|m| m.ps_cpu_factor).fold(0.0, f64::max) - 1.0) * 100.0,
        (ZOO.iter().map(|m| m.ps_bw_factor).fold(f64::INFINITY, f64::min) - 1.0) * 100.0,
        (ZOO.iter().map(|m| m.ps_bw_factor).fold(0.0, f64::max) - 1.0) * 100.0,
        ZOO.iter().map(|m| m.asgd_cpu_factor).fold(f64::INFINITY, f64::min),
        ZOO.iter().map(|m| m.asgd_cpu_factor).fold(0.0, f64::max),
        ZOO.iter().map(|m| m.asgd_bw_factor).fold(f64::INFINITY, f64::min),
        ZOO.iter().map(|m| m.asgd_bw_factor).fold(0.0, f64::max),
    );
    ctx.save("fig8", &t)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs 9–10: servers hosting more PSs
// ---------------------------------------------------------------------------

pub fn fig9_10(ctx: &ExpCtx, which: &str) -> crate::Result<()> {
    eprintln!("[exp] measurement run with server sampling…");
    let (_stats, records) = run_system(ctx, "SSGD", Arch::Ps, true, 25.0)?;

    if which == "fig9" || which == "all" {
        let mut t = Table::new(
            "Fig 9 — server records by hosted-PS count: resource usage",
            &["ps_hosted", "records", "cpu_mean", "cpu>90%", "cpu>98%", "bw_mean", "bw>90%", "bw>98%"],
        );
        for k in 0..=5usize {
            let rs: Vec<_> = records
                .iter()
                .filter(|r| if k < 5 { r.ps_hosted == k } else { r.ps_hosted >= 5 })
                .collect();
            if rs.is_empty() {
                continue;
            }
            let n = rs.len() as f64;
            let cpu: Vec<f64> = rs.iter().map(|r| r.cpu_util).collect();
            let bw: Vec<f64> = rs.iter().map(|r| r.bw_util).collect();
            t.rowf(&[
                table::s(if k < 5 { format!("{k}") } else { "5+".into() }),
                table::i(rs.len() as i64),
                table::f(stats::mean(&cpu), 3),
                table::pct(cpu.iter().filter(|&&c| c > 0.9).count() as f64 / n),
                table::pct(cpu.iter().filter(|&&c| c > 0.98).count() as f64 / n),
                table::f(stats::mean(&bw), 3),
                table::pct(bw.iter().filter(|&&c| c > 0.9).count() as f64 / n),
                table::pct(bw.iter().filter(|&&c| c > 0.98).count() as f64 / n),
            ]);
        }
        t.print();
        println!("(paper: usage above 90%/98% rises steeply with hosted-PS count)\n");
        ctx.save("fig9", &t)?;
    }

    if which == "fig10" || which == "all" {
        // controlled: single job; k extra foreign PSs on the worker server
        let mut t = Table::new(
            "Fig 10 — worker deviation ratio vs #PSs co-located on its server",
            &["extra_ps", "mean_dev", "p50", "p90", "straggler_frac"],
        );
        for &extra in &[0usize, 1, 3, 5] {
            let mut cfg = DriverConfig { seed: ctx.seed, record_series: true, ..Default::default() };
            cfg.max_job_duration_s = 4000.0;
            let mut specs = single_job(4, 4); // DenseNet121, 4 workers
            // co-located jobs contribute PSs on gpu server 0
            for e in 0..extra {
                specs.push(JobSpec {
                    id: 1 + e,
                    arrival_s: 0.0,
                    model: 7,
                    workers: 4,
                    ps_count: 1,
                    ps_on_gpu_servers: true,

                });
            }
            let driver =
                Driver::new(cfg, specs, Box::new(|_| make_policy("SSGD").expect("known system")));
            let (all, _) = driver.run();
            let s = all.iter().find(|s| s.job == 0).unwrap();
            let iters = s.series.iter().map(|w| w.len()).min().unwrap_or(0);
            let mut devs = Vec::new();
            for j in 0..iters {
                let times: Vec<f64> = s.series.iter().map(|w| w[j].total_s).collect();
                for d in crate::predict::deviation_ratios(&times) {
                    devs.push(d);
                }
            }
            let frac = devs.iter().filter(|&&d| d > STRAGGLER_DEV).count() as f64
                / devs.len().max(1) as f64;
            t.rowf(&[
                table::i(extra as i64),
                table::f(stats::mean(&devs), 3),
                table::f(stats::percentile(&devs, 50.0), 3),
                table::f(stats::percentile(&devs, 90.0), 3),
                table::pct(frac),
            ]);
        }
        t.print();
        println!("(paper: more co-located PSs ⇒ higher deviation ratios)\n");
        ctx.save("fig10", &t)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 11: switching job A to ASGD slows co-located jobs B/C
// ---------------------------------------------------------------------------

pub fn fig11(ctx: &ExpCtx) -> crate::Result<()> {
    // job A: DenseNet121, PS on gpu server with B/C workers; B, C: MobileNet
    let dense = ZOO.iter().position(|m| m.name == "DenseNet121").unwrap();
    let mobile = ZOO.iter().position(|m| m.name == "MobileNet").unwrap();
    let specs = vec![
        JobSpec { id: 0, arrival_s: 0.0, model: dense, workers: 4, ps_count: 1, ps_on_gpu_servers: true },
        JobSpec { id: 1, arrival_s: 0.0, model: mobile, workers: 4, ps_count: 1, ps_on_gpu_servers: true },
        JobSpec { id: 2, arrival_s: 0.0, model: mobile, workers: 4, ps_count: 1, ps_on_gpu_servers: true },
    ];
    let switch_step = 400u64;
    let cfg = DriverConfig {
        seed: ctx.seed,
        record_series: true,
        max_job_duration_s: 6000.0,
        ..Default::default()
    };
    let driver = Driver::new(
        cfg,
        specs,
        Box::new(move |j| -> Box<dyn Policy> {
            if j.id == 0 {
                Box::new(SwitchAt { at_step: switch_step, rescaled_after: false })
            } else {
                make_policy("SSGD").expect("known system")
            }
        }),
    );
    let (stats, _) = driver.run();

    let mut t = Table::new(
        "Fig 11 — effect of job A's SSGD→ASGD switch on co-located jobs",
        &["job", "phase", "mean_iter_s", "p90_iter_s", "straggler_frac"],
    );
    for s in &stats {
        if s.job == 0 {
            continue;
        }
        let iters = s.series.iter().map(|w| w.len()).min().unwrap_or(0);
        let half = iters / 2;
        for (phase, range) in [("before", 0..half), ("after", half..iters)] {
            let mut times = Vec::new();
            let mut devs = Vec::new();
            for j in range {
                let row: Vec<f64> = s.series.iter().map(|w| w[j].total_s).collect();
                times.extend(row.iter().copied());
                devs.extend(crate::predict::deviation_ratios(&row));
            }
            let frac = devs.iter().filter(|&&d| d > STRAGGLER_DEV).count() as f64
                / devs.len().max(1) as f64;
            t.rowf(&[
                table::s(format!("{}", if s.job == 1 { "B" } else { "C" })),
                table::s(phase),
                table::f(stats::mean(&times), 3),
                table::f(stats::percentile(&times, 90.0), 3),
                table::pct(frac),
            ]);
        }
    }
    t.print();
    println!("(paper O5: after the switch B/C iteration times rise and they become frequent stragglers)\n");
    ctx.save("fig11", &t)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs 12–13: TTA vs throttling, SSGD vs ASGD
// ---------------------------------------------------------------------------

pub fn fig12_13(ctx: &ExpCtx, cpu: bool) -> crate::Result<()> {
    let which = if cpu { "fig12" } else { "fig13" };
    let resource = if cpu { "CPU" } else { "bandwidth" };
    let mut t = Table::new(
        &format!("Fig {} — TTA (s) vs worker1 {} throttling", if cpu { 12 } else { 13 }, resource),
        &["model", "ssgd_none", "ssgd_75%", "ssgd_10%", "ssgd_5%", "asgd_none", "asgd_75%", "asgd_10%", "asgd_5%"],
    );
    let models: Vec<usize> = if ctx.quick { vec![0, 8] } else { (0..ZOO.len()).collect() };
    for mi in models {
        let mut cells = vec![table::s(ZOO[mi].name)];
        for mode in ["SSGD", "ASGD"] {
            for frac in [1.0, 0.75, 0.10, 0.05] {
                let throttle = if cpu { (frac, 1.0) } else { (1.0, frac) };
                let name = mode.to_string();
                let s = run_single(
                    mi,
                    4,
                    Box::new(move |_| make_policy(&name).expect("known system")),
                    Some(throttle),
                    ctx.seed,
                );
                cells.push(match s.tta_s {
                    Some(v) => table::f(v, 0),
                    None => table::s(">cap"),
                });
            }
        }
        let (a, b) = cells.split_at(5);
        let mut row: Vec<table::Cell> = Vec::new();
        row.extend(a.iter().map(copy_cell));
        row.extend(b.iter().map(copy_cell));
        t.rowf(&row);
    }
    t.print();
    println!("(paper O6: stragglers barely affect ASGD's TTA but inflate SSGD's; without stragglers SSGD wins)\n");
    ctx.save(which, &t)?;
    Ok(())
}

fn copy_cell(c: &table::Cell) -> table::Cell {
    match c {
        table::Cell::S(s) => table::Cell::S(s.clone()),
        table::Cell::I(v) => table::Cell::I(*v),
        table::Cell::F(v, d) => table::Cell::F(*v, *d),
        table::Cell::Pct(v) => table::Cell::Pct(*v),
    }
}

// ---------------------------------------------------------------------------
// Table I: accuracy improvement at different stages
// ---------------------------------------------------------------------------

pub fn tab1(ctx: &ExpCtx) -> crate::Result<()> {
    let dense = ZOO.iter().position(|m| m.name == "DenseNet121").unwrap();
    let stages = [("Step 2200 (early)", 150u64), ("Step 5500 (middle)", 600), ("Step 13000 (late)", 2000)];

    // improvement over 2 minutes from the stage point
    let improvement = |s: &JobStats, from_step_time: f64| -> f64 {
        let v_at = |t: f64| -> f64 {
            s.value_series
                .iter()
                .min_by(|a, b| {
                    (a.0 - t).abs().partial_cmp(&(b.0 - t).abs()).unwrap()
                })
                .map(|&(_, v)| v)
                .unwrap_or(f64::NAN)
        };
        v_at(from_step_time + 120.0) - v_at(from_step_time)
    };
    // map steps to times via value_series index of a reference run
    let step_time = |s: &JobStats, step: u64| -> f64 {
        // decisions happen ~once per round; use fraction of total updates
        let frac = (step as f64 / s.updates.max(1) as f64).min(1.0);
        s.jct_s * frac
    };

    let wo = run_single(
        dense,
        4,
        Box::new(|_| make_policy("SSGD").expect("known system")),
        None,
        ctx.seed,
    );
    let w = run_single(
        dense,
        4,
        Box::new(|_| make_policy("SSGD").expect("known system")),
        Some((0.2, 1.0)),
        ctx.seed,
    );

    let mut t = Table::new(
        "Table I — accuracy improvement in 2 min from each stage (DenseNet121, %)",
        &["system", "early", "middle", "late"],
    );
    for (label, s) in [("SSGDw/oS", &wo), ("SSGDw/S", &w)] {
        let mut row = vec![table::s(label)];
        for (_, step) in &stages {
            row.push(table::f(improvement(s, step_time(s, *step)), 2));
        }
        t.rowf(&row);
    }
    // ASGDw/S: switch at each stage
    let mut row = vec![table::s("ASGDw/S")];
    for (_, step) in &stages {
        let at = *step;
        let s = run_single(
            dense,
            4,
            Box::new(move |_| Box::new(SwitchAt { at_step: at, rescaled_after: false })),
            Some((0.2, 1.0)),
            ctx.seed,
        );
        row.push(table::f(improvement(&s, step_time(&s, at)), 2));
    }
    t.rowf(&row);
    t.print();
    println!("(paper: switching helps most at the early stage; gains shrink as training progresses)\n");
    ctx.save("tab1", &t)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig 14: optimal LR flips between SSGD and ASGD (O7)
// ---------------------------------------------------------------------------

pub fn fig14(ctx: &ExpCtx) -> crate::Result<()> {
    // Substitution (DESIGN.md §2): our progress model exposes LR through
    // the rescale decision, not a continuum — "base LR" = the SSGD-tuned
    // rate (rescaled=false for async modes), "scaled LR" = §IV-C scaling
    // (rescaled=true). O7's claim maps to: SSGD is best at base LR, while
    // ASGD converges better with the scaled LR.
    let mut t = Table::new(
        "Fig 14 — converged value: SSGD vs ASGD at base/scaled LR",
        &["model/workers", "SSGD", "ASGD@baseLR", "ASGD@scaledLR"],
    );
    let dense = ZOO.iter().position(|m| m.name == "DenseNet121").unwrap();
    let lstm = ZOO.iter().position(|m| m.name == "LSTM").unwrap();
    for (mi, n) in [(dense, 4), (dense, 8), (lstm, 4), (lstm, 8)] {
        let ssgd = run_single(
            mi,
            n,
            Box::new(|_| make_policy("SSGD").expect("known system")),
            None,
            ctx.seed,
        );
        let asgd_base = run_single(
            mi,
            n,
            Box::new(|_| {
                Box::new(Fixed {
                    mode: DriverMode::Sync(SyncMode::Asgd),
                    rescaled: false,
                    label: "ASGD@base",
                })
            }),
            None,
            ctx.seed,
        );
        let asgd_scaled = run_single(
            mi,
            n,
            Box::new(|_| {
                Box::new(Fixed {
                    mode: DriverMode::Sync(SyncMode::Asgd),
                    rescaled: true,
                    label: "ASGD@scaled",
                })
            }),
            None,
            ctx.seed,
        );
        t.rowf(&[
            table::s(format!("{}/{}w", ZOO[mi].name, n)),
            table::f(ssgd.converged_value, 2),
            table::f(asgd_base.converged_value, 2),
            table::f(asgd_scaled.converged_value, 2),
        ]);
    }
    t.print();
    println!("(paper O7: the SSGD-optimal LR is not optimal after switching to ASGD)\n");
    ctx.save("fig14", &t)?;
    Ok(())
}
