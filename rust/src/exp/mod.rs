//! Experiment harness: one subcommand per paper table/figure
//! (`cargo run --release --bin experiments -- <id>`; see DESIGN.md §4 for
//! the full index). Each experiment prints the paper's rows/series and
//! saves a CSV under `results/`.

pub mod ablation;
pub mod eval;
pub mod measure;
pub mod overhead;

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::baselines::make_policy;
use crate::driver::{Driver, DriverConfig, JobStats, ServerRecord};
use crate::stats::Band;
use crate::table::Table;
use crate::trace::{generate, Arch, TraceConfig};

/// Shared experiment context (CLI-derived).
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub jobs: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
    /// shrink everything for smoke tests
    pub quick: bool,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx { jobs: 120, seed: 0, out_dir: PathBuf::from("results"), quick: false }
    }
}

impl ExpCtx {
    pub fn effective_jobs(&self) -> usize {
        if self.quick {
            self.jobs.min(12)
        } else {
            self.jobs
        }
    }

    pub fn trace(&self) -> Vec<crate::trace::JobSpec> {
        let jobs = self.effective_jobs();
        let cfg = TraceConfig {
            jobs,
            seed: self.seed,
            // keep the cluster busy: scale the span with job count
            span_s: jobs as f64 * 280.0,
            ..Default::default()
        };
        generate(&cfg)
    }

    pub fn save(&self, name: &str, t: &Table) {
        let path = self.out_dir.join(format!("{name}.csv"));
        if let Err(e) = t.save_csv(&path) {
            eprintln!("warning: could not save {}: {e}", path.display());
        }
    }
}

/// Run one system over the context's trace.
pub fn run_system(
    ctx: &ExpCtx,
    system: &str,
    arch: Arch,
    record_series: bool,
    server_sample_s: f64,
) -> (Vec<JobStats>, Vec<ServerRecord>) {
    let cfg = DriverConfig {
        arch,
        seed: ctx.seed,
        record_series,
        server_sample_period_s: server_sample_s,
        ..Default::default()
    };
    let name = system.to_string();
    let driver = Driver::new(cfg, ctx.trace(), Box::new(move |_| make_policy(&name)));
    driver.run()
}

/// Run several systems; returns name → stats.
pub fn run_systems(
    ctx: &ExpCtx,
    systems: &[&str],
    arch: Arch,
) -> BTreeMap<String, Vec<JobStats>> {
    let mut out = BTreeMap::new();
    for sys in systems {
        eprintln!("[exp] running {sys} ({arch:?}, {} jobs)…", ctx.effective_jobs());
        let t0 = std::time::Instant::now();
        let (stats, _) = run_system(ctx, sys, arch, false, 0.0);
        eprintln!("[exp]   {sys}: {:.1}s wall", t0.elapsed().as_secs_f64());
        out.insert(sys.to_string(), stats);
    }
    out
}

/// The §V summary triple: mean, p1, p99 (the paper's error bars).
pub fn band_str(b: Band) -> Vec<String> {
    vec![format!("{:.0}", b.mean), format!("{:.0}", b.p1), format!("{:.0}", b.p99)]
}

pub fn band_str_f(b: Band, d: usize) -> Vec<String> {
    vec![
        format!("{:.*}", d, b.mean),
        format!("{:.*}", d, b.p1),
        format!("{:.*}", d, b.p99),
    ]
}

/// TTAs (jobs that reached target), JCTs, accuracies, perplexities,
/// straggler episodes of a stat set.
pub struct Summary {
    pub tta: Vec<f64>,
    pub jct: Vec<f64>,
    pub acc: Vec<f64>,
    pub ppl: Vec<f64>,
    pub stragglers: Vec<f64>,
    pub tta_reached: usize,
    pub jobs: usize,
}

pub fn summarize(stats: &[JobStats]) -> Summary {
    Summary {
        tta: stats.iter().filter_map(|s| s.tta_s).collect(),
        jct: stats.iter().map(|s| s.jct_s).collect(),
        acc: stats.iter().filter(|s| !s.is_nlp).map(|s| s.converged_value).collect(),
        ppl: stats.iter().filter(|s| s.is_nlp).map(|s| s.converged_value).collect(),
        stragglers: stats.iter().map(|s| s.straggler_episodes as f64).collect(),
        tta_reached: stats.iter().filter(|s| s.tta_s.is_some()).count(),
        jobs: stats.len(),
    }
}

/// Dispatch an experiment id. `all` runs everything.
pub fn dispatch(id: &str, ctx: &ExpCtx) -> crate::Result<()> {
    match id {
        "fig1" | "fig2" | "fig3" | "fig4" | "fig5" | "fig6" | "fig7" => {
            measure::fig1_to_7(ctx, id)
        }
        "fig8" => measure::fig8(ctx),
        "fig9" | "fig10" => measure::fig9_10(ctx, id),
        "fig11" => measure::fig11(ctx),
        "fig12" => measure::fig12_13(ctx, true),
        "fig13" => measure::fig12_13(ctx, false),
        "tab1" => measure::tab1(ctx),
        "fig14" => measure::fig14(ctx),
        "fig16" => eval::fig16(ctx),
        "fig17" => eval::fig17(ctx),
        "fig18" | "fig19" | "fig20" | "fig21" | "fig22" => eval::fig18_to_22(ctx, id),
        "fig23" | "fig24" | "fig25" | "fig26" | "fig27" => ablation::fig23_to_27(ctx, id),
        "fig28" => overhead::fig28(ctx),
        "fig29" => overhead::fig29(ctx),
        "all" => {
            for id in [
                "fig1", "fig8", "fig9", "fig11", "fig12", "fig13", "tab1", "fig14", "fig16",
                "fig17", "fig18", "fig23", "fig28", "fig29",
            ] {
                // fig1 emits figs 1–7; fig9 emits 9–10; fig18 emits 18–22;
                // fig23 emits 23–27
                dispatch(id, ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other:?} (try `all` or figN/tab1)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx() -> ExpCtx {
        ExpCtx {
            jobs: 6,
            quick: true,
            out_dir: std::env::temp_dir().join("star_exp_test"),
            ..Default::default()
        }
    }

    #[test]
    fn summarize_partitions_models() {
        let ctx = quick_ctx();
        let (stats, _) = run_system(&ctx, "SSGD", Arch::Ps, false, 0.0);
        let s = summarize(&stats);
        assert_eq!(s.jobs, stats.len());
        assert_eq!(s.acc.len() + s.ppl.len(), s.jobs);
        assert!(s.tta_reached <= s.jobs);
    }

    #[test]
    fn dispatch_rejects_unknown() {
        assert!(dispatch("fig99", &quick_ctx()).is_err());
    }
}
