//! Experiment harness: one subcommand per paper table/figure
//! (`cargo run --release --bin experiments -- <id>`; see DESIGN.md §4 for
//! the full index). Each experiment prints the paper's rows/series and
//! saves a CSV under `results/`.

pub mod ablation;
pub mod eval;
pub mod fabric_bench;
pub mod measure;
pub mod overhead;
pub mod resilience;
pub mod scale;
pub mod sweep;

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Context;

use crate::baselines::make_policy;
use crate::driver::{Driver, DriverConfig, JobStats, ServerRecord};
use crate::faults::{span_for, FaultPlan};
use crate::stats::Band;
use crate::table::Table;
use crate::trace::Arch;

/// Shared experiment context (CLI-derived).
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub jobs: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
    /// shrink everything for smoke tests
    pub quick: bool,
    /// fault-injection rate multiplier (`--fault-rate`): 0 = fault-free;
    /// 1 = the default MTBFs of [`crate::faults::FaultConfig`]; >1 =
    /// proportionally more failures. Applies to every experiment run
    /// through [`run_system`].
    pub fault_rate: f64,
    /// fault-plan seed (`--fault-seed`), independent of the trace seed
    pub fault_seed: u64,
    /// sweep worker threads (`--threads`): independent experiment cells
    /// (one cluster+driver per cell) run `threads`-wide through
    /// [`sweep::run_indexed`]. Defaults to the available parallelism;
    /// results are byte-identical at any value (cells share no state).
    pub threads: usize,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            jobs: 120,
            seed: 0,
            out_dir: PathBuf::from("results"),
            quick: false,
            fault_rate: 0.0,
            fault_seed: 0,
            threads: sweep::available_threads(),
        }
    }
}

impl ExpCtx {
    pub fn effective_jobs(&self) -> usize {
        if self.quick {
            self.jobs.min(12)
        } else {
            self.jobs
        }
    }

    /// The context's workload: the scenario layer's classic Philly
    /// family, which delegates to [`crate::trace::generate`] at the
    /// `jobs · 280 s` pacing — byte-identical to the pre-scenario
    /// `TraceConfig` construction (pinned by the golden suites).
    pub fn trace(&self) -> Vec<crate::trace::JobSpec> {
        let spec = crate::scenario::WorkloadSpec::philly(self.jobs, self.seed);
        crate::scenario::workload::build(&spec, self.effective_jobs())
            .expect("the classic Philly family has no failing configuration")
    }

    /// The context's fault plan for `trace` (empty when `fault_rate` ≤ 0):
    /// the scenario layer's rate regime, i.e. the `--fault-rate` recipe.
    pub fn fault_plan(&self, trace: &[crate::trace::JobSpec]) -> FaultPlan {
        let cfg = DriverConfig::default();
        crate::scenario::FaultRegime::Rate { rate: self.fault_rate, seed: self.fault_seed }
            .plan(trace, span_for(trace, cfg.max_job_duration_s), cfg.cluster.total_servers())
    }

    /// Save a table as `<out_dir>/<name>.csv`. A failed write fails the
    /// run — a sweep whose results were silently dropped is worse than a
    /// crashed one.
    pub fn save(&self, name: &str, t: &Table) -> crate::Result<()> {
        let path = self.out_dir.join(format!("{name}.csv"));
        t.save_csv(&path)
            .with_context(|| format!("saving experiment table {}", path.display()))
    }
}

/// One sweep cell's portable, merge-ready output: the CSV row exactly
/// as the serial table renders it, plus the `star-bench-v1` result
/// object for the JSON artifact. Both are final *rendered* forms — a
/// remote worker ships these over the cell protocol and the dispatcher
/// reassembles artifacts byte-identical to a serial in-process run
/// (strings round-trip trivially; `jsonio` numbers round-trip exactly,
/// see `jsonio` emit/parse docs).
#[derive(Clone, Debug, PartialEq)]
pub struct CellRows {
    pub csv: Vec<String>,
    pub json: crate::jsonio::Json,
}

/// Run one system over the context's trace. Unknown system names error
/// (surfaced through [`dispatch`]) instead of aborting the process.
pub fn run_system(
    ctx: &ExpCtx,
    system: &str,
    arch: Arch,
    record_series: bool,
    server_sample_s: f64,
) -> crate::Result<(Vec<JobStats>, Vec<ServerRecord>)> {
    // validate the name before building anything: the per-job factory
    // below runs mid-simulation, where failing is no longer an option
    make_policy(system)?;
    let trace = ctx.trace();
    let faults = ctx.fault_plan(&trace);
    let cfg = DriverConfig {
        arch,
        seed: ctx.seed,
        record_series,
        server_sample_period_s: server_sample_s,
        faults,
        ..Default::default()
    };
    let name = system.to_string();
    let driver = Driver::new(
        cfg,
        trace,
        Box::new(move |_| make_policy(&name).expect("validated above")),
    );
    Ok(driver.run())
}

/// Run several systems; returns name → stats. Systems are independent
/// cells (each builds its own cluster + driver from the shared context),
/// so they sweep `ctx.threads`-wide — the output is identical to a
/// serial loop because [`sweep::run_indexed`] preserves item order.
pub fn run_systems(
    ctx: &ExpCtx,
    systems: &[&str],
    arch: Arch,
) -> crate::Result<BTreeMap<String, Vec<JobStats>>> {
    crate::baselines::validate_systems(systems)?;
    eprintln!(
        "[exp] running {} systems ({arch:?}, {} jobs) on {} thread(s)…",
        systems.len(),
        ctx.effective_jobs(),
        ctx.threads
    );
    // cells return Result and errors propagate after the join: a future
    // fallible step in run_system must surface through dispatch, not as
    // a context-free worker-thread panic
    let results = sweep::run_indexed(
        systems,
        ctx.threads,
        |_, sys| -> crate::Result<Vec<JobStats>> {
            let t0 = std::time::Instant::now();
            let (stats, _) = run_system(ctx, sys, arch, false, 0.0)?;
            eprintln!("[exp]   {sys}: {:.1}s wall", t0.elapsed().as_secs_f64());
            Ok(stats)
        },
    )?;
    let results = results.into_iter().collect::<crate::Result<Vec<_>>>()?;
    Ok(systems
        .iter()
        .zip(results)
        .map(|(sys, stats)| (sys.to_string(), stats))
        .collect())
}

/// The §V summary triple: mean, p1, p99 (the paper's error bars).
pub fn band_str(b: Band) -> Vec<String> {
    vec![format!("{:.0}", b.mean), format!("{:.0}", b.p1), format!("{:.0}", b.p99)]
}

pub fn band_str_f(b: Band, d: usize) -> Vec<String> {
    vec![
        format!("{:.*}", d, b.mean),
        format!("{:.*}", d, b.p1),
        format!("{:.*}", d, b.p99),
    ]
}

/// TTAs (jobs that reached target), JCTs, accuracies, perplexities,
/// straggler episodes, downtime and rollback counts of a stat set.
pub struct Summary {
    pub tta: Vec<f64>,
    pub jct: Vec<f64>,
    pub acc: Vec<f64>,
    pub ppl: Vec<f64>,
    pub stragglers: Vec<f64>,
    /// per-job seconds lost to crashes / PS stalls (fault injection)
    pub downtime: Vec<f64>,
    /// per-job checkpoint rollbacks (fault injection)
    pub rollbacks: Vec<f64>,
    pub tta_reached: usize,
    pub jobs: usize,
}

pub fn summarize(stats: &[JobStats]) -> Summary {
    Summary {
        tta: stats.iter().filter_map(|s| s.tta_s).collect(),
        jct: stats.iter().map(|s| s.jct_s).collect(),
        acc: stats.iter().filter(|s| !s.is_nlp).map(|s| s.converged_value).collect(),
        ppl: stats.iter().filter(|s| s.is_nlp).map(|s| s.converged_value).collect(),
        stragglers: stats.iter().map(|s| s.straggler_episodes as f64).collect(),
        downtime: stats.iter().map(|s| s.downtime_s).collect(),
        rollbacks: stats.iter().map(|s| s.rollbacks as f64).collect(),
        tta_reached: stats.iter().filter(|s| s.tta_s.is_some()).count(),
        jobs: stats.len(),
    }
}

/// Every experiment id [`dispatch`] accepts, §4-table order. The single
/// source of truth for "what exists": the unknown-id error below, the
/// scenario layer's delegation validation, and the CLI usage text all
/// read this list (note fig15 deliberately does not exist — the paper
/// has no such figure).
pub const EXPERIMENT_IDS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
    "fig12", "fig13", "tab1", "fig14", "fig16", "fig17", "fig18", "fig19", "fig20", "fig21",
    "fig22", "fig23", "fig24", "fig25", "fig26", "fig27", "fig28", "fig29", "resilience",
    "scale", "fabric-bench", "all",
];

/// Dispatch an experiment id. `all` runs everything.
pub fn dispatch(id: &str, ctx: &ExpCtx) -> crate::Result<()> {
    match id {
        "fig1" | "fig2" | "fig3" | "fig4" | "fig5" | "fig6" | "fig7" => {
            measure::fig1_to_7(ctx, id)
        }
        "fig8" => measure::fig8(ctx),
        "fig9" | "fig10" => measure::fig9_10(ctx, id),
        "fig11" => measure::fig11(ctx),
        "fig12" => measure::fig12_13(ctx, true),
        "fig13" => measure::fig12_13(ctx, false),
        "tab1" => measure::tab1(ctx),
        "fig14" => measure::fig14(ctx),
        "fig16" => eval::fig16(ctx),
        "fig17" => eval::fig17(ctx),
        "fig18" | "fig19" | "fig20" | "fig21" | "fig22" => eval::fig18_to_22(ctx, id),
        "fig23" | "fig24" | "fig25" | "fig26" | "fig27" => ablation::fig23_to_27(ctx, id),
        "fig28" => overhead::fig28(ctx),
        "fig29" => overhead::fig29(ctx),
        "resilience" => resilience::resilience(ctx),
        // deliberately not part of `all`: the full grid's 50x cell is a
        // long-running benchmark, not a paper artifact (`--quick`/
        // `--smoke` selects the down-sized CI grid)
        "scale" => scale::scale(ctx, ctx.quick),
        // not part of `all` either: it benchmarks the dispatch fabric
        // (12 subprocess-fleet runs), a CI artifact, not a paper figure
        "fabric-bench" => fabric_bench::fabric_bench(ctx),
        "all" => {
            for id in [
                "fig1", "fig8", "fig9", "fig11", "fig12", "fig13", "tab1", "fig14", "fig16",
                "fig17", "fig18", "fig23", "fig28", "fig29", "resilience",
            ] {
                // fig1 emits figs 1–7; fig9 emits 9–10; fig18 emits 18–22;
                // fig23 emits 23–27
                dispatch(id, ctx)?;
            }
            Ok(())
        }
        other => {
            anyhow::bail!(
                "unknown experiment {other:?} (valid ids: {})",
                EXPERIMENT_IDS.join(", ")
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx() -> ExpCtx {
        ExpCtx {
            jobs: 6,
            quick: true,
            out_dir: std::env::temp_dir().join("star_exp_test"),
            ..Default::default()
        }
    }

    #[test]
    fn summarize_partitions_models() {
        let ctx = quick_ctx();
        let (stats, _) = run_system(&ctx, "SSGD", Arch::Ps, false, 0.0).unwrap();
        let s = summarize(&stats);
        assert_eq!(s.jobs, stats.len());
        assert_eq!(s.acc.len() + s.ppl.len(), s.jobs);
        assert!(s.tta_reached <= s.jobs);
        assert_eq!(s.downtime.len(), s.jobs);
        assert!(s.downtime.iter().all(|&d| d == 0.0), "fault-free context");
    }

    #[test]
    fn dispatch_rejects_unknown_and_lists_valid_ids() {
        let err = format!("{:#}", dispatch("fig99", &quick_ctx()).err().unwrap());
        assert!(err.contains("fig99"), "{err}");
        for id in ["fig12", "tab1", "resilience", "scale", "all"] {
            assert!(err.contains(id), "error must list {id}: {err}");
        }
    }

    #[test]
    fn experiment_id_list_is_consistent() {
        let ids = EXPERIMENT_IDS;
        let mut sorted: Vec<&str> = ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "duplicate experiment ids");
        assert!(!ids.contains(&"fig15"), "the paper has no fig15");
        for required in ["fig1", "fig29", "tab1", "resilience", "scale", "all"] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }

    #[test]
    fn run_system_surfaces_unknown_system_as_error() {
        let err = run_system(&quick_ctx(), "NotASystem", Arch::Ps, false, 0.0)
            .err()
            .expect("unknown system must error");
        assert!(format!("{err:#}").contains("unknown system"));
    }

    #[test]
    fn fault_rate_produces_plan_and_downtime() {
        let ctx = ExpCtx { fault_rate: 3.0, jobs: 3, ..quick_ctx() };
        let trace = ctx.trace();
        let plan = ctx.fault_plan(&trace);
        assert!(!plan.is_empty(), "rate 3 must schedule faults");
        assert!(ctx.fault_plan(&trace) == plan, "plan is deterministic");
        let (stats, _) = run_system(&ctx, "SSGD", Arch::Ps, false, 0.0).unwrap();
        let downtime: f64 = stats.iter().map(|s| s.downtime_s).sum();
        let rollbacks: u64 = stats.iter().map(|s| s.rollbacks).sum();
        assert!(
            downtime > 0.0 || rollbacks > 0,
            "a heavy fault plan must leave traces in the stats"
        );
    }
}
