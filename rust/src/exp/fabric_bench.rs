//! `experiments fabric-bench` — throughput benchmark of the dispatch
//! fabric itself (DESIGN.md §14): the quick resilience grid dispatched
//! at every {workers} × {window} × {group-commit} corner, reporting
//! cells/sec, protocol round-trips per cell, and journal fsyncs per run
//! as a `star-bench-v1` document (`results/BENCH_fabric.json`).
//!
//! Every fabric run's artifacts are byte-compared against a serial
//! in-process `--threads 1` baseline before its row is recorded — a
//! corner that wins throughput by corrupting determinism fails the
//! bench (this is also CI's byte-identity enforcement for `--window 4`,
//! complementing the chaos smoke step).
//!
//! The workload is deliberately the *quick* grid at a tiny job count:
//! this bench measures fabric overhead (issue latency, fsync stalls,
//! idle bubbles between cells), not cell compute, and cheap cells are
//! exactly where that overhead shows.

use anyhow::Context;

use crate::fabric::dispatch::{dispatch, DispatchOpts};
use crate::fabric::SweepSpec;
use crate::jsonio::{self, Json};
use crate::table::{self, Table};

use super::{resilience, ExpCtx};

pub fn fabric_bench(ctx: &ExpCtx) -> crate::Result<()> {
    // small + quick regardless of the invocation: fabric overhead is
    // what's measured, and the serial/fabric byte-diff below only needs
    // the two sides to agree on the workload
    let jobs = ctx.effective_jobs().clamp(2, 4);
    let sweep = SweepSpec::Resilience {
        jobs,
        seed: ctx.seed,
        quick: true,
        fault_seed: ctx.fault_seed,
    };
    let cells = sweep.cell_labels()?.len();

    // the ground truth everything is diffed against
    let serial_dir = ctx.out_dir.join("fabric_bench_serial");
    let serial_ctx = ExpCtx {
        jobs,
        seed: ctx.seed,
        out_dir: serial_dir.clone(),
        quick: true,
        fault_rate: 0.0,
        fault_seed: ctx.fault_seed,
        threads: 1,
    };
    eprintln!("[exp] fabric-bench: serial baseline ({jobs} jobs, {cells} cells)…");
    resilience::resilience(&serial_ctx)?;

    let mut rows: Vec<Json> = Vec::new();
    let mut t = Table::new(
        &format!("fabric bench ({cells} cells, {jobs} jobs; vs serial baseline)"),
        &["config", "wall_s", "cells_per_sec", "rt_per_cell", "fsyncs"],
    );
    for &workers in &[1usize, 2, 4] {
        for &window in &[1usize, 4] {
            for &group_commit in &[false, true] {
                let gc = if group_commit { "on" } else { "off" };
                let name = format!("fabric/w{workers}/win{window}/gc_{gc}");
                let out = ctx
                    .out_dir
                    .join(format!("fabric_bench_w{workers}_win{window}_gc_{gc}"));
                let opts = DispatchOpts {
                    workers,
                    out_dir: out.clone(),
                    fresh: true,
                    window,
                    commit_batch: if group_commit { 16 } else { 1 },
                    // park the interval flush: the fsync column should
                    // show batch-boundary commits, not timer noise
                    commit_interval_ms: 10_000,
                    // likewise no speculative duplicates: round-trips
                    // per cell must reflect pipelining alone
                    straggler_factor: 1e9,
                    ..Default::default()
                };
                eprintln!("[exp] fabric-bench: {name}…");
                let report = dispatch(&sweep, &opts)?;
                for ext in ["json", "csv"] {
                    let a = std::fs::read(serial_dir.join(format!("resilience.{ext}")))?;
                    let b = std::fs::read(out.join(format!("resilience.{ext}")))?;
                    if a != b {
                        anyhow::bail!(
                            "{name}: resilience.{ext} diverged from the serial baseline — \
                             the fabric corrupted determinism"
                        );
                    }
                }
                let cells_per_sec =
                    if report.wall_s > 0.0 { cells as f64 / report.wall_s } else { 0.0 };
                let rt_per_cell = report.round_trips as f64 / cells.max(1) as f64;
                let ns_per_iter =
                    if cells > 0 { report.wall_s * 1e9 / cells as f64 } else { 0.0 };
                t.rowf(&[
                    table::s(&name),
                    table::f(report.wall_s, 2),
                    table::f(cells_per_sec, 2),
                    table::f(rt_per_cell, 2),
                    table::i(report.journal_fsyncs as i64),
                ]);
                rows.push(jsonio::obj(vec![
                    ("name", jsonio::s(&name)),
                    ("iters", jsonio::num(cells as f64)),
                    ("ns_per_iter", jsonio::num(ns_per_iter)),
                    ("workers", jsonio::num(workers as f64)),
                    ("window", jsonio::num(window as f64)),
                    ("group_commit", jsonio::b(group_commit)),
                    ("cells", jsonio::num(cells as f64)),
                    ("wall_s", jsonio::num(report.wall_s)),
                    ("cells_per_sec", jsonio::num(cells_per_sec)),
                    ("round_trips", jsonio::num(report.round_trips as f64)),
                    ("round_trips_per_cell", jsonio::num(rt_per_cell)),
                    ("journal_fsyncs", jsonio::num(report.journal_fsyncs as f64)),
                    ("matches_serial", jsonio::b(true)),
                ]));
            }
        }
    }
    t.print();

    let doc = jsonio::obj(vec![
        ("schema", jsonio::s("star-bench-v1")),
        ("generated_by", jsonio::s("star::exp::fabric_bench")),
        ("results", Json::Arr(rows)),
    ]);
    std::fs::create_dir_all(&ctx.out_dir)
        .with_context(|| format!("creating {}", ctx.out_dir.display()))?;
    let path = ctx.out_dir.join("BENCH_fabric.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("fabric bench written to {}", path.display());
    Ok(())
}
