//! §V evaluation: Figs 16–22.

use super::measure::{run_single, Fixed};
use super::{band_str, band_str_f, run_system, run_systems, summarize, ExpCtx};
use crate::driver::DriverMode;
use crate::models::ZOO;
use crate::predict::{FixedDurationRule, RatioSeriesRule, Confusion, STRAGGLER_DEV};
use crate::stats;
use crate::sync::SyncMode;
use crate::table::{self, Table};
use crate::trace::Arch;

/// Fig 16 — static x-order: converged accuracy + TTA for x ∈ {1,2,4,8}.
pub fn fig16(ctx: &ExpCtx) -> crate::Result<()> {
    let dense = ZOO.iter().position(|m| m.name == "DenseNet121").unwrap();
    let mut t = Table::new(
        "Fig 16 — x-order synchronization (8 workers, DenseNet121)",
        &["mode", "converged_acc_%", "tta_s", "jct_s"],
    );
    for x in [1usize, 2, 4, 8] {
        let s = run_single(
            dense,
            8,
            Box::new(move |_| {
                Box::new(Fixed {
                    mode: DriverMode::Sync(if x == 8 {
                        SyncMode::Ssgd
                    } else {
                        SyncMode::StaticX(x)
                    }),
                    rescaled: true,
                    label: "x-order",
                })
            }),
            None,
            ctx.seed,
        );
        t.rowf(&[
            table::s(format!("{x}-order")),
            table::f(s.converged_value, 1),
            match s.tta_s {
                Some(v) => table::f(v, 0),
                None => table::s(">cap"),
            },
            table::f(s.jct_s, 0),
        ]);
    }
    t.print();
    println!("(paper: 80.3/82.7/86.4/88.9% accuracy and 15680/4120/2480/1960 s TTA for 1/2/4/8-order)\n");
    ctx.save("fig16", &t)?;
    Ok(())
}

/// Fig 17 — straggler-prediction FP/FN across methods.
///
/// STAR and STAR- confusions come from the driver's online accounting;
/// the fixed-duration rule [29] and the deviation-ratio time-series
/// baseline are evaluated offline on the recorded iteration series of the
/// measurement run, so all methods see identical workloads.
pub fn fig17(ctx: &ExpCtx) -> crate::Result<()> {
    let mut t = Table::new(
        "Fig 17 — straggler prediction accuracy (mean FP% / FN% over jobs, p90)",
        &["method", "fp_mean", "fp_p90", "fn_mean", "fn_p90"],
    );

    // offline baselines over the SSGD measurement run
    let (stats_ssgd, _) = run_system(ctx, "SSGD", Arch::Ps, true, 0.0)?;
    let _ = &stats_ssgd;
    let mut fixed_fp = Vec::new();
    let mut fixed_fn = Vec::new();
    let mut ratio_fp = Vec::new();
    let mut ratio_fn = Vec::new();
    for s in &stats_ssgd {
        let iters = s.series.iter().map(|w| w.len()).min().unwrap_or(0);
        if iters < 12 {
            continue;
        }
        let n = s.series.len();
        let mut rule_fixed = FixedDurationRule::new(n, 5.0);
        let mut rule_ratio = RatioSeriesRule::new(n);
        let mut cf = Confusion::default();
        let mut cr = Confusion::default();
        let mut tsim = 0.0;
        let mut pred_fixed = vec![false; n];
        let mut pred_ratio = vec![false; n];
        for j in 0..iters {
            let times: Vec<f64> = s.series.iter().map(|w| w[j].total_s).collect();
            let actual = crate::predict::straggler_flags(&times);
            for w in 0..n {
                cf.add(pred_fixed[w], actual[w]);
                cr.add(pred_ratio[w], actual[w]);
            }
            tsim += stats::mean(&times);
            pred_fixed = rule_fixed.observe(tsim, &times);
            pred_ratio = rule_ratio.observe_and_predict(&times);
        }
        fixed_fp.push(cf.fp_rate() * 100.0);
        fixed_fn.push(cf.fn_rate() * 100.0);
        ratio_fp.push(cr.fp_rate() * 100.0);
        ratio_fn.push(cr.fn_rate() * 100.0);
    }

    // STAR's own prediction pipeline (driver-recorded confusions)
    let mut rows: Vec<(&str, Vec<f64>, Vec<f64>)> = vec![
        ("fixed-duration [29]", fixed_fp, fixed_fn),
        ("ratio-series LSTM", ratio_fp, ratio_fn),
    ];
    for sys in ["STAR-H", "STAR-"] {
        let (stats, _) = run_system(ctx, sys, Arch::Ps, false, 0.0)?;
        let fps: Vec<f64> = stats.iter().map(|s| s.prediction.fp_rate() * 100.0).collect();
        let fns: Vec<f64> = stats.iter().map(|s| s.prediction.fn_rate() * 100.0).collect();
        rows.push((if sys == "STAR-H" { "STAR" } else { "STAR-" }, fps, fns));
    }
    for (name, fp, fn_) in rows {
        t.rowf(&[
            table::s(name),
            table::f(stats::mean(&fp), 1),
            table::f(stats::percentile(&fp, 90.0), 1),
            table::f(stats::mean(&fn_), 1),
            table::f(stats::percentile(&fn_, 90.0), 1),
        ]);
    }
    t.print();
    println!("(paper: STAR 3.5–10.4% FP, 3.8–4.2% FN — lowest; fixed-duration and ratio-LSTM are worse)\n");
    ctx.save("fig17", &t)?;
    Ok(())
}

/// Systems compared in §V-B per architecture.
pub fn eval_systems(arch: Arch) -> Vec<&'static str> {
    match arch {
        Arch::Ps => vec![
            "SSGD", "ASGD", "Sync-Switch", "LB-BSP", "LGC", "Zeno++", "STAR-H", "STAR-ML",
            "STAR-",
        ],
        Arch::AllReduce => vec!["SSGD", "LB-BSP", "LGC", "STAR-H", "STAR-ML", "STAR-"],
    }
}

/// Figs 18–22 — the §V-B overall comparison (one pass per architecture
/// feeds all five figures).
pub fn fig18_to_22(ctx: &ExpCtx, which: &str) -> crate::Result<()> {
    for arch in [Arch::Ps, Arch::AllReduce] {
        let tag = if arch == Arch::Ps { "ps" } else { "ar" };
        let results = run_systems(ctx, &eval_systems(arch), arch)?;

        let mut t18 = Table::new(
            &format!("Fig 18 ({tag}) — TTA per job (s): mean, p1, p99"),
            &["system", "mean", "p1", "p99", "reached"],
        );
        let mut t19 = Table::new(
            &format!("Fig 19 ({tag}) — JCT per job (s): mean, p1, p99"),
            &["system", "mean", "p1", "p99"],
        );
        let mut t20 = Table::new(
            &format!("Fig 20 ({tag}) — converged accuracy (image jobs, %)"),
            &["system", "mean", "p1", "p99"],
        );
        let mut t21 = Table::new(
            &format!("Fig 21 ({tag}) — converged perplexity (NLP jobs)"),
            &["system", "mean", "p1", "p99"],
        );
        let mut t22 = Table::new(
            &format!("Fig 22 ({tag}) — straggler episodes per job"),
            &["system", "mean", "p1", "p99"],
        );
        for sys in eval_systems(arch) {
            let s = summarize(&results[sys]);
            let mut row = vec![sys.to_string()];
            row.extend(band_str(stats::band(&s.tta)));
            row.push(format!("{}/{}", s.tta_reached, s.jobs));
            t18.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str(stats::band(&s.jct)));
            t19.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str_f(stats::band(&s.acc), 2));
            t20.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str_f(stats::band(&s.ppl), 1));
            t21.row(row);
            let mut row = vec![sys.to_string()];
            row.extend(band_str(stats::band(&s.stragglers)));
            t22.row(row);
        }
        let print_one = |id: &str, t: &Table| -> crate::Result<()> {
            if which == id || which == "all" || which == "fig18" {
                t.print();
                println!();
                ctx.save(&format!("{id}_{tag}"), t)?;
            }
            Ok(())
        };
        print_one("fig18", &t18)?;
        print_one("fig19", &t19)?;
        print_one("fig20", &t20)?;
        print_one("fig21", &t21)?;
        print_one("fig22", &t22)?;

        // headline reductions
        if which == "fig18" || which == "all" {
            let s_star = summarize(&results["STAR-ML"]);
            let s_ssgd = summarize(&results["SSGD"]);
            let red = (1.0 - stats::mean(&s_star.tta) / stats::mean(&s_ssgd.tta)) * 100.0;
            println!(
                "[{tag}] STAR-ML reduces mean TTA vs SSGD by {red:.0}% \
                 (paper: 84% PS / 70% AR)\n"
            );
        }
        let _ = STRAGGLER_DEV;
    }
    Ok(())
}
