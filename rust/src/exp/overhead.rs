//! §V-D/E: Fig 28 (decision-time overhead) and Fig 29 (AR parent wait
//! sweep).

use super::measure::Fixed;
use super::{run_systems, ExpCtx};
use crate::decide::DeciderKind;
use crate::driver::{DriverMode, RoundObs};
use crate::models::ZOO;
use crate::stats;
use crate::sync::SyncMode;
use crate::table::{self, Table};
use crate::trace::Arch;

/// Fig 28 — decision-making overhead.
///
/// Two views, as in §V-D:
///  * *sim-accounted* totals per job (the paper's python-scale costs the
///    simulator charges: STAR-H's 970 ms pause per switch, overlapped ML
///    inference, Zeno++ validation),
///  * *measured* wall-clock of this repo's rust decision paths
///    (microbenchmarked here; also see `cargo bench decision`).
pub fn fig28(ctx: &ExpCtx) -> crate::Result<()> {
    let mut t = Table::new(
        "Fig 28a — sim-accounted decision overhead per job (s): mean, p1, p99",
        &["system", "mean", "p1", "p99", "decisions"],
    );
    let systems = ["Sync-Switch", "LB-BSP", "LGC", "Zeno++", "STAR-H", "STAR-ML", "STAR-"];
    let results = run_systems(ctx, &systems, Arch::Ps)?;
    for sys in systems {
        let stats_v: Vec<f64> =
            results[sys].iter().map(|s| s.decision_overhead_total_s).collect();
        let decisions: u64 = results[sys].iter().map(|s| s.decision_count).sum();
        let b = stats::band(&stats_v);
        t.rowf(&[
            table::s(sys),
            table::f(b.mean, 1),
            table::f(b.p1, 1),
            table::f(b.p99, 1),
            table::i(decisions as i64),
        ]);
    }
    t.print();
    println!("(paper: H ≫ ML; ML runs concurrently with training so it does not stall jobs)\n");
    ctx.save("fig28a", &t)?;

    // measured rust decision latency (the actual hot path of this repo)
    let mut t2 = Table::new(
        "Fig 28b — measured rust decision latency (this implementation)",
        &["path", "mean_us", "p99_us"],
    );
    let spec = &ZOO[3];
    let mut rng = crate::simrng::Rng::seeded(7);
    let mut h_us = Vec::new();
    let mut ml_us = Vec::new();
    let mut ml = crate::decide::MlDecider::new();
    // train the regressor a bit so inference hits the fitted path
    for _ in 0..300 {
        let pred: Vec<f64> = (0..8).map(|_| rng.range(0.2, 2.0)).collect();
        for m in crate::sync::candidate_modes_ps(8) {
            let est = crate::decide::time_to_progress_ps(spec, 100.0, 8, &m, &pred);
            let x = crate::decide::MlDecider::features(spec, 100.0, 8, &pred, &m);
            ml.observe(&x, est);
        }
    }
    for _ in 0..2000 {
        let pred: Vec<f64> = (0..8).map(|_| rng.range(0.2, 2.0)).collect();
        let t0 = std::time::Instant::now();
        let d = crate::decide::choose_ps_heuristic(spec, 150.0, 8, &pred);
        std::hint::black_box(d);
        h_us.push(t0.elapsed().as_nanos() as f64 / 1e3);
        let t0 = std::time::Instant::now();
        let d = ml.choose(spec, 150.0, 8, &pred, crate::sync::candidate_modes_ps(8));
        std::hint::black_box(d);
        ml_us.push(t0.elapsed().as_nanos() as f64 / 1e3);
    }
    for (name, v) in [("STAR-H heuristic (rust)", &h_us), ("STAR-ML inference (rust)", &ml_us)] {
        t2.rowf(&[
            table::s(name),
            table::f(stats::mean(v), 1),
            table::f(stats::percentile(v, 99.0), 1),
        ]);
    }
    t2.print();
    println!(
        "(paper's python STAR-H heuristic: ~970 ms per decision; this rust path is ~10^4× faster, \
         so the decision pause the paper engineered around vanishes — see EXPERIMENTS.md §Perf)\n"
    );
    ctx.save("fig28b", &t2)?;
    let _ = DeciderKind::Heuristic;
    Ok(())
}

/// Fig 29 — normalized TTA vs AR parent wait time t_w (30–300 ms).
pub fn fig29(ctx: &ExpCtx) -> crate::Result<()> {
    let tws = [30.0, 60.0, 90.0, 120.0, 150.0, 180.0, 210.0, 240.0, 270.0, 300.0];
    let models: Vec<usize> = if ctx.quick { vec![3, 9] } else { vec![0, 3, 4, 7, 9] };
    let mut cols = vec!["t_w_ms".to_string()];
    cols.extend(models.iter().map(|&m| ZOO[m].name.to_string()));
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 29 — normalized TTA vs AR parent wait t_w (1 removed straggler)",
        &col_refs,
    );
    let mut ttas: Vec<Vec<f64>> = Vec::new();
    for &mi in &models {
        let mut per_model = Vec::new();
        for &tw in &tws {
            let s = run_single_ar(mi, tw, ctx.seed);
            per_model.push(s);
        }
        ttas.push(per_model);
    }
    // normalize per model by its own minimum
    for (i, &tw) in tws.iter().enumerate() {
        let mut row = vec![format!("{tw:.0}")];
        for (m, _) in models.iter().enumerate() {
            let min = ttas[m].iter().cloned().fold(f64::INFINITY, f64::min);
            row.push(format!("{:.3}", ttas[m][i] / min));
        }
        t.row(row);
    }
    t.print();
    println!("(paper: TTA dips then rises with t_w; the optimum varies per model)\n");
    ctx.save("fig29", &t)?;
    Ok(())
}

fn run_single_ar(model: usize, tw_ms: f64, seed: u64) -> f64 {
    // one 5-worker job on AR with one straggling worker (throttled CPU):
    // recovering its gradient lifts the update batch 4→5 (25%), so a wait
    // near the straggler's lag pays for itself — the Fig 29 trade-off
    let mk = move |_: &crate::trace::JobSpec| -> Box<dyn crate::driver::Policy> {
        Box::new(Fixed {
            mode: DriverMode::Sync(SyncMode::ArRing { removed: 1, tw_ms }),
            rescaled: true,
            label: "ring",
        })
    };
    let mut cfg = crate::driver::DriverConfig {
        arch: Arch::AllReduce,
        seed,
        record_series: false,
        ..Default::default()
    };
    // a *mild* straggler: slow enough to be removed from the ring, close
    // enough that a modest parent wait can recover its gradient (q=1) —
    // this is exactly the trade Fig 29 sweeps
    cfg.throttles.push((0, 1, 0.85, 0.92));
    let driver = crate::driver::Driver::new(
        cfg,
        super::measure::single_job(model, 5),
        Box::new(mk),
    );
    let (stats, _) = driver.run();
    stats[0].tta_s.unwrap_or(stats[0].jct_s)
}

#[allow(unused_imports)]
use crate::driver::Policy as _;

#[allow(dead_code)]
fn _obs_unused(_o: &RoundObs) {}
