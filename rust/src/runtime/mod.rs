//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, built
//! once by `make artifacts`) and executes them from the rust hot path.
//! Python never runs here.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax≥0.5's
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see /opt/xla-example/README.md). All modules are lowered with
//! `return_tuple=True`, so every execution returns a single tuple literal
//! that we decompose.
//!
//! The PJRT-backed half of this module is gated behind the `xla` cargo
//! feature (the native xla_extension toolchain is not part of the offline
//! image). Without the feature, the same API surface exists but every
//! execution entry point returns an explanatory error — manifest
//! inspection and the synthetic corpus generator still work, and the rest
//! of the crate (simulator, coordinator, experiments) is unaffected.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::jsonio::Json;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub json: Json,
}

/// Static description of one lowered model config.
#[derive(Clone, Debug)]
pub struct ConfigInfo {
    pub name: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: usize,
    pub padded_param_count: usize,
    pub use_pallas_matmul: bool,
}

impl Manifest {
    /// Locate artifacts: `$STAR_ARTIFACTS`, `./artifacts`, or the crate
    /// root's `artifacts/`.
    pub fn discover() -> Result<Manifest> {
        let mut candidates = Vec::new();
        if let Ok(p) = std::env::var("STAR_ARTIFACTS") {
            candidates.push(PathBuf::from(p));
        }
        candidates.push(PathBuf::from("artifacts"));
        candidates.push(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        for c in candidates {
            if c.join("manifest.json").exists() {
                return Self::load(&c);
            }
        }
        bail!("artifacts not found — run `make artifacts` first")
    }

    pub fn load(root: &Path) -> Result<Manifest> {
        let json = Json::parse_file(&root.join("manifest.json"))?;
        if json.get("interchange")?.str()? != "hlo-text" {
            bail!("unsupported artifact interchange format");
        }
        Ok(Manifest { root: root.to_path_buf(), json })
    }

    pub fn config_names(&self) -> Vec<String> {
        self.json
            .get("configs")
            .and_then(|c| c.obj().map(|m| m.keys().cloned().collect()))
            .unwrap_or_default()
    }

    pub fn config(&self, name: &str) -> Result<ConfigInfo> {
        let c = self.json.get("configs")?.get(name)?;
        Ok(ConfigInfo {
            name: name.to_string(),
            vocab: c.get("vocab")?.int()? as usize,
            seq_len: c.get("seq_len")?.int()? as usize,
            batch: c.get("batch")?.int()? as usize,
            param_count: c.get("param_count")?.int()? as usize,
            padded_param_count: c.get("padded_param_count")?.int()? as usize,
            use_pallas_matmul: c.get("use_pallas_matmul")?.boolean()?,
        })
    }

    pub fn artifact_path(&self, config: &str, which: &str) -> Result<PathBuf> {
        let rel = self
            .json
            .get("configs")?
            .get(config)?
            .get("artifacts")?
            .get(which)?
            .str()?
            .to_string();
        Ok(self.root.join(rel))
    }

    pub fn predictor_path(&self) -> Result<PathBuf> {
        Ok(self.root.join(self.json.get("predictor")?.get("artifact")?.str()?))
    }

    pub fn predictor_window(&self) -> Result<usize> {
        Ok(self.json.get("predictor")?.get("window")?.int()? as usize)
    }
}

/// Synthetic tiny-corpus batch for the e2e examples: a noisy affine
/// bigram process over a zipf-skewed alphabet — learnable structure
/// (the affine map) with irreducible entropy (the zipf innovations), so
/// training loss falls well below ln(V) but stays bounded away from 0.
pub fn synth_corpus_batch(info: &ConfigInfo, rng: &mut crate::simrng::Rng) -> Vec<i32> {
    let v = info.vocab;
    let mut out = Vec::with_capacity(info.batch * (info.seq_len + 1));
    for _ in 0..info.batch {
        let mut cur = rng.zipf(v, 1.2);
        for _ in 0..=info.seq_len {
            out.push(cur as i32);
            // local additive drift: the model can learn "next ≈ cur + small
            // zipf offset" as a relative rule, so loss falls from ln(V)
            // toward the innovation entropy within a few hundred steps
            let innovation = rng.zipf(64.min(v), 1.3) + 1; // 1-based
            cur = (cur + innovation) % v;
        }
    }
    out
}

#[cfg(feature = "xla")]
pub use pjrt::*;
#[cfg(not(feature = "xla"))]
pub use stub::*;

#[cfg(feature = "xla")]
mod pjrt {
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    use super::{ConfigInfo, Manifest};

    /// A PJRT client + compiled-executable cache.
    pub struct Runtime {
        pub client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            Ok(Runtime { client })
        }

        /// Load + compile one HLO-text artifact.
        pub fn load(&self, path: &Path) -> Result<Compiled> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap)
            .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(wrap)?;
            Ok(Compiled { exe, name: path.display().to_string() })
        }
    }

    /// One compiled executable.
    pub struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Compiled {
        /// Execute with literal inputs; returns the decomposed output tuple.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let out = self.exe.execute::<xla::Literal>(inputs).map_err(wrap)?;
            let lit = out[0][0].to_literal_sync().map_err(wrap)?;
            lit.to_tuple().map_err(wrap)
        }
    }

    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow!("xla: {e}")
    }

    /// Literal helpers.
    pub fn lit_f32(values: &[f32]) -> xla::Literal {
        xla::Literal::vec1(values)
    }

    pub fn lit_f32_2d(values: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(values.len(), rows * cols);
        xla::Literal::vec1(values).reshape(&[rows as i64, cols as i64]).map_err(wrap)
    }

    pub fn lit_i32_2d(values: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(values.len(), rows * cols);
        xla::Literal::vec1(values).reshape(&[rows as i64, cols as i64]).map_err(wrap)
    }

    pub fn lit_scalar_i32(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(wrap)
    }

    pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
        lit.get_first_element::<f32>().map_err(wrap)
    }

    /// A device-side training session for one model config: holds the five
    /// compiled functions and the parameter vector, and exposes the exact
    /// operations the coordinator composes (train_step / grad_acc /
    /// apply_update / eval_loss).
    pub struct TrainSession {
        pub info: ConfigInfo,
        init: Compiled,
        train_step: Compiled,
        eval_loss: Compiled,
        apply_update: Compiled,
        grad_acc: Compiled,
        /// current parameters (host mirror; device buffers are created per
        /// call — the PJRT CPU client aliases host memory so this is cheap;
        /// see EXPERIMENTS.md §Perf for the measured numbers)
        pub params: Vec<f32>,
    }

    impl TrainSession {
        pub fn new(rt: &Runtime, man: &Manifest, config: &str) -> Result<TrainSession> {
            let info = man.config(config)?;
            let load =
                |which: &str| -> Result<Compiled> { rt.load(&man.artifact_path(config, which)?) };
            Ok(TrainSession {
                params: vec![0.0; info.padded_param_count],
                info,
                init: load("init")?,
                train_step: load("train_step")?,
                eval_loss: load("eval_loss")?,
                apply_update: load("apply_update")?,
                grad_acc: load("grad_acc")?,
            })
        }

        /// Initialize parameters on device from a seed.
        pub fn init_params(&mut self, seed: i32) -> Result<()> {
            let out = self.init.run(&[lit_scalar_i32(seed)])?;
            self.params = to_f32_vec(&out[0])?;
            anyhow::ensure!(self.params.len() == self.info.padded_param_count);
            Ok(())
        }

        /// One worker's forward+backward on a token batch: returns (loss, grads).
        pub fn train_step(&self, tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
            let t = lit_i32_2d(tokens, self.info.batch, self.info.seq_len + 1)?;
            let out = self.train_step.run(&[lit_f32(&self.params), t])?;
            Ok((scalar_f32(&out[0])?, to_f32_vec(&out[1])?))
        }

        /// Evaluation loss on a held-out batch.
        pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
            let t = lit_i32_2d(tokens, self.info.batch, self.info.seq_len + 1)?;
            let out = self.eval_loss.run(&[lit_f32(&self.params), t])?;
            scalar_f32(&out[0])
        }

        /// acc += w*g through the fused Pallas kernel artifact.
        pub fn grad_acc(&self, acc: &[f32], g: &[f32], w: f32) -> Result<Vec<f32>> {
            let out = self.grad_acc.run(&[lit_f32(acc), lit_f32(g), lit_f32(&[w])])?;
            to_f32_vec(&out[0])
        }

        /// params -= scale * acc through the fused Pallas kernel artifact.
        pub fn apply_update(&mut self, acc: &[f32], scale: f32) -> Result<()> {
            let out = self
                .apply_update
                .run(&[lit_f32(&self.params), lit_f32(acc), lit_f32(&[scale])])?;
            self.params = to_f32_vec(&out[0])?;
            Ok(())
        }

        /// x-order update exactly as §IV-B defines it: mean of `grads` applied
        /// at `lr` (composition of grad_acc + apply_update artifacts).
        pub fn xorder_update(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<()> {
            anyhow::ensure!(!grads.is_empty());
            let mut acc = vec![0.0f32; self.info.padded_param_count];
            for g in grads {
                acc = self.grad_acc(&acc, g, 1.0)?;
            }
            self.apply_update(&acc, lr / grads.len() as f32)
        }
    }

    /// The straggler-prediction LSTM artifact (§IV-A): history → next (cpu, bw).
    pub struct LstmPredictor {
        compiled: Compiled,
        window: usize,
    }

    impl LstmPredictor {
        pub fn new(rt: &Runtime, man: &Manifest) -> Result<LstmPredictor> {
            Ok(LstmPredictor {
                compiled: rt.load(&man.predictor_path()?)?,
                window: man.predictor_window()?,
            })
        }

        pub fn predict_rows(&self, rows: &[[f32; 2]]) -> Result<(f64, f64)> {
            anyhow::ensure!(rows.len() == self.window, "history must have {} rows", self.window);
            let flat: Vec<f32> = rows.iter().flat_map(|r| r.iter().copied()).collect();
            let hist = lit_f32_2d(&flat, self.window, 2)?;
            let out = self.compiled.run(&[hist])?;
            let v = to_f32_vec(&out[0])?;
            Ok((v[0].clamp(0.0, 1.0) as f64, v[1].clamp(0.0, 1.0) as f64))
        }
    }

    impl crate::predict::ResourcePredictor for LstmPredictor {
        fn predict(&mut self, h: &crate::predict::History) -> (f64, f64) {
            match self.predict_rows(&h.padded_rows()) {
                Ok(v) => v,
                Err(_) => {
                    // degrade to last value on any runtime error
                    (
                        h.cpu.back().copied().unwrap_or(0.5),
                        h.bw.back().copied().unwrap_or(0.5),
                    )
                }
            }
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{ConfigInfo, Manifest};

    const NO_XLA: &str = "star was built without the `xla` feature — the PJRT \
        runtime is unavailable (add the xla dependency and rebuild with \
        `--features xla` to execute AOT artifacts)";

    /// Stub PJRT client: same API, every entry point errors.
    pub struct Runtime;

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            bail!(NO_XLA)
        }

        pub fn load(&self, _path: &Path) -> Result<Compiled> {
            bail!(NO_XLA)
        }
    }

    /// Stub compiled executable (never constructed).
    pub struct Compiled {
        pub name: String,
    }

    impl Compiled {
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            bail!(NO_XLA)
        }
    }

    /// Placeholder literal so helper signatures stay stable without PJRT.
    pub struct Literal;

    pub fn lit_f32(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn lit_f32_2d(_values: &[f32], _rows: usize, _cols: usize) -> Result<Literal> {
        bail!(NO_XLA)
    }

    pub fn lit_i32_2d(_values: &[i32], _rows: usize, _cols: usize) -> Result<Literal> {
        bail!(NO_XLA)
    }

    pub fn lit_scalar_i32(_v: i32) -> Literal {
        Literal
    }

    pub fn to_f32_vec(_lit: &Literal) -> Result<Vec<f32>> {
        bail!(NO_XLA)
    }

    pub fn scalar_f32(_lit: &Literal) -> Result<f32> {
        bail!(NO_XLA)
    }

    /// Stub training session: `new` always errors, so the accessors below
    /// are unreachable but keep callers compiling unchanged.
    pub struct TrainSession {
        pub info: ConfigInfo,
        pub params: Vec<f32>,
    }

    impl TrainSession {
        pub fn new(_rt: &Runtime, _man: &Manifest, _config: &str) -> Result<TrainSession> {
            bail!(NO_XLA)
        }

        pub fn init_params(&mut self, _seed: i32) -> Result<()> {
            bail!(NO_XLA)
        }

        pub fn train_step(&self, _tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
            bail!(NO_XLA)
        }

        pub fn eval_loss(&self, _tokens: &[i32]) -> Result<f32> {
            bail!(NO_XLA)
        }

        pub fn grad_acc(&self, _acc: &[f32], _g: &[f32], _w: f32) -> Result<Vec<f32>> {
            bail!(NO_XLA)
        }

        pub fn apply_update(&mut self, _acc: &[f32], _scale: f32) -> Result<()> {
            bail!(NO_XLA)
        }

        pub fn xorder_update(&mut self, _grads: &[Vec<f32>], _lr: f32) -> Result<()> {
            bail!(NO_XLA)
        }
    }

    /// Stub LSTM predictor; the trait impl degrades to last-value like the
    /// real one does on runtime errors.
    pub struct LstmPredictor;

    impl LstmPredictor {
        pub fn new(_rt: &Runtime, _man: &Manifest) -> Result<LstmPredictor> {
            bail!(NO_XLA)
        }

        pub fn predict_rows(&self, _rows: &[[f32; 2]]) -> Result<(f64, f64)> {
            bail!(NO_XLA)
        }
    }

    impl crate::predict::ResourcePredictor for LstmPredictor {
        fn predict(&mut self, h: &crate::predict::History) -> (f64, f64) {
            (h.cpu.back().copied().unwrap_or(0.5), h.bw.back().copied().unwrap_or(0.5))
        }
    }
}

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = super::Runtime::cpu().err().expect("stub must error");
        assert!(format!("{err}").contains("xla"), "{err}");
    }

    #[test]
    fn discover_without_artifacts_errors_cleanly() {
        let in_crate =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if std::path::Path::new("artifacts/manifest.json").exists()
            || in_crate.exists()
            || std::env::var("STAR_ARTIFACTS").is_ok()
        {
            return; // artifacts present: nothing to assert here
        }
        assert!(super::Manifest::discover().is_err());
    }
}
