//! Training-progress substrate: PGNS-governed statistical efficiency +
//! accuracy/perplexity curves + the paper's convergence detector.
//!
//! Per parameter update built from batch B at step s, progress advances by
//! `1/n_u = 1/(1 + φ(s)/B)` ([46], §IV-C1), discounted by `γ^staleness`
//! for stale gradient reports. Accuracy approaches a mode-dependent
//! asymptote `a_max_eff` (Fig 16 / O7 model, models::converged_value)
//! exponentially in progress. NLP models run the same machinery on
//! perplexity (descending). Convergence = change below a threshold across
//! five evaluations spaced 40 s apart (§III).

use crate::models::{Kind, ModelSpec};

/// Discount base for stale gradients (one unit of staleness = one
/// parameter update applied between a gradient's read and its apply).
pub const STALE_GAMMA: f64 = 0.9;

/// Staleness saturates: beyond ~one full round of updates the gradient is
/// "fully stale" and further version skew adds little extra damage
/// (matches staleness-aware ASGD analyses; keeps γ^σ from annihilating
/// progress under pathological contention).
pub const STALE_CAP: f64 = 8.0;

/// EMA rate for the mode-mix statistics that set the converged asymptote.
const MIX_EMA: f64 = 0.05;

/// Evaluation cadence and window from §III.
pub const EVAL_PERIOD_S: f64 = 40.0;
pub const EVAL_WINDOW: usize = 5;

/// Checkpointed training state: everything a PS crash rolls back
/// (fault subsystem, DESIGN.md §7). Evaluation history is *not* part of
/// a checkpoint — it restarts after a rollback so a pre-crash plateau
/// cannot masquerade as convergence.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub step: u64,
    pub progress: f64,
    x_over_n_ema: f64,
    stale_frac_ema: f64,
    lr_ok_ema: f64,
}

/// Evolving training state of one job.
#[derive(Clone, Debug)]
pub struct ProgressModel {
    pub spec: &'static ModelSpec,
    pub workers: usize,
    /// parameter updates applied so far
    pub step: u64,
    /// accumulated statistical progress
    pub progress: f64,
    /// EMA of x/N over applied updates (diagnostics)
    pub x_over_n_ema: f64,
    /// EMA of realized staleness as a fraction of a full round (sets the
    /// converged-quality asymptote)
    pub stale_frac_ema: f64,
    /// EMA of "update used a correctly rescaled LR" (O7)
    pub lr_ok_ema: f64,
    /// recent evaluation values for convergence detection
    evals: Vec<f64>,
    eval_due: f64,
}

impl ProgressModel {
    pub fn new(spec: &'static ModelSpec, workers: usize) -> Self {
        ProgressModel {
            spec,
            workers,
            step: 0,
            progress: 0.0,
            x_over_n_ema: 1.0,
            stale_frac_ema: 0.0,
            lr_ok_ema: 1.0,
            evals: Vec::new(),
            eval_due: EVAL_PERIOD_S,
        }
    }

    /// Capture a checkpoint of the statistical training state.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            step: self.step,
            progress: self.progress,
            x_over_n_ema: self.x_over_n_ema,
            stale_frac_ema: self.stale_frac_ema,
            lr_ok_ema: self.lr_ok_ema,
        }
    }

    /// Roll back to `snap` (PS crash): statistical progress and the step
    /// counter revert — the re-training time between checkpoint and crash
    /// is charged implicitly because those updates must be redone.
    /// Evaluation bookkeeping restarts at `now_rel` (seconds since job
    /// start) so stale plateau evidence is discarded.
    pub fn restore(&mut self, snap: &Snapshot, now_rel: f64) {
        self.step = snap.step;
        self.progress = snap.progress;
        self.x_over_n_ema = snap.x_over_n_ema;
        self.stale_frac_ema = snap.stale_frac_ema;
        self.lr_ok_ema = snap.lr_ok_ema;
        self.evals.clear();
        self.eval_due = now_rel.max(0.0) + EVAL_PERIOD_S;
    }

    /// Total batch M summed across workers (§III: 128/worker).
    pub fn total_batch(&self) -> f64 {
        (self.workers * crate::models::WORKER_BATCH) as f64
    }

    /// Apply one parameter update built from `reports` gradient reports
    /// (each of per-worker batch M/N) with mean staleness `staleness`
    /// and `lr_rescaled` indicating §IV-C LR scaling was applied when the
    /// effective batch shrank.
    pub fn apply_update(&mut self, reports: usize, staleness: f64, lr_rescaled: bool) {
        self.apply_update_mix(reports, reports, staleness, lr_rescaled);
    }

    /// Like [`apply_update`], but the converged-quality bookkeeping sees
    /// `mix_reports` instead of `reports`: Zeno++-style validation
    /// filtering keeps *quality* near-synchronous without changing the
    /// statistical batch each update carries.
    pub fn apply_update_mix(
        &mut self,
        reports: usize,
        mix_reports: usize,
        staleness: f64,
        lr_rescaled: bool,
    ) {
        debug_assert!(reports >= 1 && reports <= self.workers);
        let batch = self.total_batch() * reports as f64 / self.workers as f64;
        let delta = 1.0 / self.spec.n_u(self.progress, batch)
            * STALE_GAMMA.powf(staleness.clamp(0.0, STALE_CAP));
        self.progress += delta;
        self.step += 1;
        let x_over_n = reports as f64 / self.workers as f64;
        self.x_over_n_ema += MIX_EMA * (x_over_n - self.x_over_n_ema);
        // converged quality follows *realized* staleness; validation
        // filtering (mix_reports > reports, Zeno++) discards the stalest
        // gradients, shrinking the quality-relevant staleness
        let filter = (mix_reports.saturating_sub(reports)) as f64 / self.workers as f64;
        let denom = (self.workers.saturating_sub(1)).max(1) as f64;
        let sf = (staleness * (1.0 - filter) / denom).clamp(0.0, 1.0);
        self.stale_frac_ema += MIX_EMA * (sf - self.stale_frac_ema);
        let ok = if sf < 0.02 { 1.0 } else if lr_rescaled { 1.0 } else { 0.0 };
        self.lr_ok_ema += MIX_EMA * (ok - self.lr_ok_ema);
    }

    /// Converged asymptote for the current mode mix.
    pub fn asymptote(&self) -> f64 {
        let with = self.spec.converged_value_stale(self.stale_frac_ema, true);
        let without = self.spec.converged_value_stale(self.stale_frac_ema, false);
        // blend by how often LR was correct
        with * self.lr_ok_ema + without * (1.0 - self.lr_ok_ema)
    }

    /// Current model quality: accuracy % (image) or perplexity (NLP).
    pub fn value(&self) -> f64 {
        let a_inf = self.asymptote();
        let a0 = self.spec.acc0;
        let f = (-self.progress / self.spec.tau).exp();
        a_inf + (a0 - a_inf) * f
    }

    /// Value change per unit progress right now (for sensitivity/stage
    /// weighting in §IV-D1: "current accuracy improvement" A).
    pub fn improvement_rate(&self) -> f64 {
        let a_inf = self.asymptote();
        ((a_inf - self.spec.acc0) / self.spec.tau * (-self.progress / self.spec.tau).exp()).abs()
    }

    /// Advance evaluation bookkeeping to time `t`; returns true once the
    /// §III convergence criterion fires (<`thresh` change over 5 evals).
    pub fn converged_at(&mut self, t: f64) -> bool {
        let thresh = match self.spec.kind {
            Kind::Image => 0.02, // accuracy points
            Kind::Nlp => 0.2,    // perplexity points
        };
        while t >= self.eval_due {
            self.evals.push(self.value());
            if self.evals.len() > EVAL_WINDOW {
                self.evals.remove(0);
            }
            self.eval_due += EVAL_PERIOD_S;
        }
        if self.evals.len() < EVAL_WINDOW {
            return false;
        }
        let lo = self.evals.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = self.evals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        // a plateau only counts as convergence when the curve is actually
        // near its asymptote — a wall-clock lull (slow iterations under
        // heavy contention) must not masquerade as convergence
        let near = match self.spec.kind {
            Kind::Image => (self.value() - self.asymptote()).abs() < 1.0,
            Kind::Nlp => (self.value() - self.asymptote()).abs() < 5.0,
        };
        hi - lo < thresh && near
    }

    /// TTA target per §III: the converged value the vanilla ASGD baseline
    /// reaches (fully stale updates at the SSGD-tuned LR, per O7).
    pub fn tta_target(&self) -> f64 {
        self.spec.converged_value_stale(1.0, false)
    }

    /// Reached when within a small evaluation margin of the target (an
    /// exponential approach never *equals* its own asymptote).
    pub fn reached_target(&self) -> bool {
        let margin = match self.spec.kind {
            Kind::Image => 0.25,
            Kind::Nlp => 2.0,
        };
        let target = self.tta_target();
        let adjusted = match self.spec.kind {
            Kind::Image => target - margin,
            Kind::Nlp => target + margin,
        };
        self.spec.reached(self.value(), adjusted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ZOO;

    fn pm(model: usize, workers: usize) -> ProgressModel {
        ProgressModel::new(&ZOO[model], workers)
    }

    #[test]
    fn ssgd_progress_monotone_toward_acc_max() {
        let mut p = pm(0, 8);
        let mut last = p.value();
        for _ in 0..15_000 {
            p.apply_update(8, 0.0, true);
            let v = p.value();
            assert!(v >= last - 1e-9);
            last = v;
        }
        assert!((p.value() - p.spec.acc_max).abs() < 1.0, "v={}", p.value());
    }

    #[test]
    fn asgd_converges_lower_than_ssgd() {
        let mut sync = pm(3, 8);
        let mut asgd = pm(3, 8);
        for _ in 0..20_000 {
            sync.apply_update(8, 0.0, true);
            asgd.apply_update(1, 7.0, true); // fully stale reports
        }
        assert!(sync.value() > asgd.value());
        // and matches Fig 16 1-order vs 8-order spread direction
        assert!(sync.value() - asgd.value() > 3.0);
    }

    #[test]
    fn bigger_batch_fewer_updates_to_same_progress() {
        let mut big = pm(1, 8);
        let mut small = pm(1, 8);
        for _ in 0..200 {
            big.apply_update(8, 0.0, true);
        }
        let mut n = 0;
        while small.progress < big.progress {
            small.apply_update(2, 0.0, true);
            n += 1;
        }
        assert!(n > 200, "2-order needs more updates: {n}");
    }

    #[test]
    fn staleness_discounts_progress() {
        let mut fresh = pm(2, 4);
        let mut stale = pm(2, 4);
        for _ in 0..100 {
            fresh.apply_update(1, 0.0, true);
            stale.apply_update(1, 3.0, true);
        }
        assert!(stale.progress < fresh.progress);
    }

    #[test]
    fn lr_mismatch_lowers_asymptote() {
        let mut ok = pm(4, 8);
        let mut bad = pm(4, 8);
        for _ in 0..10_000 {
            // partially stale updates (x-order groups) with vs without the
            // §IV-C LR rescale
            ok.apply_update(2, 2.0, true);
            bad.apply_update(2, 2.0, false);
        }
        assert!(ok.value() > bad.value());
    }

    #[test]
    fn nlp_perplexity_descends() {
        let mut p = pm(8, 4); // LSTM
        let v0 = p.value();
        for _ in 0..3000 {
            p.apply_update(4, 0.0, true);
        }
        assert!(p.value() < v0);
        assert!(p.value() > p.spec.acc_max - 1.0); // asymptote from above
    }

    #[test]
    fn convergence_detector_fires_on_plateau() {
        let mut p = pm(0, 4);
        // plateau: run to near-convergence
        for _ in 0..100_000 {
            p.apply_update(4, 0.0, true);
        }
        // five evals over 200+ s on a flat curve
        assert!(!p.converged_at(100.0)); // not enough evals yet
        assert!(p.converged_at(400.0));
    }

    #[test]
    fn convergence_not_fired_early() {
        let mut p = pm(0, 4);
        for i in 0..10 {
            p.apply_update(4, 0.0, true);
            assert!(!p.converged_at(40.0 * (i + 1) as f64 - 1.0) || i > 5);
        }
    }

    #[test]
    fn tta_target_reachable_by_ssgd_and_asgd() {
        for (mi, spec) in ZOO.iter().enumerate() {
            let p = pm(mi, 8);
            let target = p.tta_target();
            // SSGD asymptote beats the ASGD target
            assert!(
                spec.reached(spec.converged_value_stale(0.0, true), target),
                "{}", spec.name
            );
            // vanilla ASGD's own asymptote equals the target exactly
            assert!((spec.converged_value_stale(1.0, false) - target).abs() < 1e-9);
        }
    }

    #[test]
    fn snapshot_restore_rolls_back_progress_and_step() {
        let mut p = pm(0, 8);
        for _ in 0..500 {
            p.apply_update(8, 0.0, true);
        }
        let snap = p.snapshot();
        let (step_at, prog_at, val_at) = (p.step, p.progress, p.value());
        for _ in 0..500 {
            p.apply_update(8, 0.0, true);
        }
        assert!(p.progress > prog_at && p.step > step_at);
        p.restore(&snap, 1234.0);
        assert_eq!(p.step, step_at);
        assert_eq!(p.progress, prog_at);
        assert_eq!(p.value(), val_at);
    }

    #[test]
    fn restore_resets_convergence_evidence() {
        let mut p = pm(0, 4);
        for _ in 0..100_000 {
            p.apply_update(4, 0.0, true);
        }
        let snap = p.snapshot();
        assert!(p.converged_at(400.0), "plateau detected pre-crash");
        p.restore(&snap, 400.0);
        // immediately after rollback the five-eval window is empty again
        assert!(!p.converged_at(401.0), "rollback must clear plateau evidence");
        // but a sustained plateau re-converges
        assert!(p.converged_at(400.0 + 6.0 * EVAL_PERIOD_S));
    }

    #[test]
    fn improvement_rate_decays_with_training_stage() {
        let mut p = pm(5, 4);
        let early = p.improvement_rate();
        for _ in 0..2000 {
            p.apply_update(4, 0.0, true);
        }
        assert!(p.improvement_rate() < early);
    }
}
