//! Fault-injection subsystem — the "Resilient" half of the paper's title.
//!
//! The contention model makes stragglers *emerge*; failures, by contrast,
//! are *injected* from a deterministic, seeded [`FaultPlan`] generated
//! per-trace from a [`FaultConfig`] (the same pattern Lin et al.'s
//! what-if analysis uses for machine failure/recovery trace events).
//! Four fault classes:
//!
//! * **worker crash** — the task suspends, its in-flight gradient is
//!   lost, and the sync round re-forms around the survivors (SSGD
//!   barriers shrink, x-order groups re-cluster, AR rings re-chain per
//!   §IV-B's removed-straggler machinery); the worker rejoins after a
//!   restart delay.
//! * **PS crash** — parameter state is lost: job progress rolls back to
//!   the last checkpoint (re-training time is charged implicitly by the
//!   reverted progress), unapplied reports are discarded, and updates
//!   stall until the PS restarts.
//! * **server outage** — every co-located task of every job on the
//!   server fails at once (workers crash, PSs roll back), recovering
//!   when the server returns.
//! * **degradation window** — the server loses a fraction of CPU /
//!   bandwidth capacity for a bounded interval, then recovers. Distinct
//!   from the contention spikes of `cluster`: windows model NIC flaps
//!   and co-located-job bursts, are part of the *plan* (known shape,
//!   sweepable rate), and subtract from available capacity directly.
//!
//! The plan is a pure function of its config (seed included), so a replay
//! with the same trace + plan is bit-identical — the determinism and
//! golden-trace suites pin exactly that.

use crate::simrng::Rng;
use crate::trace::JobSpec;

/// One injected fault. `Copy` so `Event::Fault` handling reads the plan
/// entry without cloning on the dispatch path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Worker `rank` of `job` crashes; it restarts `restart_s` later.
    WorkerCrash { job: usize, rank: usize, restart_s: f64 },
    /// PS `idx` of `job` crashes: progress reverts to the last
    /// checkpoint and updates stall for `restart_s`.
    PsCrash { job: usize, idx: usize, restart_s: f64 },
    /// Whole-server outage: all co-located tasks of every job on
    /// `server` fail for `dur_s`, then restart `restart_s` later.
    ServerOutage { server: usize, dur_s: f64, restart_s: f64 },
    /// Transient degradation: `server` loses `cpu_frac`/`bw_frac` of its
    /// capacity for `dur_s`, with full recovery afterwards.
    Degradation { server: usize, dur_s: f64, cpu_frac: f64, bw_frac: f64 },
}

/// A fault scheduled at an absolute simulation time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlannedFault {
    pub at: f64,
    pub fault: Fault,
}

/// Seeded fault-scenario parameters. Every `*_mtbf_s` is the mean gap
/// (exponential) between events of that class across the whole cluster /
/// trace; `0` disables the class.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    pub seed: u64,
    /// mean seconds between worker crashes (trace-wide)
    pub worker_mtbf_s: f64,
    /// mean seconds between PS crashes (trace-wide)
    pub ps_mtbf_s: f64,
    /// mean seconds between whole-server outages
    pub server_mtbf_s: f64,
    /// mean seconds between degradation windows
    pub degradation_mtbf_s: f64,
    /// worker/PS restart latency range, seconds
    pub restart_s: (f64, f64),
    /// server outage duration range, seconds
    pub outage_s: (f64, f64),
    /// degradation window duration range, seconds
    pub degradation_s: (f64, f64),
    /// degradation magnitude range (fraction of capacity lost)
    pub degradation_mag: (f64, f64),
    /// parameter updates between checkpoints (PS rollback granularity);
    /// 0 means "checkpoint only at step 0" (a PS crash restarts the job)
    pub checkpoint_every_updates: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            worker_mtbf_s: 1800.0,
            ps_mtbf_s: 3600.0,
            server_mtbf_s: 14_400.0,
            degradation_mtbf_s: 2400.0,
            restart_s: (20.0, 90.0),
            outage_s: (60.0, 300.0),
            degradation_s: (30.0, 240.0),
            degradation_mag: (0.3, 0.7),
            checkpoint_every_updates: 200,
        }
    }
}

impl FaultConfig {
    /// Scale all failure rates by `rate` (MTBFs divide by it); `0.0`
    /// disables every class — the sweep knob of the `resilience`
    /// experiment and the `--fault-rate` CLI option.
    pub fn with_rate(mut self, rate: f64) -> Self {
        if rate <= 0.0 {
            self.worker_mtbf_s = 0.0;
            self.ps_mtbf_s = 0.0;
            self.server_mtbf_s = 0.0;
            self.degradation_mtbf_s = 0.0;
        } else {
            self.worker_mtbf_s /= rate;
            self.ps_mtbf_s /= rate;
            self.server_mtbf_s /= rate;
            self.degradation_mtbf_s /= rate;
        }
        self
    }
}

/// The per-trace fault schedule the driver injects. Empty by default, so
/// fault-free runs are bit-identical to the pre-faults simulator.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// time-ordered injected faults
    pub faults: Vec<PlannedFault>,
    /// parameter updates between checkpoints (0 = initial state only)
    pub checkpoint_every_updates: u64,
}

impl FaultPlan {
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Count of planned faults matching `pred` (diagnostics/tests).
    pub fn count(&self, pred: impl Fn(&Fault) -> bool) -> usize {
        self.faults.iter().filter(|f| pred(&f.fault)).count()
    }

    /// Merge two plans into one time-ordered schedule (the scenario
    /// layer's storm regime: a background plan plus in-window bursts).
    /// Ties keep `self`'s entries first (stable sort — deterministic).
    /// The checkpoint cadence comes from `self` unless it is unset (0),
    /// in which case `other`'s cadence is adopted.
    pub fn merge(mut self, other: FaultPlan) -> FaultPlan {
        self.faults.extend(other.faults);
        self.faults.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        if self.checkpoint_every_updates == 0 {
            self.checkpoint_every_updates = other.checkpoint_every_updates;
        }
        self
    }
}

/// Simulated span a fault plan should cover for `trace`: the last
/// arrival plus the per-job duration cap (jobs keep running past the
/// final arrival, but never longer than the cap).
pub fn span_for(trace: &[JobSpec], max_job_duration_s: f64) -> f64 {
    trace.iter().map(|j| j.arrival_s).fold(0.0, f64::max) + max_job_duration_s
}

/// The standard rate-scaled plan behind the `--fault-rate`/`--fault-seed`
/// CLI knobs: default MTBFs scaled by `rate` (≤ 0 = empty plan). Every
/// entry point (experiments harness, `star simulate|replay`, tests)
/// builds plans through this one recipe so the same knobs always inject
/// the same schedule.
pub fn plan_at_rate(
    rate: f64,
    seed: u64,
    jobs: &[JobSpec],
    span_s: f64,
    servers: usize,
) -> FaultPlan {
    if rate <= 0.0 {
        return FaultPlan::default();
    }
    generate_plan(
        &FaultConfig { seed, ..Default::default() }.with_rate(rate),
        jobs,
        span_s,
        servers,
    )
}

/// Generate a deterministic fault plan for `jobs` over `span_s` seconds
/// of simulated time on a `servers`-server cluster. Each fault class
/// draws from its own forked RNG stream, so enabling one class never
/// perturbs another's schedule (the same discipline the contention
/// streams use, DESIGN.md §6).
pub fn generate_plan(
    cfg: &FaultConfig,
    jobs: &[JobSpec],
    span_s: f64,
    servers: usize,
) -> FaultPlan {
    let mut root = Rng::new(cfg.seed, 0xFA17);
    // fork every class stream unconditionally: disabling one class must
    // not shift another's schedule
    let mut worker_rng = root.fork(1);
    let mut ps_rng = root.fork(2);
    let mut server_rng = root.fork(3);
    let mut degrade_rng = root.fork(4);
    let mut faults: Vec<PlannedFault> = Vec::new();

    // worker crashes: uniformly victimize a (job, rank)
    if cfg.worker_mtbf_s > 0.0 && !jobs.is_empty() {
        let rng = &mut worker_rng;
        let mut t = 0.0;
        loop {
            t += rng.exponential(1.0 / cfg.worker_mtbf_s);
            if t > span_s {
                break;
            }
            let j = &jobs[rng.usize(0, jobs.len() - 1)];
            let rank = rng.usize(0, j.workers.saturating_sub(1));
            let restart_s = rng.range(cfg.restart_s.0, cfg.restart_s.1);
            faults.push(PlannedFault {
                at: t,
                fault: Fault::WorkerCrash { job: j.id, rank, restart_s },
            });
        }
    }

    // PS crashes: uniformly victimize a (job, ps index)
    if cfg.ps_mtbf_s > 0.0 && !jobs.is_empty() {
        let rng = &mut ps_rng;
        let mut t = 0.0;
        loop {
            t += rng.exponential(1.0 / cfg.ps_mtbf_s);
            if t > span_s {
                break;
            }
            let j = &jobs[rng.usize(0, jobs.len() - 1)];
            let idx = rng.usize(0, j.ps_count.saturating_sub(1));
            let restart_s = rng.range(cfg.restart_s.0, cfg.restart_s.1);
            faults.push(PlannedFault {
                at: t,
                fault: Fault::PsCrash { job: j.id, idx, restart_s },
            });
        }
    }

    // whole-server outages
    if cfg.server_mtbf_s > 0.0 && servers > 0 {
        let rng = &mut server_rng;
        let mut t = 0.0;
        loop {
            t += rng.exponential(1.0 / cfg.server_mtbf_s);
            if t > span_s {
                break;
            }
            let server = rng.usize(0, servers - 1);
            let dur_s = rng.range(cfg.outage_s.0, cfg.outage_s.1);
            let restart_s = rng.range(cfg.restart_s.0, cfg.restart_s.1);
            faults.push(PlannedFault {
                at: t,
                fault: Fault::ServerOutage { server, dur_s, restart_s },
            });
        }
    }

    // degradation windows
    if cfg.degradation_mtbf_s > 0.0 && servers > 0 {
        let rng = &mut degrade_rng;
        let mut t = 0.0;
        loop {
            t += rng.exponential(1.0 / cfg.degradation_mtbf_s);
            if t > span_s {
                break;
            }
            let server = rng.usize(0, servers - 1);
            let dur_s = rng.range(cfg.degradation_s.0, cfg.degradation_s.1);
            // NIC flap vs CPU burst vs both, like the spike streams
            let both = rng.chance(0.3);
            let on_cpu = both || rng.chance(0.5);
            let mag = rng.range(cfg.degradation_mag.0, cfg.degradation_mag.1);
            faults.push(PlannedFault {
                at: t,
                fault: Fault::Degradation {
                    server,
                    dur_s,
                    cpu_frac: if on_cpu { mag } else { 0.0 },
                    bw_frac: if !on_cpu || both { mag } else { 0.0 },
                },
            });
        }
    }

    // stable sort: ties keep class order (worker < ps < server < degrade)
    faults.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    FaultPlan { faults, checkpoint_every_updates: cfg.checkpoint_every_updates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceConfig;

    fn jobs() -> Vec<JobSpec> {
        crate::trace::generate(&TraceConfig { jobs: 10, span_s: 2000.0, ..Default::default() })
    }

    #[test]
    fn plan_is_deterministic() {
        let cfg = FaultConfig::default();
        let a = generate_plan(&cfg, &jobs(), 20_000.0, 8);
        let b = generate_plan(&cfg, &jobs(), 20_000.0, 8);
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate_plan(&FaultConfig::default(), &jobs(), 20_000.0, 8);
        let b = generate_plan(
            &FaultConfig { seed: 1, ..Default::default() },
            &jobs(),
            20_000.0,
            8,
        );
        assert_ne!(a, b);
    }

    #[test]
    fn plan_is_time_ordered_and_within_span() {
        let plan = generate_plan(&FaultConfig::default(), &jobs(), 20_000.0, 8);
        let mut last = 0.0;
        for f in &plan.faults {
            assert!(f.at >= last, "out of order: {} < {last}", f.at);
            assert!(f.at <= 20_000.0);
            last = f.at;
        }
    }

    #[test]
    fn all_classes_present_and_valid() {
        let plan = generate_plan(&FaultConfig::default(), &jobs(), 100_000.0, 8);
        assert!(plan.count(|f| matches!(f, Fault::WorkerCrash { .. })) > 0);
        assert!(plan.count(|f| matches!(f, Fault::PsCrash { .. })) > 0);
        assert!(plan.count(|f| matches!(f, Fault::ServerOutage { .. })) > 0);
        assert!(plan.count(|f| matches!(f, Fault::Degradation { .. })) > 0);
        let js = jobs();
        for pf in &plan.faults {
            match pf.fault {
                Fault::WorkerCrash { job, rank, restart_s } => {
                    let j = js.iter().find(|j| j.id == job).unwrap();
                    assert!(rank < j.workers);
                    assert!((20.0..=90.0).contains(&restart_s));
                }
                Fault::PsCrash { job, idx, .. } => {
                    let j = js.iter().find(|j| j.id == job).unwrap();
                    assert!(idx < j.ps_count);
                }
                Fault::ServerOutage { server, dur_s, .. } => {
                    assert!(server < 8);
                    assert!((60.0..=300.0).contains(&dur_s));
                }
                Fault::Degradation { server, dur_s, cpu_frac, bw_frac } => {
                    assert!(server < 8);
                    assert!((30.0..=240.0).contains(&dur_s));
                    assert!(cpu_frac > 0.0 || bw_frac > 0.0);
                    assert!(cpu_frac <= 0.7 && bw_frac <= 0.7);
                }
            }
        }
    }

    #[test]
    fn merge_is_time_ordered_and_adopts_checkpoint_cadence() {
        let a = generate_plan(&FaultConfig::default(), &jobs(), 20_000.0, 8);
        let b = generate_plan(&FaultConfig { seed: 9, ..Default::default() }, &jobs(), 20_000.0, 8);
        let n = a.len() + b.len();
        let merged = a.merge(b);
        assert_eq!(merged.len(), n);
        for w in merged.faults.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert_eq!(merged.checkpoint_every_updates, 200);
        // an empty base (checkpoint 0) adopts the other plan's cadence
        let other = generate_plan(&FaultConfig::default(), &jobs(), 5_000.0, 8);
        let merged = FaultPlan::default().merge(other);
        assert_eq!(merged.checkpoint_every_updates, 200);
    }

    #[test]
    fn rate_scales_fault_counts() {
        let base = generate_plan(&FaultConfig::default(), &jobs(), 50_000.0, 8);
        let heavy =
            generate_plan(&FaultConfig::default().with_rate(4.0), &jobs(), 50_000.0, 8);
        assert!(heavy.len() > 2 * base.len(), "{} !> 2*{}", heavy.len(), base.len());
        let off = generate_plan(&FaultConfig::default().with_rate(0.0), &jobs(), 50_000.0, 8);
        assert!(off.is_empty());
    }

    #[test]
    fn single_class_schedule_is_stream_independent() {
        // disabling other classes must not move worker-crash times
        let all = generate_plan(&FaultConfig::default(), &jobs(), 20_000.0, 8);
        let only_workers = generate_plan(
            &FaultConfig {
                ps_mtbf_s: 0.0,
                server_mtbf_s: 0.0,
                degradation_mtbf_s: 0.0,
                ..Default::default()
            },
            &jobs(),
            20_000.0,
            8,
        );
        let wa: Vec<&PlannedFault> = all
            .faults
            .iter()
            .filter(|f| matches!(f.fault, Fault::WorkerCrash { .. }))
            .collect();
        let wb: Vec<&PlannedFault> = only_workers.faults.iter().collect();
        assert_eq!(wa.len(), wb.len());
        for (a, b) in wa.iter().zip(&wb) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.fault, b.fault);
        }
    }
}
