//! # STAR — Straggler Tolerant And Resilient DL training
//!
//! Reproduction of *"Straggler Tolerant and Resilient DL Training on
//! Homogeneous GPUs"* (Zhang & Shen, CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution:
//!   straggler prediction ([`predict`]), x-order synchronization modes
//!   ([`sync`]), TTA-optimal mode selection ([`decide`]), resource-aware
//!   straggler prevention ([`prevent`]), all glued by the [`star`]
//!   controller; plus every substrate the paper's evaluation needs:
//!   a discrete-event cluster simulator ([`sim`], [`cluster`]), the
//!   ten-model zoo ([`models`]), a Philly-style trace generator
//!   ([`trace`]), the training-progress model ([`progress`]), the six
//!   comparison systems ([`baselines`]), and a declarative what-if
//!   scenario layer over all of it ([`scenario`]).
//! * **L2/L1 (python, build time only)** — the per-worker compute:
//!   a transformer-LM train step whose GEMMs and whose fused gradient
//!   aggregation/SGD-apply run as Pallas kernels, AOT-lowered to HLO text.
//! * **[`runtime`]** — loads those artifacts through PJRT (`xla` crate)
//!   and keeps parameters device-resident; python never runs at
//!   coordination time.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index (every paper table/figure → an `experiments` subcommand).

pub mod agg;
pub mod baselines;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod decide;
pub mod driver;
pub mod exp;
pub mod fabric;
pub mod faults;
pub mod jsonio;
pub mod metrics;
pub mod models;
pub mod predict;
pub mod prevent;
pub mod progress;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod simrng;
pub mod star;
pub mod stats;
pub mod sync;
pub mod table;
pub mod testutil;
pub mod trace;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
