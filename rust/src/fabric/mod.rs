//! The failure-tolerant sweep fabric (DESIGN.md §10): distribute a
//! sweep's cells across worker processes — local subprocesses or remote
//! TCP peers — and survive crashes, hangs, stragglers, and interrupted
//! runs, while producing artifacts **byte-identical** to a serial
//! in-process `--threads 1` run.
//!
//! Layering (wire up):
//!
//! * [`protocol`] — `star-cell-v1`: the line protocol and [`SweepSpec`],
//!   the self-contained description of a sweep any worker can compute
//!   cells of;
//! * [`journal`] — the group-committed append-only checkpoint
//!   (`results/<sweep>.journal.jsonl`) behind resume: appends batch in
//!   memory and one fsync commits the batch;
//! * [`worker`] — `star worker`: the stateless cell server, pipelined
//!   so the next queued cell computes while the last response ships;
//! * [`dispatch`] — `star dispatch`: credit-based pipelined scatter,
//!   EWMA-weighted load balancing, deadline, retry, straggler
//!   re-issue, re-queue, watermark-merged deterministic output;
//! * [`chaos`] — seeded fault injection (`--chaos`) so tests and CI can
//!   *prove* the recovery paths preserve byte-identity.
//!
//! Determinism rests on three facts: cells are pure functions of
//! `(SweepSpec, index)`; workers return *pre-rendered* rows
//! ([`crate::exp::CellRows`]) that `jsonio` round-trips exactly; and the
//! dispatcher merges strictly in index order. Scheduling, retries, and
//! races therefore cannot leak into artifacts.

pub mod chaos;
pub mod dispatch;
pub mod journal;
pub mod protocol;
pub mod worker;

pub use protocol::SweepSpec;
