//! Seeded fault injection for the fabric itself.
//!
//! Chaos is decided by the **dispatcher** (so a run's fault pattern is a
//! pure function of the chaos seed, independent of scheduling order) and
//! executed by the **worker** (so the real recovery machinery — crash
//! detection, re-queue, retry — is what gets exercised, not a mock).
//! Each cell draws from its own RNG stream keyed by cell index, and only
//! the *first* attempt of a cell can be sabotaged: every retry is clean,
//! so a chaos run always converges, and with `kill_prob 1.0` every cell
//! is guaranteed to lose exactly one worker before completing — the CI
//! smoke test's contract.

use crate::simrng::Rng;

use super::protocol::Chaos;

/// Knobs behind `star dispatch --chaos`.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    /// probability a cell's first attempt kills its worker
    pub kill_prob: f64,
    /// probability a cell's first attempt stalls before computing
    pub stall_prob: f64,
    /// stall duration
    pub stall_ms: u64,
    /// how long a doomed worker lingers before exiting
    pub die_after_ms: u64,
    /// model a heterogeneous fleet: every request served by this *slot*
    /// stalls `slow_ms` first (unlike the per-cell rolls above, this
    /// follows the worker, not the cell — a slow machine, not bad luck)
    pub slow_worker: Option<usize>,
    /// the slow slot's per-request stall
    pub slow_ms: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            kill_prob: 0.2,
            stall_prob: 0.1,
            stall_ms: 750,
            die_after_ms: 25,
            slow_worker: None,
            slow_ms: 2_000,
        }
    }
}

/// What (if anything) happens to `cell`'s attempt number `attempt`.
/// Deterministic in `(cfg.seed, index)`; `None` for every retry.
pub fn decide(cfg: &ChaosConfig, index: usize, attempt: usize) -> Option<Chaos> {
    if attempt != 0 {
        return None;
    }
    let mut rng = Rng::new(cfg.seed, 0x51A8_0000 ^ index as u64);
    let roll = rng.f64();
    if roll < cfg.kill_prob {
        Some(Chaos::Die { after_ms: cfg.die_after_ms })
    } else if roll < cfg.kill_prob + cfg.stall_prob {
        Some(Chaos::Stall { ms: cfg.stall_ms })
    } else {
        None
    }
}

/// The slow-machine stall for requests issued to `slot`, if this slot
/// is the configured straggler. Applied to every attempt (including
/// straggler duplicates) — a slow machine doesn't speed up on retry.
pub fn slow_stall(cfg: &ChaosConfig, slot: usize) -> Option<Chaos> {
    (cfg.slow_worker == Some(slot)).then_some(Chaos::Stall { ms: cfg.slow_ms })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_per_cell() {
        let cfg = ChaosConfig { kill_prob: 0.5, stall_prob: 0.3, ..Default::default() };
        for index in 0..32 {
            assert_eq!(decide(&cfg, index, 0), decide(&cfg, index, 0));
        }
        let other = ChaosConfig { seed: 1, ..cfg };
        assert!(
            (0..64).any(|i| decide(&cfg, i, 0) != decide(&other, i, 0)),
            "different seeds should produce different fault patterns"
        );
    }

    #[test]
    fn retries_are_always_clean() {
        let cfg = ChaosConfig { kill_prob: 1.0, ..Default::default() };
        for index in 0..16 {
            assert!(decide(&cfg, index, 0).is_some());
            assert_eq!(decide(&cfg, index, 1), None);
            assert_eq!(decide(&cfg, index, 5), None);
        }
    }

    #[test]
    fn slow_worker_stalls_only_its_own_slot_on_every_attempt() {
        let cfg = ChaosConfig { slow_worker: Some(2), slow_ms: 123, ..Default::default() };
        assert_eq!(slow_stall(&cfg, 2), Some(Chaos::Stall { ms: 123 }));
        assert_eq!(slow_stall(&cfg, 1), None);
        assert_eq!(slow_stall(&ChaosConfig::default(), 2), None);
    }

    #[test]
    fn kill_prob_one_dooms_every_first_attempt() {
        let cfg = ChaosConfig { kill_prob: 1.0, stall_prob: 0.0, ..Default::default() };
        for index in 0..16 {
            assert!(matches!(decide(&cfg, index, 0), Some(Chaos::Die { .. })));
        }
        let cfg = ChaosConfig { kill_prob: 0.0, stall_prob: 1.0, ..Default::default() };
        for index in 0..16 {
            assert!(matches!(decide(&cfg, index, 0), Some(Chaos::Stall { .. })));
        }
    }
}
