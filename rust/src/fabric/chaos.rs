//! Seeded fault injection for the fabric itself.
//!
//! Chaos is decided by the **dispatcher** (so a run's fault pattern is a
//! pure function of the chaos seed, independent of scheduling order) and
//! executed by the **worker** (so the real recovery machinery — crash
//! detection, re-queue, retry — is what gets exercised, not a mock).
//! Each cell draws from its own RNG stream keyed by cell index, and only
//! the *first* attempt of a cell can be sabotaged: every retry is clean,
//! so a chaos run always converges, and with `kill_prob 1.0` every cell
//! is guaranteed to lose exactly one worker before completing — the CI
//! smoke test's contract.

use crate::simrng::Rng;

use super::protocol::Chaos;

/// Knobs behind `star dispatch --chaos`.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    /// probability a cell's first attempt kills its worker
    pub kill_prob: f64,
    /// probability a cell's first attempt stalls before computing
    pub stall_prob: f64,
    /// stall duration
    pub stall_ms: u64,
    /// how long a doomed worker lingers before exiting
    pub die_after_ms: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig { seed: 0, kill_prob: 0.2, stall_prob: 0.1, stall_ms: 750, die_after_ms: 25 }
    }
}

/// What (if anything) happens to `cell`'s attempt number `attempt`.
/// Deterministic in `(cfg.seed, index)`; `None` for every retry.
pub fn decide(cfg: &ChaosConfig, index: usize, attempt: usize) -> Option<Chaos> {
    if attempt != 0 {
        return None;
    }
    let mut rng = Rng::new(cfg.seed, 0x51A8_0000 ^ index as u64);
    let roll = rng.f64();
    if roll < cfg.kill_prob {
        Some(Chaos::Die { after_ms: cfg.die_after_ms })
    } else if roll < cfg.kill_prob + cfg.stall_prob {
        Some(Chaos::Stall { ms: cfg.stall_ms })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_per_cell() {
        let cfg = ChaosConfig { kill_prob: 0.5, stall_prob: 0.3, ..Default::default() };
        for index in 0..32 {
            assert_eq!(decide(&cfg, index, 0), decide(&cfg, index, 0));
        }
        let other = ChaosConfig { seed: 1, ..cfg };
        assert!(
            (0..64).any(|i| decide(&cfg, i, 0) != decide(&other, i, 0)),
            "different seeds should produce different fault patterns"
        );
    }

    #[test]
    fn retries_are_always_clean() {
        let cfg = ChaosConfig { kill_prob: 1.0, ..Default::default() };
        for index in 0..16 {
            assert!(decide(&cfg, index, 0).is_some());
            assert_eq!(decide(&cfg, index, 1), None);
            assert_eq!(decide(&cfg, index, 5), None);
        }
    }

    #[test]
    fn kill_prob_one_dooms_every_first_attempt() {
        let cfg = ChaosConfig { kill_prob: 1.0, stall_prob: 0.0, ..Default::default() };
        for index in 0..16 {
            assert!(matches!(decide(&cfg, index, 0), Some(Chaos::Die { .. })));
        }
        let cfg = ChaosConfig { kill_prob: 0.0, stall_prob: 1.0, ..Default::default() };
        for index in 0..16 {
            assert!(matches!(decide(&cfg, index, 0), Some(Chaos::Stall { .. })));
        }
    }
}
