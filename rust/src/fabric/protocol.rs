//! `star-cell-v1` — the cell protocol: newline-delimited compact JSON,
//! one message per line, identical over stdin/stdout (subprocess mode)
//! and TCP (fleet mode).
//!
//! A worker announces itself with a `ready` line, then answers each
//! `cell` request with exactly one `done` or `failed` line; a `shutdown`
//! request ends the session. Requests are **stateless** — every one
//! carries the full [`SweepSpec`], so a respawned worker needs no
//! re-configuration and any worker can serve any cell.
//!
//! Determinism is the load-bearing property: a cell is a pure function
//! of `(SweepSpec, index)`, a worker ships back *rendered* rows
//! ([`CellRows`]: final CSV strings plus the `star-bench-v1` result
//! object), and `jsonio` round-trips both exactly (sorted keys, bit-
//! exact `f64` emit/parse). So the dispatcher's index-ordered merge
//! reproduces a serial `--threads 1` run's artifacts byte for byte — no
//! matter which worker computed which cell, how often a cell was
//! retried, or whether a straggler re-issue made two workers race on it.

use anyhow::Context;

use crate::exp::{resilience, CellRows, ExpCtx};
use crate::jsonio::{self, Json};
use crate::scenario::spec::FaultRegime;
use crate::scenario::{arch_tag, runner, search, Scenario, ScenarioSpace};

/// Protocol / schema tag carried by every message.
pub const PROTOCOL: &str = "star-cell-v1";

/// The sweep a dispatch scatters: which grid, and the invocation knobs
/// that shape it. Everything a worker needs to recompute any cell.
#[derive(Clone, Debug)]
pub enum SweepSpec {
    /// The resilience experiment's rate × system grid, exactly as
    /// `experiments resilience` sweeps it (same `ExpCtx` derivation).
    Resilience { jobs: usize, seed: u64, quick: bool, fault_seed: u64 },
    /// A generic scenario's arch × policy grid, exactly as
    /// `star scenario run` sweeps it.
    Generic { spec: Scenario, jobs_override: Option<usize>, quick: bool },
    /// A scenario-space search's probe + sample plan, exactly as
    /// `star scenario search` sweeps it (DESIGN.md §11). Cells are pure
    /// in `(space, count, points, index)` because the sampler forks a
    /// fresh RNG per index, so the plan rebuilds identically anywhere.
    Space {
        space: ScenarioSpace,
        count: usize,
        points: usize,
        jobs_override: Option<usize>,
        quick: bool,
    },
}

/// Equality is canonical-JSON identity — exactly what the journal's
/// fingerprint check enforces across processes.
impl PartialEq for SweepSpec {
    fn eq(&self, other: &Self) -> bool {
        self.fingerprint() == other.fingerprint()
    }
}

impl SweepSpec {
    /// Derive the sweep for a loaded scenario — the `star dispatch`
    /// front door. Generic scenarios shard their arch × policy grid;
    /// the delegated `resilience` builtin (or any spec delegating to
    /// exactly that experiment) shards the resilience grid with the
    /// same context mapping `scenario::run` uses, so the dispatched
    /// artifacts are byte-identical to both serial entry points. Other
    /// delegated experiments are not cell-sharded (their harnesses own
    /// their own loops) and are rejected.
    pub fn from_scenario(
        sc: &Scenario,
        jobs_override: Option<usize>,
        quick: bool,
    ) -> crate::Result<SweepSpec> {
        sc.validate().with_context(|| format!("scenario {:?}", sc.name))?;
        if jobs_override == Some(0) {
            anyhow::bail!("--jobs: a dispatch needs at least one job");
        }
        if sc.experiments.is_empty() {
            return Ok(SweepSpec::Generic { spec: sc.clone(), jobs_override, quick });
        }
        if sc.experiments == ["resilience"] {
            // the run_delegated mapping: spec workload -> ExpCtx knobs
            let fault_seed = match sc.faults {
                FaultRegime::Rate { seed, .. } => seed,
                _ => 0,
            };
            return Ok(SweepSpec::Resilience {
                jobs: jobs_override.unwrap_or(sc.workload.jobs),
                seed: sc.workload.seed,
                quick,
                fault_seed,
            });
        }
        anyhow::bail!(
            "dispatch shards the resilience experiment and generic scenarios; \
             scenario {:?} delegates to {:?} (run it via `star scenario run`)",
            sc.name,
            sc.experiments
        )
    }

    /// Derive the sweep for a scenario-space search — the dispatched
    /// flavor of `star scenario search`.
    pub fn from_space(
        space: &ScenarioSpace,
        count: usize,
        points: usize,
        jobs_override: Option<usize>,
        quick: bool,
    ) -> crate::Result<SweepSpec> {
        space.validate().with_context(|| format!("space {:?}", space.name))?;
        if jobs_override == Some(0) {
            anyhow::bail!("--jobs: a dispatch needs at least one job");
        }
        Ok(SweepSpec::Space { space: space.clone(), count, points, jobs_override, quick })
    }

    /// Sweep name — keys the default journal path
    /// (`results/<name>.journal.jsonl`) and log lines.
    pub fn name(&self) -> String {
        match self {
            SweepSpec::Resilience { .. } => "resilience".to_string(),
            SweepSpec::Generic { spec, .. } => format!("scenario_{}", spec.name),
            SweepSpec::Space { space, .. } => format!("search_{}", space.name),
        }
    }

    /// The resilience flavor's experiment context. `threads: 1` because
    /// a fabric worker computes each *cell* serially (pipelining only
    /// overlaps a cell's compute with the previous cell's I/O); the
    /// artifact is identical at any width anyway (the byte-identity
    /// contract).
    fn resilience_ctx(&self, out_dir: &std::path::Path) -> Option<ExpCtx> {
        match *self {
            SweepSpec::Resilience { jobs, seed, quick, fault_seed } => Some(ExpCtx {
                jobs,
                seed,
                out_dir: out_dir.to_path_buf(),
                quick,
                fault_rate: 0.0,
                fault_seed,
                threads: 1,
            }),
            SweepSpec::Generic { .. } | SweepSpec::Space { .. } => None,
        }
    }

    /// Human-readable labels, one per cell, in grid (= index) order.
    /// `labels.len()` is the cell count.
    pub fn cell_labels(&self) -> crate::Result<Vec<String>> {
        match self {
            SweepSpec::Resilience { quick, .. } => Ok(resilience::cell_specs(*quick)
                .into_iter()
                .map(|(ri, sys)| resilience::cell_label(ri, sys))
                .collect()),
            SweepSpec::Generic { spec, .. } => Ok(runner::grid(spec)
                .into_iter()
                .map(|(arch, sys)| format!("{sys}/{}", arch_tag(arch)))
                .collect()),
            SweepSpec::Space { space, count, points, .. } => {
                Ok(search::plan(space, *count, *points)
                    .into_iter()
                    .map(|c| format!("{}/{}/{}", c.scenario.name, c.policy, arch_tag(c.arch)))
                    .collect())
            }
        }
    }

    /// Relative expected compute cost per cell, in grid order
    /// (arbitrary units; only ratios matter). The dispatcher serves its
    /// pending queue longest-expected-cost-first so the big cells go
    /// out early instead of stretching the makespan tail. Resilience
    /// cells grow with the fault rate (more membership churn per
    /// round); space cells scale with their sampled job count; generic
    /// grids are uniform (one trace, policy × arch variations only).
    pub fn cost_hints(&self) -> crate::Result<Vec<f64>> {
        match self {
            SweepSpec::Resilience { quick, .. } => Ok(resilience::cell_specs(*quick)
                .into_iter()
                .map(|(ri, _)| 1.0 + resilience::RATES.get(ri).copied().unwrap_or(0.0))
                .collect()),
            SweepSpec::Generic { spec, .. } => Ok(vec![1.0; runner::grid(spec).len()]),
            SweepSpec::Space { space, count, points, .. } => {
                Ok(search::plan(space, *count, *points)
                    .into_iter()
                    .map(|c| c.scenario.workload.jobs.max(1) as f64)
                    .collect())
            }
        }
    }

    /// Compute one cell — the worker side. Pure in `(self, index)`.
    pub fn compute(&self, index: usize) -> crate::Result<CellRows> {
        match self {
            SweepSpec::Resilience { quick, .. } => {
                let cells = resilience::cell_specs(*quick);
                let &(ri, sys) = cells.get(index).with_context(|| {
                    format!("cell index {index} out of range (grid has {})", cells.len())
                })?;
                let ctx = self.resilience_ctx(std::path::Path::new("results")).expect("variant");
                resilience::compute_cell(&ctx, ri, sys)
            }
            SweepSpec::Generic { spec, jobs_override, quick } => {
                runner::compute_cell(spec, *jobs_override, *quick, index)
            }
            SweepSpec::Space { space, count, points, jobs_override, quick } => {
                search::compute_cell(space, *count, *points, *jobs_override, *quick, index)
            }
        }
    }

    /// Merge index-ordered rows into the final artifacts — the
    /// dispatcher side, shared with the serial in-process paths.
    pub fn assemble(&self, rows: &[CellRows], out_dir: &std::path::Path) -> crate::Result<()> {
        match self {
            SweepSpec::Resilience { .. } => {
                let ctx = self.resilience_ctx(out_dir).expect("variant");
                resilience::assemble(&ctx, rows)
            }
            SweepSpec::Generic { spec, jobs_override, quick } => runner::assemble_generic(
                spec,
                out_dir,
                *quick,
                runner::effective_jobs(spec, *jobs_override, *quick),
                rows,
            ),
            SweepSpec::Space { space, count, points, jobs_override, quick } => {
                search::assemble(space, out_dir, *count, *points, *quick, *jobs_override, rows)
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            SweepSpec::Resilience { jobs, seed, quick, fault_seed } => jsonio::obj(vec![
                ("kind", jsonio::s("resilience")),
                ("jobs", jsonio::num(*jobs as f64)),
                ("seed", jsonio::num(*seed as f64)),
                ("quick", jsonio::b(*quick)),
                ("fault_seed", jsonio::num(*fault_seed as f64)),
            ]),
            SweepSpec::Generic { spec, jobs_override, quick } => {
                let mut pairs = vec![
                    ("kind", jsonio::s("generic")),
                    ("quick", jsonio::b(*quick)),
                    ("spec", spec.to_json()),
                ];
                if let Some(j) = jobs_override {
                    pairs.push(("jobs_override", jsonio::num(*j as f64)));
                }
                jsonio::obj(pairs)
            }
            SweepSpec::Space { space, count, points, jobs_override, quick } => {
                let mut pairs = vec![
                    ("kind", jsonio::s("space")),
                    ("count", jsonio::num(*count as f64)),
                    ("points", jsonio::num(*points as f64)),
                    ("quick", jsonio::b(*quick)),
                    ("space", space.to_json()),
                ];
                if let Some(j) = jobs_override {
                    pairs.push(("jobs_override", jsonio::num(*j as f64)));
                }
                jsonio::obj(pairs)
            }
        }
    }

    pub fn from_json(j: &Json) -> crate::Result<SweepSpec> {
        match j.get("kind")?.str()? {
            "resilience" => Ok(SweepSpec::Resilience {
                jobs: j.get("jobs")?.u64()? as usize,
                seed: j.get("seed")?.u64()?,
                quick: j.get("quick")?.boolean()?,
                fault_seed: j.get("fault_seed")?.u64()?,
            }),
            "generic" => Ok(SweepSpec::Generic {
                spec: Scenario::from_json(j.get("spec")?)?,
                jobs_override: match j.opt("jobs_override") {
                    Some(v) => Some(v.u64()? as usize),
                    None => None,
                },
                quick: j.get("quick")?.boolean()?,
            }),
            "space" => Ok(SweepSpec::Space {
                space: ScenarioSpace::from_json(j.get("space")?)?,
                count: j.get("count")?.u64()? as usize,
                points: j.get("points")?.u64()? as usize,
                jobs_override: match j.opt("jobs_override") {
                    Some(v) => Some(v.u64()? as usize),
                    None => None,
                },
                quick: j.get("quick")?.boolean()?,
            }),
            other => anyhow::bail!("unknown sweep kind {other:?}"),
        }
    }

    /// Canonical identity string: the compact JSON form (sorted keys,
    /// exact numbers — stable across processes). The journal stores it
    /// so a resume against a *different* sweep is refused instead of
    /// silently merging foreign cells.
    pub fn fingerprint(&self) -> String {
        self.to_json().to_string_compact()
    }
}

/// One completed cell: what the journal records and the dispatcher
/// merges. `elapsed_s` is the worker-side compute seconds (feeds the
/// dispatcher's straggler threshold; excluded from artifacts).
#[derive(Clone, Debug, PartialEq)]
pub struct CellDone {
    pub index: usize,
    pub elapsed_s: f64,
    pub rows: CellRows,
}

impl CellDone {
    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("index", jsonio::num(self.index as f64)),
            ("elapsed_s", jsonio::num(self.elapsed_s)),
            ("csv", Json::Arr(self.rows.csv.iter().map(|c| jsonio::s(c)).collect())),
            ("row", self.rows.json.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<CellDone> {
        let csv = j
            .get("csv")?
            .arr()?
            .iter()
            .map(|c| Ok(c.str()?.to_string()))
            .collect::<crate::Result<Vec<String>>>()?;
        Ok(CellDone {
            index: j.get("index")?.u64()? as usize,
            elapsed_s: j.get("elapsed_s")?.num()?,
            rows: CellRows { csv, json: j.get("row")?.clone() },
        })
    }
}

/// Chaos instruction piggybacked on a request (see [`super::chaos`]):
/// executed by the worker so the *fabric's* recovery paths get
/// exercised, decided by the dispatcher so the outcome is seeded and
/// deterministic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Chaos {
    /// sleep `after_ms`, then exit without responding (a crash)
    Die { after_ms: u64 },
    /// sleep `ms`, then compute normally (a straggler)
    Stall { ms: u64 },
}

impl Chaos {
    pub fn to_json(&self) -> Json {
        match self {
            Chaos::Die { after_ms } => {
                jsonio::obj(vec![("die_after_ms", jsonio::num(*after_ms as f64))])
            }
            Chaos::Stall { ms } => jsonio::obj(vec![("stall_ms", jsonio::num(*ms as f64))]),
        }
    }

    pub fn from_json(j: &Json) -> crate::Result<Chaos> {
        if let Some(v) = j.opt("die_after_ms") {
            return Ok(Chaos::Die { after_ms: v.u64()? });
        }
        if let Some(v) = j.opt("stall_ms") {
            return Ok(Chaos::Stall { ms: v.u64()? });
        }
        anyhow::bail!("chaos object needs die_after_ms or stall_ms")
    }
}

/// A parsed dispatcher → worker message.
#[derive(Debug)]
pub enum Request {
    Cell { id: u64, index: usize, sweep: SweepSpec, chaos: Option<Chaos> },
    Shutdown,
}

impl Request {
    pub fn from_line(line: &str) -> crate::Result<Request> {
        let j = Json::parse(line)?;
        let schema = j.get("schema")?.str()?;
        if schema != PROTOCOL {
            anyhow::bail!("unexpected schema {schema:?} (want {PROTOCOL:?})");
        }
        match j.get("type")?.str()? {
            "cell" => Ok(Request::Cell {
                id: j.get("id")?.u64()?,
                index: j.get("index")?.u64()? as usize,
                sweep: SweepSpec::from_json(j.get("sweep")?)?,
                chaos: match j.opt("chaos") {
                    Some(c) => Some(Chaos::from_json(c)?),
                    None => None,
                },
            }),
            "shutdown" => Ok(Request::Shutdown),
            other => anyhow::bail!("unknown request type {other:?}"),
        }
    }

    pub fn shutdown_json() -> Json {
        jsonio::obj(vec![("schema", jsonio::s(PROTOCOL)), ("type", jsonio::s("shutdown"))])
    }
}

/// Build a `cell` request line without re-serializing the sweep each
/// time — the dispatcher caches `sweep_json` once per run.
pub fn cell_request_json(id: u64, index: usize, sweep_json: &Json, chaos: Option<Chaos>) -> Json {
    let mut pairs = vec![
        ("schema", jsonio::s(PROTOCOL)),
        ("type", jsonio::s("cell")),
        ("id", jsonio::num(id as f64)),
        ("index", jsonio::num(index as f64)),
        ("sweep", sweep_json.clone()),
    ];
    if let Some(c) = chaos {
        pairs.push(("chaos", c.to_json()));
    }
    jsonio::obj(pairs)
}

/// A parsed worker → dispatcher message.
#[derive(Debug)]
pub enum Response {
    /// `window` is the worker's announced pipelining capability: how
    /// many cell requests it is willing to queue at once. The
    /// dispatcher issues `min(--window, announced)` credits to the
    /// slot. Pre-pipelining workers emit no `window` field, which
    /// parses as 1 — they keep working, lock-step, unmodified.
    Ready { pid: u64, window: usize },
    Done { id: u64, done: CellDone },
    Failed { id: u64, index: usize, error: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Ready { pid, window } => jsonio::obj(vec![
                ("schema", jsonio::s(PROTOCOL)),
                ("type", jsonio::s("ready")),
                ("pid", jsonio::num(*pid as f64)),
                ("window", jsonio::num(*window as f64)),
            ]),
            Response::Done { id, done } => jsonio::obj(vec![
                ("schema", jsonio::s(PROTOCOL)),
                ("type", jsonio::s("done")),
                ("id", jsonio::num(*id as f64)),
                ("cell", done.to_json()),
            ]),
            Response::Failed { id, index, error } => jsonio::obj(vec![
                ("schema", jsonio::s(PROTOCOL)),
                ("type", jsonio::s("failed")),
                ("id", jsonio::num(*id as f64)),
                ("index", jsonio::num(*index as f64)),
                ("error", jsonio::s(error)),
            ]),
        }
    }

    pub fn from_line(line: &str) -> crate::Result<Response> {
        let j = Json::parse(line)?;
        let schema = j.get("schema")?.str()?;
        if schema != PROTOCOL {
            anyhow::bail!("unexpected schema {schema:?} (want {PROTOCOL:?})");
        }
        match j.get("type")?.str()? {
            "ready" => Ok(Response::Ready {
                pid: j.get("pid")?.u64()?,
                window: match j.opt("window") {
                    Some(v) => (v.u64()? as usize).max(1),
                    None => 1, // a v1 worker: lock-step
                },
            }),
            "done" => Ok(Response::Done {
                id: j.get("id")?.u64()?,
                done: CellDone::from_json(j.get("cell")?)?,
            }),
            "failed" => Ok(Response::Failed {
                id: j.get("id")?.u64()?,
                index: j.get("index")?.u64()? as usize,
                error: j.get("error")?.str()?.to_string(),
            }),
            other => anyhow::bail!("unknown response type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_rows() -> CellRows {
        CellRows {
            csv: vec!["SSGD".into(), "0.0".into(), "3/4".into()],
            json: jsonio::obj(vec![
                ("name", jsonio::s("resilience/SSGD/rate=0")),
                ("jct_mean_s", jsonio::num(1234.5678901234567)),
            ]),
        }
    }

    #[test]
    fn sweep_spec_round_trips() {
        let specs = [
            SweepSpec::Resilience { jobs: 4, seed: 0, quick: true, fault_seed: 7 },
            SweepSpec::Generic {
                spec: Scenario {
                    name: "g".into(),
                    policies: vec!["SSGD".into()],
                    ..Default::default()
                },
                jobs_override: Some(3),
                quick: false,
            },
            SweepSpec::Generic {
                spec: Scenario {
                    name: "g2".into(),
                    policies: vec!["SSGD".into()],
                    ..Default::default()
                },
                jobs_override: None,
                quick: true,
            },
            SweepSpec::Space {
                space: crate::scenario::find_space("mode_choice").unwrap(),
                count: 3,
                points: 2,
                jobs_override: Some(2),
                quick: true,
            },
            SweepSpec::Space {
                space: crate::scenario::find_space("frontier").unwrap(),
                count: 1,
                points: 3,
                jobs_override: None,
                quick: false,
            },
        ];
        for spec in specs {
            let back = SweepSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec);
            assert_eq!(back.fingerprint(), spec.fingerprint());
        }
    }

    #[test]
    fn from_space_names_and_labels_the_search_plan() {
        let space = crate::scenario::find_space("mode_choice").unwrap();
        let sweep = SweepSpec::from_space(&space, 2, 2, Some(2), true).unwrap();
        assert_eq!(sweep.name(), "search_mode_choice");
        let labels = sweep.cell_labels().unwrap();
        // 2 free dims x 2 points x grid + 2 samples x grid
        let grid = space.policies.len() * space.archs.len();
        assert_eq!(labels.len(), (2 * 2 + 2) * grid);
        assert!(labels[0].starts_with("mode_choice-c-"), "{}", labels[0]);
        assert!(labels.last().unwrap().starts_with("mode_choice-s001/"), "{:?}", labels.last());
        // zero jobs is as meaningless dispatched as it is in-process
        assert!(SweepSpec::from_space(&space, 1, 2, Some(0), true).is_err());
    }

    #[test]
    fn fingerprints_distinguish_sweeps() {
        let a = SweepSpec::Resilience { jobs: 4, seed: 0, quick: true, fault_seed: 0 };
        let b = SweepSpec::Resilience { jobs: 5, seed: 0, quick: true, fault_seed: 0 };
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn cell_done_round_trips_exactly() {
        let done = CellDone { index: 7, elapsed_s: 0.12345678901234567, rows: sample_rows() };
        let line = done.to_json().to_string_compact();
        let back = CellDone::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, done, "journal/wire round-trip must be exact");
    }

    #[test]
    fn request_and_response_round_trip() {
        let sweep = SweepSpec::Resilience { jobs: 2, seed: 0, quick: true, fault_seed: 0 };
        let line = cell_request_json(9, 3, &sweep.to_json(), Some(Chaos::Die { after_ms: 10 }))
            .to_string_compact();
        match Request::from_line(&line).unwrap() {
            Request::Cell { id, index, sweep: s, chaos } => {
                assert_eq!((id, index), (9, 3));
                assert_eq!(s, sweep);
                assert_eq!(chaos, Some(Chaos::Die { after_ms: 10 }));
            }
            other => panic!("parsed {other:?}"),
        }
        let line = Request::shutdown_json().to_string_compact();
        assert!(matches!(Request::from_line(&line).unwrap(), Request::Shutdown));

        let done = CellDone { index: 1, elapsed_s: 2.5, rows: sample_rows() };
        let line = Response::Done { id: 4, done: done.clone() }.to_json().to_string_compact();
        match Response::from_line(&line).unwrap() {
            Response::Done { id, done: d } => {
                assert_eq!(id, 4);
                assert_eq!(d, done);
            }
            other => panic!("parsed {other:?}"),
        }
        let line = Response::Failed { id: 1, index: 2, error: "boom\nline2".into() }
            .to_json()
            .to_string_compact();
        assert!(!line.contains('\n'), "errors must stay one line on the wire");
        assert!(matches!(Response::from_line(&line).unwrap(), Response::Failed { index: 2, .. }));
    }

    #[test]
    fn ready_window_round_trips_and_absent_window_means_lockstep() {
        let line = Response::Ready { pid: 7, window: 32 }.to_json().to_string_compact();
        match Response::from_line(&line).unwrap() {
            Response::Ready { pid, window } => assert_eq!((pid, window), (7, 32)),
            other => panic!("parsed {other:?}"),
        }
        // a pre-pipelining worker announces no window: the dispatcher
        // must fall back to one-in-flight so old fleets keep working
        let legacy = format!(r#"{{"pid":9,"schema":"{PROTOCOL}","type":"ready"}}"#);
        match Response::from_line(&legacy).unwrap() {
            Response::Ready { pid, window } => assert_eq!((pid, window), (9, 1)),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn cost_hints_cover_every_cell_and_weight_fault_rates() {
        let specs = [
            SweepSpec::Resilience { jobs: 2, seed: 0, quick: true, fault_seed: 0 },
            SweepSpec::Generic {
                spec: Scenario {
                    name: "g".into(),
                    policies: vec!["SSGD".into()],
                    ..Default::default()
                },
                jobs_override: None,
                quick: true,
            },
            SweepSpec::Space {
                space: crate::scenario::find_space("mode_choice").unwrap(),
                count: 2,
                points: 2,
                jobs_override: Some(2),
                quick: true,
            },
        ];
        for spec in specs {
            let hints = spec.cost_hints().unwrap();
            assert_eq!(hints.len(), spec.cell_labels().unwrap().len(), "{}", spec.name());
            assert!(hints.iter().all(|&c| c > 0.0), "{}", spec.name());
        }
        // the rate-major resilience grid: rate-4 cells (churn-heavy)
        // must be expected costlier than fault-free ones
        let r = SweepSpec::Resilience { jobs: 2, seed: 0, quick: true, fault_seed: 0 };
        let hints = r.cost_hints().unwrap();
        assert!(hints[8] > hints[0], "{hints:?}");
    }

    #[test]
    fn from_scenario_maps_builtin_resilience_to_experiment_defaults() {
        let sc = crate::scenario::find_builtin("resilience").unwrap();
        let sweep = SweepSpec::from_scenario(&sc, Some(4), true).unwrap();
        assert_eq!(
            sweep,
            SweepSpec::Resilience { jobs: 4, seed: 0, quick: true, fault_seed: 0 }
        );
        assert_eq!(sweep.cell_labels().unwrap().len(), 9, "3 rates x 3 quick systems");
    }

    #[test]
    fn from_scenario_rejects_other_delegated_experiments() {
        let sc = Scenario {
            name: "delegated".into(),
            experiments: vec!["fig16".into()],
            ..Default::default()
        };
        let err = SweepSpec::from_scenario(&sc, None, true).unwrap_err();
        assert!(format!("{err:#}").contains("scenario run"), "{err:#}");
    }

    #[test]
    fn rejects_foreign_schema_lines() {
        assert!(Request::from_line(r#"{"schema":"other-v1","type":"cell"}"#).is_err());
        assert!(Response::from_line(r#"{"no":"schema"}"#).is_err());
    }
}
