//! `star worker` — a cell server. Reads `star-cell-v1` request lines,
//! answers each with one `done`/`failed` line, over stdin/stdout
//! (subprocess mode, `star dispatch` spawns these) or a TCP listener
//! (fleet mode, `--listen host:port`).
//!
//! The worker is deliberately dumb: no state between requests, cells
//! computed one at a time in arrival order. It is however **pipelined**
//! (DESIGN.md §14): a reader thread queues incoming requests and a
//! writer thread ships responses, so while one `CellDone` is in flight
//! back to the dispatcher the next cell is already computing. The
//! `ready` line announces [`WINDOW`], the number of requests the
//! dispatcher may keep outstanding here; the dispatcher caps its
//! `--window` credits at that. All the cleverness — retries, deadlines,
//! straggler re-issue, re-queue — stays in the dispatcher, which only
//! works because a worker is safe to kill at any instant: cells are
//! pure and journaling happens dispatcher-side after the response, so a
//! dead worker costs only the cells it was holding.
//!
//! Diagnostics go to stderr; stdout carries protocol lines only (the
//! compute path never prints — pinned by the dispatch byte-identity
//! tests, which would fail on any stray stdout).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Context;

use crate::exp::sweep::panic_message;

use super::protocol::{Chaos, Request, Response};

/// How many requests this worker is willing to queue: announced in the
/// `ready` line, capping the dispatcher's per-slot credits. Generous on
/// purpose — requests are small, and the dispatcher's `--window` is the
/// real knob.
pub const WINDOW: usize = 32;

/// Serve cells over stdin/stdout until EOF or a `shutdown` request.
pub fn serve_stdio() -> crate::Result<()> {
    serve_session(BufReader::new(std::io::stdin()), std::io::stdout())
}

/// Serve cells over TCP, one connection at a time, forever. Connection
/// errors are logged and the listener keeps accepting — a fleet worker
/// survives its dispatcher dying and serves the next dispatch.
pub fn serve_tcp(addr: &str) -> crate::Result<()> {
    let addr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving listen address {addr:?}"))?
        .next()
        .with_context(|| format!("listen address {addr:?} resolved to nothing"))?;
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding worker listener on {addr}"))?;
    // tests and fleet scripts parse this line (port 0 binds ephemerally)
    println!("star worker listening on {}", listener.local_addr()?);
    std::io::stdout().flush()?;
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(pair) => pair,
            Err(e) => {
                eprintln!("star worker: accept failed: {e}");
                continue;
            }
        };
        eprintln!("star worker: serving {peer}");
        let serve = || -> crate::Result<()> {
            let reader = BufReader::new(stream.try_clone()?);
            let out = stream.try_clone()?;
            serve_session(reader, out)
        };
        if let Err(e) = serve() {
            eprintln!("star worker: connection to {peer} failed: {e:#}");
        }
    }
}

/// The pipelined session loop shared by both transports: requests queue
/// up on a reader thread, responses drain through a writer thread, and
/// this thread computes cells strictly in arrival order in between. Up
/// to [`WINDOW`] requests can be buffered, so the dispatcher's next
/// cell is already here when the current one finishes — compute
/// overlaps both directions of protocol I/O.
fn serve_session(
    reader: impl BufRead + Send + 'static,
    out: impl Write + Send + 'static,
) -> crate::Result<()> {
    let (resp_tx, resp_rx) = mpsc::channel::<String>();
    let writer = std::thread::spawn(move || write_lines(out, resp_rx));
    let (req_tx, req_rx) = mpsc::channel::<Request>();
    // the reader thread owns the input; at EOF (or shutdown) it drops
    // `req_tx`, which ends the recv loop below
    std::thread::spawn(move || read_requests(reader, req_tx));

    let ready = Response::Ready { pid: std::process::id() as u64, window: WINDOW };
    if resp_tx.send(ready.to_json().to_string_compact()).is_ok() {
        loop {
            match req_rx.recv() {
                Err(_) | Ok(Request::Shutdown) => break, // EOF or polite end
                Ok(Request::Cell { id, index, sweep, chaos }) => {
                    let resp = serve_cell(id, index, &sweep, chaos);
                    if resp_tx.send(resp.to_json().to_string_compact()).is_err() {
                        break; // writer died: the peer is gone
                    }
                }
            }
        }
    }
    drop(resp_tx); // writer drains the queue, then exits
    match writer.join() {
        Ok(served) => served.context("writing responses"),
        Err(p) => anyhow::bail!("writer thread panicked: {}", panic_message(p)),
    }
}

/// Writer thread: one line per response, flushed immediately so the
/// dispatcher sees results (and can refill credits) without delay.
fn write_lines(mut out: impl Write, rx: mpsc::Receiver<String>) -> std::io::Result<()> {
    for line in rx {
        writeln!(out, "{line}")?;
        out.flush()?;
    }
    Ok(())
}

/// Reader thread: parse request lines into the session queue.
/// Unparseable lines are warned about and skipped (they can only come
/// from a broken peer; dying on them would turn one bad line into a
/// lost worker).
fn read_requests(reader: impl BufRead, tx: mpsc::Sender<Request>) {
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match Request::from_line(&line) {
            Ok(req) => {
                let shutdown = matches!(req, Request::Shutdown);
                if tx.send(req).is_err() || shutdown {
                    break;
                }
            }
            Err(e) => eprintln!("star worker: skipping bad request line: {e:#}"),
        }
    }
}

/// Compute one cell (honoring any chaos instruction first) and build
/// the response. A `Die` never returns; a panic inside the cell becomes
/// a `failed` response rather than a dead worker.
fn serve_cell(id: u64, index: usize, sweep: &super::SweepSpec, chaos: Option<Chaos>) -> Response {
    match chaos {
        Some(Chaos::Die { after_ms }) => {
            eprintln!("star worker: chaos kill on cell {index} (after {after_ms} ms)");
            std::thread::sleep(std::time::Duration::from_millis(after_ms));
            // crash without a response: the dispatcher must detect the
            // death and re-queue the cell
            std::process::exit(3);
        }
        Some(Chaos::Stall { ms }) => {
            eprintln!("star worker: chaos stall on cell {index} ({ms} ms)");
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        None => {}
    }
    let t0 = Instant::now();
    match catch_unwind(AssertUnwindSafe(|| sweep.compute(index))) {
        Ok(Ok(rows)) => Response::Done {
            id,
            done: super::protocol::CellDone {
                index,
                elapsed_s: t0.elapsed().as_secs_f64(),
                rows,
            },
        },
        Ok(Err(e)) => Response::Failed { id, index, error: format!("{e:#}") },
        Err(p) => Response::Failed {
            id,
            index,
            error: format!("cell panicked: {}", panic_message(p)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::protocol::{cell_request_json, SweepSpec};
    use std::sync::{Arc, Mutex};

    /// A `Write` the test keeps a handle to after the writer thread
    /// takes ownership of its clone.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn serve_session_pipelines_queued_cells_and_honors_shutdown() {
        let sweep = SweepSpec::Resilience { jobs: 2, seed: 0, quick: true, fault_seed: 0 };
        let sweep_json = sweep.to_json();
        // two requests queued back-to-back (the pipelined shape: the
        // second arrives while the first computes), plus garbage and a
        // shutdown
        let input = format!(
            "{}\nnot json\n\n{}\n{}\nafter shutdown is never read\n",
            cell_request_json(1, 0, &sweep_json, None).to_string_compact(),
            cell_request_json(2, 999, &sweep_json, None).to_string_compact(),
            Request::shutdown_json().to_string_compact(),
        );
        let out = SharedBuf::default();
        serve_session(BufReader::new(std::io::Cursor::new(input.into_bytes())), out.clone())
            .unwrap();
        let text = String::from_utf8(out.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "ready + one response per cell request: {text}");
        match Response::from_line(lines[0]).unwrap() {
            Response::Ready { window, .. } => {
                assert_eq!(window, WINDOW, "the worker must announce its queue depth");
            }
            other => panic!("expected ready, got {other:?}"),
        }
        match Response::from_line(lines[1]).unwrap() {
            Response::Done { id, done } => {
                assert_eq!(id, 1);
                assert_eq!(done.index, 0);
                assert!(!done.rows.csv.is_empty());
            }
            other => panic!("expected done, got {other:?}"),
        }
        match Response::from_line(lines[2]).unwrap() {
            Response::Failed { id, index, error } => {
                assert_eq!((id, index), (2, 999));
                assert!(error.contains("out of range"), "{error}");
            }
            other => panic!("expected failed, got {other:?}"),
        }
    }
}
