//! `star worker` — a cell server. Reads `star-cell-v1` request lines,
//! answers each with one `done`/`failed` line, over stdin/stdout
//! (subprocess mode, `star dispatch` spawns these) or a TCP listener
//! (fleet mode, `--listen host:port`).
//!
//! The worker is deliberately dumb: no queue, no state between
//! requests, one cell at a time. All the cleverness — retries,
//! deadlines, straggler re-issue, re-queue — lives in the dispatcher,
//! which only works because a worker is safe to kill at any instant:
//! cells are pure and journaling happens dispatcher-side after the
//! response, so a dead worker costs only the cell it was holding.
//!
//! Diagnostics go to stderr; stdout carries protocol lines only (the
//! compute path never prints — pinned by the dispatch byte-identity
//! tests, which would fail on any stray stdout).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use anyhow::Context;

use crate::exp::sweep::panic_message;

use super::protocol::{Chaos, Request, Response};

/// Serve cells over stdin/stdout until EOF or a `shutdown` request.
pub fn serve_stdio() -> crate::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    announce(&mut out)?;
    serve_lines(stdin.lock(), &mut out)
}

/// Serve cells over TCP, one connection at a time, forever. Connection
/// errors are logged and the listener keeps accepting — a fleet worker
/// survives its dispatcher dying and serves the next dispatch.
pub fn serve_tcp(addr: &str) -> crate::Result<()> {
    let addr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving listen address {addr:?}"))?
        .next()
        .with_context(|| format!("listen address {addr:?} resolved to nothing"))?;
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding worker listener on {addr}"))?;
    // tests and fleet scripts parse this line (port 0 binds ephemerally)
    println!("star worker listening on {}", listener.local_addr()?);
    std::io::stdout().flush()?;
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(pair) => pair,
            Err(e) => {
                eprintln!("star worker: accept failed: {e}");
                continue;
            }
        };
        eprintln!("star worker: serving {peer}");
        let serve = || -> crate::Result<()> {
            let reader = BufReader::new(stream.try_clone()?);
            let mut out = stream.try_clone()?;
            announce(&mut out)?;
            serve_lines(reader, &mut out)
        };
        if let Err(e) = serve() {
            eprintln!("star worker: connection to {peer} failed: {e:#}");
        }
    }
}

fn announce(out: &mut impl Write) -> crate::Result<()> {
    let ready = Response::Ready { pid: std::process::id() as u64 };
    writeln!(out, "{}", ready.to_json().to_string_compact())?;
    out.flush()?;
    Ok(())
}

/// The request loop shared by both transports. Unparseable lines are
/// warned about and skipped (they can only come from a broken peer;
/// dying on them would turn one bad line into a lost worker).
fn serve_lines(reader: impl BufRead, out: &mut impl Write) -> crate::Result<()> {
    for line in reader.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Request::from_line(&line) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("star worker: skipping bad request line: {e:#}");
                continue;
            }
        };
        match req {
            Request::Shutdown => return Ok(()),
            Request::Cell { id, index, sweep, chaos } => {
                let resp = serve_cell(id, index, &sweep, chaos);
                writeln!(out, "{}", resp.to_json().to_string_compact())?;
                out.flush()?;
            }
        }
    }
    Ok(())
}

/// Compute one cell (honoring any chaos instruction first) and build
/// the response. A `Die` never returns; a panic inside the cell becomes
/// a `failed` response rather than a dead worker.
fn serve_cell(id: u64, index: usize, sweep: &super::SweepSpec, chaos: Option<Chaos>) -> Response {
    match chaos {
        Some(Chaos::Die { after_ms }) => {
            eprintln!("star worker: chaos kill on cell {index} (after {after_ms} ms)");
            std::thread::sleep(std::time::Duration::from_millis(after_ms));
            // crash without a response: the dispatcher must detect the
            // death and re-queue the cell
            std::process::exit(3);
        }
        Some(Chaos::Stall { ms }) => {
            eprintln!("star worker: chaos stall on cell {index} ({ms} ms)");
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        None => {}
    }
    let t0 = Instant::now();
    match catch_unwind(AssertUnwindSafe(|| sweep.compute(index))) {
        Ok(Ok(rows)) => Response::Done {
            id,
            done: super::protocol::CellDone {
                index,
                elapsed_s: t0.elapsed().as_secs_f64(),
                rows,
            },
        },
        Ok(Err(e)) => Response::Failed { id, index, error: format!("{e:#}") },
        Err(p) => Response::Failed {
            id,
            index,
            error: format!("cell panicked: {}", panic_message(p)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::protocol::{cell_request_json, SweepSpec};

    #[test]
    fn serve_lines_answers_cells_and_honors_shutdown() {
        let sweep = SweepSpec::Resilience { jobs: 2, seed: 0, quick: true, fault_seed: 0 };
        let sweep_json = sweep.to_json();
        let input = format!(
            "{}\nnot json\n\n{}\n{}\nafter shutdown is never read\n",
            cell_request_json(1, 0, &sweep_json, None).to_string_compact(),
            cell_request_json(2, 999, &sweep_json, None).to_string_compact(),
            Request::shutdown_json().to_string_compact(),
        );
        let mut out: Vec<u8> = Vec::new();
        serve_lines(BufReader::new(input.as_bytes()), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one response per cell request: {text}");
        match Response::from_line(lines[0]).unwrap() {
            Response::Done { id, done } => {
                assert_eq!(id, 1);
                assert_eq!(done.index, 0);
                assert!(!done.rows.csv.is_empty());
            }
            other => panic!("expected done, got {other:?}"),
        }
        match Response::from_line(lines[1]).unwrap() {
            Response::Failed { id, index, error } => {
                assert_eq!((id, index), (2, 999));
                assert!(error.contains("out of range"), "{error}");
            }
            other => panic!("expected failed, got {other:?}"),
        }
    }
}
