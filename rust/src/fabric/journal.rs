//! Append-only checkpoint journal: `results/<sweep>.journal.jsonl`.
//!
//! Line 1 is a header binding the journal to a sweep fingerprint and
//! cell count; every later line is one [`CellDone`] record. Appends are
//! **group-committed** (DESIGN.md §14): [`Journal::append`] only
//! buffers the record in memory, and [`Journal::flush`] writes the
//! whole batch and fsyncs once — so a cheap-cell sweep pays one fsync
//! per batch, not one per cell. The durability contract is unchanged
//! from the per-cell days because a cell only *counts* as durable after
//! its batch syncs: a crash loses at most the buffered (never-written)
//! tail, which the dispatcher simply re-runs on resume. A cell is
//! either durably journaled or it will be re-run, never half-written.
//!
//! On open, an existing journal is replayed to recover completed cells,
//! so an interrupted dispatch resumes re-running only the missing ones.
//! A torn final line (the process died mid-write, pre-fsync) is
//! detected by its missing newline and dropped; any *complete* line
//! that fails to parse means real corruption and is refused rather than
//! guessed at.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::jsonio::{self, Json};

use super::protocol::CellDone;

/// Journal header schema tag (the cell records carry none — their shape
/// is bound by the header).
pub const JOURNAL_SCHEMA: &str = "star-journal-v1";

pub struct Journal {
    file: File,
    path: PathBuf,
    /// records appended since the last flush — deliberately held in
    /// process memory (not an OS write buffer) so a crash loses exactly
    /// what was never committed, with no page-cache gray zone
    buf: String,
    buffered: usize,
    fsyncs: u64,
}

impl Journal {
    /// Open (or create) the journal for a sweep with `cells` cells and
    /// identity `fingerprint`. Returns the journal plus every cell
    /// recovered from a previous run, in journal order. `fresh`
    /// discards any existing journal first.
    pub fn open(
        path: &Path,
        fingerprint: &str,
        cells: usize,
        fresh: bool,
    ) -> crate::Result<(Journal, Vec<CellDone>)> {
        if fresh && path.exists() {
            std::fs::remove_file(path)
                .with_context(|| format!("removing stale journal {}", path.display()))?;
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating journal dir {}", dir.display()))?;
            }
        }

        if !path.exists() {
            let mut file = File::create(path)
                .with_context(|| format!("creating journal {}", path.display()))?;
            let header = jsonio::obj(vec![
                ("schema", jsonio::s(JOURNAL_SCHEMA)),
                ("cells", jsonio::num(cells as f64)),
                ("fingerprint", jsonio::s(fingerprint)),
            ]);
            writeln!(file, "{}", header.to_string_compact())?;
            file.sync_data()?;
            return Ok((Journal::around(file, path), Vec::new()));
        }

        let mut text = String::new();
        File::open(path)
            .and_then(|mut f| f.read_to_string(&mut text))
            .with_context(|| format!("reading journal {}", path.display()))?;

        let mut recovered: Vec<CellDone> = Vec::new();
        let mut seen = vec![false; cells];
        let mut good_end = 0usize;
        let mut saw_header = false;
        for seg in text.split_inclusive('\n') {
            if !seg.ends_with('\n') {
                // torn tail: the append that died mid-write
                eprintln!(
                    "journal {}: dropping torn trailing record ({} bytes)",
                    path.display(),
                    seg.len()
                );
                break;
            }
            let line = seg.trim_end();
            if line.is_empty() {
                good_end += seg.len();
                continue;
            }
            let j = Json::parse(line).with_context(|| {
                format!("journal {}: corrupt record (try --fresh)", path.display())
            })?;
            if !saw_header {
                Self::check_header(&j, path, fingerprint, cells)?;
                saw_header = true;
            } else {
                let done = CellDone::from_json(&j).with_context(|| {
                    format!("journal {}: corrupt cell record (try --fresh)", path.display())
                })?;
                let slot = seen.get_mut(done.index).with_context(|| {
                    format!(
                        "journal {}: cell index {} out of range for a {}-cell sweep \
                         (try --fresh)",
                        path.display(),
                        done.index,
                        cells
                    )
                })?;
                if *slot {
                    anyhow::bail!(
                        "journal {}: duplicate record for cell {} (try --fresh)",
                        path.display(),
                        done.index
                    );
                }
                *slot = true;
                recovered.push(done);
            }
            good_end += seg.len();
        }
        if !saw_header {
            anyhow::bail!("journal {}: missing header (try --fresh)", path.display());
        }

        let mut file = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("reopening journal {}", path.display()))?;
        file.set_len(good_end as u64)?; // drop the torn tail for good
        file.seek(SeekFrom::End(0))?;
        Ok((Journal::around(file, path), recovered))
    }

    fn around(file: File, path: &Path) -> Journal {
        Journal { file, path: path.to_path_buf(), buf: String::new(), buffered: 0, fsyncs: 0 }
    }

    fn check_header(j: &Json, path: &Path, fingerprint: &str, cells: usize) -> crate::Result<()> {
        let schema = j.get("schema").and_then(|v| Ok(v.str()?.to_string())).unwrap_or_default();
        if schema != JOURNAL_SCHEMA {
            anyhow::bail!(
                "journal {}: schema {:?} (want {:?}) — not a sweep journal (try --fresh)",
                path.display(),
                schema,
                JOURNAL_SCHEMA
            );
        }
        let jcells = j.get("cells")?.u64()? as usize;
        let jfp = j.get("fingerprint")?.str()?;
        if jcells != cells || jfp != fingerprint {
            anyhow::bail!(
                "journal {} was written by a different sweep (its grid or invocation \
                 knobs changed: {} cells vs {} expected) — pass --fresh to discard it",
                path.display(),
                jcells,
                cells
            );
        }
        Ok(())
    }

    /// Buffer one completed cell for the next group commit. The record
    /// is NOT durable (and not even written) until [`flush`] runs —
    /// callers that need per-cell durability flush after every append.
    ///
    /// [`flush`]: Journal::flush
    pub fn append(&mut self, done: &CellDone) {
        self.buf.push_str(&done.to_json().to_string_compact());
        self.buf.push('\n');
        self.buffered += 1;
    }

    /// Group commit: write every buffered record and fsync once. An
    /// empty buffer is a no-op (no write, no fsync counted).
    pub fn flush(&mut self) -> crate::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.file
            .write_all(self.buf.as_bytes())
            .and_then(|()| self.file.sync_data())
            .with_context(|| format!("committing batch to journal {}", self.path.display()))?;
        self.buf.clear();
        self.buffered = 0;
        self.fsyncs += 1;
        Ok(())
    }

    /// Records appended since the last commit.
    pub fn pending(&self) -> usize {
        self.buffered
    }

    /// Data fsyncs performed so far (the header sync at create is not
    /// counted — this is the per-sweep group-commit figure).
    pub fn fsyncs(&self) -> u64 {
        self.fsyncs
    }

    /// Simulate a crash for tests: drop the uncommitted buffer — those
    /// records were never written, exactly as if the process died
    /// mid-batch — and close the file without the drop-flush.
    pub fn abandon(mut self) {
        self.buf.clear();
        self.buffered = 0;
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Clean exit durability: whatever is still buffered gets committed.
/// Errors are swallowed (nowhere to report them in a destructor); the
/// dispatcher flushes explicitly on its happy path.
impl Drop for Journal {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::CellRows;

    fn done(index: usize) -> CellDone {
        CellDone {
            index,
            elapsed_s: 0.25 + index as f64,
            rows: CellRows {
                csv: vec![format!("row{index}"), "1.5".into()],
                json: jsonio::obj(vec![("name", jsonio::s(&format!("cell{index}")))]),
            },
        }
    }

    #[test]
    fn append_then_reopen_recovers_cells() {
        let dir = tempdir("journal_resume");
        let path = dir.join("sweep.journal.jsonl");
        let _ = std::fs::remove_file(&path);
        let (mut j, rec) = Journal::open(&path, "fp1", 4, false).unwrap();
        assert!(rec.is_empty());
        j.append(&done(2));
        j.append(&done(0));
        drop(j);

        let (_j, rec) = Journal::open(&path, "fp1", 4, false).unwrap();
        assert_eq!(rec, vec![done(2), done(0)]);

        // --fresh discards everything
        let (_j, rec) = Journal::open(&path, "fp1", 4, true).unwrap();
        assert!(rec.is_empty());
    }

    #[test]
    fn torn_tail_is_dropped_and_truncated() {
        let dir = tempdir("journal_torn");
        let path = dir.join("sweep.journal.jsonl");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, "fp", 3, false).unwrap();
        j.append(&done(0));
        j.append(&done(1));
        drop(j);

        // simulate dying mid-append: chop the file inside the last record
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 7]).unwrap();

        let (mut j, rec) = Journal::open(&path, "fp", 3, false).unwrap();
        assert_eq!(rec, vec![done(0)], "the torn record must be dropped");
        // and the file must be usable again: append lands on a clean line
        j.append(&done(2));
        drop(j);
        let (_j, rec) = Journal::open(&path, "fp", 3, false).unwrap();
        assert_eq!(rec, vec![done(0), done(2)]);
    }

    #[test]
    fn group_commit_buffers_until_flush_and_abandon_loses_only_the_tail() {
        let dir = tempdir("journal_gc");
        let path = dir.join("sweep.journal.jsonl");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, "fp", 8, false).unwrap();
        j.append(&done(0));
        j.append(&done(1));
        assert_eq!((j.pending(), j.fsyncs()), (2, 0), "appends must only buffer");
        j.flush().unwrap();
        assert_eq!((j.pending(), j.fsyncs()), (0, 1), "one sync commits the whole batch");
        j.flush().unwrap();
        assert_eq!(j.fsyncs(), 1, "an empty flush is a no-op, not an fsync");
        j.append(&done(2));
        j.append(&done(3));
        j.abandon(); // crash mid-batch: the unsynced tail dies with us

        let (mut j, rec) = Journal::open(&path, "fp", 8, false).unwrap();
        assert_eq!(rec, vec![done(0), done(1)], "only the committed batch survives");
        // clean exit (drop) still commits whatever is buffered
        j.append(&done(4));
        drop(j);
        let (_j, rec) = Journal::open(&path, "fp", 8, false).unwrap();
        assert_eq!(rec, vec![done(0), done(1), done(4)]);
    }

    #[test]
    fn foreign_fingerprint_is_refused() {
        let dir = tempdir("journal_fp");
        let path = dir.join("sweep.journal.jsonl");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, "fp-a", 2, false).unwrap();
        j.append(&done(0));
        drop(j);
        let err = Journal::open(&path, "fp-b", 2, false).unwrap_err();
        assert!(format!("{err:#}").contains("--fresh"), "{err:#}");
        let err = Journal::open(&path, "fp-a", 3, false).unwrap_err();
        assert!(format!("{err:#}").contains("--fresh"), "{err:#}");
    }

    #[test]
    fn duplicate_and_out_of_range_records_are_refused() {
        let dir = tempdir("journal_dup");
        let path = dir.join("sweep.journal.jsonl");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, "fp", 2, false).unwrap();
        j.append(&done(1));
        j.append(&done(1));
        drop(j);
        let err = Journal::open(&path, "fp", 2, false).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");

        let path = dir.join("range.journal.jsonl");
        let _ = std::fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, "fp", 2, false).unwrap();
        j.append(&done(5));
        drop(j);
        let err = Journal::open(&path, "fp", 2, false).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("star_fabric_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }
}
