//! `star dispatch` — the failure-tolerant driver of the sweep fabric.
//!
//! Scatters a sweep's cells across a fleet of workers (subprocesses it
//! spawns, or remote `star worker --listen` peers via `--connect`),
//! tolerating every failure mode a fleet exhibits:
//!
//! * **crash** — a worker dying (EOF on its link) re-queues the cell it
//!   held, with exponential backoff and a bounded retry budget;
//! * **hang** — a cell exceeding `deadline_s` retires its worker and
//!   re-queues the cell;
//! * **straggle** — once the queue drains, a cell running far past the
//!   p99 of completed cells is *duplicated* onto an idle worker; first
//!   result wins, the loser is discarded on arrival;
//! * **interruption** — every completed cell is fsync'd into the
//!   checkpoint journal before it counts, so a killed dispatch resumes
//!   re-running only the missing cells.
//!
//! None of this can perturb results: cells are pure, rows come back
//! pre-rendered, and the merge is index-ordered — so the artifacts are
//! byte-identical to a serial in-process `--threads 1` run no matter
//! how chaotic the execution was (pinned by `tests/fabric_dispatch.rs`
//! and the CI chaos-smoke step).

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::jsonio::Json;

use super::chaos::{self, ChaosConfig};
use super::journal::Journal;
use super::protocol::{cell_request_json, CellDone, Chaos, Request, Response, SweepSpec};

pub struct DispatchOpts {
    /// fleet size in subprocess mode (ignored when `connect` is set)
    pub workers: usize,
    /// remote worker addresses — non-empty switches to fleet mode
    pub connect: Vec<String>,
    pub out_dir: PathBuf,
    /// journal path override; default `out_dir/<sweep>.journal.jsonl`
    pub journal: Option<PathBuf>,
    /// discard any existing journal instead of resuming from it
    pub fresh: bool,
    /// per-cell wall deadline before its worker is presumed hung
    pub deadline_s: f64,
    /// re-issues allowed per cell after its first attempt
    pub retries: usize,
    /// base re-queue delay, doubled per attempt (capped at 10 s)
    pub backoff_ms: u64,
    /// straggler threshold: this × p99 of completed cell durations
    pub straggler_factor: f64,
    pub chaos: Option<ChaosConfig>,
    /// worker executable; default: this binary (`current_exe`)
    pub worker_bin: Option<PathBuf>,
}

impl Default for DispatchOpts {
    fn default() -> Self {
        DispatchOpts {
            workers: 4,
            connect: Vec::new(),
            out_dir: PathBuf::from("results"),
            journal: None,
            fresh: false,
            deadline_s: 600.0,
            retries: 5,
            backoff_ms: 100,
            straggler_factor: 3.0,
            chaos: None,
            worker_bin: None,
        }
    }
}

/// What a dispatch did — the fabric's observability surface (tests
/// assert on it; the summary line prints it).
#[derive(Clone, Debug, Default)]
pub struct DispatchReport {
    pub cells: usize,
    /// recovered from the journal, not re-run
    pub resumed: usize,
    /// computed this run
    pub executed: usize,
    /// re-queues after a failure/crash/deadline
    pub retries: usize,
    pub straggler_reissues: usize,
    pub worker_deaths: usize,
    pub chaos_kills: usize,
    pub chaos_stalls: usize,
    pub wall_s: f64,
}

enum Link {
    Child { child: Child, stdin: ChildStdin },
    Tcp { stream: TcpStream },
}

struct Flight {
    cell: usize,
    issued: Instant,
    duplicate: bool,
}

struct Slot {
    link: Option<Link>,
    busy: Option<Flight>,
    /// bumped on every (re)spawn so stale reader-thread events are
    /// recognizable — except `done` results, which are salvaged
    /// regardless of which incarnation produced them
    gen: u64,
}

enum Event {
    Msg(Response),
    Gone,
}

/// Run the sweep across the fleet; returns the report after the merged
/// artifacts are written.
pub fn dispatch(sweep: &SweepSpec, opts: &DispatchOpts) -> crate::Result<DispatchReport> {
    let t0 = Instant::now();
    let labels = sweep.cell_labels()?;
    let cells = labels.len();
    if cells == 0 {
        anyhow::bail!("sweep {} has no cells", sweep.name());
    }

    let journal_path = opts
        .journal
        .clone()
        .unwrap_or_else(|| opts.out_dir.join(format!("{}.journal.jsonl", sweep.name())));
    let (journal, recovered) =
        Journal::open(&journal_path, &sweep.fingerprint(), cells, opts.fresh)?;

    let mut done: BTreeMap<usize, CellDone> = BTreeMap::new();
    let mut durations: Vec<f64> = Vec::new();
    for rec in recovered {
        durations.push(rec.elapsed_s);
        done.insert(rec.index, rec);
    }
    let resumed = done.len();
    let pending: VecDeque<usize> = (0..cells).filter(|i| !done.contains_key(i)).collect();
    if resumed > 0 {
        eprintln!(
            "star dispatch: resuming {} — {} of {} cell(s) already journaled",
            journal_path.display(),
            resumed,
            cells
        );
    }

    let (tx, rx) = std::sync::mpsc::channel();
    let mut d = Dispatcher {
        sweep_json: sweep.to_json(),
        opts,
        labels,
        slots: Vec::new(),
        tx,
        rx,
        pending,
        delayed: Vec::new(),
        attempts: vec![0; cells],
        flights: vec![Vec::new(); cells],
        done,
        journal,
        durations,
        cell_error: vec![None; cells],
        report: DispatchReport { cells, resumed, ..Default::default() },
        next_id: 1,
        fatal: None,
        // covers the initial fleet plus one chaos kill per cell with
        // generous slack; only exhausted by a genuinely broken setup
        respawn_budget: opts.workers * 4 + 2 * cells + 8,
        tcp_mode: !opts.connect.is_empty(),
    };

    let result = d.run();
    d.shutdown_fleet();
    result?;
    if let Some(msg) = d.fatal.take() {
        anyhow::bail!("dispatch of {} failed: {}", sweep.name(), msg);
    }

    // deterministic merge: strictly index-ordered, identical to the
    // serial sweep's row order
    let rows: Vec<_> = (0..cells)
        .map(|i| d.done.remove(&i).expect("loop exits only when every cell is done").rows)
        .collect();
    sweep.assemble(&rows, &opts.out_dir)?;

    d.report.wall_s = t0.elapsed().as_secs_f64();
    let r = &d.report;
    eprintln!(
        "star dispatch: {} cell(s) ({} resumed, {} executed) — {} retr{}, \
         {} straggler re-issue(s), {} worker death(s), chaos {}k/{}s — {:.1}s",
        r.cells,
        r.resumed,
        r.executed,
        r.retries,
        if r.retries == 1 { "y" } else { "ies" },
        r.straggler_reissues,
        r.worker_deaths,
        r.chaos_kills,
        r.chaos_stalls,
        r.wall_s
    );
    Ok(d.report)
}

struct Dispatcher<'a> {
    sweep_json: Json,
    opts: &'a DispatchOpts,
    labels: Vec<String>,
    slots: Vec<Slot>,
    tx: Sender<(usize, u64, Event)>,
    rx: Receiver<(usize, u64, Event)>,
    pending: VecDeque<usize>,
    /// (due, cell) — backoff re-queues waiting to re-enter `pending`
    delayed: Vec<(Instant, usize)>,
    /// non-duplicate issues per cell (the retry budget's currency)
    attempts: Vec<usize>,
    /// cell -> slot ids with an attempt in flight
    flights: Vec<Vec<usize>>,
    done: BTreeMap<usize, CellDone>,
    journal: Journal,
    /// completed-cell compute seconds (feeds the straggler p99)
    durations: Vec<f64>,
    cell_error: Vec<Option<String>>,
    report: DispatchReport,
    next_id: u64,
    fatal: Option<String>,
    respawn_budget: usize,
    tcp_mode: bool,
}

impl Dispatcher<'_> {
    fn run(&mut self) -> crate::Result<()> {
        if self.tcp_mode {
            self.connect_fleet()?;
        }
        while self.done.len() < self.report.cells && self.fatal.is_none() {
            self.ensure_fleet();
            if self.fatal.is_some() {
                break;
            }
            self.promote_delayed();
            self.issue_pending();
            self.maybe_duplicate();
            match self.rx.recv_timeout(Duration::from_millis(20)) {
                Ok((slot, gen, ev)) => {
                    self.handle_event(slot, gen, ev)?;
                    while let Ok((slot, gen, ev)) = self.rx.try_recv() {
                        self.handle_event(slot, gen, ev)?;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => unreachable!("we hold a sender"),
            }
            self.check_deadlines();
        }
        Ok(())
    }

    fn outstanding(&self) -> usize {
        self.report.cells - self.done.len()
    }

    // -- fleet ------------------------------------------------------------

    fn connect_fleet(&mut self) -> crate::Result<()> {
        for addr in &self.opts.connect {
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to worker {addr}"))?;
            let reader = BufReader::new(
                stream.try_clone().context("cloning worker stream for reads")?,
            );
            let slot = self.slots.len();
            self.slots.push(Slot { link: Some(Link::Tcp { stream }), busy: None, gen: 0 });
            spawn_reader(reader, slot, 0, self.tx.clone());
        }
        Ok(())
    }

    /// Keep the fleet at strength: respawn dead subprocess workers (with
    /// a budget so a broken worker binary can't respawn forever); in TCP
    /// mode remote workers cannot be revived, so a fully dead fleet with
    /// work left is fatal.
    fn ensure_fleet(&mut self) {
        let outstanding = self.outstanding();
        if self.tcp_mode {
            if outstanding > 0 && self.slots.iter().all(|s| s.link.is_none()) {
                self.fatal = Some("every remote worker is gone (they cannot be respawned — \
                                   restart them and re-dispatch to resume)".into());
            }
            return;
        }
        let want = self.opts.workers.max(1).min(outstanding.max(1));
        loop {
            let live = self.slots.iter().filter(|s| s.link.is_some()).count();
            if live >= want {
                return;
            }
            if self.respawn_budget == 0 {
                if live == 0 && outstanding > 0 {
                    let detail = self
                        .cell_error
                        .iter()
                        .flatten()
                        .next_back()
                        .cloned()
                        .unwrap_or_else(|| "workers kept dying".into());
                    self.fatal = Some(format!(
                        "worker respawn budget exhausted with {outstanding} cell(s) \
                         outstanding ({detail})"
                    ));
                }
                return;
            }
            self.respawn_budget -= 1;
            let slot = match self.slots.iter().position(|s| s.link.is_none()) {
                Some(i) => i,
                None => {
                    self.slots.push(Slot { link: None, busy: None, gen: 0 });
                    self.slots.len() - 1
                }
            };
            if let Err(e) = self.spawn_child(slot) {
                eprintln!("star dispatch: spawning worker failed: {e:#}");
            }
        }
    }

    fn spawn_child(&mut self, slot: usize) -> crate::Result<()> {
        let bin = match &self.opts.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe().context("locating the worker binary")?,
        };
        let mut child = Command::new(&bin)
            .arg("worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning worker {}", bin.display()))?;
        let stdin = child.stdin.take().expect("stdin was piped");
        let stdout = child.stdout.take().expect("stdout was piped");
        self.slots[slot].gen += 1;
        let gen = self.slots[slot].gen;
        self.slots[slot].link = Some(Link::Child { child, stdin });
        spawn_reader(BufReader::new(stdout), slot, gen, self.tx.clone());
        Ok(())
    }

    /// Tear down a worker (idempotent). Its in-flight cell is re-queued
    /// unless another attempt is still running elsewhere.
    fn retire(&mut self, slot: usize, reason: &str) {
        let Some(link) = self.slots[slot].link.take() else { return };
        match link {
            Link::Child { mut child, stdin } => {
                drop(stdin);
                let _ = child.kill();
                let _ = child.wait();
            }
            Link::Tcp { stream } => {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
        self.slots[slot].gen += 1;
        self.report.worker_deaths += 1;
        if let Some(flight) = self.slots[slot].busy.take() {
            eprintln!(
                "star dispatch: worker {slot} lost ({reason}) holding cell {} [{}]",
                flight.cell, self.labels[flight.cell]
            );
            self.flights[flight.cell].retain(|&s| s != slot);
            if !self.done.contains_key(&flight.cell) && self.flights[flight.cell].is_empty() {
                self.requeue(flight.cell, reason);
            }
        } else {
            eprintln!("star dispatch: worker {slot} lost ({reason}) while idle");
        }
    }

    fn shutdown_fleet(&mut self) {
        let line = Request::shutdown_json().to_string_compact();
        for slot in &mut self.slots {
            let Some(link) = slot.link.take() else { continue };
            match link {
                Link::Child { mut child, mut stdin } => {
                    let _ = writeln!(stdin, "{line}");
                    let _ = stdin.flush();
                    drop(stdin);
                    let _ = child.kill();
                    let _ = child.wait();
                }
                Link::Tcp { stream } => {
                    // a polite shutdown only: the remote worker returns
                    // to its accept loop and outlives this dispatch
                    let mut s = &stream;
                    let _ = writeln!(s, "{line}");
                    let _ = s.flush();
                }
            }
        }
    }

    // -- scheduling -------------------------------------------------------

    fn promote_delayed(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= now {
                let (_, cell) = self.delayed.swap_remove(i);
                self.pending.push_back(cell);
            } else {
                i += 1;
            }
        }
    }

    fn idle_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.link.is_some() && s.busy.is_none())
    }

    fn issue_pending(&mut self) {
        while !self.pending.is_empty() {
            let Some(slot) = self.idle_slot() else { return };
            let Some(cell) = self.pending.pop_front() else { return };
            if self.done.contains_key(&cell) {
                continue;
            }
            self.issue(slot, cell, false);
        }
    }

    fn issue(&mut self, slot: usize, cell: usize, duplicate: bool) {
        let chaos: Option<Chaos> = if duplicate {
            None
        } else {
            self.opts.chaos.as_ref().and_then(|cfg| chaos::decide(cfg, cell, self.attempts[cell]))
        };
        match chaos {
            Some(Chaos::Die { .. }) => self.report.chaos_kills += 1,
            Some(Chaos::Stall { .. }) => self.report.chaos_stalls += 1,
            None => {}
        }
        if duplicate {
            self.report.straggler_reissues += 1;
            eprintln!(
                "star dispatch: re-issuing straggler cell {cell} [{}] to worker {slot}",
                self.labels[cell]
            );
        } else {
            self.attempts[cell] += 1;
        }
        let id = self.next_id;
        self.next_id += 1;
        let line = cell_request_json(id, cell, &self.sweep_json, chaos).to_string_compact();
        self.slots[slot].busy = Some(Flight { cell, issued: Instant::now(), duplicate });
        self.flights[cell].push(slot);
        let sent = match self.slots[slot].link.as_mut() {
            Some(Link::Child { stdin, .. }) => {
                writeln!(stdin, "{line}").and_then(|()| stdin.flush())
            }
            Some(Link::Tcp { stream }) => {
                writeln!(stream, "{line}").and_then(|()| stream.flush())
            }
            None => return,
        };
        if let Err(e) = sent {
            self.retire(slot, &format!("send failed: {e}"));
        }
    }

    fn requeue(&mut self, cell: usize, reason: &str) {
        if self.attempts[cell] > self.opts.retries {
            let last = self.cell_error[cell].clone().unwrap_or_else(|| reason.to_string());
            self.fatal = Some(format!(
                "cell {cell} [{}] failed after {} attempt(s): {last}",
                self.labels[cell], self.attempts[cell]
            ));
            return;
        }
        let delay = backoff_delay_ms(self.opts.backoff_ms, self.attempts[cell]);
        self.report.retries += 1;
        self.delayed.push((Instant::now() + Duration::from_millis(delay), cell));
    }

    /// Straggler re-issue (the fabric's speculative execution): once
    /// nothing is queued, duplicate any first-attempt cell running far
    /// past the p99 of completed cells onto an idle worker. First result
    /// wins; at most two attempts of a cell fly at once.
    fn maybe_duplicate(&mut self) {
        if !self.pending.is_empty() || !self.delayed.is_empty() || self.durations.len() < 3 {
            return;
        }
        let p99 = crate::stats::percentile(&self.durations, 99.0);
        let threshold = (self.opts.straggler_factor * p99).max(0.25);
        let now = Instant::now();
        let candidates: Vec<usize> = self
            .slots
            .iter()
            .filter_map(|s| s.busy.as_ref())
            .filter(|f| {
                !f.duplicate && now.duration_since(f.issued).as_secs_f64() > threshold
            })
            .map(|f| f.cell)
            .filter(|&c| !self.done.contains_key(&c) && self.flights[c].len() < 2)
            .collect();
        for cell in candidates {
            let Some(slot) = self.idle_slot() else { return };
            self.issue(slot, cell, true);
        }
    }

    // -- events -----------------------------------------------------------

    fn handle_event(&mut self, slot: usize, gen: u64, ev: Event) -> crate::Result<()> {
        let current = self.slots.get(slot).is_some_and(|s| s.gen == gen);
        match ev {
            Event::Gone => {
                if current {
                    self.retire(slot, "worker exited");
                }
            }
            Event::Msg(Response::Ready { .. }) => {}
            Event::Msg(Response::Done { done, .. }) => {
                if current {
                    if let Some(flight) = self.slots[slot].busy.take() {
                        self.flights[flight.cell].retain(|&s| s != slot);
                    }
                }
                // salvage the result even from a retired worker — it is
                // just as valid, and discarding it would waste the work
                self.record_done(done)?;
            }
            Event::Msg(Response::Failed { index, error, .. }) => {
                eprintln!(
                    "star dispatch: cell {index} failed on worker {slot}: {error}"
                );
                if !current {
                    return Ok(()); // its re-queue already happened at retire()
                }
                if let Some(flight) = self.slots[slot].busy.take() {
                    self.flights[flight.cell].retain(|&s| s != slot);
                }
                if index < self.cell_error.len() {
                    self.cell_error[index] = Some(error);
                    if !self.done.contains_key(&index) && self.flights[index].is_empty() {
                        self.requeue(index, "cell failed");
                    }
                }
            }
        }
        Ok(())
    }

    fn record_done(&mut self, done: CellDone) -> crate::Result<()> {
        if done.index >= self.report.cells {
            eprintln!("star dispatch: discarding result for unknown cell {}", done.index);
            return Ok(());
        }
        if self.done.contains_key(&done.index) {
            // the losing half of a straggler race (or a duplicate retry)
            return Ok(());
        }
        self.journal.append(&done)?;
        self.durations.push(done.elapsed_s);
        self.report.executed += 1;
        self.done.insert(done.index, done);
        Ok(())
    }

    fn check_deadlines(&mut self) {
        let now = Instant::now();
        let overdue: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.busy.as_ref().is_some_and(|f| {
                    now.duration_since(f.issued).as_secs_f64() > self.opts.deadline_s
                })
            })
            .map(|(i, _)| i)
            .collect();
        for slot in overdue {
            self.retire(slot, "cell deadline exceeded");
        }
    }
}

/// Pump a worker's response lines into the event channel. Unparseable
/// lines are warned about and skipped (a stray print must not look like
/// a dead worker); EOF or a read error reports the link gone.
fn spawn_reader(
    reader: impl BufRead + Send + 'static,
    slot: usize,
    gen: u64,
    tx: Sender<(usize, u64, Event)>,
) {
    std::thread::spawn(move || {
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            match Response::from_line(&line) {
                Ok(resp) => {
                    if tx.send((slot, gen, Event::Msg(resp))).is_err() {
                        return; // dispatch is over
                    }
                }
                Err(e) => {
                    eprintln!("star dispatch: ignoring non-protocol line from worker {slot}: {e:#}");
                }
            }
        }
        let _ = tx.send((slot, gen, Event::Gone));
    });
}

/// Exponential-backoff re-queue delay: `backoff_ms · 2^(attempt-1)`,
/// capped at 10 s. Saturating — a huge `--backoff-ms` (or a deep retry)
/// must clamp to the cap, not wrap around u64 into a near-zero delay
/// (`backoff_ms << shift` overflows silently in release builds).
fn backoff_delay_ms(backoff_ms: u64, attempts: usize) -> u64 {
    let shift = (attempts.max(1) - 1).min(16) as u32;
    backoff_ms.saturating_mul(1u64 << shift).min(10_000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_saturates_instead_of_wrapping() {
        // the overflow case: u64::MAX / 2 << 1 wraps to u64::MAX - 1,
        // and << 2 wraps to a tiny number — saturation must cap instead
        let huge = u64::MAX / 2;
        for attempts in 1..=20 {
            assert_eq!(backoff_delay_ms(huge, attempts), 10_000, "attempts={attempts}");
        }
        assert_eq!(backoff_delay_ms(huge, 0), 10_000, "attempt 0 is treated as the first");
    }

    #[test]
    fn backoff_doubles_per_attempt_up_to_the_cap() {
        assert_eq!(backoff_delay_ms(100, 0), 100);
        assert_eq!(backoff_delay_ms(100, 1), 100);
        assert_eq!(backoff_delay_ms(100, 2), 200);
        assert_eq!(backoff_delay_ms(100, 3), 400);
        assert_eq!(backoff_delay_ms(100, 8), 10_000, "cap engages");
        // the shift itself is clamped at 16, so even tiny bases stay sane
        assert_eq!(backoff_delay_ms(1, 64), 10_000.min(1u64 << 16).min(10_000));
        assert_eq!(backoff_delay_ms(0, 5), 0, "zero base means no delay at any depth");
    }
}
