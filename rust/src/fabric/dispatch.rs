//! `star dispatch` — the failure-tolerant driver of the sweep fabric.
//!
//! Scatters a sweep's cells across a fleet of workers (subprocesses it
//! spawns, or remote `star worker --listen` peers via `--connect`),
//! tolerating every failure mode a fleet exhibits:
//!
//! * **crash** — a worker dying (EOF on its link) re-queues every cell
//!   it held, with exponential backoff and a bounded retry budget; a
//!   remote worker's address is re-dialed on the same backoff schedule,
//!   so a restarted `star worker --listen` rejoins mid-dispatch;
//! * **hang** — a worker serving its current cell past `deadline_s` is
//!   retired and its cells re-queued;
//! * **straggle** — once the queue drains, a cell running far past the
//!   p99 of completed cells is *duplicated* onto the fastest idle
//!   worker; first result wins, the loser is discarded on arrival;
//! * **interruption** — a completed cell counts once its journal batch
//!   is group-committed (fsync'd); a killed dispatch resumes re-running
//!   only the cells whose batch never synced.
//!
//! Throughput comes from pipelining and weighting (DESIGN.md §14): up
//! to `--window` cells ride per worker (credit-based, capped by the
//! worker's announced capability — old workers stay at 1), a
//! dispatcher-side EWMA of per-cell service time shrinks a slow
//! worker's credits and steers new work to fast slots, and the pending
//! queue serves longest-expected-cost-first using the sweep's cost
//! hints so the big cells can't pile up at the tail.
//!
//! None of this can perturb results: cells are pure, rows come back
//! pre-rendered, and the merge is index-ordered — rows stream into the
//! artifact buffer the moment they become contiguous with the
//! completed prefix (a watermark, so merge memory is bounded by
//! scheduling skew, not sweep size) — so the artifacts are
//! byte-identical to a serial in-process `--threads 1` run no matter
//! how chaotic the execution was (pinned by `tests/fabric_dispatch.rs`
//! and the CI chaos-smoke step).

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::exp::CellRows;
use crate::jsonio::Json;
use crate::stats::SortedStream;

use super::chaos::{self, ChaosConfig};
use super::journal::Journal;
use super::protocol::{cell_request_json, CellDone, Chaos, Request, Response, SweepSpec};

pub struct DispatchOpts {
    /// fleet size in subprocess mode (ignored when `connect` is set)
    pub workers: usize,
    /// remote worker addresses — non-empty switches to fleet mode
    pub connect: Vec<String>,
    pub out_dir: PathBuf,
    /// journal path override; default `out_dir/<sweep>.journal.jsonl`
    pub journal: Option<PathBuf>,
    /// discard any existing journal instead of resuming from it
    pub fresh: bool,
    /// per-cell wall deadline before its worker is presumed hung
    pub deadline_s: f64,
    /// re-issues allowed per cell after its first attempt
    pub retries: usize,
    /// base re-queue delay, doubled per attempt (capped at 10 s)
    pub backoff_ms: u64,
    /// straggler threshold: this × p99 of completed cell durations
    pub straggler_factor: f64,
    pub chaos: Option<ChaosConfig>,
    /// worker executable; default: this binary (`current_exe`)
    pub worker_bin: Option<PathBuf>,
    /// max cells in flight per worker (credit window). Capped by each
    /// worker's announced capability, so a pre-pipelining worker keeps
    /// serving lock-step at 1.
    pub window: usize,
    /// journal group commit: fsync once this many records are buffered
    /// (`<= 1` restores per-cell durability syncs)
    pub commit_batch: usize,
    /// journal group commit: fsync a partial batch after this long
    pub commit_interval_ms: u64,
}

impl Default for DispatchOpts {
    fn default() -> Self {
        DispatchOpts {
            workers: 4,
            connect: Vec::new(),
            out_dir: PathBuf::from("results"),
            journal: None,
            fresh: false,
            deadline_s: 600.0,
            retries: 5,
            backoff_ms: 100,
            straggler_factor: 3.0,
            chaos: None,
            worker_bin: None,
            window: 4,
            commit_batch: 16,
            commit_interval_ms: 50,
        }
    }
}

/// What a dispatch did — the fabric's observability surface (tests
/// assert on it; the summary line prints it).
#[derive(Clone, Debug, Default)]
pub struct DispatchReport {
    pub cells: usize,
    /// recovered from the journal, not re-run
    pub resumed: usize,
    /// computed this run
    pub executed: usize,
    /// re-queues after a failure/crash/deadline
    pub retries: usize,
    pub straggler_reissues: usize,
    pub worker_deaths: usize,
    pub chaos_kills: usize,
    pub chaos_stalls: usize,
    /// issues that found the worker idle — a full protocol round-trip;
    /// refills behind an already-in-flight cell ride the pipeline free
    pub round_trips: usize,
    /// journal records appended this run (== executed)
    pub journal_appends: u64,
    /// journal data fsyncs this run (group commit: one per batch)
    pub journal_fsyncs: u64,
    /// successful re-dials of remote workers (fleet mode)
    pub worker_reconnects: usize,
    /// fresh results credited per worker slot — the balance report
    pub per_worker_cells: Vec<usize>,
    /// max out-of-order rows held by the watermark merge
    pub peak_merge_buffer: usize,
    pub wall_s: f64,
}

enum Link {
    Child { child: Child, stdin: ChildStdin },
    Tcp { stream: TcpStream },
}

struct Flight {
    id: u64,
    cell: usize,
    issued: Instant,
    duplicate: bool,
}

struct Slot {
    link: Option<Link>,
    /// cells in flight on this worker, oldest first (the worker serves
    /// them in arrival order)
    outstanding: VecDeque<Flight>,
    /// bumped on every (re)spawn/(re)dial so stale reader-thread events
    /// are recognizable — except `done` results, which are salvaged
    /// regardless of which incarnation produced them
    gen: u64,
    /// negotiated in-flight cap: min(--window, the worker's announced
    /// capability); 1 until this incarnation's `ready` line arrives
    window: usize,
    /// EWMA of per-cell service seconds, measured dispatcher-side
    /// (response-to-response), so stalls and protocol overhead count
    ewma_s: Option<f64>,
    /// when the head flight's service began (None while idle)
    service_mark: Option<Instant>,
    /// fresh results credited to this slot (the balance report)
    completed: usize,
    /// remote address (fleet mode) — kept so a lost link is re-dialed
    addr: Option<String>,
    /// when to try dialing `addr` next
    redial_at: Option<Instant>,
    /// consecutive failed dials (drives the re-dial backoff)
    dial_attempts: usize,
    /// times this slot's link was lost (a later successful dial is a
    /// *re*connect)
    losses: usize,
}

impl Slot {
    fn new(addr: Option<String>) -> Slot {
        let redial_at = addr.as_ref().map(|_| Instant::now());
        Slot {
            link: None,
            outstanding: VecDeque::new(),
            gen: 0,
            window: 1,
            ewma_s: None,
            service_mark: None,
            completed: 0,
            addr,
            redial_at,
            dial_attempts: 0,
            losses: 0,
        }
    }
}

enum Event {
    Msg(Response),
    Gone,
}

/// Run the sweep across the fleet; returns the report after the merged
/// artifacts are written.
pub fn dispatch(sweep: &SweepSpec, opts: &DispatchOpts) -> crate::Result<DispatchReport> {
    let t0 = Instant::now();
    let labels = sweep.cell_labels()?;
    let cells = labels.len();
    if cells == 0 {
        anyhow::bail!("sweep {} has no cells", sweep.name());
    }
    let cost = sweep.cost_hints()?;

    let journal_path = opts
        .journal
        .clone()
        .unwrap_or_else(|| opts.out_dir.join(format!("{}.journal.jsonl", sweep.name())));
    let (journal, recovered) =
        Journal::open(&journal_path, &sweep.fingerprint(), cells, opts.fresh)?;

    let (tx, rx) = std::sync::mpsc::channel();
    let mut d = Dispatcher {
        sweep_json: sweep.to_json(),
        opts,
        labels,
        cost,
        slots: Vec::new(),
        tx,
        rx,
        pending: Vec::new(),
        delayed: Vec::new(),
        attempts: vec![0; cells],
        flights: vec![Vec::new(); cells],
        done: vec![false; cells],
        done_count: 0,
        merged: Vec::with_capacity(cells),
        buffered: BTreeMap::new(),
        journal,
        commit_due: None,
        durations: SortedStream::default(),
        cell_error: vec![None; cells],
        report: DispatchReport { cells, ..Default::default() },
        next_id: 1,
        fatal: None,
        // covers the initial fleet plus one chaos kill per cell with
        // generous slack; only exhausted by a genuinely broken setup
        respawn_budget: opts.workers * 4 + 2 * cells + 8,
        tcp_mode: !opts.connect.is_empty(),
    };
    for rec in recovered {
        // the journal already refused duplicates and out-of-range cells
        d.done[rec.index] = true;
        d.done_count += 1;
        d.durations.push(rec.elapsed_s);
        d.admit_rows(rec.index, rec.rows);
    }
    d.report.resumed = d.done_count;
    d.pending = (0..cells).filter(|&i| !d.done[i]).collect();
    if d.report.resumed > 0 {
        eprintln!(
            "star dispatch: resuming {} — {} of {} cell(s) already journaled",
            journal_path.display(),
            d.report.resumed,
            cells
        );
    }

    let result = d.run();
    d.shutdown_fleet();
    result?;
    if let Some(msg) = d.fatal.take() {
        anyhow::bail!("dispatch of {} failed: {}", sweep.name(), msg);
    }

    // deterministic merge: the watermark has streamed every row into
    // `merged` in strict index order, identical to the serial sweep
    let rows = std::mem::take(&mut d.merged);
    assert_eq!(rows.len(), cells, "loop exits only when every cell is done");
    sweep.assemble(&rows, &opts.out_dir)?;

    d.report.journal_appends = d.report.executed as u64;
    d.report.journal_fsyncs = d.journal.fsyncs();
    d.report.per_worker_cells = d.slots.iter().map(|s| s.completed).collect();
    d.report.wall_s = t0.elapsed().as_secs_f64();
    let r = &d.report;
    eprintln!(
        "star dispatch: {} cell(s) ({} resumed, {} executed) — {} retr{}, \
         {} straggler re-issue(s), {} worker death(s), {} reconnect(s), \
         chaos {}k/{}s — window {}, {} round-trip(s), {} fsync(s), \
         balance {:?} — {:.1}s",
        r.cells,
        r.resumed,
        r.executed,
        r.retries,
        if r.retries == 1 { "y" } else { "ies" },
        r.straggler_reissues,
        r.worker_deaths,
        r.worker_reconnects,
        r.chaos_kills,
        r.chaos_stalls,
        opts.window.max(1),
        r.round_trips,
        r.journal_fsyncs,
        r.per_worker_cells,
        r.wall_s
    );
    Ok(d.report)
}

struct Dispatcher<'a> {
    sweep_json: Json,
    opts: &'a DispatchOpts,
    labels: Vec<String>,
    /// per-cell expected-cost hints (ratios only; drives queue order)
    cost: Vec<f64>,
    slots: Vec<Slot>,
    tx: Sender<(usize, u64, Event)>,
    rx: Receiver<(usize, u64, Event)>,
    /// cells awaiting issue — served longest-expected-cost-first
    pending: Vec<usize>,
    /// (due, cell) — backoff re-queues waiting to re-enter `pending`
    delayed: Vec<(Instant, usize)>,
    /// non-duplicate issues per cell (the retry budget's currency)
    attempts: Vec<usize>,
    /// cell -> slot ids with an attempt in flight
    flights: Vec<Vec<usize>>,
    done: Vec<bool>,
    done_count: usize,
    /// the contiguous completed prefix, already in artifact row order
    merged: Vec<CellRows>,
    /// completed rows still waiting for a lower index (watermark gap)
    buffered: BTreeMap<usize, CellRows>,
    journal: Journal,
    /// when a partially-filled journal batch must be committed
    commit_due: Option<Instant>,
    /// completed-cell compute seconds (feeds the straggler p99),
    /// incrementally sorted so the per-completion read is O(1)
    durations: SortedStream,
    cell_error: Vec<Option<String>>,
    report: DispatchReport,
    next_id: u64,
    fatal: Option<String>,
    respawn_budget: usize,
    tcp_mode: bool,
}

impl Dispatcher<'_> {
    fn run(&mut self) -> crate::Result<()> {
        if self.tcp_mode {
            for addr in self.opts.connect.clone() {
                self.slots.push(Slot::new(Some(addr.trim().to_string())));
            }
        }
        while self.done_count < self.report.cells && self.fatal.is_none() {
            self.ensure_fleet();
            if self.fatal.is_some() {
                break;
            }
            self.promote_delayed();
            self.issue_pending();
            self.maybe_duplicate();
            match self.rx.recv_timeout(Duration::from_millis(20)) {
                Ok((slot, gen, ev)) => {
                    self.handle_event(slot, gen, ev)?;
                    while let Ok((slot, gen, ev)) = self.rx.try_recv() {
                        self.handle_event(slot, gen, ev)?;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => unreachable!("we hold a sender"),
            }
            self.check_deadlines();
            self.maybe_commit()?;
        }
        // final group commit: whatever the last partial batch holds
        // becomes durable before the merge (even when bailing on fatal,
        // completed cells must survive for the resume)
        self.journal.flush()
    }

    fn outstanding_cells(&self) -> usize {
        self.report.cells - self.done_count
    }

    // -- fleet ------------------------------------------------------------

    /// Keep the fleet at strength: respawn dead subprocess workers, or
    /// (re-)dial remote addresses whose backoff has elapsed. Both paths
    /// share the respawn budget so a broken setup can't retry forever;
    /// exhausting it with work left and no live worker is fatal.
    fn ensure_fleet(&mut self) {
        let outstanding = self.outstanding_cells();
        if self.tcp_mode {
            let now = Instant::now();
            for slot in 0..self.slots.len() {
                if self.slots[slot].link.is_some() || self.respawn_budget == 0 {
                    continue;
                }
                let due = match self.slots[slot].redial_at {
                    Some(due) => due,
                    None => continue,
                };
                if due > now {
                    continue;
                }
                self.respawn_budget -= 1;
                self.dial(slot);
            }
            if outstanding > 0
                && self.slots.iter().all(|s| s.link.is_none())
                && self.respawn_budget == 0
            {
                self.fatal = Some(
                    "every remote worker is unreachable and the re-dial budget is \
                     exhausted (restart the workers and re-dispatch to resume)"
                        .into(),
                );
            }
            return;
        }
        let want = self.opts.workers.max(1).min(outstanding.max(1));
        loop {
            let live = self.slots.iter().filter(|s| s.link.is_some()).count();
            if live >= want {
                return;
            }
            if self.respawn_budget == 0 {
                if live == 0 && outstanding > 0 {
                    let detail = self
                        .cell_error
                        .iter()
                        .flatten()
                        .next_back()
                        .cloned()
                        .unwrap_or_else(|| "workers kept dying".into());
                    self.fatal = Some(format!(
                        "worker respawn budget exhausted with {outstanding} cell(s) \
                         outstanding ({detail})"
                    ));
                }
                return;
            }
            self.respawn_budget -= 1;
            let slot = match self.slots.iter().position(|s| s.link.is_none()) {
                Some(i) => i,
                None => {
                    self.slots.push(Slot::new(None));
                    self.slots.len() - 1
                }
            };
            if let Err(e) = self.spawn_child(slot) {
                eprintln!("star dispatch: spawning worker failed: {e:#}");
            }
        }
    }

    /// One dial attempt at a remote slot's address. Failure schedules
    /// the next attempt on the retry backoff curve (capped tighter than
    /// cell re-queues: a fleet should reform in seconds).
    fn dial(&mut self, slot: usize) {
        let addr = self.slots[slot].addr.clone().expect("tcp slots carry an address");
        let attempt = self.slots[slot].dial_attempts;
        self.slots[slot].dial_attempts += 1;
        let connected = try_dial(&addr).and_then(|stream| {
            let reader =
                BufReader::new(stream.try_clone().context("cloning worker stream for reads")?);
            Ok((stream, reader))
        });
        match connected {
            Ok((stream, reader)) => {
                let rejoined = attempt > 0 || self.slots[slot].losses > 0;
                let s = &mut self.slots[slot];
                s.gen += 1;
                s.window = 1; // until this incarnation's ready line
                s.link = Some(Link::Tcp { stream });
                s.redial_at = None;
                s.dial_attempts = 0;
                let gen = s.gen;
                if rejoined {
                    self.report.worker_reconnects += 1;
                    eprintln!(
                        "star dispatch: worker {slot} re-joined at {addr} \
                         (dial attempt {})",
                        attempt + 1
                    );
                }
                spawn_reader(reader, slot, gen, self.tx.clone());
            }
            Err(e) => {
                let delay =
                    backoff_delay_ms(self.opts.backoff_ms, self.slots[slot].dial_attempts)
                        .min(2_000);
                if attempt == 0 {
                    eprintln!(
                        "star dispatch: worker {addr} unreachable ({e:#}); re-dialing"
                    );
                }
                self.slots[slot].redial_at =
                    Some(Instant::now() + Duration::from_millis(delay));
            }
        }
    }

    fn spawn_child(&mut self, slot: usize) -> crate::Result<()> {
        let bin = match &self.opts.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe().context("locating the worker binary")?,
        };
        let mut child = Command::new(&bin)
            .arg("worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning worker {}", bin.display()))?;
        let stdin = child.stdin.take().expect("stdin was piped");
        let stdout = child.stdout.take().expect("stdout was piped");
        self.slots[slot].gen += 1;
        self.slots[slot].window = 1; // until this incarnation's ready line
        let gen = self.slots[slot].gen;
        self.slots[slot].link = Some(Link::Child { child, stdin });
        spawn_reader(BufReader::new(stdout), slot, gen, self.tx.clone());
        Ok(())
    }

    /// Tear down a worker (idempotent). Every cell it held in its
    /// pipeline is re-queued unless another attempt is still running
    /// elsewhere — with credit windows a death can cost several cells,
    /// and all of them must re-run. A remote slot schedules a re-dial.
    fn retire(&mut self, slot: usize, reason: &str) {
        let Some(link) = self.slots[slot].link.take() else { return };
        match link {
            Link::Child { mut child, stdin } => {
                drop(stdin);
                let _ = child.kill();
                let _ = child.wait();
            }
            Link::Tcp { stream } => {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
        self.slots[slot].gen += 1;
        self.slots[slot].service_mark = None;
        self.slots[slot].losses += 1;
        self.report.worker_deaths += 1;
        let flights: Vec<Flight> = self.slots[slot].outstanding.drain(..).collect();
        if flights.is_empty() {
            eprintln!("star dispatch: worker {slot} lost ({reason}) while idle");
        }
        for flight in flights {
            eprintln!(
                "star dispatch: worker {slot} lost ({reason}) holding cell {} [{}]",
                flight.cell, self.labels[flight.cell]
            );
            self.flights[flight.cell].retain(|&s| s != slot);
            if !self.done[flight.cell] && self.flights[flight.cell].is_empty() {
                self.requeue(flight.cell, reason);
            }
        }
        if self.tcp_mode && self.slots[slot].addr.is_some() {
            let delay = backoff_delay_ms(self.opts.backoff_ms, 1).min(2_000);
            self.slots[slot].redial_at = Some(Instant::now() + Duration::from_millis(delay));
        }
    }

    fn shutdown_fleet(&mut self) {
        let line = Request::shutdown_json().to_string_compact();
        for slot in &mut self.slots {
            let Some(link) = slot.link.take() else { continue };
            match link {
                Link::Child { mut child, mut stdin } => {
                    let _ = writeln!(stdin, "{line}");
                    let _ = stdin.flush();
                    drop(stdin);
                    let _ = child.kill();
                    let _ = child.wait();
                }
                Link::Tcp { stream } => {
                    // a polite shutdown only: the remote worker returns
                    // to its accept loop and outlives this dispatch
                    let mut s = &stream;
                    let _ = writeln!(s, "{line}");
                    let _ = s.flush();
                }
            }
        }
    }

    // -- scheduling -------------------------------------------------------

    fn promote_delayed(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= now {
                let (_, cell) = self.delayed.swap_remove(i);
                self.pending.push(cell);
            } else {
                i += 1;
            }
        }
    }

    /// Expected next-cell service seconds for a slot. The EWMA is the
    /// base; a head flight already running *longer* than it pushes the
    /// estimate up, so a freshly stalled worker looks slow immediately
    /// instead of after it recovers. `None` means "no evidence yet" —
    /// treated optimistically by the schedulers.
    fn est(&self, slot: &Slot) -> Option<f64> {
        let head = slot.service_mark.map(|m| m.elapsed().as_secs_f64());
        match (slot.ewma_s, head) {
            (Some(e), Some(h)) => Some(e.max(h)),
            (Some(e), None) => Some(e),
            (None, h) => h, // first cell still in service: all we know
        }
    }

    /// The fleet's best (smallest) service estimate among live slots.
    fn fleet_best_est(&self) -> Option<f64> {
        self.slots
            .iter()
            .filter(|s| s.link.is_some())
            .filter_map(|s| self.est(s))
            .min_by(|a, b| a.partial_cmp(b).expect("service estimates are finite"))
    }

    /// Credits for a slot: how many cells may be in flight on it. The
    /// negotiated window, scaled down by how much slower this worker is
    /// than the fleet's best (a worker 4× slower gets ¼ the credits),
    /// floored at 1 so every live worker keeps contributing.
    fn credits(&self, slot: &Slot) -> usize {
        let w = slot.window.max(1);
        let (Some(e), Some(best)) = (self.est(slot), self.fleet_best_est()) else {
            return w;
        };
        if e <= 0.0 || best <= 0.0 {
            return w;
        }
        ((w as f64 * (best / e)).round() as usize).clamp(1, w)
    }

    /// Where the next pending cell goes: the live slot with spare
    /// credits holding the fewest cells, ties broken by the faster
    /// estimate (unknown = optimistic 0), then lowest index.
    fn best_slot(&self) -> Option<usize> {
        let mut best: Option<(usize, usize, f64)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            if s.link.is_none() || s.outstanding.len() >= self.credits(s) {
                continue;
            }
            let e = self.est(s).unwrap_or(0.0);
            let k = s.outstanding.len();
            let better = match best {
                None => true,
                Some((_, bk, be)) => k < bk || (k == bk && e < be),
            };
            if better {
                best = Some((i, k, e));
            }
        }
        best.map(|(i, _, _)| i)
    }

    /// Where a straggler duplicate goes: the *fastest* idle slot — the
    /// whole point of speculative re-issue is finishing before the
    /// original, so the backup must not land on another slow worker.
    fn fastest_idle_slot(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            if s.link.is_none() || !s.outstanding.is_empty() {
                continue;
            }
            let e = self.est(s).unwrap_or(0.0);
            let better = match best {
                None => true,
                Some((_, be)) => e < be,
            };
            if better {
                best = Some((i, e));
            }
        }
        best.map(|(i, _)| i)
    }

    fn issue_pending(&mut self) {
        loop {
            if self.pending.is_empty() {
                return;
            }
            let Some(slot) = self.best_slot() else { return };
            let Some(cell) = self.pop_pending() else { return };
            self.issue(slot, cell, false);
        }
    }

    /// Longest-expected-cost-first: the big cells go out early so the
    /// makespan doesn't end on one giant cell issued last. Ties break
    /// on the lowest index (stable). Cells completed while waiting
    /// (a straggler duplicate won) are skipped.
    fn pop_pending(&mut self) -> Option<usize> {
        loop {
            let mut best: Option<(usize, usize, f64)> = None;
            for (pos, &cell) in self.pending.iter().enumerate() {
                let c = self.cost.get(cell).copied().unwrap_or(1.0);
                let better = match best {
                    None => true,
                    Some((_, bcell, bc)) => c > bc || (c == bc && cell < bcell),
                };
                if better {
                    best = Some((pos, cell, c));
                }
            }
            let (pos, cell, _) = best?;
            self.pending.swap_remove(pos);
            if !self.done[cell] {
                return Some(cell);
            }
        }
    }

    fn issue(&mut self, slot: usize, cell: usize, duplicate: bool) {
        if self.slots[slot].link.is_none() {
            return; // schedulers never pick a linkless slot
        }
        let mut chaos: Option<Chaos> = if duplicate {
            None
        } else {
            self.opts.chaos.as_ref().and_then(|cfg| chaos::decide(cfg, cell, self.attempts[cell]))
        };
        if chaos.is_none() {
            // the slow-worker knob follows the slot, not the cell: every
            // request this worker serves stalls (a slow machine)
            chaos = self.opts.chaos.as_ref().and_then(|cfg| chaos::slow_stall(cfg, slot));
        }
        match chaos {
            Some(Chaos::Die { .. }) => self.report.chaos_kills += 1,
            Some(Chaos::Stall { .. }) => self.report.chaos_stalls += 1,
            None => {}
        }
        if duplicate {
            self.report.straggler_reissues += 1;
            eprintln!(
                "star dispatch: re-issuing straggler cell {cell} [{}] to worker {slot}",
                self.labels[cell]
            );
        } else {
            self.attempts[cell] += 1;
        }
        let id = self.next_id;
        self.next_id += 1;
        let line = cell_request_json(id, cell, &self.sweep_json, chaos).to_string_compact();
        if self.slots[slot].outstanding.is_empty() {
            // nothing was in flight: this issue pays a full round-trip
            // (request out, response back, worker idle in between);
            // pipelined refills don't
            self.report.round_trips += 1;
            self.slots[slot].service_mark = Some(Instant::now());
        }
        self.slots[slot].outstanding.push_back(Flight {
            id,
            cell,
            issued: Instant::now(),
            duplicate,
        });
        self.flights[cell].push(slot);
        let sent = match self.slots[slot].link.as_mut() {
            Some(Link::Child { stdin, .. }) => {
                writeln!(stdin, "{line}").and_then(|()| stdin.flush())
            }
            Some(Link::Tcp { stream }) => {
                writeln!(stream, "{line}").and_then(|()| stream.flush())
            }
            None => unreachable!("checked above"),
        };
        if let Err(e) = sent {
            self.retire(slot, &format!("send failed: {e}"));
        }
    }

    fn requeue(&mut self, cell: usize, reason: &str) {
        if self.attempts[cell] > self.opts.retries {
            let last = self.cell_error[cell].clone().unwrap_or_else(|| reason.to_string());
            self.fatal = Some(format!(
                "cell {cell} [{}] failed after {} attempt(s): {last}",
                self.labels[cell], self.attempts[cell]
            ));
            return;
        }
        let delay = backoff_delay_ms(self.opts.backoff_ms, self.attempts[cell]);
        self.report.retries += 1;
        self.delayed.push((Instant::now() + Duration::from_millis(delay), cell));
    }

    /// Straggler re-issue (the fabric's speculative execution): once
    /// nothing is queued, duplicate any first-attempt cell running far
    /// past the p99 of completed cells onto the fastest idle worker.
    /// First result wins; at most two attempts of a cell fly at once.
    /// A cell stuck deep in a stalled worker's pipeline counts too —
    /// its wait *is* the straggle.
    fn maybe_duplicate(&mut self) {
        if !self.pending.is_empty() || !self.delayed.is_empty() || self.durations.len() < 3 {
            return;
        }
        let p99 = self.durations.percentile(99.0);
        let threshold = (self.opts.straggler_factor * p99).max(0.25);
        let now = Instant::now();
        let candidates: Vec<usize> = self
            .slots
            .iter()
            .flat_map(|s| s.outstanding.iter())
            .filter(|f| {
                !f.duplicate && now.duration_since(f.issued).as_secs_f64() > threshold
            })
            .map(|f| f.cell)
            .filter(|&c| !self.done[c] && self.flights[c].len() < 2)
            .collect();
        for cell in candidates {
            let Some(slot) = self.fastest_idle_slot() else { return };
            self.issue(slot, cell, true);
        }
    }

    // -- events -----------------------------------------------------------

    fn handle_event(&mut self, slot: usize, gen: u64, ev: Event) -> crate::Result<()> {
        let current = self.slots.get(slot).is_some_and(|s| s.gen == gen);
        match ev {
            Event::Gone => {
                if current {
                    self.retire(slot, "worker exited");
                }
            }
            Event::Msg(Response::Ready { window, .. }) => {
                if current {
                    // credit negotiation: our --window, capped at what
                    // the worker announced (1 for pre-pipelining ones)
                    self.slots[slot].window = self.opts.window.max(1).min(window.max(1));
                }
            }
            Event::Msg(Response::Done { id, done }) => {
                // salvage the result even from a retired worker — it is
                // just as valid, and discarding it would waste the work
                let fresh = self.record_done(done)?;
                if current && self.complete_flight(slot, id) && fresh {
                    self.slots[slot].completed += 1;
                }
            }
            Event::Msg(Response::Failed { id, index, error }) => {
                eprintln!("star dispatch: cell {index} failed on worker {slot}: {error}");
                if !current {
                    return Ok(()); // its re-queue already happened at retire()
                }
                self.complete_flight(slot, id);
                if index < self.cell_error.len() {
                    self.cell_error[index] = Some(error);
                    if !self.done[index] && self.flights[index].is_empty() {
                        self.requeue(index, "cell failed");
                    }
                }
            }
        }
        Ok(())
    }

    /// Remove flight `id` from a slot's pipeline and update the slot's
    /// service clock + EWMA. Timing is response-to-response on the
    /// dispatcher's clock — not the worker-reported `elapsed_s` — so
    /// chaos stalls, queueing, and protocol overhead all count against
    /// a worker's throughput estimate. Returns whether the flight was
    /// found (stale responses from a retired incarnation are not).
    fn complete_flight(&mut self, slot: usize, id: u64) -> bool {
        let Some(pos) = self.slots[slot].outstanding.iter().position(|f| f.id == id) else {
            return false;
        };
        let flight = self.slots[slot].outstanding.remove(pos).expect("position exists");
        self.flights[flight.cell].retain(|&s| s != slot);
        let now = Instant::now();
        let s = &mut self.slots[slot];
        if let Some(mark) = s.service_mark {
            let service = now.duration_since(mark).as_secs_f64();
            s.ewma_s = Some(match s.ewma_s {
                Some(prev) => 0.7 * prev + 0.3 * service,
                None => service,
            });
        }
        s.service_mark = if s.outstanding.is_empty() { None } else { Some(now) };
        true
    }

    /// Record a completed cell: journal it (group-committed), feed the
    /// straggler stats, and stream its rows past the merge watermark.
    /// Returns false for a duplicate (the losing half of a straggler
    /// race) or an out-of-range index — both discarded.
    fn record_done(&mut self, done: CellDone) -> crate::Result<bool> {
        if done.index >= self.report.cells {
            eprintln!("star dispatch: discarding result for unknown cell {}", done.index);
            return Ok(false);
        }
        if self.done[done.index] {
            return Ok(false);
        }
        self.journal.append(&done);
        if self.opts.commit_batch <= 1 || self.journal.pending() >= self.opts.commit_batch {
            self.commit()?;
        } else if self.commit_due.is_none() {
            self.commit_due = Some(
                Instant::now() + Duration::from_millis(self.opts.commit_interval_ms.max(1)),
            );
        }
        self.durations.push(done.elapsed_s);
        self.report.executed += 1;
        self.done[done.index] = true;
        self.done_count += 1;
        self.admit_rows(done.index, done.rows);
        Ok(true)
    }

    /// Watermark merge: a row joins the merged prefix the moment it is
    /// contiguous with it; only out-of-order rows wait in the buffer.
    /// Merge memory is therefore bounded by scheduling skew (at most
    /// the fleet's total in-flight window), not by the sweep size.
    fn admit_rows(&mut self, index: usize, rows: CellRows) {
        if index == self.merged.len() {
            self.merged.push(rows);
            while let Some(next) = self.buffered.remove(&self.merged.len()) {
                self.merged.push(next);
            }
        } else {
            self.buffered.insert(index, rows);
        }
        self.report.peak_merge_buffer = self.report.peak_merge_buffer.max(self.buffered.len());
    }

    fn commit(&mut self) -> crate::Result<()> {
        self.commit_due = None;
        self.journal.flush()
    }

    /// Commit a partial batch whose flush interval has elapsed — bounds
    /// how long a completed cell can sit non-durable when the sweep
    /// finishes slower than the batch fills.
    fn maybe_commit(&mut self) -> crate::Result<()> {
        if self.commit_due.is_some_and(|due| due <= Instant::now()) {
            self.commit()?;
        }
        Ok(())
    }

    /// A worker whose *current* cell (head of its pipeline, measured by
    /// the service clock) exceeds the deadline is presumed hung. Queued
    /// cells behind it don't count — they aren't being served yet.
    fn check_deadlines(&mut self) {
        let now = Instant::now();
        let overdue: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.service_mark.is_some_and(|m| {
                    now.duration_since(m).as_secs_f64() > self.opts.deadline_s
                })
            })
            .map(|(i, _)| i)
            .collect();
        for slot in overdue {
            self.retire(slot, "cell deadline exceeded");
        }
    }
}

fn try_dial(addr: &str) -> crate::Result<TcpStream> {
    let sa = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving worker address {addr:?}"))?
        .next()
        .with_context(|| format!("worker address {addr:?} resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&sa, Duration::from_millis(500))
        .with_context(|| format!("connecting to worker {addr}"))?;
    Ok(stream)
}

/// Pump a worker's response lines into the event channel. Unparseable
/// lines are warned about and skipped (a stray print must not look like
/// a dead worker); EOF or a read error reports the link gone.
fn spawn_reader(
    reader: impl BufRead + Send + 'static,
    slot: usize,
    gen: u64,
    tx: Sender<(usize, u64, Event)>,
) {
    std::thread::spawn(move || {
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            match Response::from_line(&line) {
                Ok(resp) => {
                    if tx.send((slot, gen, Event::Msg(resp))).is_err() {
                        return; // dispatch is over
                    }
                }
                Err(e) => {
                    eprintln!("star dispatch: ignoring non-protocol line from worker {slot}: {e:#}");
                }
            }
        }
        let _ = tx.send((slot, gen, Event::Gone));
    });
}

/// Exponential-backoff re-queue delay: `backoff_ms · 2^(attempt-1)`,
/// capped at 10 s. Saturating — a huge `--backoff-ms` (or a deep retry)
/// must clamp to the cap, not wrap around u64 into a near-zero delay
/// (`backoff_ms << shift` overflows silently in release builds).
fn backoff_delay_ms(backoff_ms: u64, attempts: usize) -> u64 {
    let shift = (attempts.max(1) - 1).min(16) as u32;
    backoff_ms.saturating_mul(1u64 << shift).min(10_000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_saturates_instead_of_wrapping() {
        // the overflow case: u64::MAX / 2 << 1 wraps to u64::MAX - 1,
        // and << 2 wraps to a tiny number — saturation must cap instead
        let huge = u64::MAX / 2;
        for attempts in 1..=20 {
            assert_eq!(backoff_delay_ms(huge, attempts), 10_000, "attempts={attempts}");
        }
        assert_eq!(backoff_delay_ms(huge, 0), 10_000, "attempt 0 is treated as the first");
    }

    #[test]
    fn backoff_doubles_per_attempt_up_to_the_cap() {
        assert_eq!(backoff_delay_ms(100, 0), 100);
        assert_eq!(backoff_delay_ms(100, 1), 100);
        assert_eq!(backoff_delay_ms(100, 2), 200);
        assert_eq!(backoff_delay_ms(100, 3), 400);
        assert_eq!(backoff_delay_ms(100, 8), 10_000, "cap engages");
        // the shift itself is clamped at 16, so even tiny bases stay sane
        assert_eq!(backoff_delay_ms(1, 64), 10_000.min(1u64 << 16).min(10_000));
        assert_eq!(backoff_delay_ms(0, 5), 0, "zero base means no delay at any depth");
    }
}
