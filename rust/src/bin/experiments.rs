//! Experiment harness entrypoint: regenerates every table and figure of
//! the paper's evaluation (DESIGN.md §4 maps ids to sections).
//!
//! ```text
//! experiments <id> [--jobs N] [--seed S] [--out results] [--quick]
//!             [--fault-rate R] [--fault-seed S] [--threads N] [--smoke]
//!   id ∈ { fig1..fig14, tab1, fig16..fig29, resilience, scale,
//!          fabric-bench, all }
//! ```
//!
//! `--fault-rate` injects a seeded failure plan (worker/PS crashes,
//! server outages, degradation windows — DESIGN.md §7) into every run;
//! the `resilience` experiment sweeps its own rates and ignores it, and
//! `scale` (the cluster-scale driver-throughput benchmark,
//! `BENCH_driver.json`) always runs with faults on. `--smoke` is an
//! alias for `--quick` (the `scale --smoke` CI step's spelling).
//! `--threads N` caps the parallel sweep harness (`exp::sweep`); 0 or
//! absent = all available cores. Output is byte-identical at any value.

use star::cli::Args;
use star::exp::{dispatch, ExpCtx};

fn main() {
    let args = Args::parse_env();
    let Some(id) = args.subcommand() else {
        eprintln!(
            "usage: experiments <figN|tab1|resilience|scale|fabric-bench|all> [--jobs N] [--seed S] \
             [--out DIR] [--quick|--smoke] [--fault-rate R] [--fault-seed S] [--threads N]\n\
             experiment index: DESIGN.md §4"
        );
        std::process::exit(2);
    };
    // hidden passthrough: `experiments worker` serves sweep cells over
    // stdio, so a dispatch whose --worker-bin defaults to current_exe
    // (e.g. `experiments fabric-bench`) can spawn *this* binary as its
    // subprocess fleet, exactly like `star worker`
    if id == "worker" {
        if let Err(e) = star::fabric::worker::serve_stdio() {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    let run = || -> star::Result<()> {
        args.check_known(&[
            "jobs", "seed", "out", "quick", "smoke", "fault-rate", "fault-seed", "threads",
        ])?;
        let ctx = ExpCtx {
            jobs: args.usize_or("jobs", 120)?,
            seed: args.u64_or("seed", 0)?,
            out_dir: args.str_or("out", "results").into(),
            quick: args.flag("quick") || args.flag("smoke"),
            fault_rate: args.f64_or("fault-rate", 0.0)?,
            fault_seed: args.u64_or("fault-seed", 0)?,
            threads: star::exp::sweep::resolve_threads(args.usize_or("threads", 0)?),
        };
        let t0 = std::time::Instant::now();
        dispatch(id, &ctx)?;
        eprintln!("[exp] {id} done in {:.1}s", t0.elapsed().as_secs_f64());
        Ok(())
    };
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
