//! Deterministic PRNG + distributions substrate.
//!
//! The `rand` crate family is unavailable in this offline image, so the
//! simulator carries its own generator: PCG64 (O'Neill 2014, XSL-RR
//! variant) — splittable via `fork`, with the distributions the cluster
//! model needs (normal, lognormal, exponential, Poisson, Pareto, Zipf).
//! Everything is seeded and reproducible; experiment output is a pure
//! function of the seed.

/// PCG64 XSL-RR generator. 128-bit state/increment, 64-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// cached second normal deviate (Box–Muller produces pairs)
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Create from a seed; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Rng { state: 0, inc, spare_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience single-argument constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (stable: depends only on the
    /// parent's current state and the tag).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64();
        Rng::new(s ^ tag.rotate_left(17), tag.wrapping_add(0x9e37_79b9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive (unbiased via rejection).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            return self.next_u64() as i64; // full range
        }
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as i64;
            }
        }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // avoid log(0)
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// LogNormal with given log-space mean and sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson (Knuth for small mean, normal approx for large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 30.0 {
            let v = self.normal_with(mean, mean.sqrt()).round();
            return v.max(0.0) as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Pareto with scale x_m and shape alpha (heavy-tailed durations).
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        x_m / u.powf(1.0 / alpha)
    }

    /// Zipf over {0, .., n-1} with exponent s > 1 (token sampling for the
    /// synthetic corpus): Devroye's rejection method for the (truncated)
    /// zeta distribution (Non-Uniform Random Variate Generation, X.6.1).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        let s = s.max(1.001);
        let b = 2f64.powf(s - 1.0);
        loop {
            let u = loop {
                let u = self.f64();
                if u > 1e-300 {
                    break u;
                }
            };
            let v = self.f64();
            let x = u.powf(-1.0 / (s - 1.0)).floor();
            if !(1.0..=n as f64).contains(&x) {
                continue; // truncate to [1, n]
            }
            let t = (1.0 + 1.0 / x).powf(s - 1.0);
            if v * x * (t - 1.0) / (b - 1.0) <= t / b {
                return x as usize - 1;
            }
        }
    }

    /// Pick a random element index by weight.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize(0, i);
            items.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

/// AR(1) process: x' = rho*x + sigma*eps; used for time-varying server
/// background load and bandwidth capacity (paper [31], DESIGN.md §6).
#[derive(Clone, Debug)]
pub struct Ar1 {
    pub rho: f64,
    pub sigma: f64,
    pub value: f64,
}

impl Ar1 {
    pub fn new(rho: f64, sigma: f64, init: f64) -> Self {
        Ar1 { rho, sigma, value: init }
    }

    pub fn step(&mut self, rng: &mut Rng) -> f64 {
        self.value = self.rho * self.value + self.sigma * rng.normal();
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn int_bounds_inclusive() {
        let mut r = Rng::seeded(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.int(4, 12);
            assert!((4..=12).contains(&v));
            seen_lo |= v == 4;
            seen_hi |= v == 12;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::seeded(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(4.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 4.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn lognormal_heavy_tail_positive() {
        let mut r = Rng::seeded(13);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 2.0) > 0.0);
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::seeded(17);
        let n = 20_000;
        let mut counts = vec![0usize; 50];
        for _ in 0..n {
            let v = r.zipf(50, 1.2);
            assert!(v < 50);
            counts[v] += 1;
        }
        assert!(counts[0] > counts[10]);
        // but NOT degenerate: the tail must carry real mass (this guards
        // against the s>1 inverse-CDF bug that returned rank 0 always)
        let tail: usize = counts[5..].iter().sum();
        assert!(tail > n / 5, "tail mass {tail}");
        // empirical entropy well above zero
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n as f64;
                -p * p.ln()
            })
            .sum();
        assert!(h > 1.5, "entropy {h}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::seeded(19);
        let w = [0.0, 1.0, 3.0];
        let mut c = [0usize; 3];
        for _ in 0..6000 {
            c[r.weighted_index(&w)] += 1;
        }
        assert_eq!(c[0], 0);
        assert!(c[2] > 2 * c[1]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::seeded(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn ar1_is_mean_reverting() {
        let mut rng = Rng::seeded(31);
        let mut p = Ar1::new(0.9, 0.1, 5.0);
        for _ in 0..200 {
            p.step(&mut rng);
        }
        assert!(p.value.abs() < 3.0);
    }
}
