//! Tiny CLI substrate (clap is unavailable offline): positional
//! subcommands + `--key value` / `--flag` options with typed getters.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: `prog [subcommand] [--key value | --flag] ...`
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// The `i`-th positional argument (0 = the subcommand). Multi-word
    /// subcommands (`star scenario run FILE`) read their operands here.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Error if unknown options were passed (catch typos in experiments).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig18 --seed 7 --arch ps --verbose");
        assert_eq!(a.subcommand(), Some("fig18"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.str_or("arch", "ar"), "ps");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positional_operands() {
        let a = parse("scenario run examples/x.json --quick");
        assert_eq!(a.subcommand(), Some("scenario"));
        assert_eq!(a.pos(1), Some("run"));
        assert_eq!(a.pos(2), Some("examples/x.json"));
        assert_eq!(a.pos(3), None);
        assert!(a.flag("quick"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --jobs=350 --out=results/x.csv");
        assert_eq!(a.usize_or("jobs", 0).unwrap(), 350);
        assert_eq!(a.get("out"), Some("results/x.csv"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --seed abc");
        assert!(a.u64_or("seed", 0).is_err());
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn check_known_catches_typos() {
        let a = parse("x --sede 3");
        assert!(a.check_known(&["seed"]).is_err());
        assert!(a.check_known(&["sede"]).is_ok());
    }
}
