//! Resource-aware straggler prevention (§IV-D).
//!
//! * [`equalize_group`] — within a gradient group, faster peers need not
//!   finish before the slowest: deprive their CPU/bandwidth so they
//!   complete exactly at the group deadline (free resources, zero TTA
//!   cost).
//! * [`sensitivity_deprivation`] — when that is not enough, spread the
//!   remaining shortfall over co-located tasks inversely to
//!   sensitivity × current accuracy improvement: R^k · (1/(S·A)) / Σ 1/(S·A).
//! * acceptance test (S_w < S_o) is evaluated by the caller via the
//!   iteration-time model; on failure STAR falls back to the next-ranked
//!   mode (see `star`).
//! * [`CommTree`] — §IV-D2b: amortize worker↔PS (or child↔parent)
//!   communication over a latency-layered aggregation tree.
//! * high-load task placement balancing lives in `trace::place_job`.

/// Deprivable headroom of one co-located fast worker: the cap multiplier
/// that makes its predicted completion hit the group deadline. With
/// iteration time T(c) = fixed + var/c (c = resource share), slowing from
/// T_now to T_target allows cap = var / (T_target − fixed) / share_now.
pub fn equalize_cap(t_now: f64, t_target: f64, fixed_s: f64) -> f64 {
    debug_assert!(t_target >= t_now - 1e-12);
    let var_now = (t_now - fixed_s).max(1e-9);
    let var_target = (t_target - fixed_s).max(var_now);
    (var_now / var_target).clamp(0.05, 1.0)
}

/// Equalize a gradient group (§IV-D1): returns per-member resource-cap
/// multipliers so each member lands on the group's slowest completion.
/// `times[i]` = predicted completion, `fixed_s[i]` = the share-independent
/// part (GPU compute).
pub fn equalize_group(times: &[f64], fixed_s: &[f64]) -> Vec<f64> {
    let mut out = Vec::new();
    equalize_group_into(times, fixed_s, &mut out);
    out
}

/// In-place [`equalize_group`] for the per-round hot path: writes the cap
/// multipliers into `out` (cleared first), so steady-state decisions reuse
/// the buffer instead of allocating per group.
pub fn equalize_group_into(times: &[f64], fixed_s: &[f64], out: &mut Vec<f64>) {
    assert_eq!(times.len(), fixed_s.len());
    out.clear();
    let t_max = times.iter().cloned().fold(0.0, f64::max);
    out.extend(times.iter().zip(fixed_s).map(|(&t, &f)| equalize_cap(t, t_max, f)));
}

/// A co-located task's deprivation inputs (§IV-D1).
#[derive(Clone, Copy, Debug)]
pub struct Victim {
    /// sensitivity S^k of its job to this resource (Π (TTA_j − TTA)/TTA)
    pub sensitivity: f64,
    /// current accuracy improvement A (progress::improvement_rate)
    pub improvement: f64,
    /// resource currently granted (upper bound on what can be taken)
    pub granted: f64,
    /// floor that must remain (keep the task alive)
    pub floor: f64,
}

/// Split a shortfall `needed` across victims ∝ 1/(S·A), water-filling the
/// per-victim headroom (granted − floor). Returns per-victim amounts;
/// their sum may be < needed if headroom runs out.
pub fn sensitivity_deprivation(needed: f64, victims: &[Victim]) -> Vec<f64> {
    let n = victims.len();
    let mut take = vec![0.0; n];
    if n == 0 || needed <= 0.0 {
        return take;
    }
    let weight = |v: &Victim| 1.0 / (v.sensitivity.max(1e-6) * v.improvement.max(1e-6));
    let mut remaining = needed;
    let mut open: Vec<usize> = (0..n).collect();
    // iterate: weighted split, clamp at headroom, redistribute
    for _ in 0..n + 1 {
        if remaining <= 1e-12 || open.is_empty() {
            break;
        }
        let wsum: f64 = open.iter().map(|&i| weight(&victims[i])).sum();
        let mut next_open = Vec::new();
        let mut clamped_any = false;
        for &i in &open {
            let share = remaining * weight(&victims[i]) / wsum;
            let headroom = (victims[i].granted - victims[i].floor - take[i]).max(0.0);
            if share >= headroom {
                take[i] += headroom;
                clamped_any = true;
            } else {
                next_open.push(i);
            }
        }
        let taken: f64 = take.iter().sum();
        remaining = needed - taken;
        open = next_open;
        if !clamped_any {
            // final proportional split among open victims
            let wsum: f64 = open.iter().map(|&i| weight(&victims[i])).sum();
            for &i in &open {
                take[i] += remaining * weight(&victims[i]) / wsum;
            }
            break;
        }
    }
    take
}

/// Sensitivity S^k from throttling observations (§IV-D1):
/// Π_j (TTA_j^k − TTA)/TTA over the throttling experiments of resource k.
pub fn sensitivity_from_throttles(tta_base: f64, tta_throttled: &[f64]) -> f64 {
    let mut s = 1.0;
    for &t in tta_throttled {
        s *= ((t - tta_base) / tta_base).max(1e-3);
    }
    s
}

// ---------------------------------------------------------------------------
// Communication tree (§IV-D2b)
// ---------------------------------------------------------------------------

/// Aggregation tree: `parent[i]` = parent worker of i (usize::MAX = root,
/// i.e. directly attached to the PS/AR-parent).
#[derive(Clone, Debug, PartialEq)]
pub struct CommTree {
    pub parent: Vec<usize>,
    pub branching: usize,
}

pub const ROOT: usize = usize::MAX;

impl CommTree {
    /// Build the §IV-D2b tree: workers sorted by link quality (higher
    /// bandwidth → closer to the root); each layer holds `branching`×
    /// more nodes; children attach to the best-linked node of the layer
    /// above (fewest-children-first for balance).
    pub fn build(bw_to_ps: &[f64], branching: usize) -> CommTree {
        let n = bw_to_ps.len();
        let branching = branching.max(1);
        let mut order: Vec<usize> = (0..n).collect();
        // best bandwidth first
        order.sort_by(|&a, &b| bw_to_ps[b].partial_cmp(&bw_to_ps[a]).unwrap());
        let mut parent = vec![ROOT; n];
        let mut child_count = vec![0usize; n];
        let mut prev_layer: Vec<usize> = Vec::new();
        let mut cur_layer: Vec<usize> = Vec::new();
        let mut root_slots = branching;
        for &w in &order {
            if root_slots > 0 {
                parent[w] = ROOT;
                root_slots -= 1;
                cur_layer.push(w);
                continue;
            }
            // attach to the least-loaded node of the previous layer
            let p = prev_layer
                .iter()
                .copied()
                .min_by_key(|&p| (child_count[p], std::cmp::Reverse((bw_to_ps[p] * 1e6) as u64)))
                .or_else(|| cur_layer.iter().copied().min_by_key(|&p| child_count[p]));
            match p {
                Some(p) if child_count[p] < branching => {
                    parent[w] = p;
                    child_count[p] += 1;
                    cur_layer.push(w);
                }
                _ => {
                    // previous layer full: rotate layers
                    prev_layer = std::mem::take(&mut cur_layer);
                    let p = prev_layer
                        .iter()
                        .copied()
                        .min_by_key(|&p| child_count[p])
                        .expect("nonempty layer");
                    parent[w] = p;
                    child_count[p] += 1;
                    cur_layer.push(w);
                }
            }
            if cur_layer.len() >= prev_layer.len().max(1) * branching && !cur_layer.is_empty() {
                prev_layer = std::mem::take(&mut cur_layer);
            }
        }
        CommTree { parent, branching }
    }

    /// Flat topology: every worker talks to the PS directly.
    pub fn flat(n: usize) -> CommTree {
        CommTree { parent: vec![ROOT; n], branching: usize::MAX }
    }

    pub fn depth_of(&self, mut w: usize) -> usize {
        let mut d = 1;
        let mut guard = 0;
        while self.parent[w] != ROOT {
            w = self.parent[w];
            d += 1;
            guard += 1;
            assert!(guard <= self.parent.len(), "cycle in comm tree");
        }
        d
    }

    pub fn max_depth(&self) -> usize {
        (0..self.parent.len()).map(|w| self.depth_of(w)).max().unwrap_or(0)
    }

    /// Direct PS fan-in (number of roots).
    pub fn root_fanin(&self) -> usize {
        self.parent.iter().filter(|&&p| p == ROOT).count()
    }

    pub fn children_of(&self, p: usize) -> Vec<usize> {
        (0..self.parent.len()).filter(|&w| self.parent[w] == p).collect()
    }

    /// Communication-time factor relative to flat fan-in (used by the
    /// simulator): PS serves `root_fanin` flows instead of N (less PS
    /// contention), while each extra layer adds a pipelined hop cost.
    /// Aggregation is bottom-up and overlapped, so a hop costs a fraction
    /// `hop_overlap` of a full transfer.
    pub fn effective_flows(&self) -> usize {
        self.root_fanin().max(1)
    }

    pub fn hop_penalty(&self, hop_overlap: f64) -> f64 {
        1.0 + hop_overlap * (self.max_depth().saturating_sub(1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equalize_cap_identity_when_already_at_target() {
        assert!((equalize_cap(1.0, 1.0, 0.2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn equalize_cap_slows_proportionally() {
        // T = 0.2 fixed + 0.8 var; target 1.8 => var must become 1.6 => cap 0.5
        let c = equalize_cap(1.0, 1.8, 0.2);
        assert!((c - 0.5).abs() < 1e-12);
    }

    #[test]
    fn equalize_group_into_matches_allocating_variant() {
        let mut rng = crate::simrng::Rng::seeded(7);
        let mut out = Vec::new();
        for _ in 0..100 {
            let n = rng.usize(1, 10);
            let times: Vec<f64> = (0..n).map(|_| rng.range(0.2, 4.0)).collect();
            let fixed: Vec<f64> = times.iter().map(|t| t * rng.range(0.05, 0.6)).collect();
            // buffer carries state from the previous case on purpose
            equalize_group_into(&times, &fixed, &mut out);
            assert_eq!(out, equalize_group(&times, &fixed));
        }
    }

    #[test]
    fn equalize_group_slowest_keeps_full_share() {
        let caps = equalize_group(&[1.0, 2.0, 1.5], &[0.1, 0.1, 0.1]);
        assert!((caps[1] - 1.0).abs() < 1e-12);
        assert!(caps[0] < 1.0 && caps[2] < 1.0);
        assert!(caps[0] < caps[2], "faster worker gives up more");
    }

    #[test]
    fn deprivation_prefers_insensitive_late_stage_jobs() {
        let victims = [
            Victim { sensitivity: 0.9, improvement: 0.9, granted: 10.0, floor: 0.0 },
            Victim { sensitivity: 0.1, improvement: 0.1, granted: 10.0, floor: 0.0 },
        ];
        let take = sensitivity_deprivation(5.0, &victims);
        assert!(take[1] > 10.0 * take[0], "insensitive job pays more: {take:?}");
        assert!((take.iter().sum::<f64>() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn deprivation_respects_headroom() {
        let victims = [
            Victim { sensitivity: 0.1, improvement: 0.1, granted: 2.0, floor: 1.5 },
            Victim { sensitivity: 0.9, improvement: 0.9, granted: 10.0, floor: 0.0 },
        ];
        let take = sensitivity_deprivation(5.0, &victims);
        assert!(take[0] <= 0.5 + 1e-9);
        assert!((take.iter().sum::<f64>() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn deprivation_partial_when_headroom_short() {
        let victims = [Victim { sensitivity: 0.5, improvement: 0.5, granted: 1.0, floor: 0.8 }];
        let take = sensitivity_deprivation(5.0, &victims);
        assert!((take[0] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn deprivation_empty_and_zero() {
        assert!(sensitivity_deprivation(1.0, &[]).is_empty());
        let v = [Victim { sensitivity: 1.0, improvement: 1.0, granted: 5.0, floor: 0.0 }];
        assert_eq!(sensitivity_deprivation(0.0, &v), vec![0.0]);
    }

    #[test]
    fn sensitivity_from_throttles_multiplies() {
        // two throttling runs at +50% and +20% TTA => S = 0.5*0.2 = 0.1
        let s = sensitivity_from_throttles(100.0, &[150.0, 120.0]);
        assert!((s - 0.1).abs() < 1e-9);
    }

    #[test]
    fn flat_tree_all_root() {
        let t = CommTree::flat(5);
        assert_eq!(t.root_fanin(), 5);
        assert_eq!(t.max_depth(), 1);
    }

    #[test]
    fn tree_reduces_root_fanin_and_orders_by_bw() {
        let bw: Vec<f64> = vec![1.0, 9.0, 3.0, 8.0, 2.0, 7.0, 5.0, 4.0];
        let t = CommTree::build(&bw, 2);
        assert_eq!(t.root_fanin(), 2);
        // best-bandwidth workers (1 and 3) sit at the root layer
        assert_eq!(t.parent[1], ROOT);
        assert_eq!(t.parent[3], ROOT);
        // worst-bandwidth worker (0) is at max depth
        assert_eq!(t.depth_of(0), t.max_depth());
        // all reachable, no cycles
        for w in 0..bw.len() {
            assert!(t.depth_of(w) <= bw.len());
        }
    }

    #[test]
    fn tree_respects_branching_bound() {
        let mut rng = crate::simrng::Rng::seeded(3);
        for _ in 0..50 {
            let n = rng.usize(1, 12);
            let b = rng.usize(1, 4);
            let bw: Vec<f64> = (0..n).map(|_| rng.range(0.5, 10.0)).collect();
            let t = CommTree::build(&bw, b);
            for p in 0..n {
                assert!(t.children_of(p).len() <= b, "n={n} b={b}");
            }
            assert!(t.root_fanin() <= b);
            // partition: every node has exactly one parent (by construction)
            let depth_sum: usize = (0..n).map(|w| t.depth_of(w)).sum();
            assert!(depth_sum >= n);
        }
    }

    #[test]
    fn hop_penalty_grows_with_depth() {
        let flat = CommTree::flat(8);
        let deep = CommTree::build(&vec![1.0; 8], 2);
        assert!(deep.max_depth() > flat.max_depth());
        assert!(deep.hop_penalty(0.3) > flat.hop_penalty(0.3));
        assert!(deep.effective_flows() < 8);
    }
}
