//! Console-table + CSV substrate for the experiments harness: every paper
//! table/figure prints through this so output is aligned and also lands in
//! `results/*.csv` for external plotting.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Convenience: format mixed cells.
    pub fn rowf(&mut self, cells: &[Cell]) -> &mut Self {
        self.row(cells.iter().map(|c| c.render()).collect())
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                let _ = write!(out, "{}{}", c, " ".repeat(pad));
                if i + 1 < cells.len() {
                    let _ = write!(out, "  ");
                }
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    pub fn save_csv(&self, path: &Path) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Typed cell for `rowf`.
pub enum Cell {
    S(String),
    I(i64),
    F(f64, usize), // value, decimals
    Pct(f64),      // fraction -> "12.3%"
}

impl Cell {
    /// The exact string the cell prints/saves as — public so sweep cells
    /// can ship pre-rendered rows through the fabric cell protocol and
    /// the dispatcher can rebuild byte-identical tables via [`Table::row`].
    pub fn render(&self) -> String {
        match self {
            Cell::S(s) => s.clone(),
            Cell::I(v) => format!("{v}"),
            Cell::F(v, d) => format!("{:.*}", d, v),
            Cell::Pct(v) => format!("{:.1}%", v * 100.0),
        }
    }
}

pub fn s(v: impl Into<String>) -> Cell {
    Cell::S(v.into())
}

pub fn i(v: i64) -> Cell {
    Cell::I(v)
}

pub fn f(v: f64, d: usize) -> Cell {
    Cell::F(v, d)
}

pub fn pct(v: f64) -> Cell {
    Cell::Pct(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.rowf(&[s("a"), f(1.5, 2)]);
        t.rowf(&[s("longer-name"), i(42)]);
        let out = t.render();
        assert!(out.contains("demo"));
        let lines: Vec<&str> = out.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
        // all rows same width alignment: "value" column starts at same idx
        let hidx = lines[1].find("value").unwrap();
        assert_eq!(lines[3].find("1.50").unwrap(), hidx);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn pct_cell() {
        assert_eq!(Cell::Pct(0.1234).render(), "12.3%");
    }
}
